"""The bench-regression gate's comparison logic (no benchmarks are run —
the smoke runs themselves are exercised by CI's bench-smoke job)."""
from benchmarks.check_regression import (CHURN, DISTRIBUTION, FETCH,
                                         PIPELINE, Check, build_checks)


def test_higher_is_better_band():
    assert Check("m", 100.0, 95.0, True, 0.10).ok          # inside band
    assert not Check("m", 100.0, 85.0, True, 0.10).ok      # regressed
    # abs_limit acts as a floor the band cannot drop below
    c = Check("m", 100.0, 60.0, True, 0.50, abs_limit=65.0)
    assert not c.ok and c.bound == 65.0


def test_lower_is_better_band():
    assert Check("m", 20.0, 22.0, False, 0.15).ok
    assert not Check("m", 20.0, 24.0, False, 0.15).ok
    # hard ceiling wins over a permissive band
    c = Check("m", 39.0, 41.0, False, 0.15, abs_limit=40.0)
    assert not c.ok and c.bound == 40.0


def test_missing_baseline_skips_but_missing_fresh_fails():
    # no committed baseline (the PR introducing a benchmark): skip
    c = Check("m", None, 5.0, True, 0.1)
    assert c.skipped and c.ok and "SKIP" in c.row()
    # baseline exists but the fresh run stopped emitting the metric: the
    # gate must fail, not silently disarm
    c = Check("m", 5.0, None, True, 0.1)
    assert not c.skipped and not c.ok and "missing from the fresh run" \
        in c.row()


def _docs(delta_pct, double_charged, speedup, ready_pct, offload, upstream,
          churn_reduction=27.0, churn_hit=0.34):
    fetch = {
        "delta_redeploy": {
            "archA": {"delta_saved_pct": delta_pct},
            "archB": {"delta_saved_pct": delta_pct},
        },
        "fleet_fetch": {"double_charged_bytes": double_charged},
        "fetch_concurrency": {"8": {"speedup_vs_serial": speedup}},
    }
    pipe = {"avg_ready_reduction_pct": ready_pct}
    dist = {"avg_peer_offload_ratio": offload,
            "avg_upstream_vs_baseline_pct": upstream}
    churn = {"ctr_vs_lru_upstream_reduction_pct": churn_reduction,
             "ctr_hit_rate": churn_hit}
    return {FETCH: fetch, PIPELINE: pipe, DISTRIBUTION: dist, CHURN: churn}


def test_build_checks_pass_and_fail():
    base = _docs(30.0, 0, 3.8, 66.0, 0.79, 20.8)
    good = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5)
    checks = build_checks(base, good)
    assert len(checks) == 8
    assert all(c.ok for c in checks)

    # a fleet that double-charges a single byte fails outright
    bad = _docs(29.0, 1, 3.0, 60.0, 0.78, 21.5)
    assert any(not c.ok for c in build_checks(base, bad))

    # peers never selected: offload collapses, upstream ratio explodes
    collapsed = _docs(29.0, 0, 3.0, 60.0, 0.0, 99.0)
    failed = {c.metric for c in build_checks(base, collapsed) if not c.ok}
    assert any("peer_offload" in m for m in failed)
    assert any("upstream_vs_baseline" in m for m in failed)

    # cheapest-to-restore losing its edge over lru fails the churn gate
    # (the 15% abs floor binds even within the relative band)
    worse = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5, churn_reduction=12.0,
                  churn_hit=0.10)
    failed = {c.metric for c in build_checks(base, worse) if not c.ok}
    assert any("ctr_vs_lru" in m for m in failed)
    assert any("ctr_hit_rate" in m for m in failed)


def test_build_checks_averages_common_archs_only():
    base = _docs(30.0, 0, 3.8, 66.0, 0.79, 20.8)
    fresh = _docs(30.0, 0, 3.8, 66.0, 0.79, 20.8)
    # fresh smoke run covers fewer archs than the committed full baseline
    del fresh[FETCH]["delta_redeploy"]["archB"]
    checks = {c.metric: c for c in build_checks(base, fresh)}
    c = checks[f"{FETCH}:delta_redeploy.avg_delta_saved_pct"]
    assert c.ok and c.baseline == 30.0 and c.fresh == 30.0
