"""The bench-regression gate's comparison logic (no benchmarks are run —
the smoke runs themselves are exercised by CI's bench-smoke job)."""
from benchmarks.check_regression import (CHURN, COLDSTART, CROSSPLATFORM,
                                         DISTRIBUTION, FETCH, HETERO,
                                         INTEGRITY, PIPELINE, PLACEMENT,
                                         SCALE, Check, build_checks)


def test_higher_is_better_band():
    assert Check("m", 100.0, 95.0, True, 0.10).ok          # inside band
    assert not Check("m", 100.0, 85.0, True, 0.10).ok      # regressed
    # abs_limit acts as a floor the band cannot drop below
    c = Check("m", 100.0, 60.0, True, 0.50, abs_limit=65.0)
    assert not c.ok and c.bound == 65.0


def test_lower_is_better_band():
    assert Check("m", 20.0, 22.0, False, 0.15).ok
    assert not Check("m", 20.0, 24.0, False, 0.15).ok
    # hard ceiling wins over a permissive band
    c = Check("m", 39.0, 41.0, False, 0.15, abs_limit=40.0)
    assert not c.ok and c.bound == 40.0


def test_missing_baseline_skips_but_missing_fresh_fails():
    # no committed baseline (the PR introducing a benchmark): skip
    c = Check("m", None, 5.0, True, 0.1)
    assert c.skipped and c.ok and "SKIP" in c.row()
    # baseline exists but the fresh run stopped emitting the metric: the
    # gate must fail, not silently disarm
    c = Check("m", 5.0, None, True, 0.1)
    assert not c.skipped and not c.ok and "missing from the fresh run" \
        in c.row()


def _docs(delta_pct, double_charged, speedup, ready_pct, offload, upstream,
          churn_reduction=27.0, churn_hit=0.34, scale_wall=8.0,
          scale_offload=0.99, identity_ok=1.0, loss_converged=1.0,
          loss_extra=4.0, cold_reduction=76.0, cold_identical=1.0,
          restore_reduction=100.0, p99_ready=20.0, compile_hit=0.95,
          p95_reduction=70.0, wire_overhead=0.0, downtime_ratio=0.01,
          verify_overhead=0.1, corrupt_committed=0, corrupt_rejected=22,
          chaos_identity=1.0, quarantined=1.0, tamper_rejected=1.0,
          wire_reduction=74.0, hetero_identical=1.0, ir_copies=1,
          ir_zero_off=1.0, xp_reduction=99.9, variant_sets=4):
    fetch = {
        "delta_redeploy": {
            "archA": {"delta_saved_pct": delta_pct},
            "archB": {"delta_saved_pct": delta_pct},
        },
        "fleet_fetch": {"double_charged_bytes": double_charged},
        "fetch_concurrency": {"8": {"speedup_vs_serial": speedup}},
    }
    pipe = {"avg_ready_reduction_pct": ready_pct}
    dist = {"avg_peer_offload_ratio": offload,
            "avg_upstream_vs_baseline_pct": upstream}
    churn = {"ctr_vs_lru_upstream_reduction_pct": churn_reduction,
             "ctr_hit_rate": churn_hit}
    scale = {
        "scale": {"wall_s": scale_wall,
                  "peer_offload_ratio": scale_offload},
        "identity": {"ok": identity_ok},
        "faults": {"node_loss": {"converged": loss_converged,
                                 "extra_upstream_pct": loss_extra}},
    }
    cold = {
        "cold_vs_peer": {"ready_reduction_pct": cold_reduction,
                         "accounting_identical": cold_identical},
        "snapshot": {"restore_reduction_pct": restore_reduction},
        "autoscale": {"p99_ready_s": p99_ready,
                      "compile_hit_rate": compile_hit},
    }
    place = {
        "trace": {"p95_ready_reduction_pct": p95_reduction,
                  "speculation_wire_overhead_pct": wire_overhead},
        "migration": {"migration_downtime_ratio": downtime_ratio},
    }
    integ = {
        "overhead": {"verify_overhead_pct": verify_overhead},
        "chaos": {"corrupt_chunks_committed": corrupt_committed,
                  "corrupt_chunks_rejected": corrupt_rejected,
                  "identity_ok": chaos_identity,
                  "quarantined": quarantined},
        "attestation": {"tamper_rejected": tamper_rejected},
    }
    het = {
        "split": {"wire_reduction_pct": wire_reduction,
                  "accounting_identical": hetero_identical},
        "ir_once": {"ir_published_copies": ir_copies},
        "identity": {"ir_columns_zero_when_off": ir_zero_off},
    }
    xp = {"summary": {"avg_reduction_pct": xp_reduction,
                      "distinct_variant_sets": variant_sets}}
    return {FETCH: fetch, PIPELINE: pipe, DISTRIBUTION: dist, CHURN: churn,
            SCALE: scale, COLDSTART: cold, PLACEMENT: place,
            INTEGRITY: integ, HETERO: het, CROSSPLATFORM: xp}


def test_build_checks_pass_and_fail():
    base = _docs(30.0, 0, 3.8, 66.0, 0.79, 20.8)
    good = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5)
    checks = build_checks(base, good)
    assert len(checks) == 33
    assert all(c.ok for c in checks)

    # a fleet that double-charges a single byte fails outright
    bad = _docs(29.0, 1, 3.0, 60.0, 0.78, 21.5)
    assert any(not c.ok for c in build_checks(base, bad))

    # peers never selected: offload collapses, upstream ratio explodes
    collapsed = _docs(29.0, 0, 3.0, 60.0, 0.0, 99.0)
    failed = {c.metric for c in build_checks(base, collapsed) if not c.ok}
    assert any("peer_offload" in m for m in failed)
    assert any("upstream_vs_baseline" in m for m in failed)

    # cheapest-to-restore losing its edge over lru fails the churn gate
    # (the 15% abs floor binds even within the relative band)
    worse = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5, churn_reduction=12.0,
                  churn_hit=0.10)
    failed = {c.metric for c in build_checks(base, worse) if not c.ok}
    assert any("ctr_vs_lru" in m for m in failed)
    assert any("ctr_hit_rate" in m for m in failed)


def test_build_checks_averages_common_archs_only():
    base = _docs(30.0, 0, 3.8, 66.0, 0.79, 20.8)
    fresh = _docs(30.0, 0, 3.8, 66.0, 0.79, 20.8)
    # fresh smoke run covers fewer archs than the committed full baseline
    del fresh[FETCH]["delta_redeploy"]["archB"]
    checks = {c.metric: c for c in build_checks(base, fresh)}
    c = checks[f"{FETCH}:delta_redeploy.avg_delta_saved_pct"]
    assert c.ok and c.baseline == 30.0 and c.fresh == 30.0


def test_scale_gate_binds_on_regressions():
    base = _docs(30.0, 0, 3.8, 66.0, 0.79, 20.8)
    # the 30 s ceiling caps the wall band even off a generous baseline
    slow = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5, scale_wall=31.0)
    failed = {c.metric for c in build_checks(base, slow) if not c.ok}
    assert f"{SCALE}:scale.wall_s" in failed
    # transport accounting drift is a hard failure (identity is 0/1)
    drifted = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5, identity_ok=0.0)
    failed = {c.metric for c in build_checks(base, drifted) if not c.ok}
    assert f"{SCALE}:identity.ok" in failed
    # a fault scenario that stops converging, or whose recovery wire
    # overhead explodes, fails the gate
    diverged = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5, loss_converged=0.0,
                     loss_extra=40.0)
    failed = {c.metric for c in build_checks(base, diverged) if not c.ok}
    assert f"{SCALE}:faults.node_loss.converged" in failed
    assert f"{SCALE}:faults.node_loss.extra_upstream_pct" in failed


def test_coldstart_gate_binds_on_regressions():
    base = _docs(30.0, 0, 3.8, 66.0, 0.79, 20.8)
    # the 60% floor binds even off a generous baseline (cache collapsed:
    # the second cold node re-pays its compile)
    no_cache = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5, cold_reduction=45.0,
                     compile_hit=0.2)
    failed = {c.metric for c in build_checks(base, no_cache) if not c.ok}
    assert f"{COLDSTART}:cold_vs_peer.ready_reduction_pct" in failed
    assert f"{COLDSTART}:autoscale.compile_hit_rate" in failed
    # byte-smuggled compile skips are a hard failure (identity is 0/1)
    smuggled = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5, cold_identical=0.0)
    failed = {c.metric for c in build_checks(base, smuggled) if not c.ok}
    assert f"{COLDSTART}:cold_vs_peer.accounting_identical" in failed
    # restore degrading toward a full rebuild, or p99 cold-READY blowing
    # past the band, fails the gate
    slow = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5, restore_reduction=70.0,
                 p99_ready=30.0)
    failed = {c.metric for c in build_checks(base, slow) if not c.ok}
    assert f"{COLDSTART}:snapshot.restore_reduction_pct" in failed
    assert f"{COLDSTART}:autoscale.p99_ready_s" in failed


def test_placement_gate_binds_on_regressions():
    base = _docs(30.0, 0, 3.8, 66.0, 0.79, 20.8)
    # speculation losing its edge over reactive fetch fails the gate
    # (the 40% abs floor binds even within the relative band)
    collapsed = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5, p95_reduction=35.0)
    failed = {c.metric for c in build_checks(base, collapsed) if not c.ok}
    assert f"{PLACEMENT}:trace.p95_ready_reduction_pct" in failed
    # a planner that starts flooding the WAN registry link fails outright
    # (the committed baseline is 0% overhead: any upstream leak binds)
    flooded = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5, wire_overhead=5.0)
    failed = {c.metric for c in build_checks(base, flooded) if not c.ok}
    assert f"{PLACEMENT}:trace.speculation_wire_overhead_pct" in failed
    # the migration serve gap growing toward a cold re-deploy fails the
    # hard 0.20 ceiling regardless of the baseline band
    gapped = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5, downtime_ratio=0.25)
    failed = {c.metric for c in build_checks(base, gapped) if not c.ok}
    assert f"{PLACEMENT}:migration.migration_downtime_ratio" in failed


def test_integrity_gate_binds_on_regressions():
    base = _docs(30.0, 0, 3.8, 66.0, 0.79, 20.8)
    # the receipt check creeping past the 3% hot-path ceiling fails the
    # gate even off the floored 0.1% baseline (rel 50 → bound is the abs)
    heavy = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5, verify_overhead=3.5)
    failed = {c.metric for c in build_checks(base, heavy) if not c.ok}
    assert f"{INTEGRITY}:overhead.verify_overhead_pct" in failed
    # a single tampered chunk reaching a store is a hard failure, and so
    # is the accounting identity breaking under liars
    leaked = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5, corrupt_committed=1,
                   chaos_identity=0.0)
    failed = {c.metric for c in build_checks(base, leaked) if not c.ok}
    assert f"{INTEGRITY}:chaos.corrupt_chunks_committed" in failed
    assert f"{INTEGRITY}:chaos.identity_ok" in failed
    # a liar that stays in rotation, or a forged attestation that builds
    # anyway, fails outright (both are 0/1)
    trusted = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5, quarantined=0.0,
                    tamper_rejected=0.0)
    failed = {c.metric for c in build_checks(base, trusted) if not c.ok}
    assert f"{INTEGRITY}:chaos.quarantined" in failed
    assert f"{INTEGRITY}:attestation.tamper_rejected" in failed


def test_hetero_gate_binds_on_regressions():
    base = _docs(30.0, 0, 3.8, 66.0, 0.79, 20.8)
    # the split losing its wire edge fails the gate (the 50% abs floor
    # binds even within the relative band), and a second published IR
    # copy means the fleet-wide sharing path collapsed
    dup = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5, wire_reduction=45.0,
                ir_copies=2)
    failed = {c.metric for c in build_checks(base, dup) if not c.ok}
    assert f"{HETERO}:split.wire_reduction_pct" in failed
    assert f"{HETERO}:ir_once.ir_published_copies" in failed
    # byte drift with the feature off, or §13 columns leaking when
    # disabled, is a hard failure (both are 0/1)
    leaky = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5, hetero_identical=0.0,
                  ir_zero_off=0.0)
    failed = {c.metric for c in build_checks(base, leaky) if not c.ok}
    assert f"{HETERO}:split.accounting_identical" in failed
    assert f"{HETERO}:identity.ir_columns_zero_when_off" in failed
    # the §5.3 smoke losing its size-reduction claim, or two platform
    # classes collapsing onto the same variant set, fails the gate
    flat = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5, xp_reduction=55.0,
                 variant_sets=3)
    failed = {c.metric for c in build_checks(base, flat) if not c.ok}
    assert f"{CROSSPLATFORM}:summary.avg_reduction_pct" in failed
    assert f"{CROSSPLATFORM}:summary.distinct_variant_sets" in failed


def test_new_baseline_file_missing_on_old_branch_skips_cleanly():
    """The PR that introduces ``BENCH_scale.json`` runs the gate against
    a base branch that has no such committed baseline: every scale check
    must SKIP (ok), never fail ``--write`` mode — while the other gates
    still bind."""
    base = _docs(30.0, 0, 3.8, 66.0, 0.79, 20.8)
    del base[SCALE]                      # old branch: file never committed
    base[SCALE] = None                   # exactly what _load() returns
    fresh = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5)
    checks = build_checks(base, fresh)
    scale_checks = [c for c in checks if c.metric.startswith(SCALE)]
    assert len(scale_checks) == 5
    assert all(c.skipped and c.ok for c in scale_checks)
    others = [c for c in checks if not c.metric.startswith(SCALE)]
    assert all(not c.skipped for c in others)
    # ... and a fresh run that lost a scale metric (baseline present)
    # still fails rather than silently disarming
    lost = _docs(29.0, 0, 3.0, 60.0, 0.78, 21.5)
    del lost[SCALE]["scale"]["wall_s"]
    full_base = _docs(30.0, 0, 3.8, 66.0, 0.79, 20.8)
    failed = {c.metric for c in build_checks(full_base, lost) if not c.ok}
    assert f"{SCALE}:scale.wall_s" in failed
