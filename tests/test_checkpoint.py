"""Checkpoint manager: atomic round-trip, async, gc, bucket dedup."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state(x=1.0):
    return {"params": {"w": jnp.full((4, 4), x), "b": jnp.zeros((4,))},
            "opt": {"step": jnp.asarray(3, jnp.int32),
                    "m": {"w": jnp.ones((4, 4)) * 0.1}}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(10, _state(2.5), extra={"note": "x"})
    step, state, extra = mgr.restore()
    assert step == 10 and extra["note"] == "x"
    np.testing.assert_allclose(state["params"]["w"], np.full((4, 4), 2.5))
    assert int(state["opt"]["step"]) == 3


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(1, _state(1.0))
    mgr.save(2, _state(2.0))
    mgr.wait()
    step, state, _ = mgr.restore()
    assert step == 2
    np.testing.assert_allclose(state["params"]["w"][0, 0], 2.0)


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(float(s)))
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]
    assert mgr.latest_step() == 4


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5, async_save=False)
    for s in (1, 2, 3):
        mgr.save(s, _state(float(s)))
    step, state, _ = mgr.restore(step=2)
    assert step == 2
    np.testing.assert_allclose(state["params"]["w"][0, 0], 2.0)


def test_unchanged_buckets_hardlink(tmp_path):
    """Component-level sharing for checkpoints: a bucket whose content did
    not change is hard-linked, not rewritten."""
    mgr = CheckpointManager(str(tmp_path), keep=5, async_save=False)
    s = _state(1.0)
    mgr.save(1, s)
    s2 = dict(s)
    s2 = {"params": s["params"],                      # unchanged bucket
          "opt": {"step": jnp.asarray(4, jnp.int32),
                  "m": {"w": jnp.ones((4, 4)) * 0.2}}}
    mgr.save(2, s2)
    st = mgr.sharing_stats()
    assert st["saved_bytes"] > 0
    i1 = os.stat(os.path.join(tmp_path, "step_00000001", "params.npz"))
    i2 = os.stat(os.path.join(tmp_path, "step_00000002", "params.npz"))
    assert i1.st_ino == i2.st_ino


def test_restore_with_shardings(tmp_path, smoke_mesh):
    from jax.sharding import NamedSharding, PartitionSpec
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _state(3.0))
    sh = NamedSharding(smoke_mesh, PartitionSpec())
    shardings = {"params": {"w": sh, "b": sh},
                 "opt": {"step": sh, "m": {"w": sh}}}
    _, state, _ = mgr.restore(shardings=shardings)
    assert state["params"]["w"].sharding == sh
