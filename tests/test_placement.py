"""Demand-driven chunk placement + live migration (docs/cir-format.md §11).

Covers the subsystem's claims: a ``spec:`` soft lease marks content as the
FIRST eviction tier without ever pinning it (priority order under pressure:
spec < warm < build-pin), a real demand hit promotes speculated bytes into
``spec_hit_bytes`` while eviction drains them into ``spec_wasted_bytes``
(hit + wasted <= spec_bytes always), speculative wire lands in dedicated
``NodeTraffic.spec_*`` columns so the ``bytes_total == bytes_delta_fetched``
identity is byte-identical with the planner enabled or disabled, the
``PlacementPlanner`` pre-positions predicted-hot content under per-node
wire budgets, and ``FleetDeployer.migrate`` hands a running instance off
with a serve gap far below a cold re-deploy.
"""
import dataclasses

import pytest

from repro.configs import ARCHS
from repro.core import (ChunkedComponentStore, PreBuilder, SimNetwork,
                        SPEC_LEASE_PREFIX, cpu_smoke, tpu_single_pod)
from repro.core.component import UniformComponent
from repro.core.registry import (UniformComponentRegistry,
                                 UniformComponentService)
from repro.deploy import (DemandModel, FleetDeployer, FleetTopology,
                          PlacementPlanner, speculative_replicate)


def _c(name, version="1.0", env="e", size=8 * 1024, manager="m"):
    return UniformComponent(manager=manager, name=name, version=version,
                            env=env, payload="p", size_bytes=size)


def _commit_speculative(store, comp, lease_id):
    """Land ``comp``'s chunks under a spec lease the way the replication
    executor does: speculative plan, charged fetch, speculative commit."""
    if not store.lease_active(lease_id):
        store.acquire_build_lease(lease_id, [comp])
    plan = store.plan_fetch(comp, speculative=True)
    store.commit_chunks(plan.claimed, component=comp, speculative=True)
    return plan


def _sim_fleet(service, n_edges, edge_capacity_bytes=None):
    """Cloud seed + N edges on the virtual clock (sequential workers, no
    overlap: virtual timings are exact replays)."""
    topo = FleetTopology.edge_fanout(n_edges, cloud_edge_bps=5e8,
                                     edge_edge_bps=1e9,
                                     edge_capacity_bytes=edge_capacity_bytes)
    cloud = tpu_single_pod()
    edges = [dataclasses.replace(cpu_smoke(), platform_id=f"edge-host-{i}")
             for i in range(n_edges)]
    topo.place(cloud.platform_id, "cloud")
    for i, s in enumerate(edges):
        topo.place(s.platform_id, f"edge-{i}")
    net = SimNetwork(topo)
    fd = FleetDeployer(service, topology=topo, simnet=net,
                       max_workers=1, fetch_workers=1, overlap=False)
    return net, fd, cloud, edges


# ---------------------------------------------------------------------------
# Spec soft-lease tier (store level)
# ---------------------------------------------------------------------------

def test_spec_lease_is_first_eviction_tier_and_never_pins():
    """Speculated content is evicted before OLDER ordinary content (the
    tier beats LRU age) and an active spec lease never blocks the pass."""
    s = ChunkedComponentStore(chunk_size=1024, capacity_bytes=16 * 1024)
    ordinary = _c("ordinary")
    s.put(ordinary)                              # oldest — LRU would take it
    spec = _c("spec")
    _commit_speculative(s, spec, f"{SPEC_LEASE_PREFIX}t1")
    assert s.lifecycle_stats.spec_bytes == spec.size_bytes
    assert all(s.chunk_speculative(ch.id) for ch in s.chunks_of(spec))
    s.put(_c("new"))                             # 24 KiB > 16 KiB: evict 8
    assert all(s.has_chunk(ch.id) for ch in s.chunks_of(ordinary))
    assert not any(s.has_chunk(ch.id) for ch in s.chunks_of(spec))
    # the wager lost: every speculated byte drained into spec_wasted
    ls = s.lifecycle_stats
    assert ls.spec_wasted_bytes == spec.size_bytes
    assert ls.spec_hit_bytes == 0
    assert ls.spec_hit_bytes + ls.spec_wasted_bytes <= ls.spec_bytes
    assert ls.pin_denied_evictions == 0          # the lease never pinned
    s.release_build(f"{SPEC_LEASE_PREFIX}t1")    # tolerant after eviction


def test_demand_hit_promotes_spec_bytes_out_of_the_tier():
    s = ChunkedComponentStore(chunk_size=1024, capacity_bytes=1 << 30)
    spec = _c("spec")
    _commit_speculative(s, spec, f"{SPEC_LEASE_PREFIX}t2")
    plan = s.plan_fetch(spec)                    # a REAL build demands it
    assert not plan.claimed                      # all hits — nothing moves
    ls = s.lifecycle_stats
    assert ls.spec_hit_bytes == spec.size_bytes
    assert ls.spec_wasted_bytes == 0
    # promoted: the chunks left the tier (demand overrides the lease) and
    # a later pressure pass treats them as ordinary demand content
    assert not any(s.chunk_speculative(ch.id) for ch in s.chunks_of(spec))


def test_speculative_plan_does_not_promote_or_refresh():
    """A speculative re-plan of already-speculated content must not count
    hits or pull the chunks out of the tier — only real demand does."""
    s = ChunkedComponentStore(chunk_size=1024, capacity_bytes=1 << 30)
    spec = _c("spec")
    _commit_speculative(s, spec, f"{SPEC_LEASE_PREFIX}t3")
    plan = s.plan_fetch(spec, speculative=True)
    assert not plan.claimed and not plan.component_new
    assert s.lifecycle_stats.spec_hit_bytes == 0
    assert all(s.chunk_speculative(ch.id) for ch in s.chunks_of(spec))


def test_speculative_replicate_validates_lease_and_budget():
    svc = UniformComponentService(UniformComponentRegistry())
    s = ChunkedComponentStore(chunk_size=1024)
    a, b = _c("a"), _c("b")
    with pytest.raises(ValueError, match="spec"):
        speculative_replicate(s, [a], "warm:not-a-spec-lease", service=svc)
    # the budget cuts mid-component (digest order decides which one): the
    # over-budget claims are released, not queued
    budget = a.size_bytes + b.size_bytes // 2
    st = speculative_replicate(s, [a, b], f"{SPEC_LEASE_PREFIX}budget",
                               service=svc, budget_bytes=budget)
    assert st.bytes_fetched == budget
    assert st.budget_denied_bytes == budget - a.size_bytes
    assert s.lifecycle_stats.spec_bytes == budget
    # nothing claimed was leaked: a re-plan can claim exactly the remainder
    assert sum(len(s.plan_fetch(c, speculative=True).claimed)
               for c in (a, b)) == 4


def test_demand_model_ewma_decay_and_oracle_window():
    dm = DemandModel(halflife_s=100.0, horizon_s=50.0,
                     oracle=[(200.0, "n1", "k"), (999.0, "n1", "k")])
    dm.observe("n0", "k", now=0.0)
    assert dm.predict(0.0)[("n0", "k")] == pytest.approx(1.0)
    assert dm.predict(100.0)[("n0", "k")] == pytest.approx(0.5)
    # the oracle event at t=200 scores only within [150, 200) + EWMA decay
    assert ("n1", "k") not in dm.predict(100.0)
    assert dm.predict(160.0)[("n1", "k")] == pytest.approx(1.0)
    assert ("n1", "k") not in dm.predict(201.0)


# ---------------------------------------------------------------------------
# Planner over a simulated fleet
# ---------------------------------------------------------------------------

def test_planner_prepositions_predicted_hot_edge(service):
    """An oracle-predicted edge gets the content ahead of demand: its
    deploy is near-free vs the reactive edge, all speculative wire lands
    in the spec columns, and the demand identity is untouched."""
    pb = PreBuilder(service)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    net, fd, cloud, edges = _sim_fleet(service, 2)
    assert fd.deploy(cir, [cloud]).ok
    r0 = fd.deploy(cir, [edges[0]])              # reactive cold edge
    assert r0.ok
    assert r0.bytes_speculative == 0             # no planner attached yet

    oracle = [(net.now + 1.0, "edge-1", cir.digest())]
    planner = PlacementPlanner(
        fd, demand=DemandModel(horizon_s=600.0, oracle=oracle),
        wire_budget_bytes=1 << 40)
    planner.register(cir.digest(),
                     list(r0.deployments[0].instance.bundle.components()))
    orders = planner.plan()
    assert [o.node_id for o in orders] == ["edge-1"]
    assert orders[0].est_bytes > 0 and orders[0].est_transfer_s > 0
    st = planner.run_round()
    assert st.orders_executed == 1 and st.bytes_fetched > 0
    assert planner.plan() == []                  # now fully resident

    r1 = fd.deploy(cir, [edges[1]])
    assert r1.ok
    assert r1.sim_elapsed_s < 0.5 * r0.sim_elapsed_s
    # every speculated byte was demanded: hit == speculated, wasted == 0
    assert r1.bytes_speculative == st.bytes_fetched
    assert r1.speculation_hit_bytes == st.bytes_fetched
    assert r1.speculation_wasted_bytes == 0
    assert r1.bytes_speculative == \
        r1.bytes_speculative_peer + r1.bytes_speculative_upstream
    assert "speculation:" in r1.summary()
    # identity: speculative wire never leaks into the demand columns
    for d in r1.deployments:
        t = r1.node_traffic[d.node_id]
        assert t.bytes_total == d.report.bytes_delta_fetched
        assert d.report.bytes_delta_fetched <= d.report.bytes_fetched
    # the planner's lease releases cleanly once the content went demand
    assert planner.release_all() >= 1


def test_planner_wire_budget_bounds_each_round(service):
    pb = PreBuilder(service)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    net, fd, cloud, edges = _sim_fleet(service, 2)
    assert fd.deploy(cir, [cloud]).ok
    r0 = fd.deploy(cir, [edges[0]])
    budget = 32 * 2**20
    planner = PlacementPlanner(
        fd, demand=DemandModel(horizon_s=600.0,
                               oracle=[(net.now + 1.0, "edge-1",
                                        cir.digest())]),
        wire_budget_bytes=budget)
    planner.register(cir.digest(),
                     list(r0.deployments[0].instance.bundle.components()))
    st = planner.run_round()
    assert 0 < st.bytes_fetched <= budget
    assert st.budget_denied_bytes > 0
    # successive rounds make progress under the same cap until resident
    st2 = planner.run_round()
    assert 0 < st2.bytes_fetched <= budget


def test_deploys_feed_the_planner_demand_model(service):
    """Every successful topology deploy is an EWMA observation — after a
    capacity eviction the planner re-positions the node it saw deploy."""
    pb = PreBuilder(service)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    net, fd, cloud, edges = _sim_fleet(service, 1)
    planner = PlacementPlanner(fd)
    assert fd.deploy(cir, [cloud]).ok
    assert fd.deploy(cir, [edges[0]]).ok
    assert (("edge-0", cir.digest())
            in planner.demand.predict(planner.now()))
    assert planner.plan() == []                  # resident: nothing to do
    # drop some of edge-0's content; the planner now has work there
    store = fd.node_store("edge-0")
    victim = next(iter(store._chunk_present))
    with store._lock:
        store._drop_chunks_locked([victim])
    orders = planner.plan()
    assert [o.node_id for o in orders] == ["edge-0"]


def test_existing_columns_byte_identical_with_planner_disabled(service):
    """Satellite: attaching an idle planner must not move a single byte of
    the existing FleetResult columns, and the no-planner summary carries
    no speculation/migration lines."""
    pb = PreBuilder(service)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    cols = ("bytes_fetched_total", "bytes_delta_total", "bytes_upstream_total",
            "bytes_peer_total", "chunks_hit_total", "chunks_missed_total",
            "evicted_bytes_total", "refetch_bytes_total", "sharing_rate",
            "plan_cache_hits")
    seen = {}
    for attach in (False, True):
        net, fd, cloud, edges = _sim_fleet(service, 2)
        if attach:
            PlacementPlanner(fd)                 # attached, never run
        rs = [fd.deploy(cir, [cloud]), fd.deploy(cir, edges)]
        assert all(r.ok for r in rs)
        seen[attach] = [tuple(getattr(r, c) for c in cols) for r in rs]
        for r in rs:
            assert r.bytes_speculative == 0
            assert r.migrations_total == 0
            assert "speculation:" not in r.summary()
            assert "migrations:" not in r.summary()
            for t in r.node_traffic.values():
                assert t.spec_bytes_total == 0
    assert seen[True] == seen[False]


# ---------------------------------------------------------------------------
# Live migration
# ---------------------------------------------------------------------------

def test_migrate_hands_off_with_prefetch_outside_the_gap(service):
    pb = PreBuilder(service)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    net, fd, cloud, edges = _sim_fleet(service, 2)
    assert fd.deploy(cir, [cloud]).ok
    r0 = fd.deploy(cir, [edges[0]], assemble=True, compile_steps=True)
    assert r0.ok, r0.summary()
    inst = r0.deployments[0].instance
    with pytest.raises(ValueError, match="already runs"):
        fd.migrate(inst, "edge-0")
    with pytest.raises(ValueError, match="unknown target"):
        fd.migrate(inst, "edge-99")

    rep = fd.migrate(inst, "edge-1")             # edge-1 is cold
    assert rep.source_node == "edge-0" and rep.target_node == "edge-1"
    assert rep.prefetch_bytes > 0                # bytes moved BEFORE the gap
    assert rep.downtime_s < rep.prefetch_s       # the gap is the cheap part
    assert rep.compile_cache_hit                 # no re-compile in the gap
    assert rep.instance.stage == "complete"
    # placement flipped: the platform now routes to the target node
    assert fd.topology.node_for(inst.spec.platform_id) == "edge-1"
    # decommission: the source's ads are gone, the target's survive, and
    # the source's idle copy sits in the spec tier (first-evictable)
    src_store, tgt_store = fd.node_store("edge-0"), fd.node_store("edge-1")
    comps = list(inst.bundle.components())
    for c in comps:
        for ch in src_store.chunks_of(c):
            holders = fd.peer_index.holders(ch.id)
            assert "edge-0" not in holders
            if tgt_store.has_chunk(ch.id):
                assert "edge-1" in holders
    assert any(src_store.chunk_speculative(ch.id)
               for c in comps for ch in src_store.chunks_of(c))
    # no lease leaked on either side beyond the retirement spec lease
    assert src_store.pinned_digests() == set()
    assert tgt_store.pinned_digests() == set()
    # the next deploy reports the hand-off in the migration columns
    r2 = fd.deploy(cir, [cloud])
    assert r2.migrations_total == 1
    assert r2.migration_downtime_s == pytest.approx(rep.downtime_s)
    assert "migrations: 1 hand-off(s)" in r2.summary()


def test_migrate_requires_topology_mode(service):
    fd = FleetDeployer(service)
    with pytest.raises(ValueError, match="topology"):
        fd.migrate(object(), "edge-0")
