"""Trust & integrity at fleet scale (docs/cir-format.md §12).

Covers the integrity subsystem's claims end to end: canonical manifest
attestation (sign at pre-build, verify at plan time, hard-fail before any
fetch), SBOM emission from the resolved closure, verify-on-receipt for
peer transfers (corrupt-stripe retraction + upstream re-source), the
strike/decay ``Quarantine``, and the headline chaos invariant — byzantine
peers cannot corrupt a build and cannot break the delta-byte accounting
identity.
"""
import dataclasses
import json

import pytest

from repro.configs import ARCHS
from repro.core import (Attestation, AttestationError, ED25519_AVAILABLE,
                        Ed25519Signer, HMACSigner, LazyBuilder, PreBuilder,
                        SimNetwork, attest, canonical_manifest, cpu_smoke,
                        make_sbom, tpu_single_pod, verify_attestation)
from repro.deploy import (ChunkIntegrityError, FleetDeployer, FleetTopology,
                          PeerIndex, Quarantine)


@pytest.fixture
def pb(service):
    return PreBuilder(service)


def _fanout(n_edges=2):
    """1 cloud seed + N edges, all linked (test_topology's shape)."""
    topo = FleetTopology.edge_fanout(n_edges, cloud_edge_bps=200e6,
                                     edge_edge_bps=100e6)
    cloud = tpu_single_pod()
    edges = [dataclasses.replace(cpu_smoke(), platform_id=f"edge-host-{i}")
             for i in range(n_edges)]
    topo.place(cloud.platform_id, "cloud")
    for i, s in enumerate(edges):
        topo.place(s.platform_id, f"edge-{i}")
    return topo, cloud, edges


def _smoke_cir(pb, arch="phi4-mini-3.8b", entrypoint="train"):
    return pb.prebuild(ARCHS[arch].reduced(), entrypoint=entrypoint)


# ---------------------------------------------------------------------------
# Attestation: canonical payload, sign/verify, tamper rejection
# ---------------------------------------------------------------------------

def test_canonical_manifest_is_deterministic(service, pb, cpu_spec):
    cir = _smoke_cir(pb)
    builder = LazyBuilder(service, signer=HMACSigner(b"s3cret"))
    inst = builder.build(cir, cpu_spec)
    p1 = canonical_manifest(cir, inst.lock)
    p2 = canonical_manifest(cir, inst.lock)
    assert p1 == p2
    # the payload covers the lock verbatim: any pin change reshapes it
    tampered = dataclasses.replace(
        inst.lock, pins=tuple(list(inst.lock.pins[:-1])))
    assert canonical_manifest(cir, tampered) != p1


def test_attestation_roundtrip_and_envelope_json(service, pb, cpu_spec):
    cir = _smoke_cir(pb)
    signer = HMACSigner(b"fleet-secret", key_id="k1")
    builder = LazyBuilder(service, signer=signer)
    inst = builder.build(cir, cpu_spec)
    att = builder.attest(inst)
    assert att.algorithm == "hmac-sha256" and att.key_id == "k1"
    # verify from a fresh envelope (the JSON wire form)
    att2 = Attestation.from_json(att.to_json())
    verify_attestation(cir, inst.lock, att2, signer)
    with pytest.raises(AttestationError):
        Attestation.from_json('{"not": "an envelope"}')


def test_attestation_tamper_rejected(service, pb, cpu_spec):
    cir = _smoke_cir(pb)
    signer = HMACSigner(b"fleet-secret")
    builder = LazyBuilder(service, signer=signer)
    inst = builder.build(cir, cpu_spec)
    att = builder.attest(inst)
    # forged signature
    with pytest.raises(AttestationError, match="signature"):
        verify_attestation(cir, inst.lock,
                           dataclasses.replace(att, signature="00" * 32),
                           signer)
    # tampered lock (a different pin set) -> digest mismatch, not signature
    tampered_lock = dataclasses.replace(
        inst.lock, pins=inst.lock.pins[:-1], digests=inst.lock.digests[:-1])
    with pytest.raises(AttestationError, match="digest mismatch"):
        verify_attestation(cir, tampered_lock, att, signer)
    # wrong secret on the verifier side
    with pytest.raises(AttestationError):
        verify_attestation(cir, inst.lock, att, HMACSigner(b"wrong"))
    # wrong envelope version fails closed
    with pytest.raises(AttestationError, match="version"):
        verify_attestation(cir, inst.lock,
                           dataclasses.replace(att, version=99), signer)


def test_build_verifies_attestation_before_any_fetch(service, pb, cpu_spec):
    """The hard-fail path: a required-but-missing or invalid attestation
    stops the build at plan time — zero chunks fetched, zero bytes on the
    wire."""
    cir = _smoke_cir(pb)
    signer = HMACSigner(b"fleet-secret")
    builder = LazyBuilder(service, signer=signer, require_attestation=True)
    served_before = service.bytes_served
    with pytest.raises(AttestationError, match="refusing to schedule fetch"):
        builder.build(cir, cpu_spec)
    assert service.bytes_served == served_before        # nothing fetched
    assert len(builder.store.digests()) == 0            # nothing landed

    # the legitimate flow: attest on one (pre-build side) builder, verify
    # + build on the enforcing one
    minting = LazyBuilder(service, signer=signer)
    inst0 = minting.build(cir, cpu_spec)
    att = minting.attest(inst0)
    inst = builder.build(cir, cpu_spec, attestation=att)
    assert inst.report.attestation_verified
    inst_locked = builder.build_from_lock(cir, inst0.lock, cpu_spec,
                                          attestation=att)
    assert inst_locked.report.attestation_verified


def test_builder_without_signer_rejects_supplied_attestation(
        service, pb, cpu_spec):
    cir = _smoke_cir(pb)
    minting = LazyBuilder(service, signer=HMACSigner(b"s"))
    inst = minting.build(cir, cpu_spec)
    att = minting.attest(inst)
    unsigned = LazyBuilder(service)
    with pytest.raises(AttestationError, match="no signer"):
        unsigned.build_from_lock(cir, inst.lock, cpu_spec, attestation=att)
    with pytest.raises(ValueError):
        LazyBuilder(service, require_attestation=True)  # needs a signer


@pytest.mark.skipif(not ED25519_AVAILABLE,
                    reason="optional 'cryptography' backend not installed")
def test_ed25519_signer_roundtrip(service, pb, cpu_spec):
    cir = _smoke_cir(pb)
    signer = Ed25519Signer()
    builder = LazyBuilder(service, signer=signer)
    inst = builder.build(cir, cpu_spec)
    att = builder.attest(inst)
    assert att.algorithm == "ed25519"
    verify_attestation(cir, inst.lock, att, signer)
    with pytest.raises(AttestationError):
        verify_attestation(
            cir, inst.lock,
            dataclasses.replace(att, signature="00" * 64), signer)


def test_ed25519_unavailable_raises_cleanly():
    if ED25519_AVAILABLE:
        pytest.skip("backend present — the gate is exercised elsewhere")
    with pytest.raises(RuntimeError, match="cryptography"):
        Ed25519Signer()


# ---------------------------------------------------------------------------
# SBOM emission
# ---------------------------------------------------------------------------

def test_sbom_shape_and_determinism(service, pb, cpu_spec):
    cir = _smoke_cir(pb)
    builder = LazyBuilder(service)
    inst = builder.build(cir, cpu_spec)
    sbom = builder.sbom(inst)
    assert sbom["bomFormat"] == "CycloneDX"
    assert sbom["specVersion"] == "1.5"
    assert sbom["serialNumber"] == f"urn:cir:lock:{inst.lock.digest()}"
    meta = sbom["metadata"]["component"]
    assert meta["name"] == cir.name and meta["bom-ref"] == cir.digest()
    # one record per resolved component, canonically sorted
    comps = sbom["components"]
    assert len(comps) == len(inst.bundle.components())
    keys = [(c["group"], c["name"], c["version"]) for c in comps]
    assert keys == sorted(keys)
    by_digest = {c.digest(): c for c in inst.bundle.components()}
    for rec in comps:
        c = by_digest[rec["bom-ref"]]
        assert rec["group"] == c.manager and rec["version"] == c.version
        assert rec["hashes"] == [{"alg": "SHA-256", "content": c.digest()}]
        props = {p["name"]: p["value"] for p in rec["properties"]}
        assert int(props["cir:sizeBytes"]) == c.size_bytes
        # chunk counts come from the builder's chunk store
        assert int(props["cir:chunkCount"]) == \
            len(builder.store.chunks_of(c))
    # deterministic: same build -> byte-identical document
    assert json.dumps(builder.sbom(inst), sort_keys=True) == \
        json.dumps(sbom, sort_keys=True)


def test_sbom_without_chunk_store_defaults_to_zero_counts(
        service, pb, cpu_spec):
    cir = _smoke_cir(pb)
    builder = LazyBuilder(service)
    inst = builder.build(cir, cpu_spec)
    sbom = make_sbom(cir, inst.lock, inst.bundle.resolution)
    for rec in sbom["components"]:
        props = {p["name"]: p["value"] for p in rec["properties"]}
        assert props["cir:chunkCount"] == "0"


# ---------------------------------------------------------------------------
# Quarantine: threshold + decay (fake clock — no sleeping)
# ---------------------------------------------------------------------------

def test_quarantine_threshold_and_decay():
    t = [0.0]
    q = Quarantine(threshold=3, decay_s=100.0, clock=lambda: t[0])
    assert not q.record_corruption("liar")
    assert not q.record_corruption("liar")
    assert not q.is_quarantined("liar")
    assert q.record_corruption("liar")            # third strike crosses
    assert q.is_quarantined("liar")
    assert q.active() == {"liar"}
    assert q.quarantined_at["liar"] == 0.0
    # strikes age out: past the decay window the node is readmitted
    t[0] = 100.1
    assert not q.is_quarantined("liar")
    assert q.active() == set()
    assert q.strikes("liar") == 0
    # ...but quarantined_at keeps the convergence record
    assert "liar" in q.quarantined_at
    # an honest node never quarantines
    assert not q.is_quarantined("honest")
    with pytest.raises(ValueError):
        Quarantine(threshold=0)


def test_quarantined_holder_never_selected():
    """best_many refuses a blacklisted source; holders()/holders_many()
    stay unfiltered (the eviction oracle asks existence, not pullability).
    """
    q = Quarantine(threshold=1, clock=lambda: 0.0)
    idx = PeerIndex(quarantine=q)
    idx.announce("liar", ["c1"])
    idx.announce("honest", ["c1"])
    link_bps = {"liar": 1e9, "honest": 1e6}   # the liar has the fat link
    assert idx.best_many(["c1"], link_bps, exclude="me") == {"c1": "liar"}
    q.record_corruption("liar")
    assert idx.best_many(["c1"], link_bps, exclude="me") == {"c1": "honest"}
    assert set(idx.holders("c1")) == {"honest", "liar"}  # unfiltered


# ---------------------------------------------------------------------------
# Verify-on-receipt: corrupt-stripe retraction + re-source
# ---------------------------------------------------------------------------

def _byzantine_fleet(service, n_edges, liars, simnet=True):
    topo, cloud, edges = _fanout(n_edges)
    net = SimNetwork(topo) if simnet else None
    fleet = FleetDeployer(service, topology=topo, simnet=net)
    fleet.mark_byzantine(liars)
    return fleet, topo, cloud, edges


def test_corrupt_stripe_retracts_resources_and_strikes(service, pb):
    """One lying edge: the honest neighbour detects every corrupt stripe,
    retracts the liar, re-sources (peers/upstream), and the build commits
    zero corrupt chunks."""
    fleet, topo, cloud, edges = _byzantine_fleet(service, 2, [])
    cir = _smoke_cir(pb)
    r1 = fleet.deploy(cir, [cloud, edges[0]])
    assert r1.ok and r1.corrupt_chunks_total == 0
    fleet.mark_byzantine(["edge-0"])
    r2 = fleet.deploy(cir, [edges[1]])
    assert r2.ok
    assert r2.corrupt_chunks_total > 0        # the liar was caught lying
    assert r2.corrupt_bytes_total > 0
    assert r2.peer_fallbacks_total > 0        # ...and re-sourced
    # corrupt chunks were never committed: the target store holds exactly
    # the content its build planned, all stores accounted the rejection
    st = fleet.node_store("edge-1")
    assert st.chunk_stats.corrupt_rejected == r2.corrupt_chunks_total
    # strikes landed against the liar
    assert fleet.quarantine.strikes("edge-0") > 0
    # the liar's advertisements were retracted for the failed stripes
    rep = r2.deployments[0].report
    assert rep.bytes_delta_fetched == \
        r2.node_traffic["edge-1"].bytes_total


def test_byzantine_peers_cannot_break_accounting_identity(service, pb):
    """The headline chaos invariant at K=25% liars: every build converges,
    zero corrupt chunks commit, and per-node bytes_total still equals the
    builds' bytes_delta_fetched sum (corrupt bytes are discarded, never
    double-counted)."""
    fleet, topo, cloud, edges = _byzantine_fleet(service, 4, [])
    cir = _smoke_cir(pb)
    r1 = fleet.deploy(cir, [cloud, edges[0]])
    assert r1.ok
    fleet.mark_byzantine(["edge-0"])          # 1 of 4 edges lies: 25%
    r2 = fleet.deploy(cir, edges[1:])
    assert r2.ok and r2.n_failed == 0
    assert r2.corrupt_chunks_total > 0
    # delta <= fetched per build, and the fleet identity holds exactly
    for d in r2.deployments:
        assert d.report.bytes_delta_fetched <= d.report.bytes_fetched
    assert sum(t.bytes_total for t in r2.node_traffic.values()) == \
        r2.bytes_delta_total
    # corrupt bytes are a separate column, not part of the peer bytes
    assert r2.corrupt_bytes_total > 0
    assert r2.bytes_peer_total + r2.bytes_upstream_total == \
        r2.bytes_delta_total
    # the liar converged to fleet-wide quarantine...
    assert r2.quarantined_nodes == ["edge-0"]
    assert fleet.quarantine.quarantined_at["edge-0"] > 0.0
    # ...and a fresh deploy no longer touches it at all
    extra = dataclasses.replace(cpu_smoke(), platform_id="edge-host-extra")
    topo.place(extra.platform_id, "edge-1")
    r3 = fleet.deploy(cir, [extra])
    assert r3.ok and r3.corrupt_chunks_total == 0
    assert "edge-0" not in r3.node_traffic["edge-1"].peer_sources


def test_verify_receipts_off_restores_trusting_behaviour(service, pb):
    """verify_receipts=False is the pre-§12 fleet: the tamper hook never
    runs, nothing is rejected (the opt-out that shows the default is the
    protection)."""
    topo, cloud, edges = _fanout(2)
    fleet = FleetDeployer(service, topology=topo, verify_receipts=False)
    cir = _smoke_cir(pb)
    r1 = fleet.deploy(cir, [cloud, edges[0]])
    assert r1.ok
    fleet.mark_byzantine(["edge-0"])
    r2 = fleet.deploy(cir, [edges[1]])
    assert r2.ok
    assert r2.corrupt_chunks_total == 0       # nobody checked
    assert r2.quarantined_nodes == []


def test_chunk_integrity_error_is_peer_transfer_error():
    e = ChunkIntegrityError("liar", ["c1", "c2"], 128)
    from repro.deploy import PeerTransferError
    assert isinstance(e, PeerTransferError)
    assert e.src == "liar" and e.corrupt_ids == ["c1", "c2"]
    assert e.corrupt_bytes == 128
