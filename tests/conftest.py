import jax
import pytest

# Smoke tests and benches must see the real single-CPU device; ONLY the
# dry-run (a subprocess) forces 512 host devices.


@pytest.fixture(scope="session")
def service():
    from repro.core import catalog
    return catalog.build_service()


@pytest.fixture(scope="session")
def smoke_mesh():
    from repro.launch.mesh import make_smoke_mesh
    return make_smoke_mesh(1)


@pytest.fixture(scope="session")
def cpu_spec():
    from repro.core import cpu_smoke
    return cpu_smoke()
