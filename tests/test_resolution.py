"""Algorithm 2 (uniform dependency resolution): BFS tree, reuse, context
flow, conflict-driven learning, determinism (property-based)."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip individually without hypothesis
    from _hypothesis_stub import given, settings, st

from repro.core.component import DependencyItem as D
from repro.core.component import UniformComponent as C
from repro.core.registry import (UniformComponentRegistry,
                                 UniformComponentService)
from repro.core.resolution import (ResolutionError,
                                   uniform_dependency_resolution)


def _svc(components):
    reg = UniformComponentRegistry()
    reg.register_all(components)
    return UniformComponentService(reg)


def _c(mgr, name, version, deps=(), env="generic", context=None, size=10):
    return C(manager=mgr, name=name, version=version, env=env,
             deps=tuple(D(*d) for d in deps),
             context=dict(context or {}), payload="p", size_bytes=size)


def test_bfs_and_reuse():
    svc = _svc([
        _c("app", "a", "1.0", deps=[("lib", "x", ">=1.0"),
                                    ("lib", "y", "any")]),
        _c("lib", "x", "1.5", deps=[("lib", "z", "any")]),
        _c("lib", "y", "1.0", deps=[("lib", "z", "any")]),
        _c("lib", "z", "3.0"),
    ])
    res = uniform_dependency_resolution([D("app", "a", "any")], svc, {})
    names = [(c.manager, c.name) for c in res.components]
    assert names == [("app", "a"), ("lib", "x"), ("lib", "y"), ("lib", "z")]
    # z appears once in L even though both x and y depend on it
    assert len([n for n in names if n == ("lib", "z")]) == 1
    # the explain tree marks the second z node reused
    assert "(reused)" in res.explain()


def test_conflict_learning_restarts_converge():
    """a needs x==2.*; b needs x<2 — per-BFS a pins x=2.0 first, then b's
    spec conflicts; impossible overall → ResolutionError.  But if a accepts
    x 1.x too (>=1), learning '<2' must converge to x=1.9."""
    svc = _svc([
        _c("app", "a", "1.0", deps=[("lib", "x", ">=1")]),
        _c("app", "b", "1.0", deps=[("lib", "x", "<2")]),
        _c("lib", "x", "1.9"),
        _c("lib", "x", "2.0"),
    ])
    res = uniform_dependency_resolution(
        [D("app", "a", "any"), D("app", "b", "any")], svc, {})
    x = [c for c in res.components if c.name == "x"]
    assert len(x) == 1 and x[0].version == "1.9"
    assert res.restarts >= 1


def test_unsatisfiable_conflict_raises():
    svc = _svc([
        _c("app", "a", "1.0", deps=[("lib", "x", ">=2")]),
        _c("app", "b", "1.0", deps=[("lib", "x", "<2")]),
        _c("lib", "x", "1.9"),
        _c("lib", "x", "2.0"),
    ])
    with pytest.raises(ResolutionError):
        uniform_dependency_resolution(
            [D("app", "a", "any"), D("app", "b", "any")], svc, {})


def test_context_flows_across_managers():
    """The paper's cross-manager mechanism: component context feeds later
    selections through registered getSpec hooks."""
    from repro.core.resolution import register_context_spec_hook
    svc = _svc([
        _c("model", "m", "1.0", deps=[("kernel", "k", "any")],
           context={"api": "1"}),
        _c("kernel", "k", "1.5"),
        _c("kernel", "k", "2.0"),
    ])
    register_context_spec_hook(
        "kernel", lambda name, ctx: f"~={ctx['api']}.0" if "api" in ctx
        else None)
    try:
        res = uniform_dependency_resolution([D("model", "m", "any")], svc, {})
        k = [c for c in res.components if c.name == "k"][0]
        assert k.version == "1.5"     # pinned to 1.x by the model's context
    finally:
        register_context_spec_hook("kernel", lambda name, ctx: None)


def test_context_clash_is_conflict():
    svc = _svc([
        _c("app", "a", "1.0", context={"flag": 1}),
        _c("app", "b", "1.0", context={"flag": 2}),
    ])
    with pytest.raises(ResolutionError):
        uniform_dependency_resolution(
            [D("app", "a", "any"), D("app", "b", "any")], svc, {},
            max_restarts=2)


# ---------------------------------------------------------------------------
# Property: determinism — identical inputs → identical pins (paper §3.3)
# ---------------------------------------------------------------------------

@st.composite
def _random_registry(draw):
    n_libs = draw(st.integers(1, 4))
    comps = []
    lib_names = [f"l{i}" for i in range(n_libs)]
    for ln in lib_names:
        for v in draw(st.lists(st.sampled_from(
                ["1.0", "1.5", "2.0", "2.5"]), min_size=1, max_size=3,
                unique=True)):
            comps.append(_c("lib", ln, v))
    n_apps = draw(st.integers(1, 3))
    deps = []
    for i in range(n_apps):
        sub = draw(st.lists(st.sampled_from(lib_names), min_size=0,
                            max_size=2, unique=True))
        spec = draw(st.sampled_from(["any", ">=1.0", "<2.5", "~=1.0"]))
        comps.append(_c("app", f"a{i}", "1.0",
                        deps=[("lib", s, spec) for s in sub]))
        deps.append(D("app", f"a{i}", "any"))
    return comps, deps


@given(_random_registry())
@settings(max_examples=60, deadline=None)
def test_resolution_is_deterministic(reg_and_deps):
    comps, deps = reg_and_deps
    try:
        r1 = uniform_dependency_resolution(deps, _svc(comps), {})
        r2 = uniform_dependency_resolution(deps, _svc(comps), {})
    except ResolutionError:
        # unsatisfiable is an acceptable outcome; determinism of the error
        with pytest.raises(ResolutionError):
            uniform_dependency_resolution(deps, _svc(comps), {})
        return
    assert [c.ident() for c in r1.components] == \
        [c.ident() for c in r2.components]


@given(_random_registry())
@settings(max_examples=60, deadline=None)
def test_resolution_closure_and_spec_satisfaction(reg_and_deps):
    """Every resolved component's deps are satisfied by the component list
    (L is a closed, consistent set)."""
    from repro.core.component import Specifier, Version
    comps, deps = reg_and_deps
    try:
        res = uniform_dependency_resolution(deps, _svc(comps), {})
    except ResolutionError:
        return
    by_key = {(c.manager, c.name): c for c in res.components}
    for c in res.components:
        for d in c.deps:
            assert d.key() in by_key
            assert Specifier(d.specifier).matches(
                Version.parse(by_key[d.key()].version))
