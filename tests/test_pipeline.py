"""Pipeline-parallel combinator: numeric equivalence vs sequential layers.

Needs 4 devices → subprocess with forced host device count (slow)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.pipeline import pipeline_apply, bubble_fraction
    from repro.launch.mesh import _make_mesh

    mesh = _make_mesh((4,), ("model",))
    S, LPS, B, D = 4, 2, 8, 16
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (S, LPS, D, D)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (B, D))

    def layer_fn(w, h):
        return jnp.tanh(h @ w)

    with mesh:
        out = pipeline_apply(layer_fn, ws, x, mesh=mesh, axis="model",
                             microbatches=4)

    ref = x
    for s in range(S):
        for l in range(LPS):
            ref = layer_fn(ws[s, l], ref)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    assert abs(bubble_fraction(4, 4) - 3/7) < 1e-9
    print("PIPELINE-OK")
""")


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "PIPELINE-OK" in r.stdout


def test_pipeline_component_registered(service):
    vs = service.vq("parallel", "pipeline")
    assert vs == ["1.0.0"]
    c = service.cq("parallel", "pipeline", "1.0.0", "gpipe")
    assert c.requires[0].key == "workload"
