"""Data pipeline: stateless determinism, host sharding, label alignment."""
import numpy as np

from repro.data import DataConfig, SyntheticPipeline, batch_for_arch
from repro.configs import ARCHS


def _pipe(**kw):
    d = dict(vocab=1000, seq_len=64, global_batch=8, seed=3)
    d.update(kw)
    return SyntheticPipeline(DataConfig(**d))


def test_batches_are_deterministic():
    p = _pipe()
    a = p.batch(5)
    b = _pipe().batch(5)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_different_steps_differ():
    p = _pipe()
    assert not np.array_equal(p.batch(0)["tokens"], p.batch(1)["tokens"])


def test_host_sharding_partitions_global_batch():
    p = _pipe(global_batch=8)
    full = p.batch(2, host=0, num_hosts=1)["tokens"]
    h0 = p.batch(2, host=0, num_hosts=2)["tokens"]
    h1 = p.batch(2, host=1, num_hosts=2)["tokens"]
    assert h0.shape == (4, 64) and h1.shape == (4, 64)
    # hosts see disjoint rows (different row0 seeds)
    assert not np.array_equal(h0, h1)
    assert full.shape == (8, 64)


def test_labels_shift_and_mask():
    p = _pipe(structure=0.0)
    b = p.batch(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["mask"][:, -1] == 0).all()
    assert (b["mask"][:, :-1] == 1).all()


def test_tokens_in_vocab_range():
    b = _pipe(vocab=100).batch(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 100


def test_positions_reset_at_doc_boundaries():
    b = _pipe(pack_docs=True, mean_doc_len=20).batch(0)
    pos = b["positions"]
    resets = (pos[:, 1:] == 0) & (pos[:, :-1] != 0)
    assert resets.any()     # at least one packed boundary in 8x64 tokens


def test_arch_frontend_stubs():
    b = batch_for_arch(ARCHS["musicgen-medium"].reduced(), 32, 2)
    assert b["embeds"].shape == (2, 32, 128)
    b = batch_for_arch(ARCHS["qwen2-vl-2b"].reduced(), 32, 2)
    assert b["positions"].shape == (3, 2, 32)
    assert "vis_embeds" in b
