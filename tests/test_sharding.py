"""Sharding plans: logical-axis → PartitionSpec math, shape-aware axis
dropping, ZeRO-1 placement.  Uses AbstractMesh so no devices are needed."""
import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec

from repro.models.sharding import (RULE_SETS, ShardingPlan, zero1_axes)


def _abstract_mesh(shape, axes):
    try:
        return AbstractMesh(shape, axes)
    except TypeError:   # jax <= 0.4.37: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(axes, shape)))


def _plan(rules_name, shape=(16, 16), axes=("data", "model")):
    mesh = _abstract_mesh(shape, axes)
    return ShardingPlan(rules_name, mesh,
                        RULE_SETS[rules_name](axes))


def test_tp_rules_spec():
    p = _plan("tp")
    assert p.spec(("embed", "mlp")) == PartitionSpec(None, "model")
    assert p.spec(("act_batch", "act_seq", "act_embed")) == \
        PartitionSpec("data", None, None)
    assert p.spec(("vocab", "embed")) == PartitionSpec("model", None)


def test_fsdp_tp_shards_embed_over_data():
    p = _plan("fsdp-tp")
    assert p.spec(("embed", "mlp")) == PartitionSpec("data", "model")


def test_multipod_batch_axes_compose():
    p = _plan("fsdp-tp", (2, 16, 16), ("pod", "data", "model"))
    s = p.spec(("act_batch", "act_seq", "act_embed"))
    assert s == PartitionSpec(("pod", "data"), None, None)


def test_axis_used_once_per_spec():
    p = _plan("tp")
    # both logical dims map to 'model': the second must drop it
    s = p.spec(("heads", "mlp"))
    assert s == PartitionSpec("model", None)


def test_shape_aware_dropping():
    p = _plan("tp")
    # 12 heads cannot shard over a 16-way axis
    assert p.spec(("act_batch", "act_heads", None, None),
                  (8, 12, 128, 64)) == \
        PartitionSpec(None, None, None, None)   # 8 % 16 != 0 too
    assert p.spec(("act_batch", "act_heads", None, None),
                  (32, 32, 128, 64)) == \
        PartitionSpec("data", "model", None, None)


def test_decode_rules_shard_cache_seq():
    p = _plan("decode")
    s = p.spec(("cache_batch", "cache_heads", "cache_seq", None),
               (128, 8, 32768, 256))
    assert s == PartitionSpec("data", None, "model", None)


def test_sp_decode_rules_all_axes_on_seq():
    p = _plan("sp-decode")
    s = p.spec(("cache_batch", "cache_heads", "cache_seq", None),
               (1, 8, 524288, 256))
    assert s == PartitionSpec(None, None, ("data", "model"), None)


def test_prefill_sp_rules_shard_sequence():
    p = _plan("prefill-sp")
    s = p.spec(("act_batch", "act_heads", "act_seq", None),
               (32, 24, 32768, 128))
    assert s == PartitionSpec("data", None, "model", None)
    # matmul activations stay local (no head/mlp sharding)
    assert p.spec(("act_batch", "act_seq", "act_mlp"),
                  (32, 32768, 12288)) == \
        PartitionSpec("data", "model", None)


def test_dp_rules_replicate_params_shard_batch_everywhere():
    p = _plan("dp")
    assert p.spec(("embed", "mlp"), (1536, 6144)) == \
        PartitionSpec(None, None)
    assert p.spec(("act_batch", "act_seq", "act_embed"),
                  (256, 4096, 1536)) == \
        PartitionSpec(("data", "model"), None, None)
    # ZeRO-1 target covers the whole mesh
    axes = zero1_axes(("embed", "mlp"), p, (1536, 6144))
    assert "_zero1" in axes


def test_zero1_places_on_largest_free_dim():
    p = _plan("tp")
    # (vocab, embed) -> vocab sharded by model; embed free and divisible
    axes = zero1_axes(("vocab", "embed"), p, (129280, 7168))
    assert axes == ("vocab", "_zero1")
    # nothing free & divisible -> unchanged
    axes = zero1_axes(("vocab",), p, (100,))
    assert axes == ("vocab",)
