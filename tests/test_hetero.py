"""Performance-portable CIR on a heterogeneous fleet (docs §13).

Covers the split's claims: the shared ``manager="ir"`` module is lowered
exactly once fleet-wide and peer-sourced by every other platform class;
platform tails and autotune tables never cross platform-class boundaries;
losing the IR holder (eviction retraction or byzantine quarantine) falls
back to a local lowering instead of failing the build; and with the
feature off every §13 column is zero and the build is byte-identical to a
pre-§13 deploy.
"""
import dataclasses

import pytest

from repro.configs import ARCHS
from repro.core import (PreBuilder, cpu_smoke, gpu_server,
                        legacy_compile_cache_key, tpu_single_pod)
from repro.core.irmodule import (IR_BYTES_BASE, IR_BYTES_PER_ENTRY,
                                 ir_module_component, ir_module_digest)
from repro.deploy import FleetDeployer, FleetTopology

ARCH = "starcoder2-3b"


@pytest.fixture
def pb(service):
    return PreBuilder(service)


def _hetero(service, classes=("cpu", "gpu", "tpu"), **kw):
    """One cloud seed + one edge per platform class, full edge mesh."""
    topo = FleetTopology.hetero_edge(classes)
    cloud = dataclasses.replace(tpu_single_pod(), platform_id="cloud-seed")
    mk = {"cpu": cpu_smoke, "gpu": gpu_server, "tpu": tpu_single_pod}
    edges = {p: dataclasses.replace(mk[p](), platform_id=f"{p}-edge-host")
             for p in classes}
    topo.place(cloud.platform_id, "cloud")
    for p, s in edges.items():
        topo.place(s.platform_id, f"{p}-edge")
    fd = FleetDeployer(service, topology=topo, ir_components=True,
                       max_workers=1, fetch_workers=1, overlap=False, **kw)
    return fd, cloud, edges


def test_hetero_edge_shape():
    topo = FleetTopology.hetero_edge(("cpu", "gpu", "tpu"))
    assert topo.seed == "cloud"
    assert set(topo.node_ids()) == {"cloud", "cpu-edge", "gpu-edge",
                                    "tpu-edge"}
    # cloud reaches every edge; edges form a full mesh (the IR must be
    # able to flow between platform classes without a cloud round trip)
    for p in ("cpu-edge", "gpu-edge", "tpu-edge"):
        assert topo.bandwidth("cloud", p) is not None
    assert topo.bandwidth("cpu-edge", "gpu-edge") is not None
    assert topo.bandwidth("gpu-edge", "tpu-edge") is not None


def test_ir_digest_is_platform_free(service, pb):
    """Every platform class derives the same IR module from its own lock:
    the digest ignores chip, mesh, backend, jax version and the
    platform-selected partition plan."""
    cir = pb.prebuild(ARCHS[ARCH], entrypoint="serve")
    fd, cloud, edges = _hetero(service)
    names = ("prefill", "decode_step")
    digests, comps = set(), set()
    for p, spec in edges.items():
        lock = fd.node_builder(f"{p}-edge").build(
            cir, spec, assemble=False).lock
        digests.add(ir_module_digest(lock, names))
        comps.add(ir_module_component(lock, names).digest())
    assert len(digests) == 1 and len(comps) == 1
    # the entry set IS part of the program identity
    lock = fd.node_builder("cpu-edge").build(
        cir, edges["cpu"], assemble=False).lock
    assert ir_module_digest(lock, ("train_step",)) != next(iter(digests))


def test_ir_lowered_once_and_peer_shared(service, pb):
    """Cold hetero rollout: the first class lowers + publishes the IR;
    every other class peer-fetches the identical module and compiles only
    its own tail."""
    cir = pb.prebuild(ARCHS[ARCH], entrypoint="serve")
    fd, cloud, edges = _hetero(service)
    res = fd.deploy(cir, [edges[p] for p in ("cpu", "gpu", "tpu")],
                    assemble=True, compile_steps=True)
    assert res.ok, res.summary()
    reports = [d.report for d in res.deployments]
    assert all(r.ir_enabled for r in reports)
    ir_size = IR_BYTES_BASE + 2 * IR_BYTES_PER_ENTRY
    # exactly one lowering fleet-wide ...
    assert res.ir_bytes_published_total == ir_size
    assert sum(r.ir_bytes_published > 0 for r in reports) == 1
    # ... every other class sourced the shared module (full size, wire)
    sharers = [r for r in reports if r.ir_shared_bytes > 0]
    assert len(sharers) == 2
    assert all(r.ir_shared_bytes == ir_size for r in sharers)
    wire = [t for t in res.node_traffic.values() if t.ir_shared_bytes > 0]
    assert len(wire) == 2
    assert all(t.ir_shared_bytes == ir_size and t.ir_chunks_from_peers > 0
               for t in wire)
    # derived bytes never leak into the resolved-content accounting
    for d in res.deployments:
        t = res.node_traffic[d.node_id]
        assert t.bytes_total == d.report.bytes_delta_fetched


def test_tails_never_cross_platform_classes(service, pb):
    """A same-class peer restores the tail over the tail stripe; a
    different class never sees a cache hit and compiles its own."""
    cir = pb.prebuild(ARCHS[ARCH], entrypoint="serve")
    topo = FleetTopology.hetero_edge(("cpu-a", "cpu-b", "gpu"))
    cloud = dataclasses.replace(tpu_single_pod(), platform_id="cloud-seed")
    cpu_a = dataclasses.replace(cpu_smoke(), platform_id="cpu-host-a")
    cpu_b = dataclasses.replace(cpu_smoke(), platform_id="cpu-host-b")
    gpu = dataclasses.replace(gpu_server(), platform_id="gpu-host")
    topo.place(cloud.platform_id, "cloud")
    topo.place(cpu_a.platform_id, "cpu-a-edge")
    topo.place(cpu_b.platform_id, "cpu-b-edge")
    topo.place(gpu.platform_id, "gpu-edge")
    fd = FleetDeployer(service, topology=topo, ir_components=True,
                       max_workers=1, fetch_workers=1, overlap=False)
    r_a = fd.deploy(cir, [cpu_a], assemble=True, compile_steps=True)
    assert r_a.ok and r_a.deployments[0].report.artifact_bytes_published > 0
    # same class: compile-cache hit, tail + autotune ride the peer stripes
    r_b = fd.deploy(cir, [cpu_b], assemble=True, compile_steps=True)
    rep_b = r_b.deployments[0].report
    t_b = r_b.node_traffic["cpu-b-edge"]
    assert rep_b.compile_cache_hit
    assert t_b.platform_tail_bytes > 0
    assert t_b.platform_tail_bytes == \
        rep_b.artifact_bytes_fetched + rep_b.autotune_bytes_fetched
    # different class: no hit, no tail bytes from any peer — only the IR
    r_g = fd.deploy(cir, [gpu], assemble=True, compile_steps=True)
    rep_g = r_g.deployments[0].report
    t_g = r_g.node_traffic["gpu-edge"]
    assert not rep_g.compile_cache_hit
    assert rep_g.artifact_bytes_fetched == 0
    assert rep_g.artifact_bytes_published > 0
    assert t_g.platform_tail_bytes == 0
    assert t_g.ir_shared_bytes > 0           # the neutral part DID cross


def test_ir_holder_loss_falls_back_to_local_lowering(service, pb):
    """Eviction retraction on the only IR holder: the next class finds no
    peer copy and pays the lowering itself instead of failing."""
    cir = pb.prebuild(ARCHS[ARCH], entrypoint="serve")
    fd, cloud, edges = _hetero(service)
    r0 = fd.deploy(cir, [edges["cpu"]], assemble=True, compile_steps=True)
    assert r0.ok and r0.ir_bytes_published_total > 0
    # the holder's store evicts the IR chunks: the eviction listener
    # retracts them from the PeerIndex
    lock = fd.node_builder("cpu-edge").build(
        cir, edges["cpu"], assemble=False).lock
    comp = ir_module_component(lock, ("prefill", "decode_step"))
    store = fd.node_store("cpu-edge")
    peering = fd.node_builder("cpu-edge").fetch_engine.peering
    peering.on_chunks_evicted([ch.id for ch in store.chunks_of(comp)])
    r1 = fd.deploy(cir, [edges["gpu"]], assemble=True, compile_steps=True)
    rep = r1.deployments[0].report
    assert r1.ok
    assert rep.ir_shared_bytes == 0 and rep.ir_bytes_published > 0
    assert r1.node_traffic["gpu-edge"].ir_shared_bytes == 0


def test_quarantined_ir_holder_falls_back(service, pb):
    """A byzantine-quarantined IR holder is never selected as a source:
    the next class lowers locally."""
    cir = pb.prebuild(ARCHS[ARCH], entrypoint="serve")
    fd, cloud, edges = _hetero(service)
    r0 = fd.deploy(cir, [edges["cpu"]], assemble=True, compile_steps=True)
    assert r0.ok and r0.ir_bytes_published_total > 0
    fd.mark_byzantine(["cpu-edge"])
    r1 = fd.deploy(cir, [edges["tpu"]], assemble=True, compile_steps=True)
    rep = r1.deployments[0].report
    assert r1.ok
    assert rep.ir_shared_bytes == 0 and rep.ir_bytes_published > 0


def test_split_off_is_byte_identical(service, pb):
    """``ir_components=False`` (the default) must produce a report with
    every §13 column zero and identical byte accounting — the committed
    baselines and every pre-§13 caller stay exact."""
    cir = pb.prebuild(ARCHS[ARCH], entrypoint="serve")

    def rollout(ir):
        topo = FleetTopology.hetero_edge(("cpu", "gpu"))
        cloud = dataclasses.replace(tpu_single_pod(),
                                    platform_id="cloud-seed")
        cpu = dataclasses.replace(cpu_smoke(), platform_id="cpu-edge-host")
        gpu = dataclasses.replace(gpu_server(), platform_id="gpu-edge-host")
        topo.place(cloud.platform_id, "cloud")
        topo.place(cpu.platform_id, "cpu-edge")
        topo.place(gpu.platform_id, "gpu-edge")
        fd = FleetDeployer(service, topology=topo, ir_components=ir,
                           max_workers=1, fetch_workers=1, overlap=False)
        res = fd.deploy(cir, [cpu, gpu], assemble=True, compile_steps=True)
        assert res.ok, res.summary()
        return res

    off, on = rollout(False), rollout(True)
    for d in off.deployments:
        r = d.report
        assert not r.ir_enabled
        assert r.ir_shared_bytes == r.ir_bytes_published == 0
        assert r.platform_tail_bytes == 0
        assert r.autotune_bytes_fetched == r.autotune_bytes_published == 0
    for t in off.node_traffic.values():
        assert t.ir_shared_bytes == t.ir_chunks_from_peers == 0
        assert t.platform_tail_bytes == 0
    assert off.ir_shared_bytes_total == off.ir_bytes_published_total == 0
    assert off.platform_tail_bytes_total == 0
    for d_off, d_on in zip(off.deployments, on.deployments):
        for f in ("bytes_fetched", "bytes_delta_fetched", "chunks_hit",
                  "chunks_missed", "n_components", "n_compiled",
                  "bytes_total_components"):
            assert getattr(d_off.report, f) == getattr(d_on.report, f), f
        assert off.node_traffic[d_off.node_id].bytes_total == \
            on.node_traffic[d_on.node_id].bytes_total


def test_v1_keys_never_leak_into_v2_cache(service, pb):
    """The compat shim: the old lock-digest-proxy key is still derivable,
    is never equal to the v2 key, and never appears as a key of a new
    cache entry."""
    cir = pb.prebuild(ARCHS[ARCH], entrypoint="serve")
    fd, cloud, edges = _hetero(service)
    res = fd.deploy(cir, [edges[p] for p in ("cpu", "gpu", "tpu")],
                    assemble=True, compile_steps=True)
    assert res.ok
    names = ("decode_step", "prefill")
    legacy = set()
    for p, spec in edges.items():
        lock = fd.node_builder(f"{p}-edge").build(
            cir, spec, assemble=False).lock
        legacy.add(legacy_compile_cache_key(lock, spec, names))
    cached = set(fd.compile_cache.artifacts())
    assert len(cached) == 3                 # one tail per platform class
    assert not legacy & cached, "a v1 proxy key leaked into the v2 cache"
