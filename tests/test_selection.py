"""Algorithm 1 (uniform component selection) + deployability evaluator."""
import pytest

from repro.core.component import DependencyItem, Requirement, UniformComponent
from repro.core.registry import (UniformComponentRegistry,
                                 UniformComponentService)
from repro.core.selection import (DeployabilityEvaluator, SelectionError,
                                  env_select, uniform_component_selection,
                                  version_select)


def _c(version, env, requires=(), perf=1.0, size=100):
    return UniformComponent(
        manager="kernel", name="attention", version=version, env=env,
        requires=tuple(Requirement(*r) for r in requires),
        perf_score=perf, size_bytes=size, payload="p")


@pytest.fixture
def svc():
    reg = UniformComponentRegistry()
    reg.register_all([
        _c("1.0.0", "generic", perf=1.0),
        _c("1.1.0", "generic", perf=1.0),
        _c("1.1.0", "tpu", [("chip", "eq", "tpu-v5e")], perf=3.0),
        _c("2.0.0", "tpu-only", [("chip", "eq", "tpu-v5e")], perf=3.0),
    ])
    return UniformComponentService(reg)


def test_version_select_highest_matching():
    vs = ["0.9.0", "1.0.0", "1.1.0", "2.0.0"]
    assert version_select(vs, "~=1.0") == "1.1.0"
    assert version_select(vs, "latest") == "2.0.0"
    assert version_select(vs, "<1.0") == "0.9.0"
    assert version_select(vs, ">=3.0") is None


def test_env_select_hard_gate_and_perf(svc):
    cpu_ctx = {"chip": "cpu-host"}
    tpu_ctx = {"chip": "tpu-v5e"}
    cands = svc.candidates("kernel", "attention", "1.1.0")
    best_cpu, _ = env_select(cands, DeployabilityEvaluator(cpu_ctx))
    best_tpu, _ = env_select(cands, DeployabilityEvaluator(tpu_ctx))
    assert best_cpu.env == "generic"       # tpu variant hard-gated out
    assert best_tpu.env == "tpu"           # higher perf wins when eligible


def test_algorithm1_version_backoff(svc):
    """2.0.0 only has a tpu-only env; on cpu the algorithm must do
    V <- V \\ {v} and fall back to 1.1.0 (the paper's repeat loop)."""
    d = DependencyItem("kernel", "attention", ">=1.0")
    ev = DeployabilityEvaluator({"chip": "cpu-host"})
    c = uniform_component_selection(d, svc, ev)
    assert (c.version, c.env) == ("1.1.0", "generic")


def test_algorithm1_error_when_nothing_fits(svc):
    d = DependencyItem("kernel", "attention", ">=3.0")
    ev = DeployabilityEvaluator({"chip": "cpu-host"})
    with pytest.raises(SelectionError):
        uniform_component_selection(d, svc, ev)


def test_algorithm1_extra_constraint(svc):
    d = DependencyItem("kernel", "attention", "any")
    ev = DeployabilityEvaluator({"chip": "tpu-v5e"})
    c = uniform_component_selection(d, svc, ev, extra_constraint="<2.0")
    assert c.version == "1.1.0"


def test_deployability_cache_scales_with_size():
    """Cache locality must dominate for GB components and be negligible for
    KB ones (paper §3.2: caching, size, download time, performance)."""
    big_a = _c("1.0.0", "a", perf=1.0, size=2 * 2**30)
    big_b = _c("1.0.0", "b", perf=1.3, size=2 * 2**30)
    ev = DeployabilityEvaluator({}, cached_digests={big_a.digest()})
    best, _ = env_select([big_a, big_b], ev)
    assert best.env == "a"     # avoiding a 2 GiB pull beats 0.3 perf

    small_a = _c("1.0.0", "a", perf=1.0, size=1000)
    small_b = _c("1.0.0", "b", perf=1.3, size=1000)
    ev = DeployabilityEvaluator({}, cached_digests={small_a.digest()})
    best, _ = env_select([small_a, small_b], ev)
    assert best.env == "b"     # KB-scale cache hit does not buy perf
