"""Peer-to-peer chunk distribution across a fleet topology.

Covers the distribution subsystem's claims: per-node stores with peer-first
chunk sourcing, store-verified announcements (the index can never
over-claim), upstream fallback on peer failure without poisoning the
``PeerIndex``, byte-identical per-node accounting between peer and upstream
sourcing, and ``warm()`` targeting the cloud seed node only.
"""
import dataclasses

import pytest

from repro.configs import ARCHS
from repro.core import PreBuilder, cpu_smoke, gpu_server, tpu_single_pod
from repro.deploy import (FleetDeployer, FleetTopology, PeerIndex,
                          PeerTransferError, TopologyError)


@pytest.fixture
def pb(service):
    return PreBuilder(service)


def _fanout(n_edges=2):
    """1 cloud seed + N edges, all linked; edge-edge slower than cloud-edge
    so source selection between them is observable."""
    topo = FleetTopology.edge_fanout(n_edges, cloud_edge_bps=200e6,
                                     edge_edge_bps=100e6)
    cloud = tpu_single_pod()
    edges = [dataclasses.replace(cpu_smoke(), platform_id=f"edge-host-{i}")
             for i in range(n_edges)]
    topo.place(cloud.platform_id, "cloud")
    for i, s in enumerate(edges):
        topo.place(s.platform_id, f"edge-{i}")
    return topo, cloud, edges


# ---------------------------------------------------------------------------
# Topology + index plumbing
# ---------------------------------------------------------------------------

def test_topology_validation():
    topo = FleetTopology()
    topo.add_node("a")
    topo.add_node("b", seed=True)
    assert topo.seed == "b"
    with pytest.raises(TopologyError):
        topo.add_node("a")                    # duplicate
    with pytest.raises(TopologyError):
        topo.link("a", "missing", 1e6)        # unknown node
    with pytest.raises(TopologyError):
        topo.link("a", "a", 1e6)              # self link
    with pytest.raises(TopologyError):
        topo.link("a", "b", 0)                # non-positive bandwidth
    topo.link("a", "b", 5e6)
    assert topo.bandwidth("a", "b") == topo.bandwidth("b", "a") == 5e6
    assert topo.bandwidth("a", "missing") is None
    assert topo.peers_of("a") == ["b"]
    with pytest.raises(TopologyError):
        topo.node_for("unplaced-platform")


def test_edge_fanout_shape():
    topo = FleetTopology.edge_fanout(3)
    assert topo.seed == "cloud"
    assert set(topo.node_ids()) == {"cloud", "edge-0", "edge-1", "edge-2"}
    assert topo.bandwidth("cloud", "edge-1") is not None
    assert topo.bandwidth("edge-0", "edge-2") is not None


def test_peer_index_announce_retract_drop():
    idx = PeerIndex()
    idx.announce("a", ["c1", "c2"])
    idx.announce("b", ["c2"])
    assert idx.holders("c1") == ("a",)
    assert idx.holders("c2") == ("a", "b")
    idx.retract("a", ["c2", "never-seen"])
    assert idx.holders("c2") == ("b",)
    idx.drop_node("b")
    assert idx.holders("c2") == ()
    assert idx.chunks_held("a") == 1
    assert len(idx) == 1


def test_deployer_rejects_shared_store_in_topology_mode(service):
    from repro.core import ChunkedComponentStore
    with pytest.raises(ValueError):
        FleetDeployer(service, store=ChunkedComponentStore(),
                      topology=FleetTopology.edge_fanout(1))


# ---------------------------------------------------------------------------
# Peer-first sourcing
# ---------------------------------------------------------------------------

def test_edges_source_from_cloud_seed(service, pb):
    topo, cloud, edges = _fanout(2)
    fd = FleetDeployer(service, topology=topo)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")

    seed_res = fd.deploy(cir, [cloud])
    assert seed_res.ok
    # the seed had no peers holding anything: all upstream
    assert seed_res.bytes_peer_total == 0
    assert seed_res.bytes_upstream_total > 0
    # its content is announced
    assert fd.peer_index.chunks_held("cloud") > 0

    edge_res = fd.deploy(cir, edges)
    assert edge_res.ok
    # edges pulled the shared content (weights dominate) from peers, paying
    # upstream only for chunks no peer held
    assert edge_res.bytes_peer_total > edge_res.bytes_upstream_total
    assert edge_res.peer_offload_ratio > 0.5
    for d in edge_res.deployments:
        t = edge_res.node_traffic[d.node_id]
        assert t.bytes_from_peers > 0
        assert "cloud" in t.peer_sources
        # wire split must exactly cover the build's delta bytes
        assert t.bytes_total == d.report.bytes_delta_fetched
        assert d.report.bytes_delta_fetched <= d.report.bytes_fetched
    # each platform still resolved its own env variant
    envs = {d.platform_id: {(c.manager, c.name): c.env
                            for c in d.instance.bundle.components()}
            for d in edge_res.deployments}
    for pid in envs:
        assert envs[pid][("env", "runtime-base")] == "cpu-host"


def test_no_peer_baseline_is_byte_identical_per_node(service, pb):
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    per_node = {}
    for use_peers in (True, False):
        topo, cloud, edges = _fanout(2)
        fd = FleetDeployer(service, topology=topo, use_peers=use_peers)
        fd.deploy(cir, [cloud])
        res = fd.deploy(cir, edges)
        assert res.ok
        if not use_peers:
            assert res.bytes_peer_total == 0
        per_node[use_peers] = {
            d.node_id: (d.report.bytes_delta_fetched,
                        d.report.bytes_fetched,
                        d.report.chunks_hit, d.report.chunks_missed)
            for d in res.deployments}
    # sourcing moves bytes between links, never changes what is fetched
    assert per_node[True] == per_node[False]


def test_cheapest_peer_wins(service, pb):
    """With two holders, the higher-bandwidth link is selected."""
    topo = FleetTopology()
    topo.add_node("cloud", seed=True)
    topo.add_node("near")
    topo.add_node("sink")
    topo.link("sink", "cloud", 10e6)      # slow
    topo.link("sink", "near", 100e6)      # fast — must win
    cloud = tpu_single_pod()
    near = dataclasses.replace(cpu_smoke(), platform_id="near-host")
    sink = dataclasses.replace(cpu_smoke(), platform_id="sink-host")
    topo.place(cloud.platform_id, "cloud")
    topo.place(near.platform_id, "near")
    topo.place(sink.platform_id, "sink")
    fd = FleetDeployer(service, topology=topo)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    fd.deploy(cir, [cloud])
    fd.deploy(cir, [near])                # near now holds the cpu content
    res = fd.deploy(cir, [sink])
    assert res.ok
    t = res.node_traffic["sink"]
    # everything peer-sourced came over the fast link
    assert t.bytes_from_peers > 0
    assert set(t.peer_sources) == {"near"}


def test_unlinked_holder_is_not_a_source(service, pb):
    """A node with no link to the holder pays the upstream price."""
    topo = FleetTopology()
    topo.add_node("cloud", seed=True)
    topo.add_node("island")               # no links at all
    cloud = tpu_single_pod()
    island = dataclasses.replace(cpu_smoke(), platform_id="island-host")
    topo.place(cloud.platform_id, "cloud")
    topo.place(island.platform_id, "island")
    fd = FleetDeployer(service, topology=topo)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="train")
    fd.deploy(cir, [cloud])
    res = fd.deploy(cir, [island])
    assert res.ok
    assert res.bytes_peer_total == 0
    assert res.node_traffic["island"].bytes_from_upstream > 0


# ---------------------------------------------------------------------------
# Failure paths
# ---------------------------------------------------------------------------

def test_failed_peer_falls_back_upstream_and_is_retracted(service, pb):
    """A peer that fails mid-transfer: the pulling node re-routes those
    chunks upstream (build still succeeds, invariant holds) and the dead
    advertisement is retracted so it is not retried."""
    topo, cloud, edges = _fanout(2)
    fd = FleetDeployer(service, topology=topo)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    fd.deploy(cir, [cloud])
    held_before = fd.peer_index.chunks_held("cloud")
    assert held_before > 0

    def dead_peer(src, component, chunks):
        raise PeerTransferError(f"{src} crashed mid-transfer")

    fd._node_peerings["edge-0"]._peer_pull = dead_peer
    res = fd.deploy(cir, [edges[0]])
    assert res.ok
    t = res.node_traffic["edge-0"]
    assert t.bytes_from_peers == 0
    assert t.peer_fallbacks > 0
    # invariant survives the fallback: wire split still covers the delta
    d = res.deployments[0]
    assert t.bytes_total == d.report.bytes_delta_fetched
    assert d.report.bytes_delta_fetched <= d.report.bytes_fetched
    # the failed advertisements were retracted (no poison) ...
    assert fd.peer_index.chunks_held("cloud") < held_before
    # ... and the next node is unaffected: it sources from edge-0, which
    # fetched (upstream) and announced the same content
    res2 = fd.deploy(cir, [edges[1]])
    assert res2.ok
    t2 = res2.node_traffic["edge-1"]
    assert t2.bytes_from_peers > 0
    assert "edge-0" in t2.peer_sources
    assert t2.peer_fallbacks == 0


def test_stale_advertisement_retracts_without_failing_the_build(service, pb):
    """An index entry the holder cannot honour (announced, then lost) is a
    verified-transfer failure: fallback upstream, entry removed."""
    topo, cloud, edges = _fanout(1)
    fd = FleetDeployer(service, topology=topo)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="train")
    # poison attempt: advertise chunks the cloud store does NOT hold
    fake_ids = [f"fake-{i}" for i in range(4)]
    fd.peer_index.announce("cloud", fake_ids)
    res = fd.deploy(cir, edges)     # cloud store is empty: every real chunk
    assert res.ok                   # routes upstream, nothing wedges
    assert res.node_traffic["edge-0"].bytes_from_upstream > 0


def test_announcements_are_store_verified(service, pb):
    """A node can never advertise chunks it does not hold — announcements
    derive from store presence, so a crashed fetch cannot over-claim."""
    topo, cloud, edges = _fanout(1)
    fd = FleetDeployer(service, topology=topo)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="train")
    inst = fd._node_builders["cloud"].build(cir, cloud, assemble=False)
    comp = inst.bundle.components()[0]
    peering = fd._node_peerings["edge-0"]     # edge-0's store is EMPTY
    peering.on_component_ready(comp)
    for ch in peering.store.chunks_of(comp):
        assert "edge-0" not in fd.peer_index.holders(ch.id)


# ---------------------------------------------------------------------------
# warm() + shared-store fast path
# ---------------------------------------------------------------------------

def test_warm_targets_seed_node_only(service, pb):
    """warm() under a topology pre-populates the cloud seed's store (and
    every platform's plan), leaving edge stores empty; the subsequent real
    deploy replays plans and the edges peer off the seed."""
    topo, cloud, edges = _fanout(2)
    fd = FleetDeployer(service, topology=topo)
    cir = pb.prebuild(ARCHS["phi4-mini-3.8b"], entrypoint="train")
    specs = [cloud] + edges
    assert fd.warm(cir, specs) == 3
    assert fd.node_store("cloud").chunk_count() > 0
    for e in ("edge-0", "edge-1"):
        assert fd.node_store(e).chunk_count() == 0
    res = fd.deploy(cir, specs)
    assert res.ok
    assert res.plan_cache_hits == 3
    # the seed refetched nothing; edges sourced from it over peer links
    assert res.node_traffic["cloud"].bytes_total == 0
    for e in ("edge-0", "edge-1"):
        t = res.node_traffic[e]
        assert t.bytes_from_peers > 0
        assert t.bytes_from_peers > t.bytes_from_upstream


def test_source_retraction_mid_migration_keeps_target_announcements(
        service, pb):
    """Satellite regression: retracting the migration SOURCE while the
    target's prefetch announcements are still landing — an eviction
    retraction plus a full ``drop_node`` — must not orphan the target's
    entries.  Retraction is strictly node-scoped: a chunk's index entry
    only dies when its holder set empties."""
    import dataclasses as dc
    from repro.core import SimNetwork
    topo = FleetTopology.edge_fanout(2, cloud_edge_bps=5e8,
                                     edge_edge_bps=1e9)
    cloud = tpu_single_pod()
    edges = [dc.replace(cpu_smoke(), platform_id=f"edge-host-{i}")
             for i in range(2)]
    topo.place(cloud.platform_id, "cloud")
    for i, s in enumerate(edges):
        topo.place(s.platform_id, f"edge-{i}")
    fd = FleetDeployer(service, topology=topo, simnet=SimNetwork(topo),
                       max_workers=1, fetch_workers=1, overlap=False)
    assert fd.deploy(cir := PreBuilder(service).prebuild(
        ARCHS["starcoder2-3b"], entrypoint="serve"), [cloud]).ok
    r0 = fd.deploy(cir, [edges[0]], assemble=True, compile_steps=True)
    assert r0.ok, r0.summary()
    inst = r0.deployments[0].instance

    # interleave: after the target's FIRST speculative stripe lands, the
    # source "dies" mid-hand-off — its ads are retracted as an eviction
    # would, then the whole node is dropped from the index
    tgt = fd._node_peerings["edge-1"]
    src = fd._node_peerings["edge-0"]
    real = tgt.fetch_spec_stripe
    fired = []

    def dying_source(component, stripe):
        out = real(component, stripe)
        if not fired:
            fired.append(True)
            src_ids = [ch.id for c in inst.bundle.components()
                       for ch in src.store.chunks_of(c)]
            src.on_chunks_evicted(src_ids)
            fd.peer_index.drop_node("edge-0")
        return out

    tgt.fetch_spec_stripe = dying_source
    rep = fd.migrate(inst, "edge-1")
    assert fired                                     # interleave happened
    assert rep.instance.stage == "complete"
    assert topo.node_for(edges[0].platform_id) == "edge-1"
    # the source is fully forgotten ...
    assert fd.peer_index.chunks_held("edge-0") == 0
    # ... but every chunk the target landed kept its announcement: the
    # node-scoped retraction never emptied a holder set the target joined
    tgt_store = fd.node_store("edge-1")
    announced = 0
    for c in inst.bundle.components():
        for ch in tgt_store.chunks_of(c):
            if tgt_store.has_chunk(ch.id):
                assert "edge-1" in fd.peer_index.holders(ch.id), ch.id
                announced += 1
    assert announced > 0


def test_shared_store_path_reports_no_peer_columns(service, pb):
    """The default (no-topology) deployer is untouched by the subsystem:
    no node traffic, zero peer columns."""
    fd = FleetDeployer(service)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="train")
    res = fd.deploy(cir, [tpu_single_pod(), gpu_server()])
    assert res.ok
    assert res.node_traffic == {}
    assert res.bytes_upstream_total == 0 and res.bytes_peer_total == 0
    assert res.peer_offload_ratio == 0.0
    assert all(d.node_id is None for d in res.deployments)


def test_node_traffic_ir_columns_in_since_and_as_dict():
    """The §13 columns ride the NodeTraffic delta/report plumbing like
    every other column — and stay out of ``bytes_total``, which remains
    the resolved-content wire only."""
    from repro.deploy import NodeTraffic
    t = NodeTraffic(node_id="n", bytes_from_upstream=100,
                    ir_shared_bytes=30, ir_chunks_from_peers=2,
                    platform_tail_bytes=10)
    assert t.bytes_total == 100               # derived bytes never counted
    d = t.as_dict()
    assert d["ir_shared_bytes"] == 30
    assert d["ir_chunks_from_peers"] == 2
    assert d["platform_tail_bytes"] == 10
    before = t.snapshot()
    t.ir_shared_bytes += 5
    t.platform_tail_bytes += 7
    t.ir_chunks_from_peers += 1
    delta = t.since(before)
    assert delta.ir_shared_bytes == 5
    assert delta.platform_tail_bytes == 7
    assert delta.ir_chunks_from_peers == 1
    assert delta.bytes_from_upstream == 0
