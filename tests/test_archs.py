"""Per-arch smoke tests: reduced same-family config, one lazy-built train
step on CPU, assert output shapes + finite values.  Serve-decode smoke for
every arch as well."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, ARCHS
from repro.core import LazyBuilder, PreBuilder, cpu_smoke


@pytest.fixture(scope="module")
def built(service, smoke_mesh):
    lb = LazyBuilder(service)
    pb = PreBuilder(service)
    cache = {}

    def build(arch_id, entrypoint="train"):
        key = (arch_id, entrypoint)
        if key not in cache:
            cfg = ARCHS[arch_id].reduced()
            cir = pb.prebuild(cfg, entrypoint=entrypoint)
            cache[key] = lb.build(cir, cpu_smoke(), mesh=smoke_mesh)
        return cache[key]
    return build


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_smoke(arch_id, built):
    inst = built(arch_id)
    e = inst.entry
    cfg = inst.model.cfg
    state = e["init_state"](jax.random.PRNGKey(0))
    raw = e["batch_fn"](64, 2)
    batch = {k: jnp.asarray(v) for k, v in raw.items()}
    assert batch["tokens"].shape == (2, 64)
    state, metrics = jax.jit(e["train_step"])(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 0.0 < loss < 20.0, (arch_id, loss)
    # params stay finite after the update
    leaves = jax.tree_util.tree_leaves(state["params"])
    assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
               for l in leaves), arch_id
    # a second step decreases nothing catastrophically
    state, m2 = jax.jit(e["train_step"])(state, batch)
    assert np.isfinite(float(m2["loss"]))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_smoke(arch_id, built):
    """All ten archs are decoder-style: one prefill + two decode steps."""
    inst = built(arch_id, "serve")
    model = inst.model
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    b, s, max_seq = 2, 8, 32
    cache = model.init_cache(b, max_seq)
    toks = jnp.ones((b, s), jnp.int32)
    pos = jnp.tile(jnp.arange(s), (b, 1))
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos, (3, b, s))
    batch = {"tokens": toks, "positions": pos}
    if cfg.family == "audio-lm":
        batch["embeds"] = jnp.zeros((b, s, cfg.d_model), jnp.float32)
    logits, cache = inst.entry["prefill"](params, batch, cache)
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for t in range(s, s + 2):
        p1 = jnp.full((b, 1), t, jnp.int32)
        if cfg.mrope_sections:
            p1 = jnp.broadcast_to(p1, (3, b, 1))
        logits, cache = inst.entry["decode_step"](params, nxt, p1, cache,
                                                  jnp.int32(t))
        assert logits.shape == (b, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits))), (arch_id, t)
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_matches_assignment(arch_id):
    """The full configs carry the exact public numbers."""
    cfg = ARCHS[arch_id]
    expected = {
        "deepseek-v3-671b": (61, 7168, 128, 128, 129280),
        "dbrx-132b": (40, 6144, 48, 8, 100352),
        "gemma2-9b": (42, 3584, 16, 8, 256000),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 92416),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 200064),
        "starcoder2-3b": (30, 3072, 24, 2, 49152),
        "musicgen-medium": (48, 1536, 24, 24, 2048),
        "rwkv6-1.6b": (24, 2048, 32, 32, 65536),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 65536),
        "qwen2-vl-2b": (28, 1536, 12, 2, 151936),
    }[arch_id]
    assert (cfg.num_layers, cfg.d_model, cfg.n_heads, cfg.n_kv,
            cfg.vocab) == expected


def test_param_counts_in_published_ballpark():
    """Analytic param counts land near the published sizes (within ~20%)."""
    expect = {
        "deepseek-v3-671b": 671e9, "dbrx-132b": 132e9, "gemma2-9b": 9.2e9,
        "codeqwen1.5-7b": 7.3e9, "phi4-mini-3.8b": 3.8e9,
        "starcoder2-3b": 3.0e9, "rwkv6-1.6b": 1.6e9,
        "jamba-v0.1-52b": 52e9, "qwen2-vl-2b": 1.5e9,
    }
    for aid, n in expect.items():
        got = ARCHS[aid].param_count()
        assert abs(got - n) / n < 0.25, (aid, got, n)
