"""Unit + property tests for versions, specifiers, requirements, components."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip individually without hypothesis
    from _hypothesis_stub import given, settings, st

from repro.core.component import (DependencyItem, Requirement, Specifier,
                                  UniformComponent, Version)


# ---------------------------------------------------------------------------
# Version
# ---------------------------------------------------------------------------

def test_version_parse_basics():
    assert Version.parse("1.2.3").release == (1, 2, 3)
    assert Version.parse("v2.0").release == (2, 0)
    assert Version.parse("1.0rc1").pre == ("rc", 1)
    with pytest.raises(ValueError):
        Version.parse("not-a-version")


def test_version_ordering():
    vs = ["0.9", "1.0a1", "1.0", "1.0.1", "1.1", "2.0"]
    parsed = [Version.parse(v) for v in vs]
    assert parsed == sorted(parsed)


_version_strat = st.builds(
    lambda parts, pre: ".".join(map(str, parts)) + (pre or ""),
    st.lists(st.integers(0, 30), min_size=1, max_size=4),
    st.sampled_from(["", "a1", "b2", "rc1", "rc0"]))


@given(_version_strat, _version_strat, _version_strat)
@settings(max_examples=200, deadline=None)
def test_version_total_order_properties(a, b, c):
    va, vb, vc = Version.parse(a), Version.parse(b), Version.parse(c)
    # totality + antisymmetry
    assert (va <= vb) or (vb <= va)
    if va <= vb and vb <= va:
        assert va == vb
    # transitivity
    if va <= vb and vb <= vc:
        assert va <= vc
    # hash consistency
    if va == vb:
        assert hash(va) == hash(vb)


# ---------------------------------------------------------------------------
# Specifier
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec,version,expected", [
    (">=1.0", "1.0", True),
    (">=1.0", "0.9", False),
    ("~=2.0", "2.5", True),
    ("~=2.0", "3.0", False),
    ("==1.2", "1.2.7", True),      # prefix match, PEP440-style
    ("==1.2", "1.3.0", False),
    ("!=1.3", "1.3.1", False),
    (">=1.0,<2.0", "1.5", True),
    (">=1.0,<2.0", "2.0", False),
    ("any", "0.0.1", True),
    ("latest", "9.9", True),
])
def test_specifier_matches(spec, version, expected):
    assert Specifier(spec).matches(Version.parse(version)) is expected


@given(st.sampled_from([">=1.0", "<3", "~=2.0", "any", "==2.1"]),
       st.sampled_from(["<2.5", ">=2.0", "any", "!=2.2"]),
       _version_strat)
@settings(max_examples=200, deadline=None)
def test_specifier_intersection_is_conjunction(s1, s2, v):
    """x matches intersect(a, b)  <=>  x matches a AND x matches b."""
    a, b = Specifier(s1), Specifier(s2)
    both = Specifier(a.intersect_text(b))
    ver = Version.parse(v)
    assert both.matches(ver) == (a.matches(ver) and b.matches(ver))


# ---------------------------------------------------------------------------
# Requirement
# ---------------------------------------------------------------------------

def test_requirement_ops():
    ctx = {"chip": "tpu-v5e", "mesh.chips": 256, "dtypes": ["bf16", "f32"],
           "interpret": True}
    assert Requirement("chip", "eq", "tpu-v5e").satisfied(ctx)
    assert Requirement("chip", "in", ["tpu-v5e", "tpu-v5p"]).satisfied(ctx)
    assert Requirement("mesh.chips", "ge", 256).satisfied(ctx)
    assert not Requirement("mesh.chips", "le", 16).satisfied(ctx)
    assert Requirement("dtypes", "has", "bf16").satisfied(ctx)
    assert Requirement("interpret", "true").satisfied(ctx)
    assert not Requirement("interpret", "false").satisfied(ctx)
    assert Requirement("missing", "false").satisfied(ctx)


# ---------------------------------------------------------------------------
# UniformComponent immutability/digest
# ---------------------------------------------------------------------------

def _mk(version="1.0.0", env="generic", payload="p", deps=()):
    return UniformComponent(
        manager="kernel", name="thing", version=version, env=env,
        deps=tuple(DependencyItem(*d) for d in deps), payload=payload,
        size_bytes=10)


def test_digest_stable_and_content_sensitive():
    a = _mk()
    b = _mk()
    assert a.digest() == b.digest()
    assert _mk(payload="other").digest() != a.digest()
    assert _mk(deps=[("env", "base", "any")]).digest() != a.digest()


def test_json_roundtrip():
    c = UniformComponent(
        manager="model", name="decoder-moe", version="1.1.0", env="generic",
        deps=(DependencyItem("kernel", "attention", "~=1.0"),),
        context={"kernel.api": "1"},
        requires=(Requirement("chip", "eq", "tpu-v5e"),),
        provides=("model",), payload="model.decoder", size_bytes=123,
        perf_score=1.2, meta={"x": 1})
    c2 = UniformComponent.from_json(c.to_json())
    assert c2.digest() == c.digest()
    assert c2.requires[0].satisfied({"chip": "tpu-v5e"})
