"""Event-driven build orchestrator: lifecycle stages, build-graph gates,
overlap correctness (byte-identical accounting vs the barrier pipeline),
fleet lifecycle accounting, and failure propagation."""
import threading

import pytest

from repro.configs import ARCHS
from repro.core import (BuildGraph, ChunkedComponentStore, Lifecycle,
                        LazyBuilder, PreBuilder, catalog, cpu_smoke,
                        gpu_server, tpu_single_pod)
from repro.deploy import FleetDeployer

# Fast simulated link: slow enough that the weight tail is measurable wall
# time, fast enough that the whole module stays in CI budget.
_SIM_BPS = 50e9


def _builder(sim=None, **kw):
    svc = catalog.build_service()
    return (LazyBuilder(svc, ChunkedComponentStore(),
                        fetch_simulate_bps=sim, **kw),
            PreBuilder(svc))


# ---------------------------------------------------------------------------
# Lifecycle + BuildGraph units
# ---------------------------------------------------------------------------

def test_lifecycle_is_monotonic_and_waitable():
    life = Lifecycle()
    assert life.stage == "planned"
    life.advance("compiled")            # implies fetching + assembled
    assert life.reached("assembled")
    assert life.wait("fetching", timeout=0.1) == "compiled"
    with pytest.raises(TimeoutError):
        life.wait("ready", timeout=0.01)
    life.advance("complete")
    assert life.wait("weights", timeout=0.1) == "complete"   # alias


def test_lifecycle_fail_wakes_waiters_with_the_error():
    life = Lifecycle()
    life.advance("assembled")
    seen = []

    def waiter():
        try:
            life.wait("ready")
        except RuntimeError as e:
            seen.append(e)

    t = threading.Thread(target=waiter)
    t.start()
    life.fail(RuntimeError("boom"))
    t.join(timeout=5)
    assert len(seen) == 1 and "boom" in str(seen[0])
    # stages reached before the failure still wait cleanly
    assert life.wait("assembled", timeout=0.1)
    with pytest.raises(RuntimeError):
        life.wait("complete", timeout=0.1)


def test_build_graph_gates():
    g = BuildGraph()
    assert g.stage_of("model") == "assemble"
    assert g.stage_of("runtime") == "assemble"
    assert g.stage_of("data") == "assemble"
    assert g.stage_of("env") == "compile"
    assert g.stage_of("asset") == "complete"    # first-weight-use only
    assert g.stage_of("opt") == "ready"


def test_build_graph_asset_never_gates_ready(service):
    pb = PreBuilder(service)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    lb = LazyBuilder(service)
    inst = lb.build(cir, tpu_single_pod(), assemble=False)
    comps = inst.bundle.components()
    gates = BuildGraph().gates_for(comps)
    assets = {c.digest() for c in comps if c.manager == "asset"}
    assert assets, "serve CIR should carry weight assets"
    assert not (gates["ready"] & assets)
    assert not (gates["assemble"] & assets)
    assert assets <= gates["complete"]
    assert gates["assemble"] <= gates["ready"]
    assert gates["compile"] <= gates["ready"]


# ---------------------------------------------------------------------------
# Orchestrated builds: lifecycle progression + wait API
# ---------------------------------------------------------------------------

def test_nonblocking_build_progresses_through_stages(smoke_mesh):
    lb, pb = _builder(sim=_SIM_BPS)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    inst = lb.build(cir, cpu_smoke(), mesh=smoke_mesh, block=False)
    inst.wait("assembled")
    assert inst.model is not None and inst.entry
    inst.wait("ready")
    # deployable: every non-asset component's content is proven present
    for c in inst.bundle.components():
        if c.manager != "asset":
            assert lb.store.missing_chunks(c) == []
    inst.wait("weights")                 # first-weight-use gate
    assert inst.stage == "complete"
    rep = inst.report
    assert rep.orchestrated and rep.critical_path_s > 0
    for stage in ("fetching", "assembled", "compiled", "ready", "complete"):
        assert stage in rep.stage_s
    # accounting is final at COMPLETE: every planned chunk landed
    for c in inst.bundle.components():
        assert lb.store.missing_chunks(c) == []


def test_blocking_build_returns_complete_with_final_accounting():
    lb, pb = _builder(sim=_SIM_BPS)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    inst = lb.build(cir, tpu_single_pod(), assemble=False)
    assert inst.stage == "complete"
    assert inst.report.bytes_delta_fetched > 0
    assert inst.report.overlap_s >= 0.0


def test_barrier_mode_has_no_overlap():
    lb, pb = _builder(sim=_SIM_BPS)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    rep = lb.build(cir, tpu_single_pod(), assemble=False,
                   overlap=False).report
    assert not rep.orchestrated
    assert rep.overlap_s == 0.0
    # barrier critical path covers the full stage sum
    assert rep.critical_path_s >= rep.fetch_s


# ---------------------------------------------------------------------------
# Overlap correctness: byte-identical accounting, identical locks
# ---------------------------------------------------------------------------

_ACCOUNTING_FIELDS = ("bytes_delta_fetched", "bytes_fetched",
                      "bytes_total_components", "chunks_hit",
                      "chunks_missed", "chunks_waited", "cache_hits",
                      "cache_misses", "n_components")


def test_overlapped_and_barrier_builds_account_identically():
    spec = tpu_single_pod()
    reports, locks = {}, {}
    for mode, overlap in (("barrier", False), ("overlapped", True)):
        lb, pb = _builder(sim=_SIM_BPS)
        cir = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="serve")
        inst = lb.build(cir, spec, assemble=False, overlap=overlap)
        reports[mode], locks[mode] = inst.report, inst.lock
    for f in _ACCOUNTING_FIELDS:
        assert getattr(reports["barrier"], f) == \
            getattr(reports["overlapped"], f), f
    assert locks["barrier"].to_json() == locks["overlapped"].to_json()


def test_overlap_cuts_time_to_ready():
    """READY fires while the weight tail is still streaming; the barrier
    pipeline's READY only lands after the full fetch.  Asserted on stage
    offsets *within* each build — cross-run wall comparisons are
    scheduler-noise-prone; ``benchmarks/build_time.py pipeline_overlap``
    gates the cross-mode >=25% reduction criterion in a fresh process."""
    spec = tpu_single_pod()
    reps = {}
    # slow simulated link: the ~18 GB weight tail costs >400 ms of wall,
    # dwarfing scheduler noise from a loaded CI machine
    for mode, overlap in (("barrier", False), ("overlapped", True)):
        lb, pb = _builder(sim=5e9)
        cir = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="serve")
        reps[mode] = lb.build(cir, spec, assemble=False,
                              overlap=overlap).report
    o, b = reps["overlapped"], reps["barrier"]
    # weights are ~90% of the fetch bytes, so a READY that waited for the
    # tail would sit within a few % of COMPLETE — require a real gap
    assert o.stage_s["ready"] < 0.8 * o.stage_s["complete"]
    assert o.overlap_s > 0.0
    # the barrier build is only READY once the entire fetch has landed
    assert b.stage_s["ready"] >= b.fetch_s
    assert b.overlap_s == 0.0


def test_locked_replay_through_orchestrator_is_byte_identical():
    spec = tpu_single_pod()
    svc = catalog.build_service()
    pb = PreBuilder(svc)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    cold = LazyBuilder(svc, ChunkedComponentStore(),
                       fetch_simulate_bps=_SIM_BPS).build(
        cir, spec, assemble=False)
    replay = LazyBuilder(svc, ChunkedComponentStore(),
                         fetch_simulate_bps=_SIM_BPS).build_from_lock(
        cir, cold.lock, spec, assemble=False)
    for f in _ACCOUNTING_FIELDS:
        assert getattr(cold.report, f) == getattr(replay.report, f), f


def test_fleet_overlap_accounting_matches_barrier_under_singleflight():
    """A concurrent overlapped fleet (shared store, singleflight waits)
    transfers exactly the same unique bytes as a barrier fleet: no chunk is
    double-charged and no byte is dropped, whichever build wins a claim."""
    specs = [tpu_single_pod(), cpu_smoke(), gpu_server()]
    totals, locks = {}, {}
    for mode, overlap in (("barrier", False), ("overlapped", True)):
        svc = catalog.build_service()
        fd = FleetDeployer(svc, max_workers=3, fetch_workers=4,
                           fetch_simulate_bps=_SIM_BPS, overlap=overlap)
        cir = PreBuilder(svc).prebuild(ARCHS["starcoder2-3b"],
                                       entrypoint="serve")
        res = fd.deploy(cir, specs)
        assert res.ok, res.summary()
        assert res.n_failed == 0
        # singleflight invariant: fleet wire bytes == unique chunk bytes
        assert res.bytes_delta_total == \
            fd.store.chunk_stats.chunk_bytes_stored
        totals[mode] = (res.bytes_delta_total, res.chunks_missed_total,
                        res.chunks_hit_total + res.chunks_waited_total)
        locks[mode] = {d.platform_id: d.instance.lock.to_json()
                       for d in res.deployments}
    assert totals["barrier"] == totals["overlapped"]
    assert locks["barrier"] == locks["overlapped"]


def test_fleet_records_lifecycle_walls():
    svc = catalog.build_service()
    fd = FleetDeployer(svc, max_workers=2, fetch_simulate_bps=_SIM_BPS)
    cir = PreBuilder(svc).prebuild(ARCHS["starcoder2-3b"],
                                   entrypoint="serve")
    res = fd.deploy(cir, [tpu_single_pod(), cpu_smoke()])
    assert res.ok
    assert 0.0 < res.ready_s_wall <= res.wall_s
    assert res.stage_walls.get("ready", 0.0) > 0.0
    assert res.stage_walls["ready"] <= res.stage_walls["complete"]
    for d in res.deployments:
        assert d.report is not None
        assert 0.0 < d.ready_s <= d.wall_s


# ---------------------------------------------------------------------------
# Failure propagation
# ---------------------------------------------------------------------------

def test_fetch_error_fails_lifecycle_and_propagates(monkeypatch):
    lb, pb = _builder()
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")

    def boom(c, nbytes, nchunks):
        if c.manager == "model":
            raise RuntimeError("link down")

    monkeypatch.setattr(lb.service, "fetch_chunks", boom)
    inst = lb.build(cir, tpu_single_pod(), assemble=False, block=False)
    with pytest.raises(RuntimeError, match="link down"):
        inst.wait("ready")
    assert inst.lifecycle.error is not None
    # blocking builds raise straight from build()
    lb2, pb2 = _builder()
    monkeypatch.setattr(lb2.service, "fetch_chunks", boom)
    with pytest.raises(RuntimeError, match="link down"):
        lb2.build(pb2.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve"),
                  tpu_single_pod(), assemble=False)


def test_fleet_counts_failures_and_keeps_partial_reports(monkeypatch):
    """A failed platform is counted (n_failed) and its partial fetch work
    stays in the fleet byte accounting instead of silently vanishing."""
    from repro.core.spec import ChipSpec, SpecSheet

    svc = catalog.build_service()
    fd = FleetDeployer(svc, max_workers=2)
    cir = PreBuilder(svc).prebuild(ARCHS["starcoder2-3b"],
                                   entrypoint="serve")
    # resolution failure: a chip no env component supports
    bad = SpecSheet(platform_id="fpga-odd",
                    chip=ChipSpec(name="fpga-odd", vendor="x",
                                  peak_flops_bf16=1e9, hbm_bytes=2**30,
                                  hbm_bw=1e9, vmem_bytes=2**20,
                                  ici_bw_per_link=1e9, ici_links=1,
                                  dci_bw=1e9),
                    mesh_shape=(1,), mesh_axes=("data",))
    res = fd.deploy(cir, [tpu_single_pod(), bad])
    assert not res.ok and res.n_failed == 1
    failed = [d for d in res.deployments if not d.ok][0]
    assert failed.platform_id == "fpga-odd"
    assert failed.report is None          # never got past resolution
    ok = [d for d in res.deployments if d.ok][0]
    assert res.bytes_fetched_total == ok.report.bytes_fetched

    # mid-fetch failure: resolution succeeded, so the partial report (and
    # its real transferred bytes) must be included in the totals
    svc2 = catalog.build_service()
    fd2 = FleetDeployer(svc2, max_workers=1)
    cir2 = PreBuilder(svc2).prebuild(ARCHS["starcoder2-3b"],
                                     entrypoint="serve")

    def boom(c, nbytes, nchunks):
        if c.manager == "asset":
            raise RuntimeError("upstream 503")

    monkeypatch.setattr(svc2, "fetch_chunks", boom)
    res2 = fd2.deploy(cir2, [tpu_single_pod()])
    assert res2.n_failed == 1
    failed2 = res2.deployments[0]
    assert failed2.report is not None
    assert failed2.report.resolve_s > 0
    assert failed2.report.cache_misses > 0
    # the partial build's accounting flows into the fleet totals
    assert res2.bytes_fetched_total == failed2.report.bytes_fetched
    assert res2.bytes_delta_total == failed2.report.bytes_delta_fetched


# ---------------------------------------------------------------------------
# Satellite: probe_host maps a gpu jax backend to the GPU chip
# ---------------------------------------------------------------------------

def test_probe_host_maps_backends_to_chips(monkeypatch):
    import jax

    from repro.core import CPU_HOST, GPU_A100, TPU_V5E
    from repro.core.spec import probe_host

    monkeypatch.setattr(jax, "default_backend", lambda: "gpu")
    s = probe_host()
    assert s.chip is GPU_A100
    assert s.backend == "gpu" and s.interpret_kernels
    monkeypatch.setattr(jax, "default_backend", lambda: "cuda")
    assert probe_host().chip is GPU_A100
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert probe_host().chip is CPU_HOST
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    s = probe_host()
    assert s.chip is TPU_V5E and not s.interpret_kernels
