"""Build-plan cache + fleet deployment: the staged pipeline's hot path.

Covers the deployment-service claims: cold build populates the cache, an
identical (CIR, SpecSheet) re-deploy replays the plan and skips resolution,
a catalog-epoch bump invalidates, and fleet deploys share the store.
"""
import pytest

from repro.configs import ARCHS
from repro.core import (BuildPlanCache, LazyBuilder, LocalComponentStore,
                        PreBuilder, cpu_smoke, gpu_server, tpu_single_pod)
from repro.core.component import UniformComponent
from repro.deploy import FleetDeployer


@pytest.fixture
def pb(service):
    return PreBuilder(service)


def test_cold_build_populates_cache(service, pb):
    lb = LazyBuilder(service)
    cir = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="train")
    inst = lb.build(cir, tpu_single_pod(), assemble=False)
    assert not inst.report.plan_cache_hit
    assert len(lb.plan_cache) == 1
    assert lb.plan_cache.stats.puts == 1
    plan = next(iter(lb.plan_cache._plans.values()))
    assert plan.cir_digest == cir.digest()
    assert plan.pins == inst.lock.pins


def test_warm_redeploy_hits_and_skips_resolution(service, pb):
    lb = LazyBuilder(service)
    cir = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="train")
    spec = tpu_single_pod()
    cold = lb.build(cir, spec, assemble=False)
    warm = lb.build(cir, spec, assemble=False)
    assert warm.report.plan_cache_hit
    # the replay is the identical deployment: same lock, same components
    assert warm.lock.to_json() == cold.lock.to_json()
    assert [c.digest() for c in warm.bundle.components()] == \
        [c.digest() for c in cold.bundle.components()]
    # and it skipped resolution/fetch work: everything was in the store
    assert warm.report.bytes_fetched == 0
    assert warm.report.cache_misses == 0
    assert lb.plan_cache.stats.hits == 1


def test_replay_context_matches_resolved_context(service, pb):
    """Replayed bundles carry the component context contributions — the
    assembler reads e.g. attn.impl from there."""
    lb = LazyBuilder(service)
    cir = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="train")
    spec = tpu_single_pod()
    cold = lb.build(cir, spec, assemble=False)
    warm = lb.build(cir, spec, assemble=False)
    assert warm.report.plan_cache_hit
    assert warm.bundle.context == cold.bundle.context


def test_different_overrides_do_not_share_plans(service, pb):
    lb = LazyBuilder(service)
    cir = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="serve")
    spec = tpu_single_pod()
    a = lb.build(cir, spec, assemble=False, overrides={"workload": "prefill"})
    b = lb.build(cir, spec, assemble=False, overrides={"workload": "decode"})
    assert not b.report.plan_cache_hit
    plan_of = lambda i: {(c.manager, c.name): c.env
                         for c in i.bundle.components()}[("parallel", "plan")]
    assert plan_of(a) != plan_of(b)


def test_catalog_epoch_bump_invalidates(service, pb):
    lb = LazyBuilder(service)
    cir = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="train")
    spec = tpu_single_pod()
    lb.build(cir, spec, assemble=False)
    epoch0 = service.catalog_epoch
    # catalog content changes: a new component lands upstream (an inert one
    # nothing resolves to — only the epoch change matters here)
    newcomp = UniformComponent(
        manager="test-only", name="inert", version="1.0", env="generic",
        payload="none", size_bytes=1)
    service.registry.register(newcomp)
    assert service.catalog_epoch != epoch0
    redo = lb.build(cir, spec, assemble=False)
    assert not redo.report.plan_cache_hit   # old plan keyed at old epoch
    # identical re-registration must NOT change the epoch (stable catalogs
    # keep their plans warm across service rebuilds)
    epoch1 = service.catalog_epoch
    service.registry.register(newcomp)
    assert service.catalog_epoch == epoch1
    again = lb.build(cir, spec, assemble=False)
    assert again.report.plan_cache_hit


def test_plan_cache_persists_to_disk(service, pb, tmp_path):
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="train")
    spec = cpu_smoke()
    cache_dir = str(tmp_path / "plans")
    lb1 = LazyBuilder(service, plan_cache=BuildPlanCache(cache_dir))
    lb1.build(cir, spec, assemble=False)
    # a new process (fresh builder, fresh store) reloads the plans
    lb2 = LazyBuilder(service, LocalComponentStore(),
                      plan_cache=BuildPlanCache(cache_dir))
    inst = lb2.build(cir, spec, assemble=False)
    assert inst.report.plan_cache_hit


def test_plan_cache_survives_restart_with_rebuilt_catalog(pb, tmp_path):
    """The catalog epoch is a content fingerprint, not a registration
    counter: a restarted process that rebuilds the same catalog from
    scratch must still hit plans persisted by the previous process."""
    from repro.core import catalog
    cache_dir = str(tmp_path / "plans")
    spec = cpu_smoke()

    svc1 = catalog.build_service()
    pb1 = PreBuilder(svc1)
    cir = pb1.prebuild(ARCHS["starcoder2-3b"], entrypoint="train")
    lb1 = LazyBuilder(svc1, plan_cache=BuildPlanCache(cache_dir))
    cold = lb1.build(cir, spec, assemble=False)
    assert not cold.report.plan_cache_hit

    # "restart": a brand-new service with its own freshly-built registry
    svc2 = catalog.build_service()
    assert svc2.catalog_epoch == svc1.catalog_epoch
    lb2 = LazyBuilder(svc2, LocalComponentStore(),
                      plan_cache=BuildPlanCache(cache_dir))
    warm = lb2.build(cir, spec, assemble=False)
    assert warm.report.plan_cache_hit
    assert warm.lock.to_json() == cold.lock.to_json()


def test_corrupt_persisted_plan_is_a_miss(service, pb, tmp_path):
    import os
    cache_dir = str(tmp_path / "plans")
    lb1 = LazyBuilder(service, plan_cache=BuildPlanCache(cache_dir))
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="train")
    lb1.build(cir, cpu_smoke(), assemble=False)
    for fn in os.listdir(cache_dir):
        with open(os.path.join(cache_dir, fn), "w") as f:
            f.write("not json {{{")
    lb2 = LazyBuilder(service, LocalComponentStore(),
                      plan_cache=BuildPlanCache(cache_dir))   # must not raise
    inst = lb2.build(cir, cpu_smoke(), assemble=False)
    assert not inst.report.plan_cache_hit   # torn entry = miss, rebuilt


def test_fleet_deploy_shares_components(service, pb):
    """One CIR to 3 heterogeneous specs: the shared store dedups, so the
    fleet sharing rate is nonzero and later platforms fetch less than the
    bytes their components total."""
    fd = FleetDeployer(service)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="train")
    specs = [tpu_single_pod(), cpu_smoke(), gpu_server()]
    res = fd.deploy(cir, specs)
    assert res.ok
    assert len(res.deployments) == 3
    assert res.sharing_rate > 0
    assert fd.store.stats.sharing_rate > 0
    assert res.bytes_fetched_total < res.bytes_components_total
    # every platform resolved to its own env variant despite sharing
    envs = {d.platform_id: {(c.manager, c.name): c.env
                            for c in d.instance.bundle.components()}
            for d in res.deployments}
    assert envs["tpu-v5e-16x16"][("env", "runtime-base")] == "tpu-v5e"
    assert envs["cpu-smoke-1"][("env", "runtime-base")] == "cpu-host"
    assert envs["gpu-a100-8"][("env", "runtime-base")] == "gpu-a100"


def test_fleet_redeploy_replays_all_plans(service, pb):
    fd = FleetDeployer(service)
    cir = pb.prebuild(ARCHS["phi4-mini-3.8b"], entrypoint="train")
    specs = [tpu_single_pod(), cpu_smoke(), gpu_server()]
    assert fd.warm(cir, specs) == 3
    res = fd.deploy(cir, specs)
    assert res.plan_cache_hits == 3
    assert res.bytes_fetched_total == 0   # everything already in the store
    assert all(d.instance.report.plan_cache_hit for d in res.deployments)


def test_locked_rebuild_still_bit_identical(service, pb):
    """The staged pipeline must not change CIR-locked semantics."""
    lb = LazyBuilder(service)
    cir = pb.prebuild(ARCHS["dbrx-132b"], entrypoint="train")
    spec = tpu_single_pod()
    inst = lb.build(cir, spec, assemble=False)
    relock = lb.build_from_lock(cir, inst.lock, spec, assemble=False)
    assert [c.digest() for c in relock.bundle.components()] == \
        list(inst.lock.digests)
    assert relock.report.locked


# ---------------------------------------------------------------------------
# LRU cap (long-lived deployment services must not grow the cache forever)
# ---------------------------------------------------------------------------

def _plan(tag: str):
    from repro.core import BuildPlan
    return BuildPlan(cir_digest=tag, spec_digest="s", catalog_epoch="e",
                     pins=(("model", "m", "1.0", "env"),), digests=(tag,))


def test_plan_cache_lru_cap_evicts_oldest():
    cache = BuildPlanCache(max_entries=2)
    cache.put("k1", _plan("a"))
    cache.put("k2", _plan("b"))
    cache.put("k3", _plan("c"))
    assert len(cache) == 2
    assert cache.get("k1") is None          # evicted (oldest)
    assert cache.get("k2") is not None
    assert cache.get("k3") is not None
    assert cache.stats.evictions == 1


def test_plan_cache_lru_get_refreshes_recency():
    cache = BuildPlanCache(max_entries=2)
    cache.put("k1", _plan("a"))
    cache.put("k2", _plan("b"))
    assert cache.get("k1") is not None      # k1 now most recent
    cache.put("k3", _plan("c"))
    assert cache.get("k2") is None          # k2 was LRU
    assert cache.get("k1") is not None
    assert cache.stats.evictions == 1


def test_plan_cache_lru_cap_on_disk(tmp_path):
    import os
    path = str(tmp_path / "plans")
    cache = BuildPlanCache(path, max_entries=2)
    for i in range(4):
        cache.put(f"k{i}", _plan(str(i)))
    assert len(cache) == 2
    assert cache.stats.evictions == 2
    on_disk = {fn for fn in os.listdir(path) if fn.endswith(".json")}
    assert on_disk == {"k2.json", "k3.json"}    # evicted files removed
    # a restart over an over-full directory trims to the cap too
    cache2 = BuildPlanCache(path, max_entries=1)
    assert len(cache2) == 1
    assert cache2.stats.evictions == 1


def test_plan_cache_lru_builder_integration(service, pb):
    """A capped cache keeps serving the hot path: the newest plan replays,
    the oldest is recomputed on demand."""
    cache = BuildPlanCache(max_entries=1)
    lb = LazyBuilder(service, plan_cache=cache)
    cir = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="train")
    lb.build(cir, tpu_single_pod(), assemble=False)
    lb.build(cir, cpu_smoke(), assemble=False)       # evicts the tpu plan
    assert len(cache) == 1
    assert cache.stats.evictions == 1
    warm = lb.build(cir, cpu_smoke(), assemble=False)
    assert warm.report.plan_cache_hit
    redo = lb.build(cir, tpu_single_pod(), assemble=False)
    assert not redo.report.plan_cache_hit            # evicted → re-resolved


def test_plan_cache_eviction_racing_warm_re_resolves(service, pb):
    """LRU eviction racing a concurrent ``FleetDeployer.warm()``: a plan
    evicted mid-warm must be re-resolved on the next use, never replayed
    as a dangling lock — warm completes, the follow-up deploy succeeds,
    and its lock matches a clean re-resolution."""
    import threading

    cache = BuildPlanCache(max_entries=1)
    fd = FleetDeployer(service, plan_cache=cache, max_workers=2)
    cir_a = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="train")
    cir_b = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="train")
    specs = [tpu_single_pod(), gpu_server()]

    stop = threading.Event()
    churn_errors = []

    def churn():
        # a competing workload keeps pushing its own plans through the
        # 1-entry cache, evicting warm()'s entries while warm is running
        while not stop.is_set():
            try:
                fd.builder.build(cir_b, cpu_smoke(), assemble=False)
            except Exception as e:  # noqa: BLE001 — fail the test below
                churn_errors.append(e)
                return

    th = threading.Thread(target=churn)
    th.start()
    try:
        assert fd.warm(cir_a, specs) == len(specs)
    finally:
        stop.set()
        th.join()
    assert not churn_errors
    assert cache.stats.evictions > 0                 # the race happened

    res = fd.deploy(cir_a, specs)
    assert res.ok
    # whatever the cache did, the deploy's pins equal a fresh resolution's
    clean = LazyBuilder(service).build(cir_a, tpu_single_pod(),
                                       assemble=False)
    assert res.instance(tpu_single_pod().platform_id).lock.to_json() == \
        clean.lock.to_json()
