"""Multi-pod dry-run smoke: one cheap cell on each production mesh, in a
subprocess (XLA_FLAGS must precede jax init, so it cannot run in-process).
The full 40-cell sweep artifacts live in artifacts/dryrun/."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, env=env, cwd=REPO, timeout=1200)


@pytest.mark.slow
def test_dryrun_single_pod_cell():
    r = _run(["--arch", "rwkv6-1.6b", "--shape", "long_500k"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "0 failures" in r.stdout
    art = os.path.join(REPO, "artifacts", "dryrun",
                       "rwkv6-1.6b__long_500k__16x16.json")
    assert os.path.exists(art)
    with open(art) as f:
        a = json.load(f)
    assert a["chips"] == 256
    assert a["hlo_stats"]["flops_per_device"] > 0
    assert a["memory"]["peak_bytes"] < 16 * 2**30     # fits v5e HBM


@pytest.mark.slow
def test_dryrun_multi_pod_cell():
    r = _run(["--arch", "rwkv6-1.6b", "--shape", "long_500k",
              "--multi-pod"])
    assert r.returncode == 0, r.stderr[-2000:]
    art = os.path.join(REPO, "artifacts", "dryrun",
                       "rwkv6-1.6b__long_500k__2x16x16.json")
    with open(art) as f:
        a = json.load(f)
    assert a["chips"] == 512
    assert a["mesh"] == "2x16x16"


def test_shape_applicability_rules():
    from repro.configs import ARCHS
    from repro.launch.mesh import SHAPES, applicable, live_cells
    # full-attention archs skip long_500k
    ok, why = applicable(ARCHS["codeqwen1.5-7b"], SHAPES["long_500k"])
    assert not ok and "full-attention" in why
    for aid in ("gemma2-9b", "rwkv6-1.6b", "jamba-v0.1-52b"):
        ok, _ = applicable(ARCHS[aid], SHAPES["long_500k"])
        assert ok, aid
    cells = live_cells(list(ARCHS), ARCHS)
    assert len(cells) == 33      # 10x3 + 3 long-context


def test_grad_accum_suggestion_scales_with_model():
    from repro.configs import ARCHS
    from repro.core import tpu_single_pod
    from repro.launch.mesh import SHAPES, suggest_grad_accum
    spec = tpu_single_pod()
    small = suggest_grad_accum(ARCHS["starcoder2-3b"], SHAPES["train_4k"],
                               spec)
    big = suggest_grad_accum(ARCHS["deepseek-v3-671b"], SHAPES["train_4k"],
                             spec)
    assert big >= small >= 2
    assert suggest_grad_accum(ARCHS["starcoder2-3b"],
                              SHAPES["decode_32k"], spec) == 0
