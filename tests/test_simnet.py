"""Discrete-event transport core (repro.core.simnet).

Unit coverage for the virtual clock, per-link FIFO reservation and the
seeded ``FaultPlan``, plus the contract that makes the simulated
transport trustworthy at all: a property-style sweep over randomized
(seeded) topologies of 2–32 nodes asserting the simulated path's
per-node byte accounting — upstream/peer split, delta, refetch — is
**byte-identical** to the threaded engine's.
"""
import dataclasses
import math
import random

import pytest

from repro.configs import ARCHS
from repro.core import (FaultPlan, LinkDownError, NodeDownError, PreBuilder,
                        SimClock, SimNetwork, UPSTREAM, WallClockTransport,
                        cpu_smoke, tpu_single_pod)
from repro.core.simnet import Fault
from repro.deploy import FleetDeployer, FleetTopology

ARCH = "starcoder2-3b"


# ---------------------------------------------------------------------------
# SimClock
# ---------------------------------------------------------------------------

def test_clock_starts_at_zero_and_is_monotonic():
    clk = SimClock()
    assert clk.now == 0.0
    clk.advance_to(5.0)
    clk.advance_to(3.0)          # never goes backwards
    assert clk.now == 5.0
    clk.sleep(2.5)
    assert clk.now == 7.5


def test_clock_fires_scheduled_events_in_time_order():
    clk = SimClock()
    fired = []
    clk.schedule(3.0, lambda: fired.append("b"))
    clk.schedule(1.0, lambda: fired.append("a"))
    clk.schedule(9.0, lambda: fired.append("late"))
    clk.advance_to(5.0)
    assert fired == ["a", "b"]   # time order, not scheduling order
    clk.sleep(10.0)              # sleep fires due events too
    assert fired == ["a", "b", "late"]


def test_clock_link_reservation_serializes_per_key():
    clk = SimClock()
    s1, e1 = clk.reserve("link", 4.0)
    assert (s1, e1) == (0.0, 4.0)
    # same link: FIFO behind the previous transfer's completion event
    s2, e2 = clk.reserve("link", 2.0)
    assert (s2, e2) == (4.0, 6.0)
    # a different link is independent, but virtual time already advanced
    s3, e3 = clk.reserve("other", 1.0)
    assert s3 == 6.0 and e3 == 7.0
    assert clk.now == 7.0


def test_clock_rejected_admission_reserves_nothing():
    clk = SimClock()

    def veto(t0, t1):
        raise LinkDownError("a", "b", until=9.0)

    with pytest.raises(LinkDownError):
        clk.reserve("link", 4.0, admission=veto)
    assert clk.now == 0.0                      # no time passed
    assert clk.reserve("link", 1.0) == (0.0, 1.0)   # link was never busied


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------

def test_fault_windows_and_queries():
    plan = FaultPlan()
    plan.node_loss("n1", at=10.0)                     # permanent
    plan.link_flap("a", "b", at=2.0, until=4.0)
    plan.partition(["edge"], at=5.0, until=8.0)

    assert plan.node_alive("n1", 9.9) and not plan.node_alive("n1", 10.0)
    assert not plan.node_alive("n1", 1e9)             # never heals
    assert plan.link_outage_in("a", "b", 0.0, 2.0) is None   # [t0, t1)
    assert plan.link_outage_in("b", "a", 3.0, 3.5) is not None   # symmetric
    # the partition cuts peer links crossing the boundary ...
    assert plan.link_outage_in("edge", "other", 6.0, 7.0) is not None
    # ... not links inside either side, and never the upstream registry
    assert plan.link_outage_in("other", "third", 6.0, 7.0) is None
    assert plan.link_outage_in("edge", UPSTREAM, 6.0, 7.0) is None


def test_fault_admission_raises_typed_errors():
    plan = FaultPlan()
    plan.node_loss("src", at=5.0)
    plan.link_flap("dst", UPSTREAM, at=1.0, until=2.0)

    plan.check_transfer("dst", "src", 0.0, 4.0)       # clean window
    with pytest.raises(NodeDownError) as ei:
        plan.check_transfer("dst", "src", 4.0, 6.0)   # src dies mid-window
    assert ei.value.node_id == "src"
    with pytest.raises(LinkDownError) as ei:
        plan.check_transfer("dst", UPSTREAM, 1.5, 3.0)
    assert ei.value.until == 2.0                      # honest retry hint
    # the puller's own death beats any link state
    plan.node_loss("dst", at=0.0)
    with pytest.raises(NodeDownError) as ei:
        plan.check_transfer("dst", UPSTREAM, 0.0, 1.0)
    assert ei.value.node_id == "dst"


def test_fault_kind_and_window_validation():
    with pytest.raises(ValueError):
        Fault("meteor-strike", 0.0, 1.0)
    with pytest.raises(ValueError):
        Fault("link-flap", 2.0, 2.0)


def test_random_fault_plan_is_seed_deterministic():
    topo = FleetTopology.edge_fanout(6)
    a = FaultPlan.random(topo, seed=7, n_faults=6, protect=("cloud",))
    b = FaultPlan.random(topo, seed=7, n_faults=6, protect=("cloud",))
    assert a.faults == b.faults and len(a) == 6
    c = FaultPlan.random(topo, seed=8, n_faults=6, protect=("cloud",))
    assert a.faults != c.faults
    for f in a.faults:
        assert "cloud" not in f.nodes            # protected node untouched


# ---------------------------------------------------------------------------
# SimNetwork / transports
# ---------------------------------------------------------------------------

def _two_nodes() -> FleetTopology:
    topo = FleetTopology()
    topo.add_node("a", upstream_bps=100.0, seed=True)
    topo.add_node("b", upstream_bps=50.0)
    topo.link("a", "b", 200.0)
    return topo


def test_simnetwork_transfer_durations_and_counters():
    net = SimNetwork(_two_nodes())
    ta = net.transport_for("a")
    assert ta.upstream_transfer(400) == pytest.approx(4.0)   # 400 B @ 100 B/s
    assert ta.peer_transfer("b", 400) == pytest.approx(2.0)  # 400 B @ 200 B/s
    assert net.clock.now == pytest.approx(6.0)
    assert net.n_transfers == 2 and net.bytes_moved == 800
    with pytest.raises(KeyError):
        net.transport_for("nope")
    with pytest.raises(ValueError):
        net.transfer("a", "zzz", 100)            # no such link


def test_simnetwork_node_loss_event_fires_hooks():
    net = SimNetwork(_two_nodes())
    lost = []
    net.on_node_loss(lost.append)
    net.inject_node_loss("b", at=3.0)
    net.transport_for("a").upstream_transfer(100)    # clock: 0 -> 1.0
    assert lost == [] and net.faults_fired == 0
    net.clock.sleep(5.0)                             # passes t=3.0
    assert lost == ["b"] and net.faults_fired == 1


def test_wall_clock_transport_is_inert_without_bps():
    t = WallClockTransport()
    assert t.upstream_transfer(10**12) == 0.0        # no bps -> no sleep
    assert t.peer_transfer("x", 10**12) == 0.0
    assert t.upstream_transfer(100, bps=1e9) == pytest.approx(1e-7)


def test_fleet_deployer_simnet_validation(service):
    topo = _two_nodes()
    other = _two_nodes()
    with pytest.raises(ValueError):
        FleetDeployer(service, simnet=SimNetwork(topo))      # no topology
    with pytest.raises(ValueError):
        FleetDeployer(service, topology=topo,
                      simnet=SimNetwork(other))              # wrong topology
    with pytest.raises(ValueError):
        FleetDeployer(service, topology=topo, simnet=SimNetwork(topo),
                      simulate_links=True)                   # wall + virtual


# ---------------------------------------------------------------------------
# Accounting identity: simulated transport == threaded engine, per node
# ---------------------------------------------------------------------------

def _random_topology(seed: int, n_nodes: int) -> FleetTopology:
    """A seeded random fleet: node 0 is the well-connected seed; every
    other node gets a random upstream bandwidth, a likely link to the
    seed and a few random peer links (some nodes may end up unlinked —
    they must deploy purely upstream)."""
    rng = random.Random(seed)
    pool = (5e6, 2.5e7, 1.25e8, 6.25e8)
    topo = FleetTopology()
    ids = [f"n{i}" for i in range(n_nodes)]
    topo.add_node(ids[0], upstream_bps=1.25e9, seed=True)
    for nid in ids[1:]:
        topo.add_node(nid, upstream_bps=rng.choice(pool))
        if rng.random() < 0.8:
            topo.link(ids[0], nid, rng.choice(pool))
    for _ in range(n_nodes):
        a, b = rng.sample(ids, 2)
        if topo.bandwidth(a, b) is None:
            topo.link(a, b, rng.choice(pool))
    return topo


def _place_specs(topo: FleetTopology):
    seed_spec = tpu_single_pod()
    topo.place(seed_spec.platform_id, topo.seed)
    others = []
    for nid in topo.node_ids():
        if nid == topo.seed:
            continue
        s = dataclasses.replace(cpu_smoke(), platform_id=f"plat-{nid}")
        topo.place(s.platform_id, nid)
        others.append(s)
    return seed_spec, others


def _deploy_accounting(service, cir, seed: int, n_nodes: int,
                       simulated: bool):
    """Seed node first, the rest sequentially (``max_workers=1`` +
    ``fetch_workers=1``: the deterministic configuration §9 documents),
    returning the per-node accounting tuple."""
    topo = _random_topology(seed, n_nodes)
    seed_spec, others = _place_specs(topo)
    net = SimNetwork(topo) if simulated else None
    fd = FleetDeployer(service, topology=topo, simnet=net,
                       max_workers=1, fetch_workers=1)
    out = {}
    for res in (fd.deploy(cir, [seed_spec]), fd.deploy(cir, others)):
        assert res.ok, res.summary()
        for d in res.deployments:
            t = res.node_traffic[d.node_id]
            r = d.report
            assert t.bytes_total == r.bytes_delta_fetched
            assert r.bytes_delta_fetched <= r.bytes_fetched
            out[d.node_id] = (
                t.bytes_from_upstream, t.bytes_from_peers,
                t.peer_fallbacks, dict(t.peer_sources),
                r.bytes_delta_fetched, r.bytes_fetched,
                r.chunks_hit, r.chunks_missed, r.chunks_waited,
                fd.node_store(d.node_id).lifecycle_stats.refetch_bytes,
            )
    return out


@pytest.fixture(scope="module")
def cir(service):
    return PreBuilder(service).prebuild(ARCHS[ARCH], entrypoint="serve")


@pytest.mark.parametrize("seed,n_nodes",
                         [(0, 2), (1, 5), (2, 11), (3, 32)])
def test_sim_accounting_identical_to_threaded(service, cir, seed, n_nodes):
    threaded = _deploy_accounting(service, cir, seed, n_nodes,
                                  simulated=False)
    sim = _deploy_accounting(service, cir, seed, n_nodes, simulated=True)
    assert sim == threaded


def test_sim_refetch_identity_on_bounded_node(service, cir):
    """Eviction-triggered refetch accounting must match too: a
    capacity-bounded edge churns between two CIRs, and the re-fetched
    bytes of the re-deploy are identical under both transports."""
    other = PreBuilder(service).prebuild(ARCHS["phi4-mini-3.8b"],
                                         entrypoint="serve")

    def run(capacity, simulated):
        topo = FleetTopology()
        topo.add_node("cloud", upstream_bps=1.25e9, seed=True)
        topo.add_node("edge", upstream_bps=6.25e6, capacity_bytes=capacity)
        topo.link("cloud", "edge", 1.25e8)
        spec = dataclasses.replace(cpu_smoke(), platform_id="plat-edge")
        topo.place(spec.platform_id, "edge")
        net = SimNetwork(topo) if simulated else None
        fd = FleetDeployer(service, topology=topo, simnet=net,
                           max_workers=1, fetch_workers=1)
        results = [fd.deploy(c, [spec]) for c in (cir, other, cir)]
        assert all(r.ok for r in results)
        t = fd.node_traffic("edge")
        return (t.bytes_from_upstream, t.bytes_from_peers,
                fd.node_store("edge").lifecycle_stats.refetch_bytes,
                results[-1].refetch_bytes_total)

    # size the budget off an unbounded measuring pass: big enough for one
    # CIR's working set, too small for both -> the second deploy evicts
    unbounded = run(None, simulated=False)
    capacity = int(unbounded[0] * 0.75)
    threaded = run(capacity, simulated=False)
    sim = run(capacity, simulated=True)
    assert threaded == sim
    assert threaded[2] > 0, "capacity never forced a refetch"


def test_sim_deploy_reports_virtual_elapsed(service, cir):
    topo = FleetTopology.edge_fanout(2)
    seed_spec, others = _place_specs(topo)
    net = SimNetwork(topo)
    fd = FleetDeployer(service, topology=topo, simnet=net, max_workers=1,
                       fetch_workers=1)
    r0 = fd.deploy(cir, [seed_spec])
    r1 = fd.deploy(cir, others)
    assert r0.ok and r1.ok
    # virtual link time dwarfs wall time, and the deltas partition the
    # clock: WAN seconds elapsed without wall-clock sleeping
    assert r0.sim_elapsed_s > 0 and r1.sim_elapsed_s > 0
    assert r0.sim_elapsed_s + r1.sim_elapsed_s == \
        pytest.approx(net.clock.now)
    assert r0.wall_s + r1.wall_s < r0.sim_elapsed_s + r1.sim_elapsed_s
    assert math.isfinite(net.clock.now)
