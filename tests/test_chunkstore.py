"""Chunk-addressed delta fetch + the concurrent fetch engine.

Covers the live chunk layer's claims: deterministic exact partitioning,
version-bump re-deploys paying only the unshared delta, fleet singleflight
(no chunk charged twice, even mid-flight), lockfile-replay accounting
determinism, fetch priority ordering, and the upstream converted-index /
negative-cache fast path.
"""
import threading
import time

import pytest

from repro.configs import ARCHS
from repro.core import (ChunkedComponentStore, FetchEngine, LazyBuilder,
                        PreBuilder, component_pieces, cpu_smoke, gpu_server,
                        tpu_single_pod)
from repro.core import catalog
from repro.core.component import UniformComponent
from repro.core.lazybuild import BuildReport
from repro.core.registry import (UniformComponentRegistry,
                                 UniformComponentService, UpstreamSource)
from repro.deploy import FleetDeployer


def _c(name, version="1.0", env="e", size=1000, manager="m"):
    return UniformComponent(manager=manager, name=name, version=version,
                            env=env, payload="p", size_bytes=size)


def _service():
    return catalog.build_service()


# ---------------------------------------------------------------------------
# Chunk model
# ---------------------------------------------------------------------------

def test_pieces_partition_exactly():
    c = _c("a", size=10_000)
    pieces = component_pieces(c, 1024)
    assert sum(p.size for p in pieces) == 10_000
    assert len(pieces) == 10          # ceil(10000/1024)
    assert len({p.id for p in pieces}) == len(pieces)
    # the (short) tail chunk is never part of the shared prefix
    assert not pieces[-1].shared


def test_shared_pieces_stable_across_versions_and_envs():
    a = _c("a", version="1.0", env="x", size=40_960)
    b = _c("a", version="2.0", env="y", size=40_960)
    pa = component_pieces(a, 1024)
    pb = component_pieces(b, 1024)
    shared_a = [p.id for p in pa if p.shared]
    shared_b = [p.id for p in pb if p.shared]
    assert shared_a and shared_a == shared_b       # survives the bump
    priv_a = {p.id for p in pa if not p.shared}
    priv_b = {p.id for p in pb if not p.shared}
    assert not priv_a & priv_b                     # digests differ
    # a different name shares nothing
    other = component_pieces(_c("z", size=40_960), 1024)
    assert not {p.id for p in other} & {p.id for p in pa}


def test_piece_digest_has_no_prefix_collisions():
    from repro.core.store import piece_digest
    # without length-prefixed joining these two collide
    assert piece_digest(["pip", "foo1", "2", "4194304"]) != \
        piece_digest(["pip", "foo", "12", "4194304"])


def test_put_registers_chunks():
    s = ChunkedComponentStore(chunk_size=1024)
    c = _c("a", size=10_000)
    assert s.put(c) is True
    assert s.chunk_count() == 10
    assert s.chunk_stats.chunk_bytes_stored == 10_000
    assert s.put(c) is False                       # component-level hit
    assert s.chunk_stats.chunk_bytes_stored == 10_000


def test_delta_plan_charges_only_unshared_chunks():
    s = ChunkedComponentStore(chunk_size=1024)
    v1 = _c("a", version="1.0", size=100 * 1024)
    s.put(v1)
    v2 = _c("a", version="2.0", size=100 * 1024)
    plan = s.plan_fetch(v2)
    assert plan.component_new
    n = len(s.chunks_of(v2))
    shared = int(n * s.shared_fraction)
    assert len(plan.hits) == shared
    assert len(plan.claimed) == n - shared
    assert plan.bytes_claimed < v2.size_bytes
    s.commit_chunks(plan.claimed)
    assert s.chunk_stats.chunk_bytes_stored == \
        v1.size_bytes + plan.bytes_claimed


# ---------------------------------------------------------------------------
# Delta fetch through the lazy-builder
# ---------------------------------------------------------------------------

def _bump_weights(service, arch_id, new_version="2025.12.9"):
    from benchmarks.common import bump_asset_version
    bump_asset_version(service, arch_id, new_version)


def test_version_bump_redeploy_fetches_only_delta():
    svc = _service()
    pb = PreBuilder(svc)
    lb = LazyBuilder(svc)
    spec = tpu_single_pod()
    cir = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="serve")
    cold = lb.build(cir, spec, assemble=False).report
    assert cold.chunked_fetch
    assert cold.bytes_delta_fetched == cold.bytes_fetched   # nothing shared

    _bump_weights(svc, "gemma2-9b")
    bump = lb.build(cir, spec, assemble=False).report
    weights = [c for c in lb.store.digests()
               if lb.store.get(c).name == "weights-gemma2-9b"]
    assert len(weights) == 2                      # both versions stored
    # the bumped component is a component-level miss...
    assert bump.cache_misses == 1
    # ...whose wire cost is only the unshared chunk fraction
    assert 0 < bump.bytes_delta_fetched < bump.bytes_fetched
    saved = 1 - bump.bytes_delta_fetched / bump.bytes_fetched
    assert abs(saved - 0.3) < 0.01                # the shared fraction
    assert bump.chunks_hit > 0
    # modeled deploy time improves accordingly
    assert bump.network_time(500e6) < cold.network_time(500e6)


def test_lock_replay_chunk_accounting_is_byte_identical():
    svc = _service()
    pb = PreBuilder(svc)
    spec = tpu_single_pod()
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    cold = LazyBuilder(svc).build(cir, spec, assemble=False)
    replay = LazyBuilder(svc).build_from_lock(cir, cold.lock, spec,
                                              assemble=False)
    a, b = cold.report, replay.report
    assert (a.bytes_delta_fetched, a.chunks_hit, a.chunks_missed) == \
        (b.bytes_delta_fetched, b.chunks_hit, b.chunks_missed)
    assert a.bytes_fetched == b.bytes_fetched
    assert b.chunked_fetch


def test_fetch_priority_orders_model_before_assets():
    svc = _service()
    pb = PreBuilder(svc)
    lb = LazyBuilder(svc, fetch_workers=1)
    seen = []
    orig = svc.fetch_chunks

    def spy(c, nbytes, nchunks=1):
        seen.append(c.manager)
        return orig(c, nbytes, nchunks)

    svc.fetch_chunks = spy
    cir = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="serve")
    lb.build(cir, tpu_single_pod(), assemble=False)
    assert "model" in seen and "asset" in seen
    assert seen.index("model") < seen.index("asset")
    assert max(i for i, m in enumerate(seen) if m == "asset") == len(seen) - 1


# ---------------------------------------------------------------------------
# Fleet / concurrency
# ---------------------------------------------------------------------------

def test_fleet_never_double_charges_a_chunk():
    svc = _service()
    pb = PreBuilder(svc)
    fd = FleetDeployer(svc, max_workers=4)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="train")
    res = fd.deploy(cir, [tpu_single_pod(), cpu_smoke(), gpu_server()])
    assert res.ok
    # every wire byte across the fleet corresponds to exactly one stored
    # chunk — shared chunks (cross-env runtime-base prefix) included
    assert res.bytes_delta_total == fd.store.chunk_stats.chunk_bytes_stored
    assert svc.bytes_served == res.bytes_delta_total
    # chunk-level wire is never more than component-level accounting
    assert res.bytes_delta_total <= res.bytes_fetched_total


def test_same_digest_hit_barriers_on_inflight_transfer():
    """A component-level hit while the first build of the SAME digest is
    still transferring must carry barrier events — assembly must not race
    ahead of content that is mid-flight."""
    s = ChunkedComponentStore(chunk_size=1024)
    c = _c("a", size=10_240)
    first = s.plan_fetch(c)
    assert first.component_new and first.claimed
    second = s.plan_fetch(c)
    assert not second.component_new
    assert second.barriers                     # still in flight
    s.commit_chunks(first.claimed)
    third = s.plan_fetch(c)
    assert not third.barriers                  # transfer done, plain hit


def test_aborted_fetch_is_repaired_by_next_build():
    """An aborted transfer leaves the component registered but incomplete;
    the next build of the same digest must re-plan and re-claim the missing
    chunks instead of trusting the component-level hit."""
    s = ChunkedComponentStore(chunk_size=1024)
    c = _c("a", size=10_240)
    plan = s.plan_fetch(c)
    committed, lost = plan.claimed[:3], plan.claimed[3:]
    s.commit_chunks(committed, component=c)
    s.abort_chunks(lost, component=c)          # fetch died mid-transfer
    retry = s.plan_fetch(c)
    assert not retry.component_new             # digest already registered
    assert len(retry.claimed) == len(lost)     # missing chunks re-claimed
    assert len(retry.hits) == len(committed)
    s.commit_chunks(retry.claimed, component=c)
    assert s.chunk_stats.chunk_bytes_stored == c.size_bytes
    done = s.plan_fetch(c)                     # fully repaired: plain hit
    assert not done.claimed and not done.barriers


def test_waiter_reclaims_chunk_aborted_by_other_build():
    """Build B waits on a shared chunk claimed by build A of a sibling
    version; A's fetch aborts.  B must be able to re-claim the orphaned
    chunk so its component never ends up present-with-holes."""
    s = ChunkedComponentStore(chunk_size=1024)
    v1 = _c("a", version="1.0", size=100 * 1024)
    v2 = _c("a", version="2.0", size=100 * 1024)
    plan_a = s.plan_fetch(v1)
    plan_b = s.plan_fetch(v2)
    assert plan_b.waits                       # shared prefix in flight under A
    s.abort_chunks(plan_a.claimed, component=v1)
    orphans = s.reclaim_chunks([ch for ch, _ev in plan_b.waits])
    assert {ch.id for ch, _ev in orphans} == \
        {ch.id for ch, _ev in plan_b.waits}
    s.commit_chunks(orphans, component=v2)
    s.commit_chunks(plan_b.claimed, component=v2)
    assert all(s.has_chunk(ch.id) for ch in s.chunks_of(v2))
    # v1 stays marked incomplete until its next build re-plans
    retry = s.plan_fetch(v1)
    assert not retry.component_new and retry.claimed


def test_barrier_hit_repairs_aborted_same_digest():
    """A component-level hit that barriered on an aborted same-digest
    transfer must be able to re-claim the whole component's missing
    chunks via reclaim_component."""
    s = ChunkedComponentStore(chunk_size=1024)
    c = _c("a", size=10_240)
    plan_a = s.plan_fetch(c)
    plan_b = s.plan_fetch(c)
    assert plan_b.barriers
    s.abort_chunks(plan_a.claimed, component=c)
    orphans = s.reclaim_component(c)
    assert len(orphans) == len(plan_a.claimed)
    s.commit_chunks(orphans, component=c)
    assert all(s.has_chunk(ch.id) for ch in s.chunks_of(c))
    assert not s.reclaim_component(c)          # healthy: nothing to repair


def test_crash_mid_transfer_is_not_persisted(tmp_path):
    """A path-backed store must not reload a component whose transfer never
    completed as present-with-holes: the JSON is persisted only once every
    claimed chunk has been committed."""
    path = str(tmp_path / "store")
    s1 = ChunkedComponentStore(path, chunk_size=1024)
    c = _c("a", size=10_240)
    plan = s1.plan_fetch(c)                    # claims, then "crash" —
    s2 = ChunkedComponentStore(path, chunk_size=1024)   # restart
    assert not s2.has(c)                       # never advertised
    assert s2.chunk_count() == 0
    s1.commit_chunks(plan.claimed, component=c)   # transfer completes
    s3 = ChunkedComponentStore(path, chunk_size=1024)
    assert s3.has(c)
    assert s3.chunk_count() == len(s1.chunks_of(c))


def test_rescan_build_is_accounted_as_a_miss():
    """A build that repairs an aborted digest does real transfer work: the
    report must count it as a miss so bytes_delta_fetched stays <=
    bytes_fetched (no negative savings downstream)."""
    s = ChunkedComponentStore(chunk_size=1024)
    svc = UniformComponentService(UniformComponentRegistry())
    c = _c("a", size=10_240)
    p = s.plan_fetch(c)
    s.abort_chunks(p.claimed, component=c)     # first build died
    rep = BuildReport("x", "p")
    FetchEngine(s, svc).fetch([c], rep)
    assert rep.cache_misses == 1 and rep.cache_hits == 0
    assert rep.bytes_fetched == c.size_bytes
    assert rep.bytes_delta_fetched == c.size_bytes
    assert all(s.has_chunk(ch.id) for ch in s.chunks_of(c))


def test_put_racing_inflight_fetch_self_heals():
    """A direct put() whose shared chunks are mid-flight under another
    build must not trust them blindly: the digest is marked incomplete, and
    the next plan re-claims whatever the other build failed to land."""
    s = ChunkedComponentStore(chunk_size=1024)
    v1 = _c("a", version="1.0", size=100 * 1024)
    v2 = _c("a", version="2.0", size=100 * 1024)
    plan_a = s.plan_fetch(v1)              # claims the shared prefix
    assert s.put(v2) is True               # races: shared chunks in flight
    s.abort_chunks(plan_a.claimed, component=v1)   # ...and never land
    repair = s.plan_fetch(v2)              # incomplete marker forces rescan
    assert repair.claimed                  # the aborted shared chunks
    s.commit_chunks(repair.claimed, component=v2)
    assert all(s.has_chunk(ch.id) for ch in s.chunks_of(v2))


def test_midflight_singleflight_dedup():
    """Two builders over one store fetch version-siblings concurrently: the
    shared chunk prefix must be charged exactly once even though both
    components are new and in flight at the same time."""
    store = ChunkedComponentStore(chunk_size=1024)
    registry = UniformComponentRegistry()
    svc = UniformComponentService(registry)
    v1 = _c("weights", version="1.0", size=512 * 1024)
    v2 = _c("weights", version="2.0", size=512 * 1024)
    # slow simulated link so both fetches are genuinely mid-flight
    engines = [FetchEngine(store, svc, max_workers=4, simulate_bps=50e6)
               for _ in range(2)]
    reports = [BuildReport("x", "p"), BuildReport("x", "p")]
    barrier = threading.Barrier(2)

    def go(i, comp):
        barrier.wait()
        engines[i].fetch([comp], reports[i])

    ts = [threading.Thread(target=go, args=(0, v1)),
          threading.Thread(target=go, args=(1, v2))]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    total_wire = sum(r.bytes_delta_fetched for r in reports)
    assert total_wire == store.chunk_stats.chunk_bytes_stored
    assert total_wire == svc.bytes_served
    n = len(store.chunks_of(v1))
    shared = int(n * store.shared_fraction)
    # the shared prefix was transferred once, not twice
    assert total_wire == v1.size_bytes + v2.size_bytes - shared * 1024
    assert store.chunk_stats.chunks_waited + store.chunk_stats.chunks_hit \
        == shared


def test_concurrent_builders_share_store_stress():
    """N threads × M components with overlapping names/versions: component
    and chunk accounting must both balance exactly."""
    store = ChunkedComponentStore(chunk_size=512)
    registry = UniformComponentRegistry()
    svc = UniformComponentService(registry)
    # size is a function of (name, version): digest-identical components
    # must be byte-identical (digest() does not hash size_bytes)
    comps = [_c(f"n{i % 5}", version=f"{1 + i % 3}.0",
                size=8192 + 1024 * (i % 5) + 512 * (i % 3))
             for i in range(30)]

    def worker():
        eng = FetchEngine(store, svc, max_workers=4)
        rep = BuildReport("x", "p")
        eng.fetch(comps, rep)
        return rep

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    uniq = {c.digest(): c for c in comps}
    assert store.stats.bytes_stored == sum(c.size_bytes
                                           for c in uniq.values())
    expected_chunks = {ch.id: ch.size for c in uniq.values()
                       for ch in store.chunks_of(c)}
    assert store.chunk_stats.chunk_bytes_stored == \
        sum(expected_chunks.values())
    assert store.chunk_count() == len(expected_chunks)
    assert svc.bytes_served == store.chunk_stats.chunk_bytes_stored


def test_fetch_engine_pool_overlaps_simulated_transfer():
    """With a simulated link, the striped pool's wall time lands well below
    the serial sum of per-stripe fetch times."""
    store = ChunkedComponentStore(chunk_size=64 * 1024)
    svc = UniformComponentService(UniformComponentRegistry())
    comps = [_c(f"big{i}", size=4 * 2**20) for i in range(4)]
    eng = FetchEngine(store, svc, max_workers=8, simulate_bps=400e6)
    rep = BuildReport("x", "p")
    eng.fetch(comps, rep)
    assert rep.fetch_concurrency == 8
    assert rep.fetch_serial_s > 0
    assert rep.fetch_s < rep.fetch_serial_s


# ---------------------------------------------------------------------------
# Upstream converted index + negative cache (registry fast path)
# ---------------------------------------------------------------------------

def test_upstream_index_avoids_rescans():
    listed = []

    def lister():
        listed.append(1)
        return [None]

    src = UpstreamSource(
        "hub", lister,
        lambda _raw: [_c("known", manager="asset", size=10)])
    svc = UniformComponentService(UniformComponentRegistry(), [src])

    assert svc.vq("asset", "known") == ["1.0"]     # first miss: one scan
    assert len(listed) == 1
    # a second unknown name must NOT re-run the lister/converter sweep
    with pytest.raises(Exception):
        svc.cq("asset", "unknown", "1.0", "e")
    assert len(listed) == 1
    assert svc.upstream_rescans_avoided >= 1
    # repeated misses for the same unknown key hit the negative cache
    before = svc.upstream_negative_hits
    with pytest.raises(Exception):
        svc.cq("asset", "unknown", "1.0", "e")
    assert svc.upstream_negative_hits == before + 1
    # invalidation forces one fresh sweep
    src.invalidate()
    assert src.convert_matching("asset", "known")
    assert len(listed) == 2


def test_service_invalidate_upstreams_clears_negative_cache():
    """A name that newly appears upstream must become resolvable after
    service.invalidate_upstreams() — the negative cache is not forever."""
    catalog_entries = [_c("known", manager="asset", size=10)]
    src = UpstreamSource("hub", lambda: [None],
                         lambda _raw: list(catalog_entries))
    svc = UniformComponentService(UniformComponentRegistry(), [src])
    with pytest.raises(Exception):
        svc.cq("asset", "late", "1.0", "e")    # negative-cached
    catalog_entries.append(_c("late", manager="asset", size=20))
    with pytest.raises(Exception):
        svc.cq("asset", "late", "1.0", "e")    # still cached as negative
    svc.invalidate_upstreams()
    assert svc.cq("asset", "late", "1.0", "e").name == "late"


def test_reloaded_store_delta_sharing_rate_stays_bounded(tmp_path):
    path = str(tmp_path / "store")
    s1 = ChunkedComponentStore(path, chunk_size=256)
    s1.put(_c("a", version="1.0", size=10_240))
    s2 = ChunkedComponentStore(path, chunk_size=256)
    s2.put(_c("b", version="1.0", size=1024))
    assert 0.0 <= s2.chunk_stats.delta_sharing_rate < 1.0
