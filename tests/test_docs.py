"""Doc conformance: the CIR grammar documented in docs/cir-format.md must
round-trip through the real implementation, so the spec cannot silently
drift from the code."""
import gzip
import json
import os
import re

import pytest

from repro.configs import ARCHS
from repro.core import CIR, PreBuilder

DOCS = os.path.join(os.path.dirname(__file__), "..", "docs", "cir-format.md")
README = os.path.join(os.path.dirname(__file__), "..", "README.md")


def _doc_manifest() -> str:
    with open(DOCS) as f:
        text = f.read()
    m = re.search(r"```cir-manifest\n(.*?)```", text, re.DOTALL)
    assert m, "docs/cir-format.md lost its ```cir-manifest example block"
    return m.group(1).rstrip("\n")


def test_docs_exist():
    assert os.path.exists(DOCS)
    assert os.path.exists(README)
    with open(README) as f:
        readme = f.read()
    # the tier-1 verify command is documented
    assert "python -m pytest" in readme
    assert "PYTHONPATH=src" in readme


def test_documented_manifest_roundtrips():
    """The spec's example manifest parses via from_bytes and re-emits
    byte-identically via to_text — tag order, dep lines, LOCAL lines,
    entrypoint/workdir/seed all conform."""
    manifest = _doc_manifest()
    blob_json = json.dumps({
        "manifest": manifest,
        "app": {"config": ARCHS["gemma2-9b"].to_json(),
                "kind": "arch-config"},
        "created": 0.0,
    }, sort_keys=True).encode()
    cir = CIR.from_bytes(gzip.compress(blob_json))
    assert cir.to_text() == manifest
    assert cir.name == "gemma2-9b"
    assert cir.entrypoint == "serve"
    assert cir.workdir == "/gemma2-9b"
    assert cir.seed == 7
    assert cir.locals == (("/gemma2-9b", "weights-gemma2-9b"),)
    deps = {(d.manager, d.name): d.specifier for d in cir.deps}
    assert deps[("model", "decoder-dense")] == "~=1.0"
    assert deps[("asset", "weights-gemma2-9b")] == "latest"


def test_documented_manifest_matches_prebuilder(service):
    """A real pre-build of the same app emits exactly the documented
    manifest shape (modulo the doc's fixed seed)."""
    pb = PreBuilder(service)
    cir = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="serve", seed=7)
    assert cir.to_text() == _doc_manifest()


def test_digest_stability_rules():
    """Rule §3.1: `created` is excluded from the digest; the wire bytes are
    still deterministic."""
    manifest = _doc_manifest()
    app = {"config": ARCHS["gemma2-9b"].to_json(), "kind": "arch-config"}

    def cir_at(created):
        blob = json.dumps({"manifest": manifest, "app": app,
                           "created": created}, sort_keys=True).encode()
        return CIR.from_bytes(gzip.compress(blob))

    a, b = cir_at(0.0), cir_at(1234567.0)
    assert a.digest() == b.digest()          # identity ignores created
    assert a.to_bytes() == cir_at(0.0).to_bytes()   # wire is deterministic


def test_topology_section_names_real_api():
    """§7 documents the distribution subsystem — the names it promises
    must exist with the documented shape."""
    import inspect

    from repro.deploy import FleetDeployer, FleetTopology, PeerIndex

    with open(DOCS) as f:
        text = f.read()
    assert "## 7. Fleet topology & peer-to-peer chunk distribution" in text
    for name in ("PeerIndex", "NodePeering", "use_peers",
                 "BENCH_distribution.json", "check_regression"):
        assert name in text, f"§7 lost its {name} reference"
    # the documented surface
    for attr in ("add_node", "link", "place", "node_for", "edge_fanout"):
        assert hasattr(FleetTopology, attr)
    for attr in ("announce", "retract", "holders", "drop_node"):
        assert hasattr(PeerIndex, attr)
    params = inspect.signature(FleetDeployer.__init__).parameters
    assert "topology" in params and "use_peers" in params
    assert "simulate_links" in params


def test_lifecycle_section_names_real_api():
    """§8 documents the store-lifecycle subsystem — the names and semantics
    it promises must exist with the documented shape."""
    import inspect

    from repro.core import (EVICTION_POLICIES, ChunkedComponentStore,
                            LifecycleStats, LocalComponentStore)
    from repro.deploy import FleetDeployer, FleetNode, NodePeering
    from repro.deploy.fleet import FleetResult

    with open(DOCS) as f:
        text = f.read()
    assert "## 8. Store lifecycle: capacity, pin leases, eviction, GC" \
        in text
    for name in ("acquire_build_lease", "release_build", "capacity_bytes",
                 "pin_denied_evictions", "eviction_listeners",
                 "cheapest-to-restore", "refetch_bytes", "release_warm",
                 "BENCH_churn.json", "Retract before drop"):
        assert name in text, f"§8 lost its {name} reference"
    # the documented surface
    for attr in ("acquire_build_lease", "release_build", "record_build"):
        assert hasattr(LocalComponentStore, attr)
    for pol in ("lru", "cheapest-to-restore"):
        assert pol in EVICTION_POLICIES
    for attr in ("eviction_listeners", "peer_probe"):
        assert attr in inspect.signature(
            ChunkedComponentStore.__init__).parameters or \
            attr in ChunkedComponentStore(chunk_size=1024).__dict__
    for field in ("evicted_bytes", "refetch_bytes", "pin_denied_evictions",
                  "components_gcd"):
        assert field in LifecycleStats.__dataclass_fields__
    assert "capacity_bytes" in FleetNode.__dataclass_fields__
    for attr in ("on_chunks_evicted", "peer_holds"):
        assert hasattr(NodePeering, attr)
    for attr in ("warm", "release_warm"):
        assert hasattr(FleetDeployer, attr)
    assert "eviction_policy" in inspect.signature(
        FleetDeployer.__init__).parameters
    for field in ("evicted_bytes_total", "pin_denied_evictions_total",
                  "refetch_bytes_total"):
        assert field in FleetResult.__dataclass_fields__
    # README documents the capacity/churn workflow
    with open(README) as f:
        readme = f.read()
    assert "capacity_bytes" in readme
    assert "cheapest-to-restore" in readme


def test_simnet_section_names_real_api():
    """§9 documents the simulated transport — the names and semantics it
    promises must exist with the documented shape."""
    import inspect

    from repro.core import (Fault, FaultPlan, LinkDownError, NodeDownError,
                            SimClock, SimNetwork, UPSTREAM)
    from repro.core.orchestrator import Lifecycle
    from repro.deploy import FleetDeployer, NodeTraffic
    from repro.deploy.fleet import FleetResult

    with open(DOCS) as f:
        text = f.read()
    assert "## 9. Simulated transport: discrete-event links & WAN fault " \
        "injection" in text
    for name in ("SimClock", "SimNetwork", "FaultPlan", "node_loss",
                 "link_flap", "partition", "LinkDownError", "NodeDownError",
                 "failed_stage", "sim_elapsed_s", "link_retries",
                 "BENCH_scale.json", "UPSTREAM"):
        assert name in text, f"§9 lost its {name} reference"
    # the documented surface
    for attr in ("schedule", "advance_to", "sleep", "reserve"):
        assert hasattr(SimClock, attr)
    for attr in ("node_loss", "link_flap", "partition", "random",
                 "check_transfer"):
        assert hasattr(FaultPlan, attr)
    for attr in ("transport_for", "transfer", "on_node_loss",
                 "inject_node_loss", "inject_link_flap",
                 "inject_partition"):
        assert hasattr(SimNetwork, attr)
    for kind in ("node-loss", "link-flap", "partition"):
        Fault(kind, 0.0, 1.0)            # every documented kind validates
    assert issubclass(LinkDownError, RuntimeError)
    assert issubclass(NodeDownError, RuntimeError)
    assert UPSTREAM == "@upstream"
    assert "simnet" in inspect.signature(FleetDeployer.__init__).parameters
    for field in ("sim_elapsed_s", "faults_fired_total",
                  "link_retries_total", "listener_errors_total"):
        assert field in FleetResult.__dataclass_fields__
    assert "link_retries" in NodeTraffic.__dataclass_fields__
    assert isinstance(Lifecycle.failed_stage, property)


def test_compilecache_section_names_real_api():
    """§10 documents the fleet compile cache + snapshot/restore — the
    names and semantics it promises must exist with the documented shape."""
    import inspect

    from repro.core import (COMPILED_MANAGER, COMPILE_VERSION_SALT,
                            CompileCache, CompiledArtifact,
                            InstanceSnapshot, LazyBuilder,
                            artifact_component, compile_cache_key,
                            restore_instance, snapshot_instance)
    from repro.core.lazybuild import BuildReport
    from repro.core.orchestrator import Lifecycle
    from repro.deploy import FleetDeployer, NodePeering, NodeTraffic
    from repro.deploy.fleet import FleetResult

    with open(DOCS) as f:
        text = f.read()
    assert "## 10. Compiled artifacts: fleet compile cache & " \
        "snapshot/restore" in text
    for name in ("compile_cache_key", "CompileCache", "CompiledArtifact",
                 "artifact_component", "COMPILE_VERSION_SALT",
                 "InstanceSnapshot", "snapshot_instance", "restore_instance",
                 "fetch_artifact_stripe", "compile_cache_hit",
                 "compile_skips", "artifact_bytes_fetched",
                 "artifact_bytes_published", "artifact_bytes_from_peers",
                 "reset_for_retry", "precompile", "compile_key",
                 "BENCH_coldstart.json", "--snapshot-out", "--restore"):
        assert name in text, f"§10 lost its {name} reference"
    # the documented surface
    assert COMPILED_MANAGER == "compiled"
    assert COMPILE_VERSION_SALT            # non-empty format/version salt
    cache = CompileCache(max_entries=2)
    for attr in ("get", "put", "drop", "artifacts", "stats"):
        assert hasattr(cache, attr)
    sig = inspect.signature(compile_cache_key)
    assert list(sig.parameters) == ["lock", "spec", "entry_names"]
    assert artifact_component("ab" * 32, ("x",)).manager == COMPILED_MANAGER
    for field in ("key", "component", "entry_names", "compile_s"):
        assert field in CompiledArtifact.__dataclass_fields__
    for field in ("cir_b64", "lock_json", "spec_json", "stage",
                  "entry_names", "compile_key"):
        assert field in InstanceSnapshot.__dataclass_fields__
    for fn in (snapshot_instance, restore_instance):
        assert callable(fn)
    for field in ("compile_cache_hit", "compile_skips",
                  "artifact_bytes_fetched", "artifact_bytes_published"):
        assert field in BuildReport.__dataclass_fields__
    for field in ("artifact_bytes_from_peers", "artifact_chunks_from_peers"):
        assert field in NodeTraffic.__dataclass_fields__
    for field in ("compile_cache_hits_total", "compile_skips_total",
                  "artifact_bytes_fetched_total",
                  "artifact_bytes_published_total"):
        assert field in FleetResult.__dataclass_fields__
    assert hasattr(NodePeering, "fetch_artifact_stripe")
    assert hasattr(Lifecycle, "reset_for_retry")
    assert hasattr(LazyBuilder, "retry")
    assert "compile_cache" in \
        inspect.signature(FleetDeployer.__init__).parameters
    assert "precompile" in inspect.signature(FleetDeployer.warm).parameters


def test_placement_section_names_real_api():
    """§11 documents demand-driven placement + live migration — the names
    and semantics it promises must exist with the documented shape."""
    import inspect

    from repro.core import (ChunkedComponentStore, LifecycleStats,
                            SPEC_LEASE_PREFIX)
    from repro.deploy import (DemandModel, FleetDeployer, MigrationReport,
                              NodePeering, NodeTraffic, PlacementPlanner,
                              speculative_replicate)
    from repro.deploy.fleet import FleetResult
    from repro.deploy.placement import (DEFAULT_WIRE_BUDGET_BYTES,
                                        MIN_DEMAND_SCORE, ReplicationOrder)

    with open(DOCS) as f:
        text = f.read()
    assert "## 11. Demand-driven placement: speculative replication & " \
        "live migration" in text
    for name in ("SPEC_LEASE_PREFIX", "spec  <  warm  <  build-pin",
                 "spec_hit_bytes", "spec_wasted_bytes", "DemandModel",
                 "PlacementPlanner", "ReplicationOrder", "wire_budget_bytes",
                 "speculative_replicate", "fetch_spec_stripe",
                 "bytes_speculative", "migrate", "MigrationReport",
                 "downtime_s", "spec:retired:", "--retire-spec",
                 "BENCH_placement.json", "p95_ready_reduction_pct",
                 "speculation_wire_overhead_pct", "migration_downtime_ratio"):
        assert name in text, f"§11 lost its {name} reference"
    # the documented surface
    assert SPEC_LEASE_PREFIX == "spec:"
    assert DEFAULT_WIRE_BUDGET_BYTES == 256 * 2**20
    assert 0 < MIN_DEMAND_SCORE < 1
    for field in ("spec_bytes", "spec_hit_bytes", "spec_wasted_bytes"):
        assert field in LifecycleStats.__dataclass_fields__
    for field in ("spec_bytes_from_upstream", "spec_bytes_from_peers",
                  "spec_chunks"):
        assert field in NodeTraffic.__dataclass_fields__
    for field in ("bytes_speculative", "bytes_speculative_upstream",
                  "bytes_speculative_peer", "speculation_hit_bytes",
                  "speculation_wasted_bytes", "migrations_total",
                  "migration_downtime_s"):
        assert field in FleetResult.__dataclass_fields__
    for field in ("node_id", "key", "priority", "est_bytes",
                  "est_transfer_s", "components"):
        assert field in ReplicationOrder.__dataclass_fields__
    for field in ("platform_id", "source_node", "target_node", "downtime_s",
                  "prefetch_s", "prefetch_bytes", "compile_cache_hit",
                  "decommissioned"):
        assert field in MigrationReport.__dataclass_fields__
    for attr in ("observe", "predict"):
        assert hasattr(DemandModel, attr)
    for attr in ("plan", "execute", "run_round", "observe", "register",
                 "release", "release_all"):
        assert hasattr(PlacementPlanner, attr)
    for attr in ("migrate", "attach_planner", "node_peering"):
        assert hasattr(FleetDeployer, attr)
    assert hasattr(NodePeering, "fetch_spec_stripe")
    assert "speculative" in inspect.signature(
        ChunkedComponentStore.plan_fetch).parameters
    assert "speculative" in inspect.signature(
        ChunkedComponentStore.commit_chunks).parameters
    sig = inspect.signature(speculative_replicate)
    for p in ("store", "comps", "lease_id", "peering", "budget_bytes"):
        assert p in sig.parameters
    assert "halflife_s" in inspect.signature(DemandModel.__init__).parameters
    assert "oracle" in inspect.signature(DemandModel.__init__).parameters
    # the serving launcher exposes spec-tier retirement
    import repro.launch.serve as serve_mod
    assert "--retire-spec" in inspect.getsource(serve_mod)


def test_integrity_section_names_real_api():
    """§12 documents trust & integrity — the names and semantics it
    promises must exist with the documented shape."""
    import inspect

    from repro.core import (ATTESTATION_VERSION, Attestation,
                            AttestationError, Ed25519Signer, HMACSigner,
                            LazyBuilder, attest, canonical_manifest,
                            make_sbom, manifest_digest, verify_attestation,
                            write_sbom)
    from repro.core.chunkstore import ChunkStats
    from repro.core.lazybuild import BuildReport
    from repro.deploy import (QUARANTINE_DECAY_S, QUARANTINE_THRESHOLD,
                              ChunkIntegrityError, FleetDeployer,
                              NodeTraffic, PeerIndex, PeerTransferError,
                              Quarantine)
    from repro.deploy.fleet import FleetResult

    with open(DOCS) as f:
        text = f.read()
    assert "## 12. Trust & integrity: signed manifests, SBOM, " \
        "byzantine-resilient peering" in text
    for name in ("canonical_manifest", "Attestation", "ATTESTATION_VERSION",
                 "Signer", "HMACSigner", "Ed25519Signer", "ED25519_AVAILABLE",
                 "attest", "verify_attestation", "AttestationError",
                 "require_attestation", "attestation_verified",
                 "make_sbom", "write_sbom", "CycloneDX", "cir:chunkCount",
                 "--sbom-out", "verify_receipts", "ChunkIntegrityError",
                 "corrupt_rejected", "corrupt_chunks", "corrupt_bytes",
                 "Quarantine", "QUARANTINE_THRESHOLD", "QUARANTINE_DECAY_S",
                 "quarantined_at", "mark_byzantine", "tamper_hook",
                 "BENCH_integrity.json", "verify_overhead_pct",
                 "corrupt_chunks_committed", "quarantine_convergence_s",
                 "tamper_rejected"):
        assert name in text, f"§12 lost its {name} reference"
    # the documented surface: attestation
    assert ATTESTATION_VERSION == 1
    for field in ("payload_digest", "algorithm", "key_id", "signature",
                  "version"):
        assert field in Attestation.__dataclass_fields__
    for fn in (canonical_manifest, manifest_digest, attest,
               verify_attestation, make_sbom, write_sbom):
        assert callable(fn)
    for signer_cls in (HMACSigner, Ed25519Signer):
        for attr in ("algorithm", "key_id", "sign", "verify"):
            assert hasattr(signer_cls, attr) or attr in inspect.signature(
                signer_cls.__init__).parameters
    assert issubclass(AttestationError, RuntimeError)
    params = inspect.signature(LazyBuilder.__init__).parameters
    assert "signer" in params and "require_attestation" in params
    assert "attestation" in inspect.signature(LazyBuilder.build).parameters
    assert "attestation" in \
        inspect.signature(LazyBuilder.build_from_lock).parameters
    assert "attestation_verified" in BuildReport.__dataclass_fields__
    for attr in ("attest", "sbom"):
        assert hasattr(LazyBuilder, attr)
    # the documented surface: verify-on-receipt & quarantine
    assert issubclass(ChunkIntegrityError, PeerTransferError)
    assert QUARANTINE_THRESHOLD >= 1 and QUARANTINE_DECAY_S > 0
    for attr in ("record_corruption", "is_quarantined", "strikes", "active"):
        assert hasattr(Quarantine, attr)
    assert "quarantine" in \
        inspect.signature(PeerIndex.__init__).parameters
    for field in ("corrupt_chunks", "corrupt_bytes"):
        assert field in NodeTraffic.__dataclass_fields__
    assert "corrupt_rejected" in ChunkStats.__dataclass_fields__
    for field in ("corrupt_chunks_total", "corrupt_bytes_total",
                  "quarantined_nodes"):
        assert field in FleetResult.__dataclass_fields__
    fd_params = inspect.signature(FleetDeployer.__init__).parameters
    assert "verify_receipts" in fd_params and "quarantine" in fd_params
    for attr in ("mark_byzantine", "clear_byzantine"):
        assert hasattr(FleetDeployer, attr)
    # the serving launcher exposes SBOM emission; the README documents it
    import repro.launch.serve as serve_mod
    assert "--sbom-out" in inspect.getsource(serve_mod)
    with open(README) as f:
        readme = f.read()
    assert "--sbom-out" in readme
    assert "verify_receipts" in readme


def test_architecture_doc_names_real_layers():
    """docs/architecture.md is the layer map — every module it names must
    exist on disk and every key class must import from the layer it is
    filed under."""
    import importlib

    arch_doc = os.path.join(os.path.dirname(__file__), "..", "docs",
                            "architecture.md")
    assert os.path.exists(arch_doc), "docs/architecture.md is missing"
    with open(arch_doc) as f:
        text = f.read()

    # every named module exists on disk
    src = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
    for rel in re.findall(r"`(?:core|deploy|launch)/(\w+)\.py`", text):
        found = any(
            os.path.exists(os.path.join(src, pkg, rel + ".py"))
            for pkg in ("core", "deploy", "launch"))
        assert found, f"architecture.md names a missing module {rel}.py"

    # every "Key classes:" name imports from the package the layer maps to
    layer_classes = {
        "repro.core": [
            "UniformComponent", "Specifier", "Requirement",
            "UniformComponentRegistry", "UniformComponentService",
            "Resolution", "CIR", "PreBuilder", "LocalComponentStore",
            "ChunkedComponentStore", "LazyBuilder", "Lockfile",
            "ContainerInstance", "CompileCache", "InstanceSnapshot",
            "Attestation", "HMACSigner", "Ed25519Signer",
            "AttestationError", "SimClock", "SimNetwork", "FaultPlan"],
        "repro.deploy": [
            "FleetTopology", "FleetNode", "PeerIndex", "NodePeering",
            "NodeTraffic", "Quarantine", "ChunkIntegrityError",
            "PlacementPlanner", "DemandModel", "FleetDeployer",
            "FleetResult", "PlatformDeployment", "MigrationReport"],
        "repro.core.lazybuild": ["FetchEngine", "BuildReport",
                                 "BuildPlanCache"],
        "repro.core.orchestrator": ["BuildOrchestrator", "BuildGraph",
                                    "Lifecycle"],
        "repro.core.store": ["Chunk", "LifecycleStats"],
        "repro.core.chunkstore": ["FetchPlan", "ChunkStats"],
        "repro.core.simnet": ["SimTransport", "WallClockTransport",
                              "LinkDownError", "NodeDownError"],
        "repro.core.integrity": ["Signer"],
        "repro.deploy.placement": ["speculative_replicate"],
    }
    for mod_name, names in layer_classes.items():
        mod = importlib.import_module(mod_name)
        for name in names:
            assert name in text, f"architecture.md lost its {name} entry"
            assert hasattr(mod, name), \
                f"architecture.md files {name} under {mod_name}, " \
                f"which does not export it"

    # the map's cross-references resolve
    assert "cir-format.md" in text
    assert "benchmarks/README.md" in text
    assert os.path.exists(os.path.join(
        os.path.dirname(__file__), "..", "benchmarks", "README.md"))
    # README links the layer map
    with open(README) as f:
        readme = f.read()
    assert "docs/architecture.md" in readme


def test_irmodule_section_names_real_api():
    """§13 documents the performance-portable split — shared IR modules,
    per-platform artifact tails, autotune tables, the v2 cache rekey and
    the hetero gating surface must exist with the documented shape."""
    import inspect

    from repro.core import (AUTOTUNE_MANAGER, IR_MANAGER, IR_VERSION_SALT,
                            LazyBuilder, artifact_component,
                            autotune_component, compile_cache_key,
                            cpu_smoke, ir_module_component,
                            ir_module_digest, legacy_compile_cache_key)
    from repro.core.compilecache import (COMPILE_VERSION_SALT,
                                         LEGACY_COMPILE_VERSION_SALT,
                                         CompiledArtifact)
    from repro.core.irmodule import (IR_BYTES_BASE, IR_BYTES_PER_ENTRY,
                                     IR_PROGRAM_MANAGERS,
                                     partition_plan_digest)
    from repro.core.lazybuild import BuildReport
    from repro.deploy import FleetDeployer, FleetTopology, NodePeering, \
        NodeTraffic
    from repro.deploy.fleet import FleetResult

    with open(DOCS) as f:
        text = f.read()
    assert "## 13. Performance-portable CIR: shared IR modules & " \
        "per-platform artifact tails" in text
    for name in ("irmodule", "ir_module_digest", "ir_module_component",
                 "autotune_component", "partition_plan_digest",
                 "IR_VERSION_SALT", "IR_PROGRAM_MANAGERS",
                 '`manager="ir"`', '`manager="autotune"`',
                 "fetch_ir_stripe", "fetch_tail_stripe", "hetero_edge",
                 "ir_components", "ir_shared_bytes", "ir_bytes_published",
                 "platform_tail_bytes", "legacy_compile_cache_key",
                 "cir-xla-exec-v2", "cir-xla-exec-v1",
                 "BENCH_hetero.json", "BENCH_crossplatform.json",
                 "--platform-report", "wire_reduction_pct",
                 "ir_published_copies"):
        assert name in text, f"§13 lost its {name} reference"
    # the documented surface
    assert IR_MANAGER == "ir" and AUTOTUNE_MANAGER == "autotune"
    assert IR_VERSION_SALT and "parallel" not in IR_PROGRAM_MANAGERS
    assert COMPILE_VERSION_SALT == "cir-xla-exec-v2"
    assert LEGACY_COMPILE_VERSION_SALT == "cir-xla-exec-v1"
    # the v1/v2 signatures stay interchangeable (the compat shim contract)
    for fn in (compile_cache_key, legacy_compile_cache_key):
        assert list(inspect.signature(fn).parameters) == \
            ["lock", "spec", "entry_names"]
    assert "tail" in inspect.signature(artifact_component).parameters
    assert "autotune" in CompiledArtifact.__dataclass_fields__
    for fn in (ir_module_digest, ir_module_component, autotune_component,
               partition_plan_digest):
        assert callable(fn)
    # conservation: IR + tail re-labels the monolithic envelope exactly
    mono = artifact_component("ab" * 32, ("x",))
    tail = artifact_component("ab" * 32, ("x",), tail=True)
    assert tail.size_bytes + IR_BYTES_BASE + IR_BYTES_PER_ENTRY == \
        mono.size_bytes
    auto = autotune_component("ab" * 32, cpu_smoke(), ("x",))
    assert auto.manager == AUTOTUNE_MANAGER
    for field in ("ir_enabled", "ir_shared_bytes", "ir_bytes_published",
                  "platform_tail_bytes", "autotune_bytes_fetched",
                  "autotune_bytes_published"):
        assert field in BuildReport.__dataclass_fields__
    for field in ("ir_shared_bytes", "ir_chunks_from_peers",
                  "platform_tail_bytes"):
        assert field in NodeTraffic.__dataclass_fields__
    for field in ("ir_shared_bytes_total", "ir_bytes_published_total",
                  "platform_tail_bytes_total"):
        assert field in FleetResult.__dataclass_fields__
    for cls, meth in ((NodePeering, "fetch_ir_stripe"),
                      (NodePeering, "fetch_tail_stripe"),
                      (FleetTopology, "hetero_edge")):
        assert hasattr(cls, meth)
    for cls in (LazyBuilder, FleetDeployer):
        assert "ir_components" in \
            inspect.signature(cls.__init__).parameters
    # the serving launcher exposes the per-kind shared-vs-built report
    import repro.launch.serve as serve_mod
    assert "--platform-report" in inspect.getsource(serve_mod)
