"""Fleet compile cache + snapshot/restore (docs/cir-format.md §10).

Covers the serverless-cold-start claims: the compile stage derives a
fleet-stable cache key (platform *class*, not node), publishes the
compiled executable as a content-addressed component, peers restore it
over the ordinary chunk path with byte accounting identical to the
cache-miss build of the same content, an unreachable artifact degrades to
a local recompile, and snapshot/restore rebuilds a scaled-to-zero
instance without re-resolving, re-fetching or re-compiling.  Also the
lifecycle retry fix: a successful rebuild after a transient fault clears
``failed_stage``.
"""
import dataclasses

import pytest

from repro.configs import ARCHS
from repro.core import (COMPILED_MANAGER, CompileCache, CompiledArtifact,
                        InstanceSnapshot, LazyBuilder, PreBuilder,
                        artifact_component, compile_cache_key, cpu_smoke,
                        gpu_server, restore_instance, snapshot_instance,
                        tpu_single_pod)
from repro.core.orchestrator import Lifecycle
from repro.deploy import FleetDeployer, FleetTopology

ARCH = "starcoder2-3b"


@pytest.fixture
def pb(service):
    return PreBuilder(service)


def _edge_fleet(service, n_edges=2, **kw):
    topo = FleetTopology.edge_fanout(n_edges)
    cloud = tpu_single_pod()
    edges = [dataclasses.replace(cpu_smoke(), platform_id=f"edge-host-{i}")
             for i in range(n_edges)]
    topo.place(cloud.platform_id, "cloud")
    for i, s in enumerate(edges):
        topo.place(s.platform_id, f"edge-{i}")
    fd = FleetDeployer(service, topology=topo, **kw)
    return fd, cloud, edges


# ---------------------------------------------------------------------------
# Cache key derivation
# ---------------------------------------------------------------------------

def test_cache_key_is_platform_class_not_node(service, pb):
    """Two nodes of the same platform class derive the same key from their
    own locks — that is what makes one compile a fleet-wide hit — while a
    different platform class or jax version never collides."""
    cir = pb.prebuild(ARCHS[ARCH], entrypoint="serve")
    lb = LazyBuilder(service)
    e0 = dataclasses.replace(cpu_smoke(), platform_id="edge-host-0")
    e1 = dataclasses.replace(cpu_smoke(), platform_id="edge-host-1")
    lock0 = lb.build(cir, e0, assemble=False).lock
    lock1 = lb.build(cir, e1, assemble=False).lock
    names = ("prefill", "decode_step")
    assert compile_cache_key(lock0, e0, names) == \
        compile_cache_key(lock1, e1, names)
    # platform class changes the key
    gpu = gpu_server()
    lock_gpu = lb.build(cir, gpu, assemble=False).lock
    assert compile_cache_key(lock_gpu, gpu, names) != \
        compile_cache_key(lock0, e0, names)
    # version salt: a jax upgrade must never false-hit
    bumped = dataclasses.replace(e0, jax_version="99.0")
    assert compile_cache_key(lock0, bumped, names) != \
        compile_cache_key(lock0, e0, names)
    # entry set is part of the program identity
    assert compile_cache_key(lock0, e0, ("train_step",)) != \
        compile_cache_key(lock0, e0, names)


def test_cache_key_is_ir_digest_not_lock_proxy(service, pb):
    """The v2 key (docs §13) digests the real IR module identity, not the
    lock-digest proxy: the legacy v1 derivation still exists as a compat
    shim but can never collide with — or alias — a v2 key, so stale v1
    entries are unreachable by construction."""
    from repro.core import legacy_compile_cache_key
    from repro.core.irmodule import ir_module_digest
    cir = pb.prebuild(ARCHS[ARCH], entrypoint="serve")
    lb = LazyBuilder(service)
    spec = cpu_smoke()
    lock = lb.build(cir, spec, assemble=False).lock
    names = ("prefill", "decode_step")
    v2, v1 = compile_cache_key(lock, spec, names), \
        legacy_compile_cache_key(lock, spec, names)
    assert v2 != v1
    # the v2 key moves with the IR module identity and nothing else on
    # the program side: same lock + same entries is stable ...
    assert v2 == compile_cache_key(lock, spec, names)
    assert ir_module_digest(lock, names) == ir_module_digest(lock, names)
    # ... and the platform side still separates classes (both versions)
    gpu = gpu_server()
    lock_gpu = lb.build(cir, gpu, assemble=False).lock
    assert compile_cache_key(lock_gpu, gpu, names) != v2
    assert legacy_compile_cache_key(lock_gpu, gpu, names) != v1


def test_artifact_component_is_content_addressed():
    a = artifact_component("ab" * 32, ("prefill", "decode_step"))
    b = artifact_component("ab" * 32, ("decode_step", "prefill"))
    assert a.manager == COMPILED_MANAGER
    assert a.digest() == b.digest()          # order-insensitive identity
    assert a.size_bytes > 0
    c = artifact_component("cd" * 32, ("prefill", "decode_step"))
    assert c.digest() != a.digest()
    # the §13 tail is a distinct carrier for the same key — sized so that
    # IR + tail exactly re-labels the monolithic envelope
    from repro.core.irmodule import IR_BYTES_BASE, IR_BYTES_PER_ENTRY
    t = artifact_component("ab" * 32, ("prefill", "decode_step"), tail=True)
    assert t.digest() != a.digest()
    assert t.context["tail"] and not a.context["tail"]
    assert t.size_bytes + IR_BYTES_BASE + 2 * IR_BYTES_PER_ENTRY == \
        a.size_bytes


def test_compile_cache_lru_and_stats():
    cache = CompileCache(max_entries=2)
    arts = [CompiledArtifact(
        key=f"k{i}", component=artifact_component(f"k{i}" * 16, ("x",)),
        entry_names=("x",)) for i in range(3)]
    cache.put(arts[0])
    cache.put(arts[1])
    assert cache.get("k0") is arts[0]        # refresh k0
    cache.put(arts[2])                       # evicts k1 (LRU)
    assert cache.get("k1") is None
    assert cache.get("k0") is arts[0] and cache.get("k2") is arts[2]
    assert cache.stats.evictions == 1
    assert cache.stats.misses == 1 and cache.stats.hits == 3
    assert 0.0 < cache.stats.hit_rate < 1.0
    assert len(cache) == 2
    assert cache.drop("k0") and not cache.drop("k0")


# ---------------------------------------------------------------------------
# Compile stage: publish on miss, restore on hit
# ---------------------------------------------------------------------------

def test_compile_miss_publishes_then_local_hit_skips(service, pb):
    cache = CompileCache()
    lb = LazyBuilder(service, compile_cache=cache)
    cir = pb.prebuild(ARCHS[ARCH], entrypoint="serve")
    spec = cpu_smoke()
    cold = lb.build(cir, spec, assemble=True, compile_steps=True)
    rep = cold.report
    assert rep.n_compiled > 0
    assert not rep.compile_cache_hit and rep.compile_skips == 0
    assert rep.artifact_bytes_published > 0
    assert cold.compile_key is not None
    # the executable is a real component in the content-addressed store
    art = cache.artifacts()[cold.compile_key]
    assert lb.store.has(art.component)
    assert not lb.store.missing_chunks(art.component)

    warm = lb.build(cir, spec, assemble=True, compile_steps=True)
    rep2 = warm.report
    assert rep2.compile_cache_hit
    assert rep2.compile_skips == rep2.n_compiled > 0
    assert rep2.artifact_bytes_fetched == 0      # resident: free hit
    assert rep2.artifact_bytes_published == 0
    assert warm.entry.keys() == cold.entry.keys()
    assert cache.stats.hits == 1 and cache.stats.compile_skips > 0


def test_peer_sources_artifact_and_accounting_identity(service, pb):
    """One edge compiles; the same-class peer restores the executable over
    a peer link — and the resolved-content byte accounting of the two
    builds is identical (compile skips are explicit, never byte-smuggled).
    """
    fd, cloud, edges = _edge_fleet(service, n_edges=2, max_workers=1)
    cir = pb.prebuild(ARCHS[ARCH], entrypoint="serve")
    fd.deploy(cir, [cloud])                      # seed content on the cloud
    r0 = fd.deploy(cir, [edges[0]], assemble=True, compile_steps=True)
    r1 = fd.deploy(cir, [edges[1]], assemble=True, compile_steps=True)
    assert r0.ok and r1.ok
    miss, hit = r0.deployments[0].report, r1.deployments[0].report

    assert not miss.compile_cache_hit and miss.compile_skips == 0
    assert miss.artifact_bytes_published > 0
    assert hit.compile_cache_hit and hit.compile_skips == hit.n_compiled > 0
    assert hit.artifact_bytes_fetched > 0        # pulled from edge-0/cloud
    assert hit.artifact_chunks_fetched > 0
    assert r1.compile_cache_hits_total == 1
    assert r1.compile_skips_total == hit.compile_skips
    assert r1.artifact_bytes_fetched_total == hit.artifact_bytes_fetched

    # byte/compile accounting identity on the same content, hit vs miss
    for f in ("bytes_fetched", "bytes_delta_fetched", "chunks_hit",
              "chunks_missed", "chunks_waited", "cache_hits", "cache_misses",
              "n_components", "n_compiled", "bytes_total_components"):
        assert getattr(miss, f) == getattr(hit, f), f
    for res in (r0, r1):
        d = res.deployments[0]
        assert d.report.bytes_delta_fetched <= d.report.bytes_fetched
        # artifact bytes stay out of the wire-byte identity
        assert res.node_traffic[d.node_id].bytes_total == \
            d.report.bytes_delta_fetched
    t1 = r1.node_traffic[r1.deployments[0].node_id]
    assert t1.artifact_bytes_from_peers == hit.artifact_bytes_fetched
    assert t1.artifact_chunks_from_peers == hit.artifact_chunks_fetched


def test_unreachable_artifact_recompiles(service, pb):
    """A cache hit whose bytes no linked peer can serve degrades to a local
    recompile + republish — never an upstream fetch, never a failed build."""
    fd, cloud, edges = _edge_fleet(service, n_edges=2, max_workers=1,
                                   use_peers=False)
    cir = pb.prebuild(ARCHS[ARCH], entrypoint="serve")
    fd.deploy(cir, [cloud])
    r0 = fd.deploy(cir, [edges[0]], assemble=True, compile_steps=True)
    r1 = fd.deploy(cir, [edges[1]], assemble=True, compile_steps=True)
    assert r0.ok and r1.ok
    hit = r1.deployments[0].report
    # the key matched (same platform class) but peering is disabled, so the
    # artifact is unreachable: the node compiled and published its own copy
    assert not hit.compile_cache_hit and hit.compile_skips == 0
    assert hit.artifact_bytes_fetched == 0
    assert hit.artifact_bytes_published > 0
    assert fd.compile_cache.stats.hits >= 1      # index hit, content miss


# ---------------------------------------------------------------------------
# Snapshot / restore (scale-to-zero)
# ---------------------------------------------------------------------------

def test_snapshot_restore_roundtrip(service, pb):
    cache = CompileCache()
    lb = LazyBuilder(service, compile_cache=cache)
    cir = pb.prebuild(ARCHS[ARCH], entrypoint="serve")
    spec = cpu_smoke()
    inst = lb.build(cir, spec, assemble=True, compile_steps=True)
    snap = snapshot_instance(inst)
    snap = InstanceSnapshot.from_json(snap.to_json())   # wire round-trip
    assert snap.compile_key == inst.compile_key
    assert snap.stage in ("compiled", "ready", "complete")

    restored = restore_instance(snap, lb)
    rep = restored.report
    assert restored.stage == "complete"
    assert rep.locked                        # pin replay, no re-resolution
    assert rep.compile_cache_hit             # no re-compile
    assert rep.compile_skips == rep.n_compiled > 0
    assert rep.bytes_delta_fetched == 0      # no re-fetch (store resident)
    assert rep.artifact_bytes_fetched == 0
    assert restored.entry.keys() == inst.entry.keys()
    assert restored.lock.to_json() == inst.lock.to_json()


def test_snapshot_requires_compiled_state(service, pb):
    lb = LazyBuilder(service)
    cir = pb.prebuild(ARCHS[ARCH], entrypoint="serve")
    inst = lb.build(cir, cpu_smoke(), assemble=False, block=False)
    inst.wait("planned")
    if not inst.lifecycle.reached("compiled"):
        with pytest.raises(ValueError, match="snapshot requires"):
            snapshot_instance(inst)
    inst.wait("complete")


def test_stale_snapshot_key_refused(service, pb):
    cache = CompileCache()
    lb = LazyBuilder(service, compile_cache=cache)
    cir = pb.prebuild(ARCHS[ARCH], entrypoint="serve")
    inst = lb.build(cir, cpu_smoke(), assemble=True, compile_steps=True)
    snap = snapshot_instance(inst)
    stale = dataclasses.replace(snap, compile_key="0" * 64)
    with pytest.raises(ValueError, match="stale snapshot"):
        restore_instance(stale, lb)


# ---------------------------------------------------------------------------
# Lifecycle retry (satellite: failed_stage must not outlive a rebuild)
# ---------------------------------------------------------------------------

def test_lifecycle_reset_for_retry_unit():
    life = Lifecycle()
    life.advance("fetching")
    boom = RuntimeError("transient")
    life.fail(boom)
    assert life.error is boom and life.failed_stage == "fetching"
    assert life.wait("fetching") == "fetching"   # reached before the fault
    with pytest.raises(RuntimeError, match="transient"):
        life.wait("ready")
    life.reset_for_retry()
    assert life.error is None and life.failed_stage is None
    assert life.reached("fetching")              # completed stages survive
    with pytest.raises(TimeoutError):
        life.wait("ready", timeout=0.01)         # re-armed, not signalled
    life.advance("complete")
    assert life.wait("ready") == "complete"


def test_retry_clears_stale_failed_stage(service, pb):
    """A build that failed on a transient fault retries to success — and
    the instance stops reporting the dead attempt's failed stage."""
    lb = LazyBuilder(service)
    cir = pb.prebuild(ARCHS[ARCH], entrypoint="train")
    spec = tpu_single_pod()
    real = service.fetch_chunks
    calls = {"n": 0}

    def flaky(*a, **kw):
        calls["n"] += 1
        raise ConnectionError("transient uplink blip")

    service.fetch_chunks = flaky
    try:
        inst = lb.build(cir, spec, assemble=False, block=False)
        with pytest.raises(ConnectionError):
            inst.wait("complete")
        assert inst.lifecycle.failed_stage == "fetching"
        assert calls["n"] >= 1
    finally:
        service.fetch_chunks = real

    lb.retry(inst, assemble=False)
    assert inst.stage == "complete"
    assert inst.lifecycle.error is None
    assert inst.lifecycle.failed_stage is None   # the fix under test
    assert inst.report.bytes_delta_fetched <= inst.report.bytes_fetched


# ---------------------------------------------------------------------------
# warm(precompile=True): the seed pre-compiles for the fleet
# ---------------------------------------------------------------------------

def test_warm_precompile_seeds_fleet_cache(service, pb):
    fd, cloud, edges = _edge_fleet(service, n_edges=2, max_workers=1)
    cir = pb.prebuild(ARCHS[ARCH], entrypoint="serve")
    assert fd.warm(cir, [edges[0]], precompile=True) == 1
    assert len(fd.compile_cache) >= 1
    # the first REAL cold deploy of that platform class skips its compile
    r = fd.deploy(cir, [edges[0]], assemble=True, compile_steps=True)
    assert r.ok
    rep = r.deployments[0].report
    assert rep.compile_cache_hit and rep.compile_skips == rep.n_compiled > 0
