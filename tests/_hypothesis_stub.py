"""Stand-ins for hypothesis when it is not installed.

``pytest.importorskip("hypothesis")`` at module level would skip whole
modules, losing their plain (non-property) tests.  These stubs keep the
modules importable so plain tests run, while every ``@given`` test is
collected and individually skipped.  Install hypothesis (see
requirements-dev.txt) to run the property tests for real.
"""
import pytest


class _Anything:
    """Swallows any strategy expression (st.lists(st.integers(1, 5)), …)."""

    def __call__(self, *args, **kwargs):
        return self

    def __getattr__(self, name):
        return self


st = _Anything()


def settings(*args, **kwargs):
    def deco(fn):
        return fn
    return deco


def given(*args, **kwargs):
    def deco(fn):
        return pytest.mark.skip(reason="hypothesis not installed")(fn)
    return deco
