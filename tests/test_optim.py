"""Optimizer substrate: schedules, int8 blocks, error feedback, grad
accumulation equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip individually without hypothesis
    from _hypothesis_stub import given, settings, st

from repro.optim import (AdamWConfig, TrainStepConfig, _dq8, _q8,
                         adamw_init, adamw_update, build_train_step,
                         cosine_schedule, ef_compress, ef_compress_init)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100, final_frac=0.1)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(5)) == pytest.approx(5e-4)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-3)
    assert float(lr(50)) < float(lr(20))


@given(st.integers(1, 2000), st.floats(0.01, 100.0))
@settings(max_examples=40, deadline=None)
def test_q8_roundtrip_error_bounded(n, scale):
    x = jnp.asarray(np.random.default_rng(n).standard_normal(n) * scale,
                    jnp.float32)
    q, s = _q8(x)
    y = _dq8(q, s, x.shape)
    # block-wise absmax quantization: error <= blockmax/254 per element
    err = np.abs(np.asarray(x - y))
    assert err.max() <= float(jnp.max(jnp.abs(x))) / 254.0 + 1e-7


def test_ef_compression_is_unbiased_over_time():
    """Error feedback: the SUM of compressed gradients converges to the sum
    of true gradients (residual carries over)."""
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .standard_normal(256) * 0.1, jnp.float32)}
    err = ef_compress_init(g)
    total_sent = jnp.zeros(256)
    steps = 50
    for _ in range(steps):
        sent, err = ef_compress(g, err)
        total_sent = total_sent + sent["w"]
    np.testing.assert_allclose(total_sent / steps, g["w"], atol=1e-3)


def test_adamw_moves_params_down_gradient():
    cfg = AdamWConfig(lr=lambda s: 1e-2, weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.ones((8, 8))}
    state = adamw_init(params, cfg)
    grads = {"w": jnp.ones((8, 8))}
    new_p, state, m = adamw_update(params, grads, state, cfg)
    assert float(jnp.max(new_p["w"])) < 1.0
    assert m["grad_norm"] == pytest.approx(8.0)


@pytest.mark.parametrize("moments", ["f32", "bf16", "int8"])
def test_adamw_moment_dtypes(moments):
    cfg = AdamWConfig(lr=lambda s: 1e-3, moments=moments)
    params = {"w": jnp.ones((4, 129))}     # non-multiple of the q8 block
    state = adamw_init(params, cfg)
    grads = {"w": jnp.full((4, 129), 0.5)}
    for _ in range(3):
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert np.isfinite(np.asarray(params["w"], np.float32)).all()


def test_grad_accumulation_matches_full_batch():
    """microbatch=k gives (numerically close) identical updates to the full
    batch when the loss is a mean over examples."""
    class TinyModel:
        def loss(self, params, batch):
            x, y = batch["x"], batch["y"]
            pred = x @ params["w"]
            l = jnp.mean((pred - y) ** 2)
            return l, {"ce": l}

    model = TinyModel()
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)}
    batch = {"x": jnp.asarray(rng.standard_normal((16, 8)), jnp.float32),
             "y": jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)}
    mk = lambda mb: build_train_step(model, TrainStepConfig(
        microbatch=mb, adamw=AdamWConfig(lr=lambda s: 1e-2)))
    s_full = {"params": params,
              "opt": adamw_init(params, AdamWConfig())}
    s_micro = jax.tree.map(lambda x: x, s_full)
    full, _ = jax.jit(mk(0))(s_full, batch)
    micro, _ = jax.jit(mk(4))(s_micro, batch)
    np.testing.assert_allclose(full["params"]["w"], micro["params"]["w"],
                               atol=1e-5, rtol=1e-5)
