"""Lazy-builder: byte accounting, active sharing, lock determinism,
cross-platform variant selection — the paper's core claims as tests."""
import pytest

from repro.configs import ARCHS
from repro.core import (LazyBuilder, LocalComponentStore, PreBuilder,
                        cpu_smoke, gpu_server, tpu_multi_pod, tpu_single_pod)


@pytest.fixture
def pb(service):
    return PreBuilder(service)


def test_image_size_reduction(service, pb):
    """CIR bytes << legacy bundle bytes (Fig. 6's ~95%+)."""
    cir = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="train")
    lb = LazyBuilder(service)
    inst = lb.build(cir, tpu_single_pod(), assemble=False)
    legacy = inst.report.bytes_total_components
    assert cir.size_bytes() < 0.05 * legacy


def test_active_sharing_across_archs(service):
    """Second build on the same platform fetches only arch-specific bytes —
    the component store is shared (paper §5.7 active sharing)."""
    store = LocalComponentStore()
    lb = LazyBuilder(service, store)
    pb = PreBuilder(service)
    spec = tpu_single_pod()
    r1 = lb.build(pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="train"),
                  spec, assemble=False).report
    r2 = lb.build(pb.prebuild(ARCHS["phi4-mini-3.8b"], entrypoint="train"),
                  spec, assemble=False).report
    assert r1.cache_misses > 0
    # phi4 build reuses every shared component (env, kernels, runtime, …)
    assert r2.bytes_fetched < 0.05 * r1.bytes_fetched
    assert r2.cache_hits > 0


def test_rebuild_same_platform_is_deterministic(service, pb):
    lb = LazyBuilder(service)
    cir = pb.prebuild(ARCHS["jamba-v0.1-52b"], entrypoint="train")
    spec = tpu_multi_pod()
    l1 = lb.build(cir, spec, assemble=False).lock
    l2 = lb.build(cir, spec, assemble=False).lock
    assert l1.to_json() == l2.to_json()
    assert l1.digest() == l2.digest()


def test_locked_rebuild_bit_identical_and_immutable(service, pb):
    lb = LazyBuilder(service)
    cir = pb.prebuild(ARCHS["dbrx-132b"], entrypoint="train")
    spec = tpu_single_pod()
    inst = lb.build(cir, spec, assemble=False)
    inst2 = lb.build_from_lock(cir, inst.lock, spec, assemble=False)
    assert [c.digest() for c in inst2.bundle.components()] == \
        list(inst.lock.digests)
    # a lock from a different CIR must be rejected
    other = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="train")
    with pytest.raises(ValueError):
        lb.build_from_lock(other, inst.lock, spec, assemble=False)


def test_cross_platform_variant_selection(service, pb):
    """One CIR, three platforms, different concrete components (Fig. 1)."""
    lb = LazyBuilder(service)
    cir = pb.prebuild(ARCHS["codeqwen1.5-7b"], entrypoint="train")
    picks = {}
    for spec in (tpu_single_pod(), cpu_smoke(), gpu_server()):
        inst = lb.build(cir, spec, assemble=False)
        picks[spec.platform_id] = {
            (c.manager, c.name): c.env for c in inst.bundle.components()}
    tpu, cpu, gpu = picks.values()
    assert tpu[("env", "runtime-base")] == "tpu-v5e"
    assert cpu[("env", "runtime-base")] == "cpu-host"
    assert gpu[("env", "runtime-base")] == "gpu-a100"
    assert tpu[("parallel", "plan")] == "fsdp-tp"       # 16x16 pod
    assert cpu[("parallel", "plan")] == "tp"            # single device


def test_workload_override_changes_plan(service, pb):
    """Deployment-time workload facts steer environment selection — the
    paper's 'architecture-aware optimizations during deployment-time'."""
    lb = LazyBuilder(service)
    cir = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="serve")
    spec = tpu_single_pod()
    plain = lb.build(cir, spec, assemble=False,
                     overrides={"workload": "prefill"})
    dec = lb.build(cir, spec, assemble=False,
                   overrides={"workload": "decode"})
    lng = lb.build(cir, spec, assemble=False,
                   overrides={"workload": "long-decode"})
    get = lambda i: {(c.manager, c.name): c.env
                     for c in i.bundle.components()}[("parallel", "plan")]
    assert get(plain) == "fsdp-tp"
    assert get(dec) == "decode"
    assert get(lng) == "sp-decode"


def test_multipod_selects_dci_compression(service, pb):
    lb = LazyBuilder(service)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="train")
    single = lb.build(cir, tpu_single_pod(), assemble=False)
    multi = lb.build(cir, tpu_multi_pod(), assemble=False)
    env_of = lambda i: {(c.manager, c.name): c.env
                        for c in i.bundle.components()}
    assert env_of(single)[("runtime", "train-step")] == "standard"
    assert env_of(multi)[("runtime", "train-step")] == "compressed-dci"
