"""HLO parser: flops/bytes/collective accounting with while-trip correction,
validated against a compiled module with a known FLOP count."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_stats import (module_cost, parse_hlo, shape_bytes,
                                    shape_dims, xla_cost_analysis)


def test_shape_parsing():
    assert shape_bytes("f32[4,8]{1,0}") == 128
    assert shape_bytes("bf16[2,2]") == 8
    assert shape_bytes("(s32[], f32[10]{0})") == 44
    assert shape_bytes("pred[]") == 1
    assert shape_dims("f32[3,5,7]{2,1,0}") == [3, 5, 7]


def test_scan_flops_multiplied_by_trip_count():
    """7-iteration scan of a 128x256 @ 256x256 matmul:
    expected = 7 * 2 * 128 * 256 * 256 flops, which plain cost_analysis
    misses by ~7x."""
    def body(c, w):
        return jnp.tanh(c @ w), ()

    def f(c, ws):
        return jax.lax.scan(body, c, ws)

    c = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 256, 256), jnp.float32)
    compiled = jax.jit(f).lower(c, ws).compile()
    cost = module_cost(compiled.as_text())
    expected = 7 * 2 * 128 * 256 * 256
    assert abs(cost.flops - expected) / expected < 0.05
    xla = xla_cost_analysis(compiled)["flops"]
    assert xla < expected / 2          # demonstrates the undercount


def test_nested_scan_multiplies_both_trips():
    def inner(c, w):
        return c @ w, ()

    def outer(c, ws):
        c, _ = jax.lax.scan(inner, c, ws)
        return c, ()

    def f(c, wss):
        return jax.lax.scan(outer, c, wss)

    c = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    wss = jax.ShapeDtypeStruct((3, 5, 64, 64), jnp.float32)
    compiled = jax.jit(f).lower(c, wss).compile()
    cost = module_cost(compiled.as_text())
    expected = 3 * 5 * 2 * 64 * 64 * 64
    assert abs(cost.flops - expected) / expected < 0.10


def test_collective_bytes_from_synthetic_hlo():
    text = """
HloModule test

ENTRY %main (p: f32[1024,64]) -> f32[1024,64] {
  %p = f32[1024,64]{1,0} parameter(0)
  %ar = f32[1024,64]{1,0} all-reduce(%p), replica_groups={}, to_apply=%add
  %ag = f32[2048,64]{1,0} all-gather(%ar), dimensions={0}
  ROOT %out = f32[1024,64]{1,0} slice(%ag), slice={[0:1024], [0:64]}
}
"""
    cost = module_cost(text)
    assert cost.by_collective["all-reduce"] == 1024 * 64 * 4
    assert cost.by_collective["all-gather"] == 1024 * 64 * 4
    assert cost.collectives == 2


def test_dynamic_slice_charged_at_slice_size():
    """A scan that slices a big tensor per step must charge the SLICE, not
    the whole operand (else seq scans look quadratic in HBM traffic)."""
    text = """
HloModule t

ENTRY %main (p: f32[4096,512], i: s32[]) -> f32[1,512] {
  %p = f32[4096,512]{1,0} parameter(0)
  %i = s32[] parameter(1)
  %z = s32[] constant(0)
  ROOT %ds = f32[1,512]{1,0} dynamic-slice(%p, %i, %z), dynamic_slice_sizes={1,512}
}
"""
    cost = module_cost(text)
    assert cost.hbm_bytes == 2 * 1 * 512 * 4


def test_elementwise_excluded_from_hbm():
    """tanh on its own contributes no HBM bytes (models TPU fusion)."""
    text = """
HloModule t

ENTRY %main (p: f32[256,256]) -> f32[256,256] {
  %p = f32[256,256]{1,0} parameter(0)
  %t = f32[256,256]{1,0} tanh(%p)
  ROOT %c = f32[256,256]{1,0} copy(%t)
}
"""
    cost = module_cost(text)
    # only the copy is charged: 2 x 256x256x4
    assert cost.hbm_bytes == 2 * 256 * 256 * 4
