"""Serving engine: continuous batching correctness — staggered slot-based
decode must produce exactly the tokens of isolated greedy decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import LazyBuilder, PreBuilder, cpu_smoke
from repro.serving import ServingEngine


def _isolated_greedy(model, params, prompt, n_new, max_seq=64):
    """Reference: decode one request alone through the cache."""
    cfg = model.cfg
    b, s = 1, len(prompt)
    cache = model.init_cache(1, max_seq)
    toks = jnp.asarray([prompt], jnp.int32)
    pos = jnp.arange(s, dtype=jnp.int32)[None]
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos, (3, 1, s))
    batch = {"tokens": toks, "positions": pos}
    logits, cache = model.prefill(params, batch, cache)
    out = [int(jnp.argmax(logits[0]))]
    for t in range(s, s + n_new - 1):
        p1 = jnp.full((1, 1), t, jnp.int32)
        if cfg.mrope_sections:
            p1 = jnp.broadcast_to(p1, (3, 1, 1))
        logits, cache = model.decode_step(
            params, jnp.asarray([[out[-1]]], jnp.int32), p1, cache,
            jnp.int32(t))
        out.append(int(jnp.argmax(logits[0])))
    return out


@pytest.mark.parametrize("arch_id", ["codeqwen1.5-7b", "gemma2-9b",
                                     "jamba-v0.1-52b"])
def test_continuous_batching_matches_isolated_decode(arch_id, service,
                                                     smoke_mesh):
    cfg = ARCHS[arch_id].reduced()
    pb = PreBuilder(service)
    lb = LazyBuilder(service)
    inst = lb.build(pb.prebuild(cfg, entrypoint="serve"), cpu_smoke(),
                    mesh=smoke_mesh)
    model = inst.model
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab, rng.integers(3, 9)).tolist()
               for _ in range(5)]
    n_new = 5

    expected = [_isolated_greedy(model, params, p, n_new) for p in prompts]

    # 2 slots for 5 requests: forces queueing, staggered positions and
    # slot reuse — the adversarial case for per-slot cache_pos
    eng = ServingEngine(model, params, num_slots=2, max_seq=64,
                        prefill_buckets=(16,))
    for p in prompts:
        eng.submit(p, max_new_tokens=n_new)
    resp = eng.run_until_drained()
    got = {r.rid: r.tokens for r in resp}
    assert len(got) == 5
    for i, exp in enumerate(expected):
        assert got[i] == exp, f"{arch_id} request {i}: {got[i]} != {exp}"


def test_engine_respects_max_new_tokens(service, smoke_mesh):
    cfg = ARCHS["starcoder2-3b"].reduced()
    pb = PreBuilder(service)
    lb = LazyBuilder(service)
    inst = lb.build(pb.prebuild(cfg, entrypoint="serve"), cpu_smoke(),
                    mesh=smoke_mesh)
    model = inst.model
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, num_slots=3, max_seq=64,
                        prefill_buckets=(16,))
    for n in (1, 3, 7):
        eng.submit([1, 2, 3], max_new_tokens=n)
    resp = eng.run_until_drained()
    assert sorted(len(r.tokens) for r in resp) == [1, 3, 7]


def test_temperature_sampling_differs_from_greedy(service, smoke_mesh):
    cfg = ARCHS["phi4-mini-3.8b"].reduced()
    pb = PreBuilder(service)
    lb = LazyBuilder(service)
    inst = lb.build(pb.prebuild(cfg, entrypoint="serve"), cpu_smoke(),
                    mesh=smoke_mesh)
    model = inst.model
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, num_slots=2, max_seq=64,
                        prefill_buckets=(16,), rng_seed=7)
    eng.submit([5, 6, 7], max_new_tokens=12, temperature=0.0)
    eng.submit([5, 6, 7], max_new_tokens=12, temperature=5.0)
    resp = {r.rid: r.tokens for r in eng.run_until_drained()}
    # first emitted token comes from prefill argmax for both; the decode
    # tail should diverge at high temperature
    assert resp[0] != resp[1]
