"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles,
plus the lax variants vs the same oracles and decode-path equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.rwkv6_scan import wkv6_pallas
from repro.models.attention import lax_flash_attention, naive_attention
from repro.models.ssm import wkv6_chunked, wkv6_sequential


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 4, 4, 128, 64),        # MHA
    (2, 8, 2, 256, 64),        # GQA 4:1
    (1, 6, 1, 128, 32),        # MQA
    (1, 4, 2, 512, 128),       # long-ish, MXU-aligned head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(b, hq, hkv, s, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    out = flash_attention(q, k, v, scale=d ** -0.5,
                          block_q=64, block_k=64)
    exp = ref.attention_ref(q, k, v, scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window,softcap", [(64, 0.0), (0, 30.0), (32, 50.0)])
def test_flash_attention_window_softcap(window, softcap):
    b, hq, hkv, s, d = 1, 4, 2, 256, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    out = flash_attention(q, k, v, scale=0.2, window=window, softcap=softcap,
                          block_q=64, block_k=64)
    exp = ref.attention_ref(q, k, v, scale=0.2, window=window,
                            softcap=softcap)
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=3e-5)


def test_flash_attention_mla_asymmetric_vdim():
    """MLA: qk head dim 192, v head dim 128."""
    b, h, s = 1, 4, 128
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, h, s, 192))
    k = jax.random.normal(ks[1], (b, h, s, 192))
    v = jax.random.normal(ks[2], (b, h, s, 128))
    out = flash_attention(q, k, v, scale=192 ** -0.5,
                          block_q=64, block_k=64)
    exp = ref.attention_ref(q, k, v, scale=192 ** -0.5)
    assert out.shape == (b, h, s, 128)
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=3e-5)


def test_lax_flash_matches_ref_and_naive():
    b, hq, hkv, s, d = 2, 4, 2, 256, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    out = lax_flash_attention(q, k, v, scale=0.3, block_q=64, block_k=64)
    np.testing.assert_allclose(out, ref.attention_ref(q, k, v, scale=0.3),
                               atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(
        out, naive_attention(q, k, v, scale=0.3), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,s,K,chunk", [
    (1, 2, 128, 32, 32),
    (2, 3, 64, 16, 16),
    (1, 1, 256, 64, 64),
])
def test_wkv6_pallas_vs_ref(b, h, s, K, chunk):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    r = jax.random.normal(ks[0], (b, h, s, K))
    k = jax.random.normal(ks[1], (b, h, s, K))
    v = jax.random.normal(ks[2], (b, h, s, K))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, s, K))) * 0.9 + 0.05
    u = jax.random.normal(ks[4], (h, K)) * 0.1
    y, S = wkv6_pallas(r, k, v, w, u, chunk=chunk)
    ye, Se = ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(y, ye, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(S, Se, atol=2e-4, rtol=2e-4)


def test_wkv6_pallas_with_initial_state():
    b, h, s, K = 1, 2, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    r = jax.random.normal(ks[0], (b, h, s, K))
    k = jax.random.normal(ks[1], (b, h, s, K))
    v = jax.random.normal(ks[2], (b, h, s, K))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, s, K))) * 0.9 + 0.05
    u = jax.random.normal(ks[4], (h, K)) * 0.1
    s0 = jax.random.normal(ks[5], (b, h, K, K), jnp.float32)
    y, S = wkv6_pallas(r, k, v, w, u, s0, chunk=32)
    ye, Se = ref.wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(y, ye, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(S, Se, atol=2e-4, rtol=2e-4)


def test_wkv6_chunked_and_sequential_match_ref():
    b, h, s, K = 2, 2, 96, 16
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    r = jax.random.normal(ks[0], (b, h, s, K))
    k = jax.random.normal(ks[1], (b, h, s, K))
    v = jax.random.normal(ks[2], (b, h, s, K))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, s, K))) * 0.9 + 0.05
    u = jax.random.normal(ks[4], (h, K)) * 0.1
    ye, _ = ref.wkv6_ref(r, k, v, w, u)
    y1, _ = wkv6_sequential(r, k, v, w, u)
    y2, _ = wkv6_chunked(r, k, v, w, u, chunk=32)
    np.testing.assert_allclose(y1, ye, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(y2, ye, atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 128), (2, 37, 512), (5, 3, 7, 64)])
@pytest.mark.parametrize("plus_one", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_pallas_vs_ref(shape, plus_one, dtype):
    x = jax.random.normal(jax.random.PRNGKey(7), shape, dtype)
    w = jax.random.normal(jax.random.PRNGKey(8), (shape[-1],), dtype)
    out = rmsnorm_pallas(x, w, plus_one=plus_one, block_rows=16)
    exp = ref.rmsnorm_ref(x, w, plus_one=plus_one)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# decode-path equivalences (cache vs full forward)
# ---------------------------------------------------------------------------

def test_gqa_decode_matches_train_attention():
    """Prefill+decode through the KV cache reproduces the full causal
    attention output for the decoded position."""
    from repro.configs import ARCHS
    from repro.models.attention import gqa_attention, gqa_cache_spec
    from repro.models.common import init_tree
    from repro.models.attention import gqa_spec
    import dataclasses
    cfg = dataclasses.replace(ARCHS["starcoder2-3b"].reduced(), qkv_bias=False)
    p = init_tree(jax.random.PRNGKey(0), gqa_spec(cfg))
    b, s = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s + 1, cfg.d_model))
    pos = jnp.tile(jnp.arange(s + 1), (b, 1))
    full, _ = gqa_attention(p, x, cfg, positions=pos, kernel="naive")

    cache = init_tree(jax.random.PRNGKey(2),
                      gqa_cache_spec(cfg, b, 32))
    cache = jax.tree.map(jnp.zeros_like, cache)
    _, cache = gqa_attention(p, x[:, :s], cfg, positions=pos[:, :s],
                             kernel="naive", cache=cache, cache_pos=0)
    out1, _ = gqa_attention(p, x[:, s:], cfg, positions=pos[:, s:],
                            kernel="naive", cache=cache, cache_pos=s)
    np.testing.assert_allclose(out1[:, 0], full[:, s], atol=1e-4, rtol=1e-4)


def test_ring_buffer_window_decode_matches_full_cache():
    """Sliding-window ring cache (len=window) decode == full cache decode
    with window masking."""
    from repro.configs import ARCHS
    from repro.models.attention import gqa_attention, gqa_cache_spec
    from repro.models.common import init_tree
    from repro.models.attention import gqa_spec
    import dataclasses
    cfg = dataclasses.replace(ARCHS["gemma2-9b"].reduced(),
                              attn_softcap=0.0, post_norms=False)
    W = cfg.sliding_window            # 64 in the reduced config
    p = init_tree(jax.random.PRNGKey(0), gqa_spec(cfg))
    b, total = 1, 80                  # > window so wraparound is exercised
    x = jax.random.normal(jax.random.PRNGKey(1), (b, total, cfg.d_model)) \
        * 0.3
    pos = jnp.tile(jnp.arange(total), (b, 1))

    full_cache = jax.tree.map(jnp.zeros_like, init_tree(
        jax.random.PRNGKey(2), gqa_cache_spec(cfg, b, total)))
    ring_cache = jax.tree.map(jnp.zeros_like, init_tree(
        jax.random.PRNGKey(2), gqa_cache_spec(cfg, b, W)))

    for t in range(total):
        xt = x[:, t:t + 1]
        pt = pos[:, t:t + 1]
        o_full, full_cache = gqa_attention(
            p, xt, cfg, positions=pt, kernel="naive", window=W,
            cache=full_cache, cache_pos=t)
        o_ring, ring_cache = gqa_attention(
            p, xt, cfg, positions=pt, kernel="naive", window=W,
            cache=ring_cache, cache_pos=t)
        np.testing.assert_allclose(o_ring, o_full, atol=1e-4, rtol=1e-4,
                                   err_msg=f"step {t}")


def test_mla_decode_matches_train_path():
    """The compressed-cache (absorbed) MLA decode equals the decompressed
    train attention at the decoded position."""
    from repro.configs import ARCHS
    from repro.models.attention import (mla_attention, mla_cache_spec,
                                        mla_spec)
    from repro.models.common import init_tree
    cfg = ARCHS["deepseek-v3-671b"].reduced()
    p = init_tree(jax.random.PRNGKey(0), mla_spec(cfg))
    b, s = 1, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s + 1, cfg.d_model)) \
        * 0.3
    pos = jnp.tile(jnp.arange(s + 1), (b, 1))
    full, _ = mla_attention(p, x, cfg, positions=pos, kernel="naive")

    cache = jax.tree.map(jnp.zeros_like, init_tree(
        jax.random.PRNGKey(2), mla_cache_spec(cfg, b, 32)))
    _, cache = mla_attention(p, x[:, :s], cfg, positions=pos[:, :s],
                             cache=cache, cache_pos=0)
    out1, _ = mla_attention(p, x[:, s:], cfg, positions=pos[:, s:],
                            cache=cache, cache_pos=s)
    np.testing.assert_allclose(out1[:, 0], full[:, s], atol=2e-4, rtol=2e-4)
