"""Local component store: dedup accounting + sharing-granularity report."""
import json
import os
import threading

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip individually without hypothesis
    from _hypothesis_stub import given, settings, st

from repro.core.chunkstore import ChunkedComponentStore
from repro.core.component import UniformComponent
from repro.core.store import LocalComponentStore


def _c(name, version="1.0", env="e", size=1000):
    return UniformComponent(manager="m", name=name, version=version,
                            env=env, payload="p", size_bytes=size)


def test_dedup_counts():
    s = LocalComponentStore()
    a = _c("a", size=500)
    assert s.put(a) is True
    assert s.put(a) is False
    assert s.stats.bytes_stored == 500
    assert s.stats.bytes_requested == 1000
    assert s.stats.hits == 1 and s.stats.misses == 1


def test_sharing_report_granularities():
    s = LocalComponentStore()
    shared = [_c(f"common{i}", size=1_300_000) for i in range(4)]
    for b in ("b1", "b2", "b3"):
        comps = shared + [_c(f"uniq-{b}", size=900_000)]
        for c in comps:
            s.put(c)
        s.record_build(b, comps)
    rep = s.sharing_report()
    # component-level dedup saves the shared components' duplicated bytes
    assert rep["component"]["bytes_saved_pct"] > 40
    # layer-level (groups) shares less than component-level …
    assert rep["layer"]["bytes_saved_pct"] <= \
        rep["component"]["bytes_saved_pct"] + 1e-9
    # … and fine granularities need far more objects (paper Table 1)
    assert rep["chunk"]["before_objects"] > rep["file"]["before_objects"] \
        > rep["component"]["before_objects"]


def test_pairwise_sharing_bounds():
    s = LocalComponentStore()
    common = _c("x", size=100)
    a_only = _c("a", size=100)
    b_only = _c("b", size=100)
    for c in (common, a_only, b_only):
        s.put(c)
    s.record_build("a", [common, a_only])
    s.record_build("b", [common, b_only])
    pw = s.pairwise_sharing()
    assert abs(pw[("a", "b")] - 1 / 3) < 1e-9


@given(st.lists(st.tuples(st.sampled_from("abcdef"),
                          st.integers(1, 5),
                          st.integers(100, 10_000)),
                min_size=1, max_size=30))
@settings(max_examples=50, deadline=None)
def test_store_invariants(entries):
    s = LocalComponentStore()
    for name, ver, size in entries:
        s.put(_c(name, version=f"{ver}.0", size=size))
    assert 0 <= s.stats.bytes_stored <= s.stats.bytes_requested
    assert 0.0 <= s.stats.sharing_rate < 1.0 or \
        s.stats.bytes_requested == 0
    assert s.stats.hits + s.stats.misses == len(entries)


def test_concurrent_readers_never_race_writers():
    """digests()/has()/get()/reports snapshot under the store lock, so
    concurrent FleetDeployer-style putters cannot corrupt a reader's
    iteration (satellite: the read-without-lock race)."""
    s = LocalComponentStore()
    # size derives from (name, version): equal digests ⇒ equal bytes
    comps = [_c(f"n{i % 11}", version=f"{1 + i % 7}.0",
                size=100 + 10 * (i % 11) + (i % 7))
             for i in range(400)]
    errors = []
    stop = threading.Event()

    def writer(part):
        try:
            for c in part:
                s.put(c)
                s.record_build(f"b-{c.digest()[:8]}", [c])
        except Exception as e:           # noqa: BLE001
            errors.append(e)

    def reader():
        try:
            while not stop.is_set():
                for dg in s.digests():
                    s.get(dg)
                s.has(comps[0])
                s.pairwise_sharing()
        except Exception as e:           # noqa: BLE001
            errors.append(e)

    writers = [threading.Thread(target=writer, args=(comps[i::4],))
               for i in range(4)]
    readers = [threading.Thread(target=reader) for _ in range(3)]
    for t in readers + writers:
        t.start()
    for t in writers:
        t.join()
    stop.set()
    for t in readers:
        t.join()
    assert not errors
    uniq = {c.digest(): c for c in comps}
    assert s.stats.bytes_stored == sum(c.size_bytes for c in uniq.values())
    assert s.digests() == set(uniq)


def test_load_skips_corrupt_entries(tmp_path):
    """A torn/corrupt on-disk entry is skipped and counted, not fatal —
    mirroring BuildPlanCache._load."""
    path = str(tmp_path / "store")
    s1 = LocalComponentStore(path)
    good = [_c("a", size=500), _c("b", size=700)]
    for c in good:
        s1.put(c)
    with open(os.path.join(path, "torn.json"), "w") as f:
        f.write("not json {{{")
    with open(os.path.join(path, "wrongshape.json"), "w") as f:
        json.dump({"manager": "m"}, f)     # missing required fields
    s2 = LocalComponentStore(path)         # must not raise
    assert s2.stats.corrupt_skipped == 2
    assert s2.digests() == {c.digest() for c in good}
    assert s2.stats.bytes_stored == 1200


def test_chunked_store_reload_restores_chunk_presence(tmp_path):
    path = str(tmp_path / "store")
    s1 = ChunkedComponentStore(path, chunk_size=256)
    v1 = _c("a", version="1.0", size=10_240)
    s1.put(v1)
    s2 = ChunkedComponentStore(path, chunk_size=256)
    assert s2.chunk_count() == s1.chunk_count()
    # a version bump against the reloaded store still only pays the delta
    plan = s2.plan_fetch(_c("a", version="2.0", size=10_240))
    assert plan.hits and plan.bytes_claimed < 10_240
