"""Local component store: dedup accounting + sharing-granularity report."""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip individually without hypothesis
    from _hypothesis_stub import given, settings, st

from repro.core.component import UniformComponent
from repro.core.store import LocalComponentStore


def _c(name, version="1.0", env="e", size=1000):
    return UniformComponent(manager="m", name=name, version=version,
                            env=env, payload="p", size_bytes=size)


def test_dedup_counts():
    s = LocalComponentStore()
    a = _c("a", size=500)
    assert s.put(a) is True
    assert s.put(a) is False
    assert s.stats.bytes_stored == 500
    assert s.stats.bytes_requested == 1000
    assert s.stats.hits == 1 and s.stats.misses == 1


def test_sharing_report_granularities():
    s = LocalComponentStore()
    shared = [_c(f"common{i}", size=1_300_000) for i in range(4)]
    for b in ("b1", "b2", "b3"):
        comps = shared + [_c(f"uniq-{b}", size=900_000)]
        for c in comps:
            s.put(c)
        s.record_build(b, comps)
    rep = s.sharing_report()
    # component-level dedup saves the shared components' duplicated bytes
    assert rep["component"]["bytes_saved_pct"] > 40
    # layer-level (groups) shares less than component-level …
    assert rep["layer"]["bytes_saved_pct"] <= \
        rep["component"]["bytes_saved_pct"] + 1e-9
    # … and fine granularities need far more objects (paper Table 1)
    assert rep["chunk"]["before_objects"] > rep["file"]["before_objects"] \
        > rep["component"]["before_objects"]


def test_pairwise_sharing_bounds():
    s = LocalComponentStore()
    common = _c("x", size=100)
    a_only = _c("a", size=100)
    b_only = _c("b", size=100)
    for c in (common, a_only, b_only):
        s.put(c)
    s.record_build("a", [common, a_only])
    s.record_build("b", [common, b_only])
    pw = s.pairwise_sharing()
    assert abs(pw[("a", "b")] - 1 / 3) < 1e-9


@given(st.lists(st.tuples(st.sampled_from("abcdef"),
                          st.integers(1, 5),
                          st.integers(100, 10_000)),
                min_size=1, max_size=30))
@settings(max_examples=50, deadline=None)
def test_store_invariants(entries):
    s = LocalComponentStore()
    for name, ver, size in entries:
        s.put(_c(name, version=f"{ver}.0", size=size))
    assert 0 <= s.stats.bytes_stored <= s.stats.bytes_requested
    assert 0.0 <= s.stats.sharing_rate < 1.0 or \
        s.stats.bytes_requested == 0
    assert s.stats.hits + s.stats.misses == len(entries)
