"""WAN fault injection under the simulated transport.

The fault matrix of ISSUE 6: a node dying mid-stripe, a link flapping
during a peer transfer, a partition isolating an edge from every peer,
and a fault striking during an eviction-triggered refetch.  Every
scenario pins the two invariants the discrete-event transport must not
bend: ``bytes_delta_fetched <= bytes_fetched`` on every node (partial
work included), and the ``PeerIndex`` never over-claiming — every
holder it advertises really has the chunk in its store.
"""
import dataclasses
import math

import pytest

from repro.configs import ARCHS
from repro.core import (FaultPlan, PreBuilder, SimNetwork, UPSTREAM,
                        cpu_smoke, tpu_single_pod)
from repro.deploy import FleetDeployer, FleetTopology


@pytest.fixture(scope="module")
def cir(service):
    return PreBuilder(service).prebuild(ARCHS["starcoder2-3b"],
                                        entrypoint="serve")


@pytest.fixture(scope="module")
def other_cir(service):
    return PreBuilder(service).prebuild(ARCHS["phi4-mini-3.8b"],
                                        entrypoint="serve")


def _fleet(service, n_edges, faults=None, **kw):
    """1 cloud seed + N edges on a simulated network, sequential and
    single-fetch-worker so fault timing is deterministic."""
    topo = FleetTopology.edge_fanout(n_edges)
    cloud = tpu_single_pod()
    edges = [dataclasses.replace(cpu_smoke(), platform_id=f"edge-host-{i}")
             for i in range(n_edges)]
    topo.place(cloud.platform_id, "cloud")
    for i, s in enumerate(edges):
        topo.place(s.platform_id, f"edge-{i}")
    net = SimNetwork(topo, faults=faults)
    fd = FleetDeployer(service, topology=topo, simnet=net,
                       max_workers=1, fetch_workers=1, **kw)
    return topo, net, fd, cloud, edges


def _assert_no_overclaim(fd, topo, comps):
    """Every chunk holder the index advertises truly has the chunk."""
    store = fd.node_store(topo.seed)
    for comp in comps:
        for ch in store.chunks_of(comp):
            for node in fd.peer_index.holders(ch.id):
                assert fd.node_store(node).has_chunk(ch.id), \
                    f"index over-claims {ch.id} on {node}"


def _assert_partial_work_sane(res):
    for d in res.deployments:
        if d.report is not None:
            assert d.report.bytes_delta_fetched <= d.report.bytes_fetched


# ---------------------------------------------------------------------------
# Dead node mid-stripe
# ---------------------------------------------------------------------------

def test_dead_node_mid_stripe_falls_back_upstream(service, cir):
    """The seed dies while an edge is mid-transfer from it: the admission
    window overlaps the death, the peer pull fails, the edge re-routes
    the stripe upstream — and once virtual time passes the death, the
    ``PeerIndex`` drops the node so later selections route around it."""
    topo, net, fd, cloud, edges = _fleet(service, 3)
    res0 = fd.deploy(cir, [cloud])
    assert res0.ok
    comps = res0.deployments[0].instance.bundle.components()

    # the first edge transfer is always longer than 10 ms of virtual
    # time, so the death lands inside its admission window: mid-stripe
    net.inject_node_loss("cloud", at=net.clock.now + 0.01)
    res = fd.deploy(cir, edges)
    assert res.ok, res.summary()
    assert res.faults_fired_total >= 1
    assert res.peer_fallbacks_total > 0       # a pull actually died
    for d in res.deployments:
        assert res.node_traffic[d.node_id].bytes_total == \
            d.report.bytes_delta_fetched
    _assert_partial_work_sane(res)
    for comp in comps:
        for ch in fd.node_store("edge-0").chunks_of(comp):
            assert "cloud" not in fd.peer_index.holders(ch.id)
    _assert_no_overclaim(fd, topo, comps)


# ---------------------------------------------------------------------------
# Link flap during peer transfer
# ---------------------------------------------------------------------------

def test_link_flap_during_peer_transfer(service, cir):
    """The only peer link is down when the edge tries its peer pull: the
    transfer is refused at admission, the stripe falls back upstream and
    the deploy still converges — with zero peer bytes."""
    topo, net, fd, cloud, edges = _fleet(service, 1)
    assert fd.deploy(cir, [cloud]).ok
    net.inject_link_flap("cloud", "edge-0", at=net.clock.now,
                         until=math.inf)
    res = fd.deploy(cir, edges)
    assert res.ok, res.summary()
    t = res.node_traffic["edge-0"]
    assert t.bytes_from_peers == 0
    assert t.peer_fallbacks > 0
    assert t.bytes_from_upstream == \
        res.deployments[0].report.bytes_delta_fetched
    _assert_partial_work_sane(res)


# ---------------------------------------------------------------------------
# Partition isolating one edge
# ---------------------------------------------------------------------------

def test_partition_isolated_edge_converges_upstream(service, cir):
    """A partition cuts every peer link with exactly one endpoint in the
    group: the isolated edge converges purely upstream while the rest of
    the fleet keeps peering normally."""
    topo, net, fd, cloud, edges = _fleet(service, 3)
    assert fd.deploy(cir, [cloud]).ok
    net.inject_partition(["edge-0"], at=net.clock.now, until=math.inf)
    res = fd.deploy(cir, edges)
    assert res.ok, res.summary()
    isolated = res.node_traffic["edge-0"]
    assert isolated.bytes_from_peers == 0 and isolated.peer_fallbacks > 0
    # the others still reach the cloud (outside the group boundary)
    assert any(res.node_traffic[f"edge-{i}"].bytes_from_peers > 0
               for i in (1, 2))
    _assert_partial_work_sane(res)
    comps = res.deployments[0].instance.bundle.components()
    _assert_no_overclaim(fd, topo, comps)


# ---------------------------------------------------------------------------
# Fault during eviction-triggered refetch
# ---------------------------------------------------------------------------

def test_link_flap_during_eviction_refetch(service, cir, other_cir):
    """A capacity-bounded node churns A → B → A; the uplink flaps just as
    the re-deploy starts refetching evicted content.  The transient
    ``LinkDownError`` is retried with exponential virtual backoff until
    the link heals — the deploy converges and the retries are counted."""
    def build(capacity):
        topo = FleetTopology()
        topo.add_node("n0", upstream_bps=6.25e6, capacity_bytes=capacity)
        spec = dataclasses.replace(cpu_smoke(), platform_id="plat-n0")
        topo.place(spec.platform_id, "n0")
        net = SimNetwork(topo)
        fd = FleetDeployer(service, topology=topo, simnet=net,
                           max_workers=1, fetch_workers=1)
        return net, fd, spec

    # measure the A∪B working set unbounded, then bound below it
    net, fd, spec = build(None)
    for c in (cir, other_cir):
        assert fd.deploy(c, [spec]).ok
    union = fd.node_traffic("n0").bytes_from_upstream
    net, fd, spec = build(int(union * 0.75))
    assert fd.deploy(cir, [spec]).ok
    assert fd.deploy(other_cir, [spec]).ok    # evicts part of A
    # flap the WAN uplink across the start of the re-deploy; the window
    # (4 s) is far inside the ~51 s cumulative retry budget
    net.inject_link_flap("n0", UPSTREAM, at=net.clock.now,
                         until=net.clock.now + 4.0)
    res = fd.deploy(cir, [spec])
    assert res.ok, res.summary()
    assert res.refetch_bytes_total > 0, "capacity never forced a refetch"
    assert res.link_retries_total > 0, "flap never hit the refetch"
    _assert_partial_work_sane(res)


# ---------------------------------------------------------------------------
# Permanent faults: failure propagation through the lifecycle
# ---------------------------------------------------------------------------

def test_permanent_upstream_outage_fails_build_cleanly(service, cir):
    """An uplink that never heals exhausts the retry budget: the build
    fails with the link error, partial fetch accounting stays sane, the
    store's build lease is released (content is evictable again), and
    ``Lifecycle.failed_stage`` records where the fault struck."""
    topo = FleetTopology()
    topo.add_node("n0", upstream_bps=6.25e6)
    spec = dataclasses.replace(cpu_smoke(), platform_id="plat-n0")
    topo.place(spec.platform_id, "n0")
    net = SimNetwork(topo)
    net.inject_link_flap("n0", UPSTREAM, at=0.0, until=math.inf)
    fd = FleetDeployer(service, topology=topo, simnet=net,
                       max_workers=1, fetch_workers=1)
    res = fd.deploy(cir, [spec])
    assert not res.ok and res.n_failed == 1
    assert "LinkDownError" in res.deployments[0].error
    _assert_partial_work_sane(res)
    assert not fd.node_store("n0")._leases    # lease released on failure

    # the lifecycle pins the failed stage for error propagation
    inst = fd._node_builders["n0"].build(cir, spec, block=False)
    with pytest.raises(Exception, match="down"):
        inst.wait("complete")
    assert inst.lifecycle.failed_stage == "fetching"


def test_building_node_death_fails_its_own_build(service, cir):
    """The puller itself dying is not retried or re-routed: its build
    fails with ``NodeDownError``."""
    topo, net, fd, cloud, edges = _fleet(service, 1)
    assert fd.deploy(cir, [cloud]).ok
    net.inject_node_loss("edge-0", at=net.clock.now + 0.01)
    res = fd.deploy(cir, edges)
    assert not res.ok and res.n_failed == 1
    assert "NodeDownError" in res.deployments[0].error
    _assert_partial_work_sane(res)


# ---------------------------------------------------------------------------
# Satellite: listener errors surfaced in FleetResult
# ---------------------------------------------------------------------------

def test_listener_errors_aggregate_into_fleet_result(service, cir):
    """Advisory readiness listeners that raise are swallowed per build
    (the deploy still succeeds) but never silently: ``FleetResult``
    aggregates them as ``listener_errors_total``."""
    topo, net, fd, cloud, edges = _fleet(service, 2)

    def bad_listener(comp):
        raise RuntimeError("advisory consumer exploded")

    for lb in fd._node_builders.values():
        lb.readiness_listeners.append(bad_listener)
    res0 = fd.deploy(cir, [cloud])
    res1 = fd.deploy(cir, edges)
    assert res0.ok and res1.ok                # advisory: never fails a build
    assert res0.listener_errors_total > 0
    assert res1.listener_errors_total == \
        sum(d.report.listener_errors for d in res1.deployments)
    assert res1.listener_errors_total > 0
    assert "readiness-listener" in res1.summary()


# ---------------------------------------------------------------------------
# Seeded random fault plans: convergence property
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [11, 29])
def test_random_fault_plan_converges_or_fails_typed(service, cir, seed):
    """Under an arbitrary seeded fault plan (seed node protected), every
    deployment either converges or fails with a typed fault error — and
    the accounting/index invariants hold either way."""
    topo = FleetTopology.edge_fanout(4)
    plan = FaultPlan.random(topo, seed=seed, n_faults=5, horizon_s=30.0,
                            protect=("cloud",))
    cloud = tpu_single_pod()
    edges = [dataclasses.replace(cpu_smoke(), platform_id=f"edge-host-{i}")
             for i in range(4)]
    topo.place(cloud.platform_id, "cloud")
    for i, s in enumerate(edges):
        topo.place(s.platform_id, f"edge-{i}")
    net = SimNetwork(topo, faults=plan)
    fd = FleetDeployer(service, topology=topo, simnet=net,
                       max_workers=1, fetch_workers=1)
    res0 = fd.deploy(cir, [cloud])
    assert res0.ok                            # protected seed always lands
    res = fd.deploy(cir, edges)
    for d in res.deployments:
        assert d.ok or "DownError" in d.error, d.error
    _assert_partial_work_sane(res)
    comps = res0.deployments[0].instance.bundle.components()
    _assert_no_overclaim(fd, topo, comps)
    # failed nodes must not leak pin leases
    for d in res.deployments:
        if not d.ok:
            assert not fd.node_store(d.node_id)._leases
