"""Fault-tolerant driver: restart-from-checkpoint, elastic rescale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import LazyBuilder, PreBuilder, cpu_smoke
from repro.data import batch_for_arch
from repro.runtime import (RuntimeConfig, SimulatedFailure, TrainDriver,
                           elastic_rescale)


@pytest.fixture(scope="module")
def trainable(service, smoke_mesh):
    cfg = ARCHS["starcoder2-3b"].reduced()
    pb = PreBuilder(service)
    lb = LazyBuilder(service)
    inst = lb.build(pb.prebuild(cfg, entrypoint="train"), cpu_smoke(),
                    mesh=smoke_mesh)
    e = inst.entry
    step_fn = jax.jit(e["train_step"])

    def batch_fn(step):
        b = batch_for_arch(cfg, 32, 2, step=step)
        return {k: jnp.asarray(v) for k, v in b.items()}
    return inst, step_fn, batch_fn


def test_failure_injection_restarts_and_completes(tmp_path, trainable):
    inst, step_fn, batch_fn = trainable
    fails = {5, 13}

    def hook(step):
        if step in fails:
            fails.discard(step)
            raise SimulatedFailure(step)

    drv = TrainDriver(
        train_step=step_fn,
        init_state=lambda: inst.entry["init_state"](jax.random.PRNGKey(0)),
        batch_fn=batch_fn, ckpt_dir=str(tmp_path),
        cfg=RuntimeConfig(total_steps=20, checkpoint_every=4),
        failure_hook=hook)
    res = drv.run()
    assert res.steps_done == 20
    assert res.restarts == 2
    assert np.isfinite(res.final_loss)


def test_restart_resumes_from_checkpoint_not_zero(tmp_path, trainable):
    """After a crash at step 9 the driver resumes at step 8 (the last
    checkpoint), not at step 0."""
    inst, step_fn, batch_fn = trainable
    executed = []
    state = {"crashed": False}

    def hook(step):
        if step == 9 and not state["crashed"]:
            state["crashed"] = True
            raise SimulatedFailure(step)
        executed.append(step)

    drv = TrainDriver(
        train_step=step_fn,
        init_state=lambda: inst.entry["init_state"](jax.random.PRNGKey(0)),
        batch_fn=batch_fn, ckpt_dir=str(tmp_path),
        cfg=RuntimeConfig(total_steps=12, checkpoint_every=4),
        failure_hook=hook)
    res = drv.run()
    assert res.steps_done == 12 and res.restarts == 1
    # first run: 0..8 executed, crash before 9; second run resumes at 8
    i = executed.index(8)                   # first pass reaches 8
    assert executed[i + 1:][0] == 8         # resume re-executes from 8
    assert 0 not in executed[i + 1:]        # never restarted from scratch


def test_elastic_rescale_rebuilds_and_restores(tmp_path, service, smoke_mesh):
    """The paper's migration story: checkpoint on platform A, lazy-rebuild
    the same CIR for platform B, restore resharded."""
    cfg = ARCHS["phi4-mini-3.8b"].reduced()
    pb = PreBuilder(service)
    lb = LazyBuilder(service)
    cir = pb.prebuild(cfg, entrypoint="train")
    inst = lb.build(cir, cpu_smoke(), mesh=smoke_mesh)
    e = inst.entry
    state = e["init_state"](jax.random.PRNGKey(0))
    step_fn = jax.jit(e["train_step"])
    b = {k: jnp.asarray(v) for k, v in
         batch_for_arch(cfg, 32, 2, step=0).items()}
    state, _ = step_fn(state, b)
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, state)

    def shardings_fn(container, mesh):
        return container.entry["state_shardings"]()

    container, step, state2 = elastic_rescale(
        lb, cir, inst.lock, cpu_smoke(), smoke_mesh, str(tmp_path),
        shardings_fn)
    assert step == 1
    w1 = jax.tree_util.tree_leaves(state["params"])[0]
    w2 = jax.tree_util.tree_leaves(state2["params"])[0]
    np.testing.assert_allclose(np.asarray(w1, np.float32),
                               np.asarray(w2, np.float32))
    # the rebuilt container still steps
    state3, m = jax.jit(container.entry["train_step"])(state2, b)
    assert np.isfinite(float(m["loss"]))
