"""Store lifecycle: capacity-bounded eviction, build pin leases,
eviction-aware peering, and component GC (docs/cir-format.md §8).

Covers the subsystem's claims: pinned and in-flight content is never
evicted, `PeerIndex` retraction is ordered before the bytes drop (a peer
fetch after eviction falls back upstream, never over-claims), bounded
stores are byte-identical to unbounded ones until capacity binds, evicted
chunks re-enter plans as misses (`delta <= fetched` survives churn),
components whose every chunk was evicted are GC'd, the orchestrator
acquires/releases leases around the lifecycle (error paths included), and
`warm()` pins seed content against churn.
"""
import dataclasses
import threading

import pytest

from repro.configs import ARCHS
from repro.core import (ChunkedComponentStore, FetchEngine, LazyBuilder,
                        LocalComponentStore, PreBuilder, cpu_smoke,
                        tpu_single_pod)
from repro.core import catalog
from repro.core.component import UniformComponent
from repro.core.lazybuild import BuildReport
from repro.core.registry import (UniformComponentRegistry,
                                 UniformComponentService)
from repro.deploy import FleetDeployer, FleetTopology


def _c(name, version="1.0", env="e", size=1000, manager="m"):
    return UniformComponent(manager=manager, name=name, version=version,
                            env=env, payload="p", size_bytes=size)


# ---------------------------------------------------------------------------
# Base store: component-granularity capacity + leases
# ---------------------------------------------------------------------------

def test_component_store_evicts_lru_past_capacity():
    s = LocalComponentStore(capacity_bytes=2500)
    a, b, c = _c("a"), _c("b"), _c("c")          # 1000 B each
    s.put(a), s.put(b)
    s.get(a.digest())                            # refresh a: b is now LRU
    s.put(c)                                     # 3000 > 2500: evict b
    assert s.has(a) and s.has(c) and not s.has(b)
    assert s.stats.bytes_stored == 2000
    assert s.lifecycle_stats.evicted_bytes == 1000
    s.put(b)                                     # re-fetch of evicted entry
    assert s.lifecycle_stats.refetch_bytes == 1000


def test_component_store_lease_pins_against_eviction():
    s = LocalComponentStore(capacity_bytes=2500)
    a, b, c = _c("a"), _c("b"), _c("c")
    s.put(a), s.put(b)
    s.acquire_build_lease("build-1", [a, b])
    s.put(c)                                     # over budget, all pinned
    assert s.has(a) and s.has(b)                 # pins held
    assert s.lifecycle_stats.pin_denied_evictions >= 1
    assert s.stats.bytes_stored == 3000          # soft budget: still over
    s.release_build("build-1")                   # deferred eviction
    assert s.stats.bytes_stored <= 2500
    assert s.release_build("build-1") is False   # idempotent
    s.acquire_build_lease("b2", [a])
    with pytest.raises(ValueError):
        s.acquire_build_lease("b2", [a])         # double acquire is a bug


def test_release_build_keeps_build_history():
    """The lease is lifecycle; record_build is accounting — releasing the
    lease must not erase the sharing-report history."""
    s = LocalComponentStore()
    a = _c("a")
    s.put(a)
    s.acquire_build_lease("b1", [a])
    s.record_build("b1", [a])
    s.release_build("b1")
    rep = s.sharing_report()
    assert rep["component"]["after_objects"] == 1


def test_eviction_policy_validated():
    with pytest.raises(ValueError):
        LocalComponentStore(eviction_policy="fifo")
    with pytest.raises(ValueError):
        ChunkedComponentStore(capacity_bytes=0)


# ---------------------------------------------------------------------------
# Chunk store: chunk-granularity eviction, pins, GC
# ---------------------------------------------------------------------------

def test_chunk_eviction_marks_incomplete_and_replans_as_miss():
    """An evicted chunk re-entering a plan is accounted as a miss, so the
    `delta <= fetched` invariant survives churn."""
    s = ChunkedComponentStore(chunk_size=1024, capacity_bytes=12 * 1024)
    svc = UniformComponentService(UniformComponentRegistry())
    a = _c("a", size=10 * 1024)
    rep = BuildReport("x", "p")
    FetchEngine(s, svc).fetch([a], rep)
    assert rep.bytes_delta_fetched == a.size_bytes
    b = _c("b", size=8 * 1024)                   # pushes over 12 KiB
    FetchEngine(s, svc).fetch([b], BuildReport("x", "p"))
    assert s.lifecycle_stats.evicted_bytes >= 6 * 1024   # a's LRU chunks
    # a's digest is incomplete now: re-planning it re-claims the evicted
    # chunks and counts a component-level miss with delta <= fetched
    rep2 = BuildReport("x", "p")
    FetchEngine(s, svc).fetch([a], rep2)
    assert rep2.cache_misses == 1
    assert 0 < rep2.bytes_delta_fetched <= rep2.bytes_fetched
    assert s.lifecycle_stats.refetch_bytes == rep2.bytes_delta_fetched
    assert all(s.has_chunk(ch.id) for ch in s.chunks_of(a))


def test_component_gc_when_every_chunk_evicted():
    """A tiny capacity churns whole components out: the emptied component
    is GC'd and its next build is a plain component-level miss."""
    s = ChunkedComponentStore(chunk_size=1024, capacity_bytes=8 * 1024)
    a = _c("a", size=8 * 1024)
    b = _c("b", size=8 * 1024)
    s.put(a)
    s.put(b)                                     # evicts ALL of a
    assert not s.has(a)                          # GC'd, not just holey
    assert s.lifecycle_stats.components_gcd == 1
    plan = s.plan_fetch(a)
    assert plan.component_new                    # plain miss again
    assert len(plan.claimed) == 8


def test_shared_chunk_eviction_does_not_gc_siblings():
    """Evicting a shared chunk leaves its sibling versions registered (but
    incomplete) as long as they still hold content."""
    s = ChunkedComponentStore(chunk_size=1024, capacity_bytes=1 << 40)
    v1 = _c("a", version="1.0", size=10 * 1024)
    v2 = _c("a", version="2.0", size=10 * 1024)
    s.put(v1)
    s.put(v2)
    shared = [ch.id for ch in s.chunks_of(v1) if ch.shared]
    s.capacity_bytes = s.chunk_stats.chunk_bytes_stored - 1024
    with s._lock:
        s._enforce_capacity_locked()             # evicts the LRU chunk
    assert s.has(v1) and s.has(v2)               # both still registered
    assert s.lifecycle_stats.components_gcd == 0
    # the digest(s) referencing the evicted chunk were marked incomplete
    assert s._incomplete
    assert shared                                # sanity: the model shares


def test_pinned_chunks_never_evicted_and_deferred_on_release():
    s = ChunkedComponentStore(chunk_size=1024, capacity_bytes=10 * 1024)
    a = _c("a", size=8 * 1024)
    b = _c("b", size=8 * 1024)
    s.acquire_build_lease("build-a", [a])        # two concurrent builds,
    s.acquire_build_lease("build-b", [b])        # both leased (orchestrator)
    s.put(a)
    s.put(b)                                     # 16 KiB resident, all pinned
    assert all(s.has_chunk(ch.id) for ch in s.chunks_of(a))
    assert all(s.has_chunk(ch.id) for ch in s.chunks_of(b))
    assert s.lifecycle_stats.pin_denied_evictions >= 1
    assert s.lifecycle_stats.evicted_bytes == 0
    s.release_build("build-a")                   # deferred eviction runs
    assert s.chunk_stats.chunk_bytes_stored <= 10 * 1024
    assert s.lifecycle_stats.evicted_bytes >= 6 * 1024
    assert all(s.has_chunk(ch.id) for ch in s.chunks_of(b))  # b still pinned
    s.release_build("build-b")


def test_inflight_claims_survive_concurrent_eviction():
    """Eviction vs a mid-flight singleflight claim: the claimed chunks are
    exempt, commit lands them, and the committing build's content is intact
    afterwards (its own lease protects it from the very eviction its
    commit triggers)."""
    s = ChunkedComponentStore(chunk_size=1024, capacity_bytes=10 * 1024)
    filler = _c("filler", size=9 * 1024)
    s.put(filler)
    a = _c("a", size=8 * 1024)
    s.acquire_build_lease("build-a", [a])        # what the orchestrator does
    plan = s.plan_fetch(a)
    assert plan.claimed
    # committing a's chunks pushes the store over budget mid-commit: the
    # eviction pass inside commit_chunks must take filler, never a
    s.commit_chunks(plan.claimed, component=a)
    assert all(s.has_chunk(ch.id) for ch in s.chunks_of(a))
    assert s.lifecycle_stats.evicted_bytes > 0   # filler paid
    s.release_build("build-a")


def test_eviction_listener_ordered_before_drop():
    """The listener fires while the bytes are still present — retraction
    strictly precedes the drop."""
    s = ChunkedComponentStore(chunk_size=1024, capacity_bytes=8 * 1024)
    observed = []

    def listener(chunk_ids):
        # called under the store lock (RLock: has_chunk re-enters safely)
        observed.extend((cid, s.has_chunk(cid)) for cid in chunk_ids)

    s.eviction_listeners.append(listener)
    s.put(_c("a", size=8 * 1024))
    s.put(_c("b", size=8 * 1024))
    assert observed
    assert all(present for _cid, present in observed)
    assert all(not s.has_chunk(cid) for cid, _p in observed)


def test_cheapest_to_restore_prefers_peer_held_chunks():
    s = ChunkedComponentStore(chunk_size=1024, capacity_bytes=16 * 1024,
                              eviction_policy="cheapest-to-restore")
    peer_held = _c("held", size=8 * 1024)
    local_only = _c("local", size=8 * 1024)
    s.put(peer_held)
    s.put(local_only)
    held_ids = {ch.id for ch in s.chunks_of(peer_held)}
    s.peer_probe = lambda cid: cid in held_ids
    # local_only is older-ish? make peer_held the LRU-oldest is irrelevant:
    # policy must pick peer-held first even though local_only is not older
    s.put(_c("new", size=8 * 1024))              # forces an 8 KiB eviction
    assert all(s.has_chunk(ch.id) for ch in s.chunks_of(local_only))
    assert not any(s.has_chunk(cid) for cid in held_ids)


def test_bounded_store_matches_unbounded_until_capacity_binds():
    """Byte-identical accounting between bounded and unbounded stores when
    capacity is never hit — capacity must be invisible until it evicts."""
    svc = catalog.build_service()
    pb = PreBuilder(svc)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    spec = tpu_single_pod()
    reports = {}
    for name, store in (
            ("unbounded", ChunkedComponentStore()),
            ("bounded", ChunkedComponentStore(capacity_bytes=1 << 50,
                                              eviction_policy="lru"))):
        lb = LazyBuilder(svc, store)
        cold = lb.build(cir, spec, assemble=False).report
        warm = lb.build(cir, spec, assemble=False).report
        reports[name] = [
            (r.bytes_delta_fetched, r.bytes_fetched, r.chunks_hit,
             r.chunks_missed, r.cache_hits, r.cache_misses)
            for r in (cold, warm)]
        assert store.lifecycle_stats.evicted_bytes == 0
    assert reports["bounded"] == reports["unbounded"]


# ---------------------------------------------------------------------------
# Orchestrator lease lifecycle
# ---------------------------------------------------------------------------

def test_build_lease_released_at_complete(service):
    pb = PreBuilder(service)
    lb = LazyBuilder(service)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="train")
    inst = lb.build(cir, tpu_single_pod(), assemble=False)
    assert inst.stage == "complete"
    ls = lb.store.lifecycle_stats
    assert ls.leases_acquired >= 1
    assert ls.leases_released == ls.leases_acquired
    assert lb.store.pinned_digests() == set()


def test_build_lease_released_on_error_path(service):
    pb = PreBuilder(service)
    lb = LazyBuilder(service)
    # serve pulls the weight asset — the fetch we make die mid-transfer
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    orig = lb.service.fetch_chunks

    def boom(c, nbytes, nchunks=1):
        if c.manager == "asset":
            raise RuntimeError("link died")
        return orig(c, nbytes, nchunks)

    lb.service.fetch_chunks = boom
    try:
        with pytest.raises(RuntimeError):
            lb.build(cir, tpu_single_pod(), assemble=False, overlap=False)
    finally:
        lb.service.fetch_chunks = orig
    ls = lb.store.lifecycle_stats
    assert ls.leases_released == ls.leases_acquired  # no leaked pin
    assert lb.store.pinned_digests() == set()


def test_listener_errors_are_counted_not_fatal(service):
    """Satellite: a raising readiness listener must not fail the build,
    but the swallows are observable through BuildReport.listener_errors."""
    pb = PreBuilder(service)
    lb = LazyBuilder(service)
    calls = []

    def bad_listener(c):
        calls.append(c)
        raise RuntimeError("advisory consumer crashed")

    lb.readiness_listeners.append(bad_listener)
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="train")
    inst = lb.build(cir, tpu_single_pod(), assemble=False)
    assert inst.stage == "complete"
    assert inst.report.listener_errors == len(calls)
    assert inst.report.listener_errors == inst.report.n_components


# ---------------------------------------------------------------------------
# Eviction-aware peering (topology mode)
# ---------------------------------------------------------------------------

def _bounded_fanout(service, capacity_bytes, n_edges=2,
                    policy="lru"):
    topo = FleetTopology.edge_fanout(n_edges,
                                     edge_capacity_bytes=capacity_bytes)
    cloud = tpu_single_pod()
    edges = [dataclasses.replace(cpu_smoke(), platform_id=f"edge-host-{i}")
             for i in range(n_edges)]
    topo.place(cloud.platform_id, "cloud")
    for i, s in enumerate(edges):
        topo.place(s.platform_id, f"edge-{i}")
    fd = FleetDeployer(service, topology=topo, eviction_policy=policy)
    return fd, cloud, edges


def test_eviction_retracts_announcements_then_peers_fall_back(service):
    """After an edge's content is evicted, its PeerIndex advertisements are
    gone; a later node must fall back upstream — never a failed build or
    an over-claiming index."""
    pb = PreBuilder(service)
    big = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="serve")
    small = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    # capacity fits either CIR's cpu content but not both
    fd, cloud, edges = _bounded_fanout(service, 9 * 2**30)
    res = fd.deploy(small, [edges[0]])
    assert res.ok
    held_small = fd.peer_index.chunks_held("edge-0")
    assert held_small > 0
    res = fd.deploy(big, [edges[0]])             # churns small out
    assert res.ok
    store = fd.node_store("edge-0")
    # deploy() returns at lifecycle COMPLETE; the build's lease release —
    # and the deferred eviction it triggers — may still be settling on the
    # driver thread, so the over-claim check must exploit the ordering
    # invariant instead of assuming quiescence: retraction strictly
    # precedes the drop, so checking the store FIRST and the index SECOND
    # can never report a false over-claim.
    with fd.peer_index._lock:
        advertised = [cid for cid, holders in fd.peer_index._holders.items()
                      if "edge-0" in holders]
    over_claims = [cid for cid in advertised
                   if not store.has_chunk(cid)
                   and "edge-0" in fd.peer_index.holders(cid)]
    assert over_claims == []
    # small's content was churned out mid-deploy (its bytes were unpinned
    # while big's build — leased — landed), counted in this deploy
    assert res.evicted_bytes_total > 0
    # edge-1 deploying the small CIR cannot rely on edge-0 anymore for the
    # evicted chunks — it pulls upstream (or from the cloud) and succeeds
    res2 = fd.deploy(small, [edges[1]])
    assert res2.ok
    t = res2.node_traffic["edge-1"]
    assert t.bytes_from_upstream > 0
    d = res2.deployments[0]
    assert t.bytes_total == d.report.bytes_delta_fetched
    assert d.report.bytes_delta_fetched <= d.report.bytes_fetched


def test_fleet_reports_eviction_columns(service):
    pb = PreBuilder(service)
    big = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="serve")
    small = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    fd, cloud, edges = _bounded_fanout(service, 9 * 2**30)
    fd.deploy(small, [edges[0]])
    res = fd.deploy(big, [edges[0]])
    assert res.evicted_bytes_total > 0
    assert "store churn" in res.summary()
    res3 = fd.deploy(small, [edges[0]])          # re-fetch evicted content
    assert res3.refetch_bytes_total > 0


def test_warm_pins_seed_content_against_churn(service):
    """Satellite: a churny workload on the seed node must not evict the
    just-warmed bytes (they are pinned until release_warm)."""
    pb = PreBuilder(service)
    common = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    churny = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="serve")
    topo = FleetTopology.edge_fanout(1, cloud_capacity_bytes=12 * 2**30)
    cloud = tpu_single_pod()
    edge = dataclasses.replace(cpu_smoke(), platform_id="edge-host-0")
    topo.place(cloud.platform_id, "cloud")
    topo.place(edge.platform_id, "edge-0")
    fd = FleetDeployer(service, topology=topo)
    assert fd.warm(common, [cloud]) == 1
    seed_store = fd.node_store("cloud")
    warmed = seed_store.chunk_count()
    assert warmed > 0
    res = fd.deploy(churny, [cloud])             # churn on the seed itself
    assert res.ok
    assert seed_store.lifecycle_stats.pin_denied_evictions >= 1
    # re-warming refreshes the lease with no unpinned window (the new
    # generation is acquired before the old one is released)
    assert fd.warm(common, [cloud]) == 1
    assert seed_store.pinned_digests()           # still pinned throughout
    # every warmed chunk survived: the edge can still peer off the seed
    inst_comps = {c.digest() for c in res.instance(
        cloud.platform_id).bundle.components()}
    assert inst_comps                            # sanity
    edge_res = fd.deploy(common, [edge])
    assert edge_res.ok
    assert edge_res.node_traffic["edge-0"].bytes_from_peers > 0
    # releasing the warm lease makes the seed content evictable again
    assert fd.release_warm(common) is True
    assert fd.release_warm(common) is False


def test_spec_release_mid_restore_never_drops_proven_chunks(service):
    """Satellite: snapshot-restore racing a ``spec:`` lease release.  A
    retired instance's content survives only under the spec soft lease;
    a restore starts (its build lease pins what its plan proved present)
    and the spec lease is released MID-restore under capacity pressure.
    The release must not let the pass drop chunks the restore proved —
    the control case (same release + pressure, no restore in flight)
    shows the same content IS the first victim otherwise."""
    from repro.core import (CompileCache, SPEC_LEASE_PREFIX,
                            restore_instance, snapshot_instance)
    pb = PreBuilder(service)
    store = ChunkedComponentStore()
    lb = LazyBuilder(service, store, compile_cache=CompileCache())
    cir = pb.prebuild(ARCHS["starcoder2-3b"], entrypoint="serve")
    inst = lb.build(cir, cpu_smoke(), assemble=True, compile_steps=True)
    snap = snapshot_instance(inst)
    comps = list(inst.bundle.components())
    proven = {ch.id for c in comps for ch in store.chunks_of(c)}
    evicted = []
    store.eviction_listeners.append(evicted.extend)

    # -- control: retired content under pressure, NO restore in flight --
    store.acquire_build_lease(f"{SPEC_LEASE_PREFIX}retired:ctl", comps)
    store.capacity_bytes = store.chunk_stats.chunk_bytes_stored
    store.put(_c("filler-1", size=64 * 1024))    # over budget: pass runs
    assert proven & set(evicted)                 # spec tier went first
    store.release_build(f"{SPEC_LEASE_PREFIX}retired:ctl")

    # repair, then retire again for the raced restore
    store.capacity_bytes = None
    assert restore_instance(snap, lb).stage == "complete"
    store.acquire_build_lease(f"{SPEC_LEASE_PREFIX}retired:raced", comps)
    store.capacity_bytes = store.chunk_stats.chunk_bytes_stored
    evicted.clear()
    fired = []

    def release_mid_restore(c):
        if not fired:
            fired.append(True)
            # the race: the spec lease goes away while the restore is
            # mid-flight, and a filler lands to force an eviction pass
            store.release_build(f"{SPEC_LEASE_PREFIX}retired:raced")
            store.put(_c("filler-2", size=64 * 1024))

    lb.readiness_listeners.append(release_mid_restore)
    try:
        restored = restore_instance(snap, lb, block=False)
        restored.wait("ready")
        # the pass ran under pressure and evicted unpinned bytes (filler,
        # artifact chunks) — but every chunk the restore proved present is
        # pinned by its build lease, so none of THOSE dropped
        assert fired
        assert evicted
        assert not (proven & set(evicted))
        assert all(store.has_chunk(cid) for cid in proven)
        restored.wait("complete")
        assert restored.report.bytes_delta_fetched == 0
    finally:
        lb.readiness_listeners.remove(release_mid_restore)


def test_concurrent_churn_never_evicts_pinned_or_inflight(service):
    """Eviction races under real concurrency: two edges churn CIRs while
    every eviction pass is checked against the pin/in-flight exemption."""
    pb = PreBuilder(service)
    cirs = [pb.prebuild(ARCHS[a], entrypoint="serve")
            for a in ("starcoder2-3b", "phi4-mini-3.8b")]
    fd, cloud, edges = _bounded_fanout(service, 8 * 2**30)
    violations = []
    orig = ChunkedComponentStore._drop_chunks_locked

    def checked(self, victims):
        for cid in victims:
            if self._chunk_pins.get(cid) or cid in self._chunk_inflight:
                violations.append(cid)
        return orig(self, victims)

    ChunkedComponentStore._drop_chunks_locked = checked
    try:
        def churn_edge(i):
            for _round in range(2):
                for cir in cirs:
                    res = fd.deploy(cir, [edges[i]])
                    assert res.ok, res.summary()

        threads = [threading.Thread(target=churn_edge, args=(i,))
                   for i in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        ChunkedComponentStore._drop_chunks_locked = orig
    assert violations == []
    assert sum(fd.node_store(f"edge-{i}").lifecycle_stats.evicted_bytes
               for i in range(2)) > 0
