"""CIR format + pre-builder: round-trip, digests, indirect-dep filtering."""
from repro.configs import ARCHS
from repro.core import CIR, PreBuilder
from repro.core.component import DependencyItem as D


def test_cir_roundtrip_and_digest_stability(service):
    pb = PreBuilder(service)
    cir = pb.prebuild(ARCHS["gemma2-9b"], entrypoint="train", seed=7)
    blob = cir.to_bytes()
    cir2 = CIR.from_bytes(blob)
    assert cir2.name == cir.name
    assert cir2.seed == 7
    assert cir2.deps == cir.deps
    assert cir2.arch_config().d_model == ARCHS["gemma2-9b"].d_model
    # digest is over deterministic bytes (mtime=0 gzip)
    assert cir.digest() == CIR.from_bytes(blob).digest()


def test_cir_is_lightweight(service):
    """The paper's 95% claim: a CIR is KBs; the environment it expands to is
    hundreds of MBs+ of components."""
    pb = PreBuilder(service)
    for arch_id in ("gemma2-9b", "deepseek-v3-671b", "rwkv6-1.6b"):
        cir = pb.prebuild(ARCHS[arch_id], entrypoint="train")
        assert cir.size_bytes() < 16 * 1024, arch_id


def test_manifest_text_format(service):
    pb = PreBuilder(service)
    cir = pb.prebuild(ARCHS["qwen2-vl-2b"], entrypoint="serve")
    txt = cir.to_text()
    assert "[NAME] qwen2-vl-2b" in txt
    assert "[DEPENDENCY]" in txt
    assert "- [model] decoder-vlm" in txt
    assert "- [asset] weights-qwen2-vl-2b [latest]" in txt
    assert "[ENTRYPOINT] serve" in txt


def test_prebuilder_filters_indirect_deps(service):
    """Declared deps reachable from another declared dep's transitive
    metadata closure are dropped (paper §4.1 'filters out the indirect
    dependencies')."""
    pb = PreBuilder(service)
    cfg = ARCHS["starcoder2-3b"]
    deps = pb.analyze(cfg, "train")
    # user also (redundantly) declares what the model family already implies
    deps = deps + [D("kernel", "attention", "any"),
                   D("env", "runtime-base", "any")]
    kept = pb.filter_indirect(deps)
    kept_keys = {d.key() for d in kept}
    assert ("kernel", "attention") not in kept_keys
    assert ("env", "runtime-base") not in kept_keys
    assert ("model", "decoder-dense") in kept_keys
