"""Batched serving example: lazy-build a serve container and drive the
slot-based continuous-batching engine with a bursty synthetic workload.

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch gemma2-9b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.core import LazyBuilder, PreBuilder, probe_host
from repro.core import catalog
from repro.launch.mesh import make_smoke_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b",
                    choices=sorted(ARCHS.keys()))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    service = catalog.build_service()
    cir = PreBuilder(service).prebuild(cfg, entrypoint="serve")
    inst = LazyBuilder(service).build(
        cir, probe_host(mesh_shape=(1,), mesh_axes=("data",)),
        mesh=make_smoke_mesh(1), overrides={"workload": "decode"})
    print(f"lazy-built {cfg.arch_id} for serving "
          f"(plan={inst.bundle.context.get('plan.rules')})")

    params = inst.model.init(jax.random.PRNGKey(0))
    engine = inst.entry["make_engine"](
        params, num_slots=args.slots, max_seq=256, prefill_buckets=(32,))

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    # bursty arrivals: half up front, half mid-flight
    for _ in range(args.requests // 2):
        engine.submit(rng.integers(1, cfg.vocab,
                                   int(rng.integers(4, 28))).tolist(),
                      max_new_tokens=args.max_new)
    for _ in range(20):
        engine.tick()
    for _ in range(args.requests - args.requests // 2):
        engine.submit(rng.integers(1, cfg.vocab,
                                   int(rng.integers(4, 28))).tolist(),
                      max_new_tokens=args.max_new)
    responses = engine.run_until_drained()
    dt = time.perf_counter() - t0

    toks = sum(len(r.tokens) for r in responses)
    lat = sorted(r.queued_s for r in responses)
    print(f"{len(responses)} responses, {toks} tokens, {dt:.1f}s wall "
          f"({toks/dt:.1f} tok/s, {engine._ticks} fused decode ticks)")
    print(f"latency p50={lat[len(lat)//2]*1e3:.0f}ms "
          f"p95={lat[int(len(lat)*0.95)]*1e3:.0f}ms")


if __name__ == "__main__":
    main()
