"""End-to-end driver: train a ~100M-parameter decoder for a few hundred
steps through the full stack — CIR pre-build → lazy-build → fault-tolerant
driver with checkpointing — and report the loss curve.

The data pipeline injects copy structure, so the loss measurably drops.

Run:  PYTHONPATH=src python examples/train_end_to_end.py [--steps 200]
(~100M params on CPU: expect a few seconds per step; use --small for CI.)
"""
import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import LazyBuilder, PreBuilder, probe_host
from repro.core import catalog
from repro.launch.mesh import make_smoke_mesh
from repro.runtime import RuntimeConfig, TrainDriver

# a ~107M-parameter dense LM (41M embedding + 66M blocks)
CONFIG_100M = ArchConfig(
    arch_id="demo-107m", family="dense-lm",
    num_layers=10, d_model=640, n_heads=10, n_kv=5, head_dim=64,
    d_ff=2560, vocab=32000, ffn="swiglu", norm="rms",
    rope_theta=10000.0, dtype="float32", max_seq=1024,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--small", action="store_true",
                    help="~10M params / fast CI variant")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e")
    args = ap.parse_args()

    cfg = CONFIG_100M
    if args.small:
        cfg = dataclasses.replace(cfg, num_layers=4, d_model=256,
                                  n_heads=4, n_kv=2, d_ff=1024, vocab=8000,
                                  arch_id="demo-10m")
    n_params = cfg.param_count()
    print(f"{cfg.arch_id}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq} tokens")

    service = catalog.build_service()
    cir = PreBuilder(service).prebuild(cfg, entrypoint="train")
    inst = LazyBuilder(service).build(
        cir, probe_host(mesh_shape=(1,), mesh_axes=("data",)),
        mesh=make_smoke_mesh(1),
        overrides={"lr": 6e-4, "total_steps": args.steps,
                   "warmup": args.steps // 10})
    e = inst.entry
    step_fn = jax.jit(e["train_step"], donate_argnums=(0,))

    def batch_fn(step):
        return {k: jnp.asarray(v) for k, v in
                e["batch_fn"](args.seq, args.batch, step=step).items()}

    driver = TrainDriver(
        train_step=step_fn,
        init_state=lambda: e["init_state"](jax.random.PRNGKey(0)),
        batch_fn=batch_fn,
        ckpt_dir=os.path.join(args.ckpt_dir, cfg.arch_id),
        cfg=RuntimeConfig(total_steps=args.steps,
                          checkpoint_every=max(args.steps // 4, 10)))
    t0 = time.perf_counter()
    res = driver.run()
    dt = time.perf_counter() - t0
    k = max(1, args.steps // 10)
    first = sum(res.losses[:k]) / k
    last = sum(res.losses[-k:]) / k
    toks = args.steps * args.batch * args.seq
    print(f"done in {dt:.0f}s ({toks/dt:.0f} tok/s on CPU)")
    print(f"loss: first-{k}-avg {first:.4f}  ->  last-{k}-avg {last:.4f}")
    assert last < first, "loss did not decrease"
    print("loss decreased — end-to-end training path OK")


if __name__ == "__main__":
    main()
