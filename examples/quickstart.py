"""Quickstart — the paper's whole story in one script.

1. PRE-BUILD (development platform): an architecture config is analyzed
   into a CIR holding only declarative DIRECT dependencies — a few hundred
   bytes, fully cross-platform.
2. LAZY-BUILD (deployment platform): the CIR is resolved against the
   platform's specSheet (Algorithms 1+2), components are fetched with
   component-level active sharing, and assembled into a runnable container
   (model + jitted step functions).
3. The same CIR deploys to a second, different platform — different
   concrete components, zero developer effort.
4. The lockfile pins every selected component for bit-identical rebuilds.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.core import (CIR, LazyBuilder, LocalComponentStore, PreBuilder,
                        cpu_smoke, tpu_single_pod)
from repro.core import catalog
from repro.launch.mesh import make_smoke_mesh


def main():
    service = catalog.build_service()

    # -- 1. pre-build ------------------------------------------------------
    cfg = ARCHS["gemma2-9b"].reduced()     # same family, laptop-sized
    cir = PreBuilder(service).prebuild(cfg, entrypoint="train")
    print("=== CIR manifest", f"({cir.size_bytes()} bytes on the wire) ===")
    print(cir.to_text(), "\n")

    # the image round-trips as bytes — this is what a registry stores
    blob = cir.to_bytes()
    cir = CIR.from_bytes(blob)

    # -- 2. lazy-build on this machine --------------------------------------
    builder = LazyBuilder(service, LocalComponentStore())
    mesh = make_smoke_mesh(1)
    inst = builder.build(cir, cpu_smoke(), mesh=mesh)
    print("=== resolved component tree (this platform) ===")
    print(inst.bundle.resolution.explain(), "\n")

    state = inst.entry["init_state"](jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in
             inst.entry["batch_fn"](64, 2).items()}
    step = jax.jit(inst.entry["train_step"])
    for i in range(3):
        state, metrics = step(state, batch)
        print(f"step {i}: loss={float(metrics['loss']):.4f} "
              f"grad_norm={float(metrics['grad_norm']):.3f}")

    # -- 3. the SAME CIR on a different platform ----------------------------
    pod = builder.build(cir, tpu_single_pod(), assemble=False)
    mine = {c.name: c.env for c in inst.bundle.components()}
    theirs = {c.name: c.env for c in pod.bundle.components()}
    print("\n=== same CIR, two platforms — differing variant picks ===")
    for name in sorted(set(mine) & set(theirs)):
        if mine[name] != theirs[name]:
            print(f"  {name:16s} cpu-smoke={mine[name]:14s} "
                  f"tpu-pod={theirs[name]}")

    # -- 4. lockfile ---------------------------------------------------------
    print(f"\nlockfile digest {inst.lock.digest()[:16]}… pins "
          f"{len(inst.lock.pins)} components; rebuilds are bit-identical")


if __name__ == "__main__":
    main()
