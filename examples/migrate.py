"""Workload migration — the sky/edge-computing scenario the paper targets.

A training job runs on platform A; mid-run it must MOVE (spot preemption,
data locality, cheaper capacity elsewhere).  With conventional images a
per-platform image must exist in advance.  With CIR:

  1. the driver checkpoints (atomic, bucket-deduped);
  2. the SAME CIR is lazily re-built for platform B's specSheet — new
     variant picks, new sharding plan, zero developer action;
  3. the checkpoint is restored with platform B's shardings (reshard on
     restore) and training resumes exactly where it stopped.

The builder's persistent build-plan cache makes the round-trip cheap: when
capacity on A frees up again, failing BACK replays A's cached build plan —
no re-resolution, no re-fetch (see the timing printed at the end).

Run:  PYTHONPATH=src python examples/migrate.py
"""
import os
import shutil
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.core import (LazyBuilder, PreBuilder, cpu_smoke, gpu_server)
from repro.core import catalog
from repro.launch.mesh import make_smoke_mesh
from repro.runtime import elastic_rescale
from repro.checkpoint import CheckpointManager

CKPT = "/tmp/repro_migrate"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    service = catalog.build_service()
    cfg = ARCHS["phi4-mini-3.8b"].reduced()
    cir = PreBuilder(service).prebuild(cfg, entrypoint="train")
    builder = LazyBuilder(service)
    mesh = make_smoke_mesh(1)

    # ---- platform A: run 10 steps, checkpoint ------------------------------
    spec_a = cpu_smoke()
    a = builder.build(cir, spec_a, mesh=mesh)
    step = jax.jit(a.entry["train_step"])
    state = a.entry["init_state"](jax.random.PRNGKey(0))
    losses_a = []
    for i in range(10):
        batch = {k: jnp.asarray(v) for k, v in
                 a.entry["batch_fn"](64, 2, step=i).items()}
        state, m = step(state, batch)
        losses_a.append(float(m["loss"]))
    mgr = CheckpointManager(CKPT, async_save=False)
    mgr.save(10, state)
    print(f"platform A ({spec_a.platform_id}): 10 steps, "
          f"loss {losses_a[0]:.4f} -> {losses_a[-1]:.4f}; checkpointed")
    print("  A picks:", {c.name: c.env for c in a.bundle.components()
                         if c.manager in ("env", "opt", "parallel")})

    # ---- migrate: same CIR, platform B -------------------------------------
    spec_b = gpu_server()
    b, restored_step, state_b = elastic_rescale(
        builder, cir, a.lock, spec_b, mesh, CKPT,
        lambda container, _mesh: container.entry["state_shardings"]())
    print(f"\nmigrated to platform B ({spec_b.platform_id}) at step "
          f"{restored_step} — SAME {cir.size_bytes()}-byte CIR, re-resolved")
    print("  B picks:", {c.name: c.env for c in b.bundle.components()
                         if c.manager in ("env", "opt", "parallel")})

    # state continuity: B's restored params == A's params bit-for-bit
    import numpy as np
    wa = jax.tree_util.tree_leaves(state["params"])[0]
    wb = jax.tree_util.tree_leaves(state_b["params"])[0]
    np.testing.assert_array_equal(np.asarray(wa), np.asarray(wb))
    assert int(state_b["opt"]["step"]) == int(state["opt"]["step"])

    step_b = jax.jit(b.entry["train_step"])
    losses_b = []
    for i in range(restored_step, restored_step + 10):
        batch = {k: jnp.asarray(v) for k, v in
                 b.entry["batch_fn"](64, 2, step=i).items()}
        state_b, m = step_b(state_b, batch)
        losses_b.append(float(m["loss"]))
    print(f"platform B: 10 more steps, loss {losses_b[0]:.4f} -> "
          f"{losses_b[-1]:.4f}")
    print("\nmigration preserved training state bit-for-bit — optimizer "
          "step and params carried across platforms")

    # ---- fail back to A: the build-plan cache replays A's plan -------------
    t0 = time.perf_counter()
    back = builder.build(cir, spec_a, mesh=mesh, assemble=False)
    warm_s = time.perf_counter() - t0
    assert back.report.plan_cache_hit, "expected a plan-cache replay"
    print(f"\nfail-back to {spec_a.platform_id}: plan-cache replay in "
          f"{warm_s*1e3:.1f} ms — {back.report.bytes_fetched} bytes fetched, "
          f"resolution skipped "
          f"(cache: {builder.plan_cache.stats.hits} hits, "
          f"{builder.plan_cache.stats.puts} plans)")


if __name__ == "__main__":
    main()
