"""Store-lifecycle churn under capacity-bounded edge nodes.

The sky/edge pitch assumes nodes with tight disks that continuously rotate
workloads.  This benchmark makes that scenario measurable: K CIRs rotate
across capacity-bounded edge nodes of a fleet topology (1 unbounded cloud
seed that holds the *common* CIR's content + N edges, each rotating the
common CIR plus its own edge-local CIRs).  Every edge's store evicts under
the churn; the eviction policy decides what the next round costs:

  * ``lru``                 — evict by recency, blind to restore cost.
  * ``cheapest-to-restore`` — prefer evicting chunks a linked peer still
    holds (restoring them later costs a peer link, not the upstream
    registry), so edge-local content — restorable only from upstream —
    stays resident.

The headline metric is total **upstream wire bytes** across the churn:
``cheapest-to-restore`` must come in at least ``CTR_VS_LRU_FLOOR_PCT``
(15 %) under ``lru`` at the same capacity.  ``hit_rate`` is wire-based:
the fraction of requested component bytes the store did NOT transfer.

Two invariant phases ride along:

  * *accounting identity* — a bounded store whose capacity is never hit
    produces byte-identical per-deploy chunk accounting to an unbounded
    one (capacity must be invisible until it binds);
  * *concurrent churn* — edges churn concurrently while every eviction is
    checked against the pin/in-flight exemption (a pinned or claimed
    chunk must never be dropped).

Writes ``BENCH_churn.json`` (CI artifact + regression-gate baseline; see
``benchmarks.check_regression``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Tuple

from repro.configs import ARCHS
from repro.core import (EVICTION_POLICIES, PreBuilder, catalog, cpu_smoke,
                        tpu_single_pod)
from repro.core.chunkstore import ChunkedComponentStore
from repro.deploy import FleetDeployer, FleetTopology

from .common import csv_row

# compare every policy the store implements (canonical tuple — a policy
# added to the store automatically joins the comparison)
POLICIES = EVICTION_POLICIES
# capacity = this fraction of one full rotation's resident bytes: an edge
# holds most — but never all — of its working set, so every round evicts
CAPACITY_FRACTION = 0.75
ROUNDS = 3
# the common CIR is seeded (and pinned) on the cloud: its chunks are always
# peer-restorable; each edge's local CIRs exist nowhere else — evicting
# them is what costs upstream wire
COMMON_ARCH = "gemma2-9b"
EDGE_LOCAL_ARCHS = (
    ("starcoder2-3b", "phi4-mini-3.8b", "qwen2-vl-2b"),
    ("codeqwen1.5-7b", "musicgen-medium", "rwkv6-1.6b"),
)
# acceptance floor: cheapest-to-restore must beat lru's upstream wire bytes
# by at least this much at the same capacity
CTR_VS_LRU_FLOOR_PCT = 15.0


def _rotations(n_edges: int) -> Dict[str, List[str]]:
    """Per-edge CIR rotation: the common CIR first, then the edge's own
    local CIRs (disjoint across edges)."""
    return {f"edge-{i}": [COMMON_ARCH] + list(EDGE_LOCAL_ARCHS[i])
            for i in range(n_edges)}


def _build_fleet(policy: str,
                 capacities: Optional[Dict[str, int]],
                 n_edges: int) -> Tuple[FleetDeployer, Dict, object, Dict]:
    """Fresh service + fleet: 1 unbounded cloud seed + N bounded edges,
    cloud↔edge and edge↔edge links, cloud warmed (and pinned) with the
    common CIR.  Returns (deployer, cirs, cloud_spec, edge_specs)."""
    svc = catalog.build_service()
    pb = PreBuilder(svc)
    rotations = _rotations(n_edges)
    archs = sorted({a for rot in rotations.values() for a in rot})
    cirs = {a: pb.prebuild(ARCHS[a], entrypoint="serve") for a in archs}
    topo = FleetTopology()
    topo.add_node("cloud", upstream_bps=1.25e9, seed=True)
    edge_specs = {}
    for i in range(n_edges):
        node = f"edge-{i}"
        cap = capacities.get(node) if capacities else None
        topo.add_node(node, upstream_bps=6.25e6, capacity_bytes=cap)
        topo.link("cloud", node, 125e6)
        spec = dataclasses.replace(cpu_smoke(),
                                   platform_id=f"edge-host-{i}")
        topo.place(spec.platform_id, node)
        edge_specs[node] = spec
    for i in range(n_edges):
        for j in range(i + 1, n_edges):
            topo.link(f"edge-{i}", f"edge-{j}", 2.5e8)
    cloud_spec = tpu_single_pod()
    topo.place(cloud_spec.platform_id, "cloud")
    # fetch_workers=1: serial stripe commits keep the LRU order — and so
    # the evicted set and the upstream bytes — deterministic run to run
    fd = FleetDeployer(svc, topology=topo, eviction_policy=policy,
                       fetch_workers=1)
    assert fd.warm(cirs[COMMON_ARCH], [cloud_spec]) == 1
    return fd, cirs, cloud_spec, edge_specs


def probe_capacities(n_edges: int = 2,
                     fraction: float = CAPACITY_FRACTION) -> Dict[str, int]:
    """One unbounded rotation per edge measures the full working set; the
    churn capacity is ``fraction`` of it (deterministic byte accounting,
    so this is stable across runs and machines)."""
    fd, cirs, _cloud, edge_specs = _build_fleet("lru", None, n_edges)
    caps = {}
    for node, rot in _rotations(n_edges).items():
        for a in rot:
            res = fd.deploy(cirs[a], [edge_specs[node]])
            assert res.ok, res.summary()
        resident = fd.node_store(node).chunk_stats.chunk_bytes_stored
        caps[node] = int(resident * fraction)
    return caps


def run_churn(policy: str,
              capacities: Optional[Dict[str, int]],
              rounds: int = ROUNDS,
              n_edges: int = 2,
              concurrent: bool = False) -> Dict[str, object]:
    """Rotate every edge through its CIR set for ``rounds`` rounds and
    account the churn.  ``concurrent=True`` churns the edges on parallel
    threads (the pin/in-flight eviction exemption under real contention);
    the sequential mode is byte-deterministic and feeds the policy rows."""
    fd, cirs, _cloud, edge_specs = _build_fleet(policy, capacities, n_edges)
    rotations = _rotations(n_edges)
    up0 = {n: fd.node_traffic(n).bytes_from_upstream for n in edge_specs}
    wire = total = 0
    per_deploy: List[Tuple] = []

    def one_deploy(node: str, arch: str) -> Tuple:
        res = fd.deploy(cirs[arch], [edge_specs[node]])
        assert res.ok, res.summary()
        rep = res.deployments[0].report
        # the churn invariant: an evicted chunk re-entering a plan is a
        # miss, so chunk-delta wire can never exceed component accounting
        assert rep.bytes_delta_fetched <= rep.bytes_fetched, \
            f"{node}/{arch}: delta exceeds component fetch bytes"
        return (node, arch, rep.bytes_delta_fetched, rep.bytes_fetched,
                rep.bytes_total_components, rep.chunks_hit,
                rep.chunks_missed)

    if concurrent:
        with ThreadPoolExecutor(max_workers=n_edges) as pool:
            def edge_loop(node: str) -> List[Tuple]:
                return [one_deploy(node, a)
                        for _r in range(rounds)
                        for a in rotations[node]]
            for rows in pool.map(edge_loop, sorted(edge_specs)):
                per_deploy.extend(rows)
    else:
        for _r in range(rounds):
            for k in range(max(len(r) for r in rotations.values())):
                for node in sorted(edge_specs):
                    rot = rotations[node]
                    per_deploy.append(one_deploy(node, rot[k % len(rot)]))
    for row in per_deploy:
        wire += row[2]
        total += row[4]

    upstream = sum(fd.node_traffic(n).bytes_from_upstream - up0[n]
                   for n in edge_specs)
    peers = sum(fd.node_traffic(n).bytes_from_peers for n in edge_specs)
    stats = [fd.node_store(n).lifecycle_stats for n in edge_specs]
    return {
        "policy": policy,
        "bounded": capacities is not None,
        "upstream_bytes": upstream,
        "peer_bytes": peers,
        "wire_bytes": wire,
        "hit_rate": 1.0 - wire / total if total else 0.0,
        "evicted_bytes": sum(s.evicted_bytes for s in stats),
        "refetch_bytes": sum(s.refetch_bytes for s in stats),
        "pin_denied_evictions": sum(s.pin_denied_evictions for s in stats),
        "components_gcd": sum(s.components_gcd for s in stats),
        "per_deploy": per_deploy,
    }


def policy_comparison(rounds: int = ROUNDS, n_edges: int = 2,
                      quiet: bool = False) -> Dict[str, Dict]:
    """The headline table: lru vs cheapest-to-restore at the same capacity,
    plus the unbounded reference."""
    caps = probe_capacities(n_edges)
    rows: Dict[str, Dict] = {}
    for policy in POLICIES:
        rows[policy] = run_churn(policy, caps, rounds=rounds,
                                 n_edges=n_edges)
    rows["unbounded"] = run_churn("lru", None, rounds=rounds,
                                  n_edges=n_edges)
    lru_up = rows["lru"]["upstream_bytes"]
    ctr_up = rows["cheapest-to-restore"]["upstream_bytes"]
    reduction = 100.0 * (1.0 - ctr_up / lru_up) if lru_up else 0.0
    rows["_meta"] = {
        "capacities": caps,
        "rounds": rounds,
        "n_edges": n_edges,
        "ctr_vs_lru_upstream_reduction_pct": reduction,
    }
    assert reduction >= CTR_VS_LRU_FLOOR_PCT, \
        f"cheapest-to-restore saved only {reduction:.1f}% of lru's " \
        f"upstream wire bytes (floor {CTR_VS_LRU_FLOOR_PCT}%)"
    if not quiet:
        print(f"-- churn: {rounds} rounds x {n_edges} bounded edges "
              f"(capacity {CAPACITY_FRACTION:.0%} of the working set)")
        print(f"{'policy':20s} {'upstream':>10s} {'peers':>10s} "
              f"{'hit rate':>9s} {'evicted':>10s}")
        for name in (*POLICIES, "unbounded"):
            r = rows[name]
            print(f"{name:20s} {r['upstream_bytes']/2**30:>8.2f} G "
                  f"{r['peer_bytes']/2**30:>8.2f} G "
                  f"{r['hit_rate']*100:>8.1f}% "
                  f"{r['evicted_bytes']/2**30:>8.2f} G")
        print(f"cheapest-to-restore upstream vs lru: -{reduction:.1f}% "
              f"(floor {CTR_VS_LRU_FLOOR_PCT}%)")
    return rows


def accounting_identity(quiet: bool = False) -> bool:
    """A bounded store whose capacity never binds must be byte-identical —
    per deploy — to an unbounded one: capacity is invisible until it
    evicts."""
    caps = {f"edge-{i}": 1 << 60 for i in range(2)}   # never reached
    bounded = run_churn("cheapest-to-restore", caps, rounds=2)
    unbounded = run_churn("lru", None, rounds=2)
    same = bounded["per_deploy"] == unbounded["per_deploy"]
    assert same, "bounded-but-unhit accounting diverged from unbounded"
    assert bounded["evicted_bytes"] == 0
    if not quiet:
        print(f"-- bounded (capacity unhit) vs unbounded: "
              f"{len(bounded['per_deploy'])} deploys byte-identical")
    return same


def concurrent_churn(rounds: int = 2, quiet: bool = False,
                     caps: Optional[Dict[str, int]] = None
                     ) -> Dict[str, int]:
    """Edges churn on concurrent threads while every eviction pass is
    checked: a pinned or in-flight-claimed chunk must never be dropped.
    ``caps`` reuses capacities a prior ``policy_comparison`` probed (the
    probe is deterministic, so re-running it would only burn time)."""
    violations: List[str] = []
    orig = ChunkedComponentStore._drop_chunks_locked

    def checked(self, victims):
        for cid in victims:
            if self._chunk_pins.get(cid):
                violations.append(f"pinned chunk {cid[:12]} evicted")
            if cid in self._chunk_inflight:
                violations.append(f"in-flight chunk {cid[:12]} evicted")
        return orig(self, victims)

    caps = caps if caps is not None else probe_capacities(2)
    ChunkedComponentStore._drop_chunks_locked = checked
    try:
        row = run_churn("cheapest-to-restore", caps, rounds=rounds,
                        concurrent=True)
    finally:
        ChunkedComponentStore._drop_chunks_locked = orig
    assert not violations, violations[:5]
    assert row["evicted_bytes"] > 0, "concurrent churn never evicted"
    out = {"pin_violations": 0, "deploys": len(row["per_deploy"]),
           "evicted_bytes": row["evicted_bytes"]}
    if not quiet:
        print(f"-- concurrent churn: {out['deploys']} deploys, "
              f"{out['evicted_bytes']/2**30:.2f} G evicted, "
              f"0 pin/in-flight violations")
    return out


def write_bench_churn(path: Optional[str] = None,
                      smoke: bool = False,
                      rows: Optional[Dict] = None) -> str:
    """Record the churn trajectory (CI artifact + the committed
    regression-gate baseline)."""
    path = path or os.environ.get("BENCH_CHURN_PATH", "BENCH_churn.json")
    if rows is None:
        rows = policy_comparison(quiet=True)
    meta = rows["_meta"]
    payload = {
        "config": {
            "smoke": smoke,
            "rounds": meta["rounds"],
            "n_edges": meta["n_edges"],
            "capacity_fraction": CAPACITY_FRACTION,
            "common_arch": COMMON_ARCH,
        },
        "policies": {
            name: {k: v for k, v in rows[name].items() if k != "per_deploy"}
            for name in (*POLICIES, "unbounded")
        },
        "ctr_vs_lru_upstream_reduction_pct":
            meta["ctr_vs_lru_upstream_reduction_pct"],
        "ctr_hit_rate": rows["cheapest-to-restore"]["hit_rate"],
        "lru_hit_rate": rows["lru"]["hit_rate"],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def main(smoke: bool = False) -> List[str]:
    rows = policy_comparison(quiet=True)
    accounting_identity(quiet=True)
    if not smoke:
        concurrent_churn(quiet=True, caps=rows["_meta"]["capacities"])
    write_bench_churn(smoke=smoke, rows=rows)
    meta = rows["_meta"]
    return [
        csv_row(
            "churn.policy_comparison", 0.0,
            f"ctr_vs_lru=-"
            f"{meta['ctr_vs_lru_upstream_reduction_pct']:.1f}%;"
            f"hit_lru={rows['lru']['hit_rate'] * 100:.1f}%;"
            f"hit_ctr={rows['cheapest-to-restore']['hit_rate'] * 100:.1f}%"),
    ]


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    rows = policy_comparison()
    print()
    accounting_identity()
    if not smoke:
        print()
        concurrent_churn(caps=rows["_meta"]["capacities"])
    out = write_bench_churn(smoke=smoke, rows=rows)
    print(f"wrote {out}")
