"""Fleet scale on the discrete-event transport: 200 nodes in CI smoke time.

The threaded fetch path sleeps real wall clock per stripe; at WAN
bandwidths a 200-node fan-out would sleep for hours.  The simulated
transport (``repro.core.simnet``) replaces the sleeps with virtual-time
link reservations, so the same deploy — same code, same byte accounting —
finishes in seconds of wall clock while reporting thousands of seconds of
virtual WAN time.  This benchmark pins that contract:

  * *scale fan-out* — 1 cloud hub + ``SCALE_N_EDGES`` edges (hub spokes +
    a same-site ring) deploys under ``WALL_CEILING_S`` of wall clock,
    with the peer mesh carrying nearly all edge bytes;
  * *identity* — a small fan-out run under BOTH transports produces
    byte-identical per-node accounting (the simulation earns its speed
    by changing nothing else);
  * *fault scenarios* — seeded WAN faults (hub death mid-deploy, uplink
    flap, partition) against the same topologies: every scenario must
    converge, and the wire-byte overhead of recovering from a dead hub is
    measured as ``extra_upstream_pct``.

Writes ``BENCH_scale.json`` (CI artifact + regression-gate baseline; see
``benchmarks.check_regression``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time
from typing import Dict, List, Optional

from repro.configs import ARCHS
from repro.core import PreBuilder, SimNetwork, UPSTREAM, catalog, \
    cpu_smoke, tpu_single_pod
from repro.deploy import FleetDeployer, FleetTopology

from .common import csv_row

ARCH = "starcoder2-3b"
SCALE_N_EDGES = 199              # + 1 cloud hub = a 200-node fleet
WALL_CEILING_S = 30.0            # hard wall-clock budget for the fan-out
FAULT_N_EDGES = 24               # fault scenarios run on a smaller fleet
IDENTITY_N_EDGES = 4


def _scale_topology(n_edges: int) -> FleetTopology:
    """Hub-and-spoke + same-site ring: every edge links the cloud hub
    (125 MB/s) and its two ring neighbours (250 MB/s); edge uplinks are
    slow WAN (6.25 MB/s).  Constant links per node — selection stays
    O(links), not O(fleet)."""
    topo = FleetTopology()
    topo.add_node("cloud", upstream_bps=1.25e9, seed=True)
    edges = [f"edge-{i}" for i in range(n_edges)]
    for e in edges:
        topo.add_node(e, upstream_bps=6.25e6)
        topo.link("cloud", e, 1.25e8)
    if n_edges == 2:                     # a 2-ring is a single link
        topo.link(edges[0], edges[1], 2.5e8)
    elif n_edges > 2:
        for i in range(n_edges):
            topo.link(edges[i], edges[(i + 1) % n_edges], 2.5e8)
    return topo


def _place(topo: FleetTopology, n_edges: int):
    cloud = tpu_single_pod()
    topo.place(cloud.platform_id, "cloud")
    edges = []
    for i in range(n_edges):
        s = dataclasses.replace(cpu_smoke(), platform_id=f"edge-host-{i}")
        topo.place(s.platform_id, f"edge-{i}")
        edges.append(s)
    return cloud, edges


def scale_fanout(service=None, n_edges: int = SCALE_N_EDGES,
                 quiet: bool = False) -> Dict[str, float]:
    """Deploy a serve CIR to the full fleet on the simulated transport;
    the wall-clock ceiling is the headline assertion."""
    service = service or catalog.build_service()
    cir = PreBuilder(service).prebuild(ARCHS[ARCH], entrypoint="serve")
    topo = _scale_topology(n_edges)
    cloud, edges = _place(topo, n_edges)
    net = SimNetwork(topo)
    fd = FleetDeployer(service, topology=topo, simnet=net,
                       max_workers=16, fetch_workers=2)
    t0 = time.perf_counter()
    assert fd.deploy(cir, [cloud]).ok
    res = fd.deploy(cir, edges)
    wall = time.perf_counter() - t0
    assert res.ok, res.summary()
    for d in res.deployments:
        assert d.report.bytes_delta_fetched <= d.report.bytes_fetched
        assert res.node_traffic[d.node_id].bytes_total == \
            d.report.bytes_delta_fetched
    assert wall < WALL_CEILING_S, \
        f"{n_edges + 1}-node deploy took {wall:.1f}s wall " \
        f"(ceiling {WALL_CEILING_S}s)"
    row = {
        "n_nodes": float(n_edges + 1),
        "wall_s": wall,
        "sim_elapsed_s": res.sim_elapsed_s,
        "peer_offload_ratio": res.peer_offload_ratio,
        "bytes_upstream": float(res.bytes_upstream_total),
        "bytes_peers": float(res.bytes_peer_total),
    }
    if not quiet:
        print(f"-- scale fan-out ({n_edges + 1} nodes, {ARCH} serve)")
        print(f"   wall {wall:.2f}s (ceiling {WALL_CEILING_S:.0f}s), "
              f"{res.sim_elapsed_s:.0f}s virtual WAN time, "
              f"peer offload {res.peer_offload_ratio * 100:.1f}%")
    return row


def identity_check(service=None, n_edges: int = IDENTITY_N_EDGES,
                   quiet: bool = False) -> Dict[str, float]:
    """The accounting contract: simulated vs threaded transport, same
    sequential fan-out, byte-identical per-node columns."""
    service = service or catalog.build_service()
    cir = PreBuilder(service).prebuild(ARCHS[ARCH], entrypoint="serve")

    def run(simulated: bool):
        topo = _scale_topology(n_edges)
        cloud, edges = _place(topo, n_edges)
        net = SimNetwork(topo) if simulated else None
        fd = FleetDeployer(service, topology=topo, simnet=net,
                           max_workers=1, fetch_workers=1)
        out = {}
        for res in (fd.deploy(cir, [cloud]), fd.deploy(cir, edges)):
            assert res.ok, res.summary()
            for d in res.deployments:
                t = res.node_traffic[d.node_id]
                out[d.node_id] = (
                    t.bytes_from_upstream, t.bytes_from_peers,
                    d.report.bytes_delta_fetched, d.report.bytes_fetched,
                    d.report.chunks_hit, d.report.chunks_missed)
        return out

    threaded, sim = run(False), run(True)
    ok = sim == threaded
    assert ok, "simulated transport drifted from threaded accounting"
    if not quiet:
        print(f"-- identity check ({n_edges + 1} nodes): per-node "
              f"accounting {'identical' if ok else 'DIFFERS'} "
              f"across transports")
    return {"ok": 1.0 if ok else 0.0, "n_nodes": float(n_edges + 1)}


def _fault_fleet(service, n_edges: int):
    topo = _scale_topology(n_edges)
    cloud, edges = _place(topo, n_edges)
    net = SimNetwork(topo)
    fd = FleetDeployer(service, topology=topo, simnet=net,
                       max_workers=1, fetch_workers=1)
    return net, fd, cloud, edges


def fault_node_loss(service=None, n_edges: int = FAULT_N_EDGES,
                    quiet: bool = False) -> Dict[str, float]:
    """Kill the cloud hub mid-deploy and measure the recovery overhead:
    the edges that lose their best peer source converge anyway, paying
    ``extra_upstream_pct`` more registry wire than a fault-free run of
    the identical shape."""
    service = service or catalog.build_service()
    cir = PreBuilder(service).prebuild(ARCHS[ARCH], entrypoint="serve")

    def run(kill_hub: bool) -> Dict[str, float]:
        net, fd, cloud, edges = _fault_fleet(service, n_edges)
        assert fd.deploy(cir, [cloud]).ok
        if kill_hub:
            # lands inside the first edge's first transfer window: the
            # hub dies mid-stripe, before any other node holds content
            net.inject_node_loss("cloud", at=net.clock.now + 0.01)
        res = fd.deploy(cir, edges)
        assert res.ok, res.summary()
        total = sum(t.bytes_total for t in res.node_traffic.values())
        return {"upstream": float(res.bytes_upstream_total),
                "total": float(total),
                "fallbacks": float(res.peer_fallbacks_total),
                "faults_fired": float(res.faults_fired_total)}

    base = run(kill_hub=False)
    faulted = run(kill_hub=True)
    assert faulted["fallbacks"] > 0, "hub death never struck a transfer"
    # recovery overhead as a fraction of the fleet's wire bytes: what the
    # dead hub's orphaned pulls cost the registry link
    extra_pct = 100.0 * (faulted["upstream"] - base["upstream"]) \
        / max(faulted["total"], 1.0)
    row = {
        "converged": 1.0,
        "extra_upstream_pct": extra_pct,
        "peer_fallbacks": faulted["fallbacks"],
        "faults_fired": faulted["faults_fired"],
    }
    if not quiet:
        print(f"-- fault: hub death mid-deploy ({n_edges} edges): "
              f"converged, +{extra_pct:.1f}% upstream wire, "
              f"{faulted['fallbacks']:.0f} peer fallbacks")
    return row


def fault_link_flap(service=None, quiet: bool = False) -> Dict[str, float]:
    """Flap one edge's WAN uplink during its deploy: the transient
    ``LinkDownError`` is retried with virtual backoff until the window
    closes — the deploy converges with the retries on the books."""
    service = service or catalog.build_service()
    cir = PreBuilder(service).prebuild(ARCHS[ARCH], entrypoint="serve")
    topo = FleetTopology()
    topo.add_node("n0", upstream_bps=6.25e6)
    spec = dataclasses.replace(cpu_smoke(), platform_id="plat-n0")
    topo.place(spec.platform_id, "n0")
    net = SimNetwork(topo)
    net.inject_link_flap("n0", UPSTREAM, at=0.0, until=4.0)
    fd = FleetDeployer(service, topology=topo, simnet=net,
                       max_workers=1, fetch_workers=1)
    res = fd.deploy(cir, [spec])
    assert res.ok, res.summary()
    assert res.link_retries_total > 0
    if not quiet:
        print(f"-- fault: uplink flap: converged after "
              f"{res.link_retries_total} virtual-backoff retries")
    return {"converged": 1.0, "link_retries": float(res.link_retries_total)}


def fault_partition(service=None, quiet: bool = False) -> Dict[str, float]:
    """Partition one edge away from every peer: it converges purely
    upstream while the rest of the fleet keeps peering."""
    service = service or catalog.build_service()
    cir = PreBuilder(service).prebuild(ARCHS[ARCH], entrypoint="serve")
    net, fd, cloud, edges = _fault_fleet(service, 3)
    assert fd.deploy(cir, [cloud]).ok
    net.inject_partition(["edge-0"], at=net.clock.now, until=float("inf"))
    res = fd.deploy(cir, edges)
    assert res.ok, res.summary()
    isolated = res.node_traffic["edge-0"]
    assert isolated.bytes_from_peers == 0
    if not quiet:
        print(f"-- fault: partition: isolated edge fell back upstream "
              f"({isolated.peer_fallbacks} fallbacks), fleet converged")
    return {"converged": 1.0,
            "isolated_peer_bytes": float(isolated.bytes_from_peers)}


def write_bench_scale(path: Optional[str] = None,
                      smoke: bool = False,
                      rows: Optional[Dict] = None) -> str:
    """Record the scale/fault trajectory (CI artifact + the committed
    regression-gate baseline)."""
    path = path or os.environ.get("BENCH_SCALE_PATH", "BENCH_scale.json")
    if rows is None:
        rows = collect(smoke=smoke, quiet=True)
    payload = {
        "config": {
            "smoke": smoke,
            "arch": ARCH,
            "n_edges": SCALE_N_EDGES,
            "wall_ceiling_s": WALL_CEILING_S,
        },
        "scale": rows["scale"],
        "identity": rows["identity"],
        "faults": rows["faults"],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def collect(smoke: bool = False, quiet: bool = False,
            service=None) -> Dict[str, Dict]:
    """All phases; smoke keeps the full 200-node fan-out (that IS the
    smoke-time claim) but runs only the hub-death fault scenario."""
    service = service or catalog.build_service()
    rows: Dict[str, Dict] = {
        "scale": scale_fanout(service, quiet=quiet),
        "identity": identity_check(service, quiet=quiet),
        "faults": {"node_loss": fault_node_loss(service, quiet=quiet)},
    }
    if not smoke:
        rows["faults"]["link_flap"] = fault_link_flap(service, quiet=quiet)
        rows["faults"]["partition"] = fault_partition(service, quiet=quiet)
    return rows


def main(smoke: bool = False) -> List[str]:
    rows = collect(smoke=smoke, quiet=True)
    write_bench_scale(smoke=smoke, rows=rows)
    s, nl = rows["scale"], rows["faults"]["node_loss"]
    return [
        csv_row(
            "scale.fanout", 0.0,
            f"nodes={s['n_nodes']:.0f};wall={s['wall_s']:.2f}s;"
            f"virtual={s['sim_elapsed_s']:.0f}s;"
            f"offload={s['peer_offload_ratio'] * 100:.1f}%"),
        csv_row(
            "scale.fault_node_loss", 0.0,
            f"converged={nl['converged']:.0f};"
            f"extra_upstream={nl['extra_upstream_pct']:.1f}%"),
    ]


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    rows = collect(smoke=smoke)
    out = write_bench_scale(smoke=smoke, rows=rows)
    print(f"wrote {out}")
