"""Performance-portable CIR on a heterogeneous fleet (docs §13).

A mixed fleet (cpu-host + gpu + tpu edges behind one cloud seed) deploying
one CIR used to re-ship a monolithic compiled executable per platform
class — 24 MiB + 8 MiB/entry each — even though most of those bytes are
the platform-neutral program, identical across classes.  The §13 split
publishes one shared ``manager="ir"`` module (lowered once fleet-wide)
plus small per-platform artifact *tails* and Pallas autotune tables, all
over the ordinary peer chunk path.  All timings are **virtual** seconds
on the simulated transport, so the benchmark is deterministic.  Phases:

  * *cross-platform split* — warm cloud precompiles all three platform
    classes; each edge's re-deploy then moves only its tail + autotune.
    The compiled-artifact wire across the fleet must shrink by
    ``>= HETERO_MIN_REDUCTION_PCT`` vs the monolithic baseline, with the
    resolved-content byte accounting **identical** in both modes;
  * *IR shared once* — no warm: the first cold edge lowers and publishes
    the IR exactly once; every other platform class peer-fetches it.
    Tails never cross platform-class boundaries (each class compiles its
    own);
  * *byte identical* — with the split disabled every §13 column is zero
    and every per-node byte column matches the pre-§13 build exactly:
    the split re-labels bytes, it never smuggles or invents them.

Writes ``BENCH_hetero.json`` (CI artifact + regression-gate baseline;
see ``benchmarks.check_regression``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

from repro.configs import ARCHS
from repro.core import (PreBuilder, SimNetwork, catalog, cpu_smoke,
                        gpu_server, tpu_single_pod)
from repro.deploy import FleetDeployer, FleetTopology

from .common import csv_row

ARCH = "starcoder2-3b"
HETERO_MIN_REDUCTION_PCT = 50.0   # cross-platform compiled wire eliminated
PLATFORM_CLASSES = ("cpu", "gpu", "tpu")


def _fleet(service, ir_components: bool):
    """Cloud seed + one edge per platform class on the virtual clock.
    Sequential workers + no overlap: virtual timings are exact replays."""
    topo = FleetTopology.hetero_edge(PLATFORM_CLASSES)
    cloud = dataclasses.replace(tpu_single_pod(), platform_id="cloud-seed")
    mk = {"cpu": cpu_smoke, "gpu": gpu_server, "tpu": tpu_single_pod}
    edges = {p: dataclasses.replace(mk[p](), platform_id=f"{p}-edge-host")
             for p in PLATFORM_CLASSES}
    topo.place(cloud.platform_id, "cloud")
    for p, s in edges.items():
        topo.place(s.platform_id, f"{p}-edge")
    net = SimNetwork(topo)
    fd = FleetDeployer(service, topology=topo, simnet=net,
                       max_workers=1, fetch_workers=1, overlap=False,
                       ir_components=ir_components)
    return net, fd, cloud, edges


def _deploy_edges(service, ir: bool, warm: bool) -> Tuple:
    """One warm-or-cold hetero rollout; returns (fleet result, deployer)."""
    cir = PreBuilder(service).prebuild(ARCHS[ARCH], entrypoint="serve")
    net, fd, cloud, edges = _fleet(service, ir_components=ir)
    specs = [edges[p] for p in PLATFORM_CLASSES]
    if warm:
        assert fd.warm(cir, specs, precompile=True) == len(specs)
    res = fd.deploy(cir, specs, assemble=True, compile_steps=True)
    assert res.ok, res.summary()
    return res, fd


def cross_platform_split(service=None, quiet: bool = False
                         ) -> Dict[str, float]:
    """Warm cloud precompiles all three classes; each edge re-deploy then
    moves only its platform tail + autotune table instead of the whole
    monolithic executable — >= 50% of the compiled wire eliminated, with
    resolved-content accounting identical in both modes."""
    service = service or catalog.build_service()
    off, _ = _deploy_edges(service, ir=False, warm=True)
    on, _ = _deploy_edges(service, ir=True, warm=True)
    for res in (off, on):
        assert res.compile_cache_hits_total == len(PLATFORM_CLASSES), \
            res.summary()
    # the split must never change WHAT a node resolves and fetches — only
    # how the compiled bytes that ride on top are labeled and shipped
    for nid, t_off in off.node_traffic.items():
        t_on = on.node_traffic[nid]
        assert t_off.bytes_total == t_on.bytes_total, nid
        assert t_off.bytes_from_upstream == t_on.bytes_from_upstream, nid
    mono_wire = off.artifact_bytes_fetched_total
    split_wire = sum(t.platform_tail_bytes + t.ir_shared_bytes
                     for t in on.node_traffic.values())
    assert mono_wire > 0 and split_wire > 0
    reduction = 100.0 * (1.0 - split_wire / mono_wire)
    assert reduction >= HETERO_MIN_REDUCTION_PCT, \
        f"split only eliminated {reduction:.1f}% of the compiled wire " \
        f"(floor {HETERO_MIN_REDUCTION_PCT:.0f}%): monolithic " \
        f"{mono_wire / 2**20:.1f} MiB vs split {split_wire / 2**20:.1f} MiB"
    row = {
        "monolithic_wire_mib": mono_wire / 2**20,
        "split_wire_mib": split_wire / 2**20,
        "wire_reduction_pct": reduction,
        "redeploy_virtual_s_off": off.sim_elapsed_s,
        "redeploy_virtual_s_on": on.sim_elapsed_s,
        "accounting_identical": 1.0,
    }
    if not quiet:
        print(f"-- cross-platform split ({ARCH} serve, "
              f"{len(PLATFORM_CLASSES)} classes): monolithic "
              f"{row['monolithic_wire_mib']:.1f} MiB vs tails "
              f"{row['split_wire_mib']:.2f} MiB on the wire "
              f"(-{reduction:.1f}%), accounting identical")
    return row


def ir_shared_once(service=None, quiet: bool = False) -> Dict[str, float]:
    """Cold hetero rollout, no warm: the first edge lowers + publishes the
    shared IR exactly once; the other platform classes peer-fetch it and
    compile only their own tails (which never cross class boundaries)."""
    service = service or catalog.build_service()
    res, fd = _deploy_edges(service, ir=True, warm=False)
    reports = [d.report for d in res.deployments]
    assert all(r.ir_enabled for r in reports)
    # exactly one lowering fleet-wide: one node published IR bytes, and
    # they sum to a single module
    publishers = [r for r in reports if r.ir_bytes_published > 0]
    assert len(publishers) == 1, \
        f"{len(publishers)} nodes lowered the IR (want 1)"
    ir_size = publishers[0].ir_bytes_published
    assert res.ir_bytes_published_total == ir_size
    ir_peers = [t for t in res.node_traffic.values()
                if t.ir_shared_bytes > 0]
    assert len(ir_peers) == len(PLATFORM_CLASSES) - 1
    assert all(t.ir_shared_bytes == ir_size for t in ir_peers)
    # no cache crosses platform classes: every class compiles its own tail
    assert all(not r.compile_cache_hit and r.artifact_bytes_published > 0
               for r in reports)
    assert res.artifact_bytes_fetched_total == 0
    row = {
        "ir_published_copies": float(res.ir_bytes_published_total / ir_size),
        "ir_module_mib": ir_size / 2**20,
        "ir_peer_nodes": float(len(ir_peers)),
        "tails_published": float(sum(r.artifact_bytes_published > 0
                                     for r in reports)),
        "cold_virtual_s": res.sim_elapsed_s,
    }
    if not quiet:
        print(f"-- IR shared once: {row['ir_module_mib']:.0f} MiB module "
              f"lowered once, peer-fetched by {len(ir_peers)} other "
              f"class(es); {row['tails_published']:.0f} per-class tails "
              f"compiled locally")
    return row


def byte_identical(service=None, quiet: bool = False) -> Dict[str, float]:
    """With ``ir_components`` off, every §13 report column is zero and the
    whole per-node report matches the pre-§13 build field-for-field."""
    service = service or catalog.build_service()
    off, _ = _deploy_edges(service, ir=False, warm=False)
    on, _ = _deploy_edges(service, ir=True, warm=False)
    for d in off.deployments:
        r = d.report
        assert not r.ir_enabled
        assert r.ir_shared_bytes == r.ir_bytes_published == 0
        assert r.platform_tail_bytes == 0
        assert r.autotune_bytes_fetched == r.autotune_bytes_published == 0
    for nid, t in off.node_traffic.items():
        assert t.ir_shared_bytes == t.platform_tail_bytes == 0, nid
        assert t.ir_chunks_from_peers == 0, nid
    # resolved content is untouched by the split in EITHER mode
    for d_off, d_on in zip(off.deployments, on.deployments):
        for f in ("bytes_fetched", "bytes_delta_fetched", "chunks_hit",
                  "chunks_missed", "cache_hits", "cache_misses",
                  "n_components", "n_compiled", "bytes_total_components"):
            assert getattr(d_off.report, f) == getattr(d_on.report, f), f
    assert off.bytes_delta_total == on.bytes_delta_total
    row = {
        "accounting_identical": 1.0,
        "ir_columns_zero_when_off": 1.0,
        "bytes_delta_mib": off.bytes_delta_total / 2**20,
    }
    if not quiet:
        print(f"-- byte identical: split off == pre-§13 build "
              f"({row['bytes_delta_mib']:.1f} MiB resolved delta in both "
              f"modes, every §13 column zero when off)")
    return row


def write_bench_hetero(path: Optional[str] = None,
                       smoke: bool = False,
                       rows: Optional[Dict] = None) -> str:
    """Record the heterogeneous-fleet trajectory (CI artifact + the
    committed regression-gate baseline)."""
    path = path or os.environ.get("BENCH_HETERO_PATH", "BENCH_hetero.json")
    if rows is None:
        rows = collect(smoke=smoke, quiet=True)
    payload = {
        "config": {
            "smoke": smoke,
            "arch": ARCH,
            "platform_classes": list(PLATFORM_CLASSES),
            "hetero_min_reduction_pct": HETERO_MIN_REDUCTION_PCT,
        },
        "split": rows["split"],
        "ir_once": rows["ir_once"],
        "identity": rows["identity"],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def collect(smoke: bool = False, quiet: bool = False,
            service=None) -> Dict[str, Dict]:
    """All phases; the deterministic fleet is already small, so smoke
    changes nothing — every assertion IS the claim under test."""
    service = service or catalog.build_service()
    return {
        "split": cross_platform_split(service, quiet=quiet),
        "ir_once": ir_shared_once(service, quiet=quiet),
        "identity": byte_identical(service, quiet=quiet),
    }


def main(smoke: bool = False) -> List[str]:
    rows = collect(smoke=smoke, quiet=True)
    write_bench_hetero(smoke=smoke, rows=rows)
    sp, ir = rows["split"], rows["ir_once"]
    return [
        csv_row(
            "hetero.cross_platform_split", 0.0,
            f"mono={sp['monolithic_wire_mib']:.1f}MiB;"
            f"split={sp['split_wire_mib']:.2f}MiB;"
            f"reduction={sp['wire_reduction_pct']:.1f}%"),
        csv_row(
            "hetero.ir_shared_once", 0.0,
            f"ir={ir['ir_module_mib']:.0f}MiB;"
            f"copies={ir['ir_published_copies']:.0f};"
            f"peers={ir['ir_peer_nodes']:.0f}"),
    ]


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    rows = collect(smoke=smoke)
    out = write_bench_hetero(smoke=smoke, rows=rows)
    print(f"wrote {out}")
