"""Demand-driven chunk placement: speculative replication vs reactive fetch.

The paper's sky-computing deployment rotates demand across edge regions
(diurnal load following the sun): an edge that served yesterday's peak has
long since had its chunks churned out by other tenants when its demand
returns, so purely reactive fetch re-pays the full cold transfer every
rotation.  The ``PlacementPlanner`` (``repro.deploy.placement``,
docs/cir-format.md §11) closes that gap by pre-positioning the predicted
next region's chunk stripes under ``spec:`` soft leases — first eviction
tier, dedicated ``spec_*`` wire columns — *before* the demand phase opens.
All timings are **virtual** seconds on the simulated transport, so the
benchmark is deterministic.  Phases:

  * *rotating demand trace* — the hot edge rotates across a 4-edge fleet
    on a fixed phase schedule; between phases a co-tenant churns the idle
    edge's store (capacity-bounded, so the returning content is cold).
    The reactive run re-fetches on demand; the speculative run gives an
    oracle ``DemandModel`` the rotation and runs one planner round ahead
    of each phase.  Speculation must cut p95 time-to-READY by
    ``>= P95_READY_MIN_REDUCTION_PCT`` at ``<= SPEC_WIRE_MAX_OVERHEAD_PCT``
    extra upstream wire, with every per-deploy byte-accounting identity
    intact (speculative wire never leaks into demand columns);
  * *live migration* — hand a running serve instance to a cold node via
    ``FleetDeployer.migrate`` (snapshot, pinned source, spec-lease
    prefetch, restore inside the gap with a compile-cache hit).  The
    serve gap must stay ``<= MIGRATION_MAX_DOWNTIME_RATIO`` of the honest
    alternative — a cold re-deploy on the target, itself riding peer
    chunks and the fleet compile cache.

Writes ``BENCH_placement.json`` (CI artifact + regression-gate baseline;
see ``benchmarks.check_regression``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
from typing import Dict, List, Optional

import numpy as np

from repro.configs import ARCHS
from repro.core import PreBuilder, SimNetwork, catalog, cpu_smoke, \
    tpu_single_pod
from repro.core.component import UniformComponent
from repro.deploy import DemandModel, FleetDeployer, FleetTopology, \
    PlacementPlanner

from .common import csv_row

ARCH = "starcoder2-3b"
N_EDGES = 4
P95_READY_MIN_REDUCTION_PCT = 40.0   # speculative vs reactive p95 READY
SPEC_WIRE_MAX_OVERHEAD_PCT = 25.0    # extra upstream wire speculation adds
MIGRATION_MAX_DOWNTIME_RATIO = 0.20  # serve gap vs cold re-deploy
PHASE_S = 1000.0                     # virtual rotation period
CAPACITY_FACTOR = 1.3                # edge capacity / one arch's content
# hot-edge rotation (edge index per phase); smoke runs one cycle, the full
# trace revisits churned edges so speculation must re-position them
TRACE_FULL = (1, 2, 3, 0, 1, 2)
TRACE_SMOKE = (1, 2, 3)


def _fleet(service, n_edges: int, edge_capacity_bytes: Optional[int] = None):
    """Cloud seed + N edges on the virtual clock (sequential workers, no
    overlap: virtual timings are exact replays)."""
    topo = FleetTopology.edge_fanout(n_edges, cloud_edge_bps=5e8,
                                     edge_edge_bps=1e9,
                                     edge_capacity_bytes=edge_capacity_bytes)
    cloud = tpu_single_pod()
    edges = [dataclasses.replace(cpu_smoke(), platform_id=f"edge-host-{i}")
             for i in range(n_edges)]
    topo.place(cloud.platform_id, "cloud")
    for i, s in enumerate(edges):
        topo.place(s.platform_id, f"edge-{i}")
    net = SimNetwork(topo)
    fd = FleetDeployer(service, topology=topo, simnet=net,
                       max_workers=1, fetch_workers=1, overlap=False)
    return net, fd, cloud, edges


def _p95(xs: List[float]) -> float:
    return float(np.percentile(np.asarray(xs, dtype=float), 95))


def _fleet_upstream_bytes(fd: FleetDeployer) -> int:
    """Demand + speculative upstream wire across every node — the cost the
    overhead gate bounds (peer links are LAN; upstream is the WAN registry
    link speculation must not flood)."""
    total = 0
    for node_id in fd.topology.node_ids():
        t = fd.node_traffic(node_id)
        total += t.bytes_from_upstream + t.spec_bytes_from_upstream
    return total


def _churn(fd: FleetDeployer, node_id: str, tag: str, size: int) -> None:
    """A co-tenant fills ``node_id``'s capacity-bounded store, evicting the
    resident arch content — the reason reactive fetch re-pays the rotation
    (local put: no wire, identical in both runs)."""
    fd.node_store(node_id).put(UniformComponent(
        manager="tenant", name=f"filler-{node_id}-{tag}", version="1",
        env="e", payload="x", size_bytes=size))


def _run_trace(service, cir, comps, trace, speculative: bool) -> Dict:
    """One pass over the rotation: prime edge-0, then per phase churn the
    hot edge, (speculatively) pre-position it, and deploy when demand
    arrives.  Returns per-phase READY times + fleet wire/spec totals."""
    content_bytes = sum(c.size_bytes for c in comps)
    capacity = int(CAPACITY_FACTOR * content_bytes)
    net, fd, cloud, edges = _fleet(service, N_EDGES,
                                   edge_capacity_bytes=capacity)
    assert fd.deploy(cir, [cloud]).ok            # seed content on the cloud
    r_prime = fd.deploy(cir, [edges[0]])         # yesterday's hot edge
    assert r_prime.ok, r_prime.summary()

    planner = None
    if speculative:
        oracle = [(k * PHASE_S, f"edge-{e}", cir.digest())
                  for k, e in enumerate(trace, start=1)]
        # short EWMA halflife: by the next phase boundary an old
        # observation has decayed below the noise floor, so the oracle
        # window alone names the one edge each round pre-positions
        planner = PlacementPlanner(
            fd, demand=DemandModel(halflife_s=50.0, horizon_s=PHASE_S,
                                   oracle=oracle),
            wire_budget_bytes=2 * content_bytes)
        planner.register(cir.digest(), comps)

    ready_s: List[float] = []
    spec_prepositioned = 0
    for k, e in enumerate(trace, start=1):
        node = f"edge-{e}"
        _churn(fd, node, tag=str(k), size=content_bytes)
        if planner is not None:
            st = planner.run_round(now=k * PHASE_S)
            spec_prepositioned += st.bytes_fetched
        r = fd.deploy(cir, [edges[e]])
        assert r.ok, r.summary()
        ready_s.append(r.sim_elapsed_s)
        # identity: speculative wire never leaks into the demand columns
        for d in r.deployments:
            assert d.report.bytes_delta_fetched <= d.report.bytes_fetched
            assert r.node_traffic[d.node_id].bytes_total == \
                d.report.bytes_delta_fetched

    # fleet spec accounting closes: every speculated byte came over the
    # spec wire, and demand hits + evictions never exceed what was staked
    sb = hb = wb = wire = 0
    for node_id in fd.topology.node_ids():
        ls = fd.node_store(node_id).lifecycle_stats
        sb += ls.spec_bytes
        hb += ls.spec_hit_bytes
        wb += ls.spec_wasted_bytes
        wire += fd.node_traffic(node_id).spec_bytes_total
    assert sb == wire == spec_prepositioned
    assert hb + wb <= sb
    if planner is not None:
        assert spec_prepositioned > 0
        assert planner.release_all() >= 1
    else:
        assert sb == 0
    return {
        "ready_s": ready_s,
        "upstream_bytes": _fleet_upstream_bytes(fd),
        "spec_bytes": sb,
        "spec_hit_bytes": hb,
        "spec_wasted_bytes": wb,
    }


def rotating_trace(service=None, quiet: bool = False,
                   smoke: bool = False) -> Dict[str, float]:
    """Reactive vs speculative over the same rotating-demand trace."""
    service = service or catalog.build_service()
    cir = PreBuilder(service).prebuild(ARCHS[ARCH], entrypoint="serve")
    # resolve the edge-platform bundle once (what the planner replicates)
    net, fd, cloud, edges = _fleet(service, 1)
    assert fd.deploy(cir, [cloud]).ok
    r = fd.deploy(cir, [edges[0]])
    assert r.ok, r.summary()
    comps = list(r.deployments[0].instance.bundle.components())

    trace = TRACE_SMOKE if smoke else TRACE_FULL
    reactive = _run_trace(service, cir, comps, trace, speculative=False)
    spec = _run_trace(service, cir, comps, trace, speculative=True)

    p95_reactive, p95_spec = _p95(reactive["ready_s"]), _p95(spec["ready_s"])
    reduction = 100.0 * (1.0 - p95_spec / p95_reactive)
    assert reduction >= P95_READY_MIN_REDUCTION_PCT, \
        f"speculation cut p95 READY only {reduction:.1f}% " \
        f"(floor {P95_READY_MIN_REDUCTION_PCT:.0f}%): reactive " \
        f"{p95_reactive:.2f}s vs speculative {p95_spec:.2f}s virtual"
    overhead = 100.0 * (spec["upstream_bytes"] - reactive["upstream_bytes"]) \
        / reactive["upstream_bytes"]
    assert overhead <= SPEC_WIRE_MAX_OVERHEAD_PCT, \
        f"speculation added {overhead:.1f}% upstream wire " \
        f"(cap {SPEC_WIRE_MAX_OVERHEAD_PCT:.0f}%)"
    row = {
        "n_phases": float(len(trace)),
        "reactive_p95_ready_s": p95_reactive,
        "spec_p95_ready_s": p95_spec,
        "p95_ready_reduction_pct": reduction,
        "reactive_upstream_bytes": float(reactive["upstream_bytes"]),
        "spec_upstream_bytes": float(spec["upstream_bytes"]),
        "speculation_wire_overhead_pct": overhead,
        "spec_mib_prepositioned": spec["spec_bytes"] / 2**20,
        "spec_hit_ratio": spec["spec_hit_bytes"] / spec["spec_bytes"],
        "spec_wasted_mib": spec["spec_wasted_bytes"] / 2**20,
    }
    if not quiet:
        print(f"-- rotating demand ({len(trace)} phases, {N_EDGES} edges): "
              f"p95 READY reactive {p95_reactive:.1f}s vs speculative "
              f"{p95_spec:.2f}s virtual (-{reduction:.1f}%), upstream wire "
              f"+{overhead:.1f}%, spec hit ratio "
              f"{row['spec_hit_ratio'] * 100:.0f}%")
    return row


def live_migration(service=None, quiet: bool = False) -> Dict[str, float]:
    """Serve-gap of a live hand-off vs the honest cold re-deploy — both
    riding peer chunks and the fleet compile cache."""
    service = service or catalog.build_service()
    cir = PreBuilder(service).prebuild(ARCHS[ARCH], entrypoint="serve")
    net, fd, cloud, edges = _fleet(service, 3)
    assert fd.deploy(cir, [cloud]).ok
    r0 = fd.deploy(cir, [edges[0]], assemble=True, compile_steps=True)
    assert r0.ok, r0.summary()
    # the alternative to migrating: tear down and cold re-deploy on a cold
    # node, with every fleet amortisation already granted (peer chunk
    # sources + the compile cache r0 populated) — downtime = full deploy
    r1 = fd.deploy(cir, [edges[1]], assemble=True, compile_steps=True)
    assert r1.ok, r1.summary()
    assert r1.deployments[0].report.compile_cache_hit
    t_cold = r1.sim_elapsed_s

    rep = fd.migrate(r0.deployments[0].instance, "edge-2")  # edge-2 is cold
    assert rep.instance.stage == "complete"
    assert rep.prefetch_bytes > 0                # moved BEFORE the gap
    assert rep.downtime_s < rep.prefetch_s       # the gap is the cheap part
    assert rep.compile_cache_hit and rep.decommissioned
    ratio = rep.downtime_s / t_cold
    assert ratio <= MIGRATION_MAX_DOWNTIME_RATIO, \
        f"migration serve gap {rep.downtime_s:.2f}s is {ratio:.2f} of the " \
        f"{t_cold:.2f}s cold re-deploy " \
        f"(cap {MIGRATION_MAX_DOWNTIME_RATIO:.2f})"
    row = {
        "cold_redeploy_s": t_cold,
        "migration_downtime_s": rep.downtime_s,
        "migration_downtime_ratio": ratio,
        "prefetch_s": rep.prefetch_s,
        "prefetch_mib": rep.prefetch_bytes / 2**20,
        "restore_delta_mib": rep.restore_delta_bytes / 2**20,
    }
    if not quiet:
        print(f"-- live migration: serve gap {rep.downtime_s:.3f}s vs cold "
              f"re-deploy {t_cold:.1f}s virtual (ratio {ratio:.3f}); "
              f"{row['prefetch_mib']:.0f} MiB pre-fetched in "
              f"{rep.prefetch_s:.1f}s outside the gap")
    return row


def write_bench_placement(path: Optional[str] = None,
                          smoke: bool = False,
                          rows: Optional[Dict] = None) -> str:
    """Record the placement trajectory (CI artifact + the committed
    regression-gate baseline)."""
    path = path or os.environ.get("BENCH_PLACEMENT_PATH",
                                  "BENCH_placement.json")
    if rows is None:
        rows = collect(smoke=smoke, quiet=True)
    payload = {
        "config": {
            "smoke": smoke,
            "arch": ARCH,
            "n_edges": N_EDGES,
            "p95_ready_min_reduction_pct": P95_READY_MIN_REDUCTION_PCT,
            "spec_wire_max_overhead_pct": SPEC_WIRE_MAX_OVERHEAD_PCT,
            "migration_max_downtime_ratio": MIGRATION_MAX_DOWNTIME_RATIO,
        },
        "trace": rows["trace"],
        "migration": rows["migration"],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def collect(smoke: bool = False, quiet: bool = False,
            service=None) -> Dict[str, Dict]:
    """Both phases; smoke runs one rotation cycle but keeps every
    assertion (the reduction/overhead/ratio ARE the claims under test)."""
    service = service or catalog.build_service()
    return {
        "trace": rotating_trace(service, quiet=quiet, smoke=smoke),
        "migration": live_migration(service, quiet=quiet),
    }


def main(smoke: bool = False) -> List[str]:
    rows = collect(smoke=smoke, quiet=True)
    write_bench_placement(smoke=smoke, rows=rows)
    tr, mg = rows["trace"], rows["migration"]
    return [
        csv_row(
            "placement.rotating_trace", 0.0,
            f"reactive_p95={tr['reactive_p95_ready_s']:.1f}s;"
            f"spec_p95={tr['spec_p95_ready_s']:.2f}s;"
            f"reduction={tr['p95_ready_reduction_pct']:.1f}%;"
            f"wire_overhead={tr['speculation_wire_overhead_pct']:.1f}%"),
        csv_row(
            "placement.live_migration", 0.0,
            f"gap={mg['migration_downtime_s']:.3f}s;"
            f"cold={mg['cold_redeploy_s']:.1f}s;"
            f"ratio={mg['migration_downtime_ratio']:.3f}"),
    ]


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    rows = collect(smoke=smoke)
    out = write_bench_placement(smoke=smoke, rows=rows)
    print(f"wrote {out}")
