"""Paper Fig. 7 — build time under 10–1000 Mbps links (gemma2-9b as the
YOLO11-analog test application), CIR vs conventional vs CIR-locked."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.configs import ARCHS
from repro.core import tpu_single_pod

from .common import (MBPS, bump_asset_version, conventional_for, csv_row,
                     fresh_builder, lazy_deploy_time)

BANDWIDTHS = (10, 20, 50, 100, 200, 500, 800, 1000)


def run(arch_id: str = "gemma2-9b", quiet: bool = False
        ) -> Dict[int, Dict]:
    spec = tpu_single_pod()
    rows: Dict[int, Dict] = {}
    for mbps in BANDWIDTHS:
        bw = mbps * MBPS
        lb, pb = fresh_builder(mbps)
        cir = pb.prebuild(ARCHS[arch_id], entrypoint="serve")
        conv = conventional_for(cir, lb, spec)
        lb_cold, _ = fresh_builder(mbps, host_spec=spec)
        rep = lb_cold.build(cir, spec, assemble=False).report
        # the cloud-edge hot path: a weight refresh lands upstream and the
        # same node re-deploys — chunk-level delta fetch pays ~70% of the
        # bumped component only (vs the conventional full image re-pull)
        bump_asset_version(lb_cold.service, arch_id)
        delta = lb_cold.build(cir, spec, assemble=False).report
        lb_cold2, _ = fresh_builder(mbps, host_spec=spec)
        lock = lb.build(cir, spec, assemble=False).lock
        warm = lb_cold2.build_from_lock(cir, lock, spec,
                                        assemble=False).report
        rows[mbps] = {
            "conv_s": conv.build_time(bw) + conv.pull_time(bw),
            "cir_s": lazy_deploy_time(rep, bw),
            "cir_delta_s": lazy_deploy_time(delta, bw),
            "cir_locked_s": lazy_deploy_time(warm, bw),
        }
    if not quiet:
        print(f"{'Mbps':>5s} {'conventional':>13s} {'CIR':>9s} "
              f"{'CIR-delta':>10s} {'CIR-locked':>11s}")
        for mbps, r in rows.items():
            print(f"{mbps:>5d} {r['conv_s']:>12.1f}s {r['cir_s']:>8.1f}s "
                  f"{r['cir_delta_s']:>9.1f}s {r['cir_locked_s']:>10.1f}s")
        gaps = [r["conv_s"] - r["cir_s"] for r in rows.values()]
        print(f"conventional-vs-CIR gap: {min(gaps):.0f}s … {max(gaps):.0f}s "
              f"(paper: a persistent ~100 s install-stage gap)")
    return rows


def main() -> List[str]:
    rows = run(quiet=True)
    red = [100 * (1 - r["cir_s"] / r["conv_s"]) for r in rows.values()]
    dred = [100 * (1 - r["cir_delta_s"] / r["cir_s"]) for r in rows.values()]
    return [csv_row(
        "bandwidth.fig7", 0.0,
        f"avg_reduction={sum(red)/len(red):.1f}%;"
        f"min={min(red):.1f}%;max={max(red):.1f}%;"
        f"delta_redeploy_vs_cold={sum(dred)/len(dred):.1f}%")]


if __name__ == "__main__":
    run()
