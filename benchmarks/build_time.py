"""Paper Fig. 9 (+ Fig. 8 CPU sweep, §5.4 CIR-locked) — build / deployment /
end-to-end time for the whole suite vs the conventional builder, at a
representative 500 Mbps link.

  conventional: build (dev) + push + pull (deploy); the image bundles the
                runtime env + code (+ weights when serving).
  CIR:          pre-build (dev) + push CIR + lazy-build (deploy); the
                deployment host's accelerator runtime is REUSED (seeded
                cache — the libnvidia-container analog), components are
                pre-compiled, fetch overlaps resolution.

Two suites are reported: train CIRs (environment-only, the paper's
build-time story) and serve CIRs (weights included on both sides).
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.configs import ARCHS
from repro.core import tpu_single_pod

from .common import (MBPS, conventional_for, csv_row, fresh_builder,
                     lazy_deploy_time)


def run(bw_mbps: float = 500.0, locked: bool = False, cores: int = 4,
        entrypoint: str = "train", quiet: bool = False) -> Dict[str, Dict]:
    bw = bw_mbps * MBPS
    spec = tpu_single_pod()
    lb, pb = fresh_builder(bw_mbps)
    rows: Dict[str, Dict] = {}
    for arch_id in ARCHS:
        t0 = time.perf_counter()
        cir = pb.prebuild(ARCHS[arch_id], entrypoint=entrypoint)
        prebuild_s = time.perf_counter() - t0
        conv = conventional_for(cir, lb, spec)

        # cold deployment node: fresh store, host runtime pre-installed
        lb_cold, _ = fresh_builder(bw_mbps, host_spec=spec)
        if locked:
            # lock produced on a TEST node; production node is still cold
            lock = lb.build(cir, spec, assemble=False).lock
            inst = lb_cold.build_from_lock(cir, lock, spec, assemble=False)
        else:
            inst = lb_cold.build(cir, spec, assemble=False)
        rep = inst.report

        # warm re-deploy of the SAME (CIR, SpecSheet) on the same node: the
        # build-plan cache (or, in locked mode, the lock itself) replays the
        # version-lock manifest — no resolution — and the store already
        # holds every component.
        if locked:
            warm_rep = lb_cold.build_from_lock(cir, lock, spec,
                                               assemble=False).report
        else:
            warm_rep = lb_cold.build(cir, spec, assemble=False).report

        conv_build = conv.build_time(bw, cores)
        conv_deploy = conv.pull_time(bw)
        conv_e2e = conv_build + conv.push_time(bw) + conv_deploy
        cir_deploy = lazy_deploy_time(rep, bw)
        warm_deploy = lazy_deploy_time(warm_rep, bw)
        cir_build = prebuild_s + cir_deploy
        cir_e2e = prebuild_s + (rep.bytes_cir / bw) + cir_deploy
        rows[arch_id] = {
            "conv_build_s": conv_build, "cir_build_s": cir_build,
            "conv_deploy_s": conv_deploy, "cir_deploy_s": cir_deploy,
            "cir_warm_deploy_s": warm_deploy,
            "warm_plan_hit": warm_rep.plan_cache_hit or warm_rep.locked,
            "conv_e2e_s": conv_e2e, "cir_e2e_s": cir_e2e,
            "build_reduction_pct": 100 * (1 - cir_build / conv_build),
            "deploy_reduction_pct": 100 * (1 - cir_deploy / conv_deploy),
            "warm_reduction_pct": 100 * (1 - warm_deploy
                                         / max(cir_deploy, 1e-12)),
            "e2e_reduction_pct": 100 * (1 - cir_e2e / conv_e2e),
        }
    if not quiet:
        print(f"-- {entrypoint} CIRs, {bw_mbps:.0f} Mbps, {cores} cores, "
              f"locked={locked}")
        print(f"{'arch':24s} {'conv bld':>9s} {'cir bld':>8s} "
              f"{'conv dep':>9s} {'cold dep':>8s} {'warm dep':>8s} "
              f"{'conv e2e':>9s} {'cir e2e':>8s}")
        for a, r in rows.items():
            print(f"{a:24s} {r['conv_build_s']:>8.1f}s "
                  f"{r['cir_build_s']:>7.1f}s "
                  f"{r['conv_deploy_s']:>8.1f}s {r['cir_deploy_s']:>7.1f}s "
                  f"{r['cir_warm_deploy_s']:>7.3f}s "
                  f"{r['conv_e2e_s']:>8.1f}s {r['cir_e2e_s']:>7.1f}s")
        for k in ("build", "deploy", "e2e"):
            avg = sum(r[f"{k}_reduction_pct"] for r in rows.values()) \
                / len(rows)
            print(f"avg {k} time reduction: {avg:.1f}%   "
                  f"(paper: build 77–87%, deploy 42–63%, e2e ~91%)")
        avg_w = sum(r["warm_reduction_pct"] for r in rows.values()) / len(rows)
        print(f"avg warm-vs-cold deploy reduction: {avg_w:.1f}%   "
              f"(build-plan cache replay, all components local)")
    return rows


def cpu_sweep(bw_mbps: float = 500.0, quiet: bool = False) -> Dict[int, Dict]:
    """Fig. 8 analog: conventional build time scales with install cores;
    CIR lazy-build barely moves (no install stage)."""
    out = {}
    for cores in (1, 2, 4, 8, 16):
        rows = run(bw_mbps=bw_mbps, cores=cores, quiet=True)
        conv = sum(r["conv_build_s"] for r in rows.values())
        cir = sum(r["cir_build_s"] for r in rows.values())
        out[cores] = {"conv_total_s": conv, "cir_total_s": cir}
        if not quiet:
            print(f"cores={cores:2d}  conventional={conv:8.1f}s  "
                  f"CIR={cir:6.1f}s")
    return out


def main() -> List[str]:
    rows = run(quiet=True)
    avg_b = sum(r["build_reduction_pct"] for r in rows.values()) / len(rows)
    avg_d = sum(r["deploy_reduction_pct"] for r in rows.values()) / len(rows)
    avg_e = sum(r["e2e_reduction_pct"] for r in rows.values()) / len(rows)
    avg_w = sum(r["warm_reduction_pct"] for r in rows.values()) / len(rows)
    serve = run(entrypoint="serve", quiet=True)
    avg_sd = sum(r["deploy_reduction_pct"] for r in serve.values()) \
        / len(serve)
    locked = run(locked=True, quiet=True)
    avg_lock = sum(r["deploy_reduction_pct"] for r in locked.values()) \
        / len(locked)
    sweep = cpu_sweep(quiet=True)
    spread_conv = sweep[1]["conv_total_s"] / sweep[16]["conv_total_s"]
    spread_cir = sweep[1]["cir_total_s"] / sweep[16]["cir_total_s"]
    return [
        csv_row("build_time.fig9", 0.0,
                f"build_red={avg_b:.1f}%;deploy_red={avg_d:.1f}%;"
                f"e2e_red={avg_e:.1f}%;serve_deploy_red={avg_sd:.1f}%"),
        csv_row("build_time.locked", 0.0,
                f"locked_deploy_red={avg_lock:.1f}%"),
        csv_row("build_time.plan_cache", 0.0,
                f"warm_vs_cold_deploy_red={avg_w:.1f}%"),
        csv_row("build_time.cpu_sweep.fig8", 0.0,
                f"conv_1c_vs_16c={spread_conv:.2f}x;"
                f"cir_1c_vs_16c={spread_cir:.2f}x"),
    ]


if __name__ == "__main__":
    run()
    print()
    run(entrypoint="serve")
    print()
    cpu_sweep()
