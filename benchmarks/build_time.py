"""Paper Fig. 9 (+ Fig. 8 CPU sweep, §5.4 CIR-locked) — build / deployment /
end-to-end time for the whole suite vs the conventional builder, at a
representative 500 Mbps link.

  conventional: build (dev) + push + pull (deploy); the image bundles the
                runtime env + code (+ weights when serving).
  CIR:          pre-build (dev) + push CIR + lazy-build (deploy); the
                deployment host's accelerator runtime is REUSED (seeded
                cache — the libnvidia-container analog), components are
                pre-compiled, fetch overlaps resolution.

Two suites are reported: train CIRs (environment-only, the paper's
build-time story) and serve CIRs (weights included on both sides).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Sequence

from repro.configs import ARCHS
from repro.core import tpu_single_pod

from .common import (MBPS, SMOKE_ARCHS as _SMOKE_ARCHS, bump_asset_version,
                     conventional_for, csv_row, fresh_builder,
                     lazy_deploy_time)

# Simulated per-build link for the fetch-concurrency study: fast enough that
# the whole sweep stays sub-second, slow enough that stripe overlap is
# measurable far above scheduler noise.
_SIM_FETCH_BPS = 100e9


def run(bw_mbps: float = 500.0, locked: bool = False, cores: int = 4,
        entrypoint: str = "train", quiet: bool = False,
        archs: Optional[Sequence[str]] = None) -> Dict[str, Dict]:
    bw = bw_mbps * MBPS
    spec = tpu_single_pod()
    lb, pb = fresh_builder(bw_mbps)
    rows: Dict[str, Dict] = {}
    for arch_id in (archs or ARCHS):
        t0 = time.perf_counter()
        cir = pb.prebuild(ARCHS[arch_id], entrypoint=entrypoint)
        prebuild_s = time.perf_counter() - t0
        conv = conventional_for(cir, lb, spec)

        # cold deployment node: fresh store, host runtime pre-installed
        lb_cold, _ = fresh_builder(bw_mbps, host_spec=spec)
        if locked:
            # lock produced on a TEST node; production node is still cold
            lock = lb.build(cir, spec, assemble=False).lock
            inst = lb_cold.build_from_lock(cir, lock, spec, assemble=False)
        else:
            inst = lb_cold.build(cir, spec, assemble=False)
        rep = inst.report

        # warm re-deploy of the SAME (CIR, SpecSheet) on the same node: the
        # build-plan cache (or, in locked mode, the lock itself) replays the
        # version-lock manifest — no resolution — and the store already
        # holds every component.
        if locked:
            warm_rep = lb_cold.build_from_lock(cir, lock, spec,
                                               assemble=False).report
        else:
            warm_rep = lb_cold.build(cir, spec, assemble=False).report

        conv_build = conv.build_time(bw, cores)
        conv_deploy = conv.pull_time(bw)
        conv_e2e = conv_build + conv.push_time(bw) + conv_deploy
        cir_deploy = lazy_deploy_time(rep, bw)
        warm_deploy = lazy_deploy_time(warm_rep, bw)
        cir_build = prebuild_s + cir_deploy
        cir_e2e = prebuild_s + (rep.bytes_cir / bw) + cir_deploy
        rows[arch_id] = {
            "conv_build_s": conv_build, "cir_build_s": cir_build,
            "conv_deploy_s": conv_deploy, "cir_deploy_s": cir_deploy,
            "cir_warm_deploy_s": warm_deploy,
            "warm_plan_hit": warm_rep.plan_cache_hit or warm_rep.locked,
            "conv_e2e_s": conv_e2e, "cir_e2e_s": cir_e2e,
            "build_reduction_pct": 100 * (1 - cir_build / conv_build),
            "deploy_reduction_pct": 100 * (1 - cir_deploy / conv_deploy),
            "warm_reduction_pct": 100 * (1 - warm_deploy
                                         / max(cir_deploy, 1e-12)),
            "e2e_reduction_pct": 100 * (1 - cir_e2e / conv_e2e),
        }
    if not quiet:
        print(f"-- {entrypoint} CIRs, {bw_mbps:.0f} Mbps, {cores} cores, "
              f"locked={locked}")
        print(f"{'arch':24s} {'conv bld':>9s} {'cir bld':>8s} "
              f"{'conv dep':>9s} {'cold dep':>8s} {'warm dep':>8s} "
              f"{'conv e2e':>9s} {'cir e2e':>8s}")
        for a, r in rows.items():
            print(f"{a:24s} {r['conv_build_s']:>8.1f}s "
                  f"{r['cir_build_s']:>7.1f}s "
                  f"{r['conv_deploy_s']:>8.1f}s {r['cir_deploy_s']:>7.1f}s "
                  f"{r['cir_warm_deploy_s']:>7.3f}s "
                  f"{r['conv_e2e_s']:>8.1f}s {r['cir_e2e_s']:>7.1f}s")
        for k in ("build", "deploy", "e2e"):
            avg = sum(r[f"{k}_reduction_pct"] for r in rows.values()) \
                / len(rows)
            print(f"avg {k} time reduction: {avg:.1f}%   "
                  f"(paper: build 77–87%, deploy 42–63%, e2e ~91%)")
        avg_w = sum(r["warm_reduction_pct"] for r in rows.values()) / len(rows)
        print(f"avg warm-vs-cold deploy reduction: {avg_w:.1f}%   "
              f"(build-plan cache replay, all components local)")
    return rows


def cpu_sweep(bw_mbps: float = 500.0, quiet: bool = False) -> Dict[int, Dict]:
    """Fig. 8 analog: conventional build time scales with install cores;
    CIR lazy-build barely moves (no install stage)."""
    out = {}
    for cores in (1, 2, 4, 8, 16):
        rows = run(bw_mbps=bw_mbps, cores=cores, quiet=True)
        conv = sum(r["conv_build_s"] for r in rows.values())
        cir = sum(r["cir_build_s"] for r in rows.values())
        out[cores] = {"conv_total_s": conv, "cir_total_s": cir}
        if not quiet:
            print(f"cores={cores:2d}  conventional={conv:8.1f}s  "
                  f"CIR={cir:6.1f}s")
    return out


def delta_redeploy(bw_mbps: float = 500.0,
                   archs: Sequence[str] = _SMOKE_ARCHS,
                   quiet: bool = False) -> Dict[str, Dict]:
    """The chunk-store delta-fetch column: cold serve deploy, then an
    upstream weight refresh (version bump) and a re-deploy on the same node.
    Component-level dedup must re-fetch the whole bumped component; the live
    chunk store pays only the unshared chunk fraction (~70% of its bytes)."""
    bw = bw_mbps * MBPS
    spec = tpu_single_pod()
    rows: Dict[str, Dict] = {}
    for arch_id in archs:
        lb, pb = fresh_builder(bw_mbps, host_spec=spec)
        cir = pb.prebuild(ARCHS[arch_id], entrypoint="serve")
        cold = lb.build(cir, spec, assemble=False).report
        bump_asset_version(lb.service, arch_id)
        bump = lb.build(cir, spec, assemble=False).report
        rows[arch_id] = {
            "cold_wire_bytes": cold.bytes_wire_fetched,
            "bump_component_bytes": bump.bytes_fetched,
            "bump_delta_bytes": bump.bytes_delta_fetched,
            "chunks_hit": bump.chunks_hit,
            "chunks_missed": bump.chunks_missed,
            "delta_saved_pct": 100.0 * (1 - bump.bytes_delta_fetched
                                        / max(bump.bytes_fetched, 1)),
            "cold_deploy_s": lazy_deploy_time(cold, bw),
            "bump_deploy_s": lazy_deploy_time(bump, bw),
        }
    if not quiet:
        print(f"-- version-bump re-deploy (weights refresh), "
              f"{bw_mbps:.0f} Mbps, chunk-addressed delta fetch")
        print(f"{'arch':24s} {'cold':>10s} {'bump comp':>10s} "
              f"{'bump wire':>10s} {'saved':>6s} {'cold dep':>9s} "
              f"{'bump dep':>9s}")
        for a, r in rows.items():
            print(f"{a:24s} {r['cold_wire_bytes']/2**30:>8.2f} G "
                  f"{r['bump_component_bytes']/2**30:>8.2f} G "
                  f"{r['bump_delta_bytes']/2**30:>8.2f} G "
                  f"{r['delta_saved_pct']:>5.1f}% "
                  f"{r['cold_deploy_s']:>8.1f}s {r['bump_deploy_s']:>8.1f}s")
    return rows


def fetch_concurrency(arch_id: str = "gemma2-9b",
                      widths: Sequence[int] = (1, 2, 4, 8),
                      quiet: bool = False) -> Dict[int, Dict]:
    """Pool-width sweep: one cold serve deploy per width on a simulated
    link (``_SIM_FETCH_BPS``); the striped fetch engine overlaps chunk
    transfers, so fetch wall time drops roughly with the pool width."""
    spec = tpu_single_pod()
    rows: Dict[int, Dict] = {}
    for w in widths:
        lb, pb = fresh_builder(host_spec=spec, fetch_workers=w,
                               fetch_simulate_bps=_SIM_FETCH_BPS)
        cir = pb.prebuild(ARCHS[arch_id], entrypoint="serve")
        rep = lb.build(cir, spec, assemble=False).report
        rows[w] = {"fetch_s": rep.fetch_s,
                   "fetch_serial_s": rep.fetch_serial_s,
                   "fetch_concurrency": rep.fetch_concurrency,
                   "speedup_vs_serial": rep.fetch_serial_s
                   / max(rep.fetch_s, 1e-12)}
    if not quiet:
        print(f"-- fetch pool-width sweep ({arch_id}, simulated "
              f"{_SIM_FETCH_BPS/1e9:.0f} GB/s link)")
        for w, r in rows.items():
            print(f"  width={w:2d}  fetch={r['fetch_s']*1e3:8.1f} ms  "
                  f"serial-sum={r['fetch_serial_s']*1e3:8.1f} ms  "
                  f"({r['speedup_vs_serial']:.2f}x)")
    return rows


def pipeline_overlap(archs: Sequence[str] = _SMOKE_ARCHS,
                     sim_bps: float = 10e9,
                     quiet: bool = False) -> Dict[str, Dict]:
    """Barrier vs event-driven pipeline on the same serve CIR and the same
    simulated link (fresh node each): the orchestrator overlaps assemble +
    jit-staging with the weight-asset tail and READY does not gate on
    first-weight-use content, so time-to-deployable drops sharply while the
    byte/chunk accounting — and the lockfile — stay identical.

    ``barrier_ready_s`` / ``overlapped_ready_s`` are *measured* critical
    paths (build start → lifecycle READY); ``complete_s`` runs until the
    asset tail has landed, which the two modes must roughly share (overlap
    moves work, it doesn't remove any)."""
    spec = tpu_single_pod()
    rows: Dict[str, Dict] = {}
    for arch_id in archs:
        reps = {}
        locks = {}
        for mode, overlap in (("barrier", False), ("overlapped", True)):
            lb, pb = fresh_builder(host_spec=spec,
                                   fetch_simulate_bps=sim_bps)
            cir = pb.prebuild(ARCHS[arch_id], entrypoint="serve")
            inst = lb.build(cir, spec, assemble=True, compile_steps=True,
                            overlap=overlap)
            reps[mode], locks[mode] = inst.report, inst.lock
        b, o = reps["barrier"], reps["overlapped"]
        accounting = ("bytes_delta_fetched", "bytes_fetched",
                      "chunks_hit", "chunks_missed", "chunks_waited",
                      "cache_hits", "cache_misses", "n_components")
        for f in accounting:
            assert getattr(b, f) == getattr(o, f), \
                f"{arch_id}: {f} differs barrier={getattr(b, f)} " \
                f"overlapped={getattr(o, f)}"
        assert locks["barrier"].to_json() == locks["overlapped"].to_json(), \
            f"{arch_id}: lockfiles differ across pipeline modes"
        rows[arch_id] = {
            "barrier_ready_s": b.critical_path_s,
            "overlapped_ready_s": o.critical_path_s,
            "barrier_complete_s": b.stage_s.get("complete", 0.0),
            "overlapped_complete_s": o.stage_s.get("complete", 0.0),
            "overlap_s": o.overlap_s,
            "ready_reduction_pct": 100.0 * (1 - o.critical_path_s
                                            / max(b.critical_path_s, 1e-12)),
            "accounting_identical": True,
        }
    avg = sum(r["ready_reduction_pct"] for r in rows.values()) / len(rows)
    # the acceptance floor for the overlapped pipeline: at least 25% lower
    # time-to-deployable than the barrier pipeline (per-arch numbers sit at
    # 60-90% on an idle machine; the average absorbs scheduler noise)
    assert avg >= 25.0, \
        f"overlapped pipeline reduction regressed: avg {avg:.1f}% < 25%"
    if not quiet:
        print(f"-- barrier vs overlapped pipeline (serve CIRs, simulated "
              f"{sim_bps / 1e9:.0f} GB/s link)")
        print(f"{'arch':24s} {'barrier rdy':>11s} {'overlap rdy':>11s} "
              f"{'saved':>6s} {'complete':>9s}")
        for a, r in rows.items():
            print(f"{a:24s} {r['barrier_ready_s']*1e3:>9.1f}ms "
                  f"{r['overlapped_ready_s']*1e3:>9.1f}ms "
                  f"{r['ready_reduction_pct']:>5.1f}% "
                  f"{r['overlapped_complete_s']*1e3:>7.1f}ms")
        print(f"avg time-to-deployable reduction: {avg:.1f}%   "
              f"(paper: deployment-time reduction 40-60%)")
    return rows


def write_bench_pipeline(path: Optional[str] = None,
                         smoke: bool = False,
                         rows: Optional[Dict] = None,
                         sim_bps: float = 10e9) -> str:
    """Record the barrier-vs-overlapped pipeline trajectory (CI artifact,
    written next to BENCH_fetch.json).  ``sim_bps`` must match the link the
    passed-in ``rows`` were measured at."""
    path = path or os.environ.get("BENCH_PIPELINE_PATH",
                                  "BENCH_pipeline.json")
    if rows is None:
        rows = pipeline_overlap(sim_bps=sim_bps, quiet=True)
    avg = sum(r["ready_reduction_pct"] for r in rows.values()) / len(rows)
    payload = {
        "config": {"smoke": smoke, "sim_bps": sim_bps},
        "pipeline_overlap": rows,
        "avg_ready_reduction_pct": avg,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def fleet_fetch(arch_id: str = "gemma2-9b", fetch_workers: int = 8,
                quiet: bool = False) -> Dict[str, float]:
    """Fleet deploy (1 CIR -> 3 platforms) through the concurrent engine on
    a simulated link: fetch wall time lands well below the serial sum of
    per-component fetch times, and singleflight keeps every chunk charged
    exactly once across the fleet."""
    from repro.core import catalog, cpu_smoke, gpu_server, PreBuilder
    from repro.deploy import FleetDeployer

    svc = catalog.build_service()
    pb = PreBuilder(svc)
    fd = FleetDeployer(svc, max_workers=3, fetch_workers=fetch_workers,
                       fetch_simulate_bps=_SIM_FETCH_BPS)
    cir = pb.prebuild(ARCHS[arch_id], entrypoint="serve")
    res = fd.deploy(cir, [tpu_single_pod(), cpu_smoke(), gpu_server()])
    assert res.ok, res.summary()
    rows = {
        "fetch_serial_s_total": res.fetch_serial_s_total,
        "fetch_s_wall": res.fetch_s_wall,
        "speedup": res.fetch_serial_s_total / max(res.fetch_s_wall, 1e-12),
        "fetch_concurrency": res.fetch_concurrency,
        "bytes_delta_total": res.bytes_delta_total,
        "bytes_fetched_total": res.bytes_fetched_total,
        "chunks_missed_total": res.chunks_missed_total,
        "chunks_waited_total": res.chunks_waited_total,
        "double_charged_bytes": res.bytes_delta_total
        - fd.store.chunk_stats.chunk_bytes_stored,
    }
    if not quiet:
        print(f"-- fleet fetch pipeline ({arch_id} -> 3 platforms, "
              f"width {fetch_workers})")
        print(res.summary())
    return rows


def write_bench_fetch(path: Optional[str] = None,
                      smoke: bool = False,
                      delta: Optional[Dict] = None,
                      concurrency: Optional[Dict] = None,
                      fleet: Optional[Dict] = None) -> str:
    """Record the fetch-engine perf trajectory (consumed by CI).  Callers
    that already ran a sweep pass its rows in; only missing sections are
    computed here."""
    path = path or os.environ.get("BENCH_FETCH_PATH", "BENCH_fetch.json")
    if delta is None:
        delta = delta_redeploy(
            archs=_SMOKE_ARCHS if smoke else _SMOKE_ARCHS + ("dbrx-132b",),
            quiet=True)
    if concurrency is None:
        concurrency = fetch_concurrency(widths=(1, 8) if smoke else
                                        (1, 2, 4, 8), quiet=True)
    if fleet is None:
        fleet = fleet_fetch(quiet=True)
    payload = {
        "config": {"sim_fetch_bps": _SIM_FETCH_BPS, "smoke": smoke},
        "delta_redeploy": delta,
        "fetch_concurrency": concurrency,
        "fleet_fetch": fleet,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def main() -> List[str]:
    rows = run(quiet=True)
    avg_b = sum(r["build_reduction_pct"] for r in rows.values()) / len(rows)
    avg_d = sum(r["deploy_reduction_pct"] for r in rows.values()) / len(rows)
    avg_e = sum(r["e2e_reduction_pct"] for r in rows.values()) / len(rows)
    avg_w = sum(r["warm_reduction_pct"] for r in rows.values()) / len(rows)
    serve = run(entrypoint="serve", quiet=True)
    avg_sd = sum(r["deploy_reduction_pct"] for r in serve.values()) \
        / len(serve)
    locked = run(locked=True, quiet=True)
    avg_lock = sum(r["deploy_reduction_pct"] for r in locked.values()) \
        / len(locked)
    sweep = cpu_sweep(quiet=True)
    spread_conv = sweep[1]["conv_total_s"] / sweep[16]["conv_total_s"]
    spread_cir = sweep[1]["cir_total_s"] / sweep[16]["cir_total_s"]
    delta = delta_redeploy(quiet=True)
    avg_delta = sum(r["delta_saved_pct"] for r in delta.values()) / len(delta)
    fleet = fleet_fetch(quiet=True)
    write_bench_fetch(delta=delta, fleet=fleet)
    pipe = pipeline_overlap(quiet=True)
    avg_pipe = sum(r["ready_reduction_pct"] for r in pipe.values()) / len(pipe)
    write_bench_pipeline(rows=pipe)
    return [
        csv_row("build_time.fig9", 0.0,
                f"build_red={avg_b:.1f}%;deploy_red={avg_d:.1f}%;"
                f"e2e_red={avg_e:.1f}%;serve_deploy_red={avg_sd:.1f}%"),
        csv_row("build_time.locked", 0.0,
                f"locked_deploy_red={avg_lock:.1f}%"),
        csv_row("build_time.plan_cache", 0.0,
                f"warm_vs_cold_deploy_red={avg_w:.1f}%"),
        csv_row("build_time.cpu_sweep.fig8", 0.0,
                f"conv_1c_vs_16c={spread_conv:.2f}x;"
                f"cir_1c_vs_16c={spread_cir:.2f}x"),
        csv_row("build_time.delta_fetch", 0.0,
                f"version_bump_wire_saved={avg_delta:.1f}%"),
        csv_row("build_time.fleet_fetch", 0.0,
                f"fetch_wall_vs_serial={fleet['speedup']:.2f}x;"
                f"width={fleet['fetch_concurrency']};"
                f"double_charged_bytes={fleet['double_charged_bytes']}"),
        csv_row("build_time.pipeline_overlap", 0.0,
                f"ready_reduction={avg_pipe:.1f}%"),
    ]


if __name__ == "__main__":
    import sys
    if "--smoke" in sys.argv:
        # CI smoke: reduced arch set + the fetch-trajectory JSON artifact
        run(quiet=False, archs=_SMOKE_ARCHS)
        print()
        delta = delta_redeploy()
        print()
        conc = fetch_concurrency(widths=(1, 8))
        print()
        fleet = fleet_fetch()
        out = write_bench_fetch(smoke=True, delta=delta, concurrency=conc,
                                fleet=fleet)
        print(f"\nwrote {out}")
        print()
        pipe = pipeline_overlap()
        out = write_bench_pipeline(smoke=True, rows=pipe)
        print(f"\nwrote {out}")
    else:
        run()
        print()
        run(entrypoint="serve")
        print()
        cpu_sweep()
        print()
        delta_redeploy()
        print()
        fetch_concurrency()
        print()
        fleet_fetch()
        print()
        pipeline_overlap()
