"""Paper §5.3 — a single CIR deployed on four heterogeneous platforms.

The conventional baseline needs one image per platform (4 builds); CIR
needs one pre-build and four lazy-builds that each pick platform-fitted
variants.

Writes ``BENCH_crossplatform.json`` (CI artifact + regression-gate
baseline; see ``benchmarks.check_regression``)."""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List, Optional

from repro.configs import ARCHS
from repro.core import (cpu_smoke, gpu_server, tpu_multi_pod,
                        tpu_single_pod)

from .common import (MBPS, conventional_for, csv_row, fresh_builder,
                     lazy_deploy_time)

ARCH = "gemma2-9b"
# Paper §5.3 reports 78.7% average build-time reduction vs per-platform
# conventional builds; the gate holds the floor well below the paper's
# figure but far above noise.
CROSSPLATFORM_MIN_REDUCTION_PCT = 60.0

PLATFORMS = {
    "cpu-server": cpu_smoke,
    "gpu-server": gpu_server,
    "tpu-pod": tpu_single_pod,
    "tpu-multipod": tpu_multi_pod,
}


def run(arch_id: str = ARCH, bw_mbps: float = 500.0,
        quiet: bool = False) -> Dict[str, Dict]:
    bw = bw_mbps * MBPS
    lb, pb = fresh_builder(bw_mbps)
    cir = pb.prebuild(ARCHS[arch_id], entrypoint="train")
    rows: Dict[str, Dict] = {}
    for name, mk in PLATFORMS.items():
        spec = mk()
        # each platform is its own deployment node with its host runtime
        node, _ = fresh_builder(bw_mbps, host_spec=spec)
        inst = node.build(cir, spec, assemble=False)
        conv = conventional_for(lb=lb, cir=cir, spec=spec)
        rows[name] = {
            "lazy_s": lazy_deploy_time(inst.report, bw),
            "conv_s": conv.build_time(bw),
            "fetched_mb": inst.report.bytes_fetched / 2**20,
            "picks": {f"{c.manager}:{c.name}": c.env
                      for c in inst.bundle.components()
                      if c.manager in ("env", "parallel", "kernel", "opt",
                                       "runtime")},
        }
    if not quiet:
        print(f"single CIR: {arch_id} ({cir.size_bytes()} bytes) "
              f"deployed on {len(rows)} platforms @ {bw_mbps:.0f} Mbps")
        for name, r in rows.items():
            print(f"  {name:14s} lazy={r['lazy_s']:7.1f}s  "
                  f"conv-build={r['conv_s']:7.1f}s  "
                  f"fetched={r['fetched_mb']:7.1f} MiB")
            print(f"    env={r['picks'].get('env:runtime-base')} "
                  f"plan={r['picks'].get('parallel:plan')} "
                  f"train-step={r['picks'].get('runtime:train-step')}")
        avg = sum(100 * (1 - r["lazy_s"] / r["conv_s"])
                  for r in rows.values()) / len(rows)
        print(f"avg build-time reduction vs per-platform builds: {avg:.1f}% "
              f"(paper §5.3: 78.7%)")
    return rows


def _metrics(rows: Dict[str, Dict]) -> Dict[str, float]:
    avg = sum(100 * (1 - r["lazy_s"] / r["conv_s"])
              for r in rows.values()) / len(rows)
    distinct = len({tuple(sorted(r["picks"].items()))
                    for r in rows.values()})
    assert avg >= CROSSPLATFORM_MIN_REDUCTION_PCT, \
        f"avg build-time reduction only {avg:.1f}% " \
        f"(floor {CROSSPLATFORM_MIN_REDUCTION_PCT:.0f}%)"
    assert distinct == len(rows), \
        "platforms did not pick distinct variant sets"
    return {"avg_reduction_pct": avg,
            "distinct_variant_sets": float(distinct),
            "n_platforms": float(len(rows))}


def collect(smoke: bool = False, quiet: bool = False,
            service=None) -> Dict[str, Dict]:
    """The §5.3 sweep as a gated phase; smoke changes nothing (the run is
    already a single deterministic pass per platform) and ``service`` is
    accepted for uniformity with the other modules (the sweep builds its
    own per-platform nodes)."""
    rows = run(quiet=quiet)
    return {"platforms": rows, "summary": _metrics(rows)}


def write_bench_crossplatform(path: Optional[str] = None,
                              smoke: bool = False,
                              rows: Optional[Dict] = None) -> str:
    """Record the §5.3 cross-platform trajectory (CI artifact + the
    committed regression-gate baseline)."""
    path = path or os.environ.get("BENCH_CROSSPLATFORM_PATH",
                                  "BENCH_crossplatform.json")
    if rows is None:
        rows = collect(smoke=smoke, quiet=True)
    payload = {
        "config": {
            "smoke": smoke,
            "arch": ARCH,
            "min_reduction_pct": CROSSPLATFORM_MIN_REDUCTION_PCT,
        },
        "summary": rows["summary"],
        "platforms": {
            name: {k: v for k, v in r.items() if k != "picks"}
            for name, r in rows["platforms"].items()
        },
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def main(smoke: bool = False) -> List[str]:
    rows = collect(smoke=smoke, quiet=True)
    write_bench_crossplatform(smoke=smoke, rows=rows)
    s = rows["summary"]
    return [csv_row("cross_platform.s5_3", 0.0,
                    f"avg_reduction={s['avg_reduction_pct']:.1f}%;"
                    f"distinct_variant_sets="
                    f"{s['distinct_variant_sets']:.0f}/"
                    f"{s['n_platforms']:.0f}")]


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    rows = collect(smoke=smoke)
    out = write_bench_crossplatform(smoke=smoke, rows=rows)
    print(f"wrote {out}")
