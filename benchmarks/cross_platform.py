"""Paper §5.3 — a single CIR deployed on four heterogeneous platforms.

The conventional baseline needs one image per platform (4 builds); CIR
needs one pre-build and four lazy-builds that each pick platform-fitted
variants."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.configs import ARCHS
from repro.core import (cpu_smoke, gpu_server, tpu_multi_pod,
                        tpu_single_pod)

from .common import (MBPS, conventional_for, csv_row, fresh_builder,
                     lazy_deploy_time)

PLATFORMS = {
    "cpu-server": cpu_smoke,
    "gpu-server": gpu_server,
    "tpu-pod": tpu_single_pod,
    "tpu-multipod": tpu_multi_pod,
}


def run(arch_id: str = "gemma2-9b", bw_mbps: float = 500.0,
        quiet: bool = False) -> Dict[str, Dict]:
    bw = bw_mbps * MBPS
    lb, pb = fresh_builder(bw_mbps)
    cir = pb.prebuild(ARCHS[arch_id], entrypoint="train")
    rows: Dict[str, Dict] = {}
    for name, mk in PLATFORMS.items():
        spec = mk()
        # each platform is its own deployment node with its host runtime
        node, _ = fresh_builder(bw_mbps, host_spec=spec)
        inst = node.build(cir, spec, assemble=False)
        conv = conventional_for(lb=lb, cir=cir, spec=spec)
        rows[name] = {
            "lazy_s": lazy_deploy_time(inst.report, bw),
            "conv_s": conv.build_time(bw),
            "fetched_mb": inst.report.bytes_fetched / 2**20,
            "picks": {f"{c.manager}:{c.name}": c.env
                      for c in inst.bundle.components()
                      if c.manager in ("env", "parallel", "kernel", "opt",
                                       "runtime")},
        }
    if not quiet:
        print(f"single CIR: {arch_id} ({cir.size_bytes()} bytes) "
              f"deployed on {len(rows)} platforms @ {bw_mbps:.0f} Mbps")
        for name, r in rows.items():
            print(f"  {name:14s} lazy={r['lazy_s']:7.1f}s  "
                  f"conv-build={r['conv_s']:7.1f}s  "
                  f"fetched={r['fetched_mb']:7.1f} MiB")
            print(f"    env={r['picks'].get('env:runtime-base')} "
                  f"plan={r['picks'].get('parallel:plan')} "
                  f"train-step={r['picks'].get('runtime:train-step')}")
        avg = sum(100 * (1 - r["lazy_s"] / r["conv_s"])
                  for r in rows.values()) / len(rows)
        print(f"avg build-time reduction vs per-platform builds: {avg:.1f}% "
              f"(paper §5.3: 78.7%)")
    return rows


def main() -> List[str]:
    rows = run(quiet=True)
    avg = sum(100 * (1 - r["lazy_s"] / r["conv_s"])
              for r in rows.values()) / len(rows)
    distinct = len({tuple(sorted(r["picks"].items()))
                    for r in rows.values()})
    return [csv_row("cross_platform.s5_3", 0.0,
                    f"avg_reduction={avg:.1f}%;distinct_variant_sets="
                    f"{distinct}/4")]


if __name__ == "__main__":
    run()
