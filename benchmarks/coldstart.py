"""Scale-to-zero cold starts: fleet compile cache + snapshot/restore.

A serverless fleet pays three costs to bring a cold node to READY:
resolution, chunk fetch, and XLA compile.  The compile cache
(``repro.core.compilecache``) makes the compile a fleet-wide
content-addressed component — one node compiles, every same-platform-class
peer restores the executable over a peer link — and the snapshot path
(``repro.core.snapshot``) replays a retired instance's lock against a
still-resident store, so scale-from-zero is a pin replay plus a free
compile-cache hit.  All timings below are **virtual** seconds on the
simulated transport (``repro.core.simnet``) with a fixed virtual compile
cost per step entry, so the benchmark is deterministic.  Phases:

  * *cold vs peer* — first cold edge pays fetch + compile; the second
    same-class edge peers both chunks AND the compiled artifact.  Its
    time-to-READY must be ``>= COLD_PEER_MIN_REDUCTION_PCT`` lower, and
    its resolved-content byte accounting must be **identical** to the
    cache-miss build (compile skips are explicit, never byte-smuggled);
  * *snapshot restore* — a snapshotted instance restored on its own node
    must reach READY ``>= RESTORE_MIN_REDUCTION_PCT`` cheaper than the
    full cold build, in sub-second virtual time;
  * *poisson autoscale* — a bursty Poisson request trace drives
    scale-up/scale-to-zero over a fleet of edges; reports p50/p99
    time-to-READY of cold provisioning, instances-per-(virtual)-second,
    and the fleet compile-cache hit rate.

Writes ``BENCH_coldstart.json`` (CI artifact + regression-gate baseline;
see ``benchmarks.check_regression``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
from typing import Dict, List, Optional

import numpy as np

from repro.configs import ARCHS
from repro.core import PreBuilder, SimNetwork, catalog, cpu_smoke, \
    restore_instance, snapshot_instance, tpu_single_pod
from repro.deploy import FleetDeployer, FleetTopology

from .common import csv_row

ARCH = "starcoder2-3b"
COLD_PEER_MIN_REDUCTION_PCT = 60.0   # second cold node vs first
RESTORE_MIN_REDUCTION_PCT = 80.0     # snapshot restore vs full cold build
AUTOSCALE_N_EDGES = 6                # fleet size for the Poisson trace
AUTOSCALE_N_REQUESTS = 48
AUTOSCALE_SMOKE_REQUESTS = 20
SERVICE_TIME_S = 2.0                 # virtual busy time per request
IDLE_RETIRE_S = 6.0                  # idle instances scale to zero after


def _fleet(service, n_edges: int):
    """Cloud seed + N same-platform-class edges on the virtual clock.
    Sequential workers + no overlap: virtual timings are exact replays.
    Links are same-site LAN (fast), so time-to-READY is dominated by the
    XLA compile — the cost this benchmark exists to amortise."""
    topo = FleetTopology.edge_fanout(n_edges, cloud_edge_bps=5e8,
                                     edge_edge_bps=1e9)
    cloud = tpu_single_pod()
    edges = [dataclasses.replace(cpu_smoke(), platform_id=f"edge-host-{i}")
             for i in range(n_edges)]
    topo.place(cloud.platform_id, "cloud")
    for i, s in enumerate(edges):
        topo.place(s.platform_id, f"edge-{i}")
    net = SimNetwork(topo)
    fd = FleetDeployer(service, topology=topo, simnet=net,
                       max_workers=1, fetch_workers=1, overlap=False)
    return net, fd, cloud, edges


def _pct(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    return float(np.percentile(np.asarray(xs, dtype=float), q))


def cold_vs_peer(service=None, quiet: bool = False) -> Dict[str, float]:
    """First cold edge compiles; the second restores the executable from
    the fleet — and must come up >= 60% faster with identical resolved-
    content byte accounting."""
    service = service or catalog.build_service()
    cir = PreBuilder(service).prebuild(ARCHS[ARCH], entrypoint="serve")
    net, fd, cloud, edges = _fleet(service, 2)
    assert fd.deploy(cir, [cloud]).ok            # seed content on the cloud
    r0 = fd.deploy(cir, [edges[0]], assemble=True, compile_steps=True)
    r1 = fd.deploy(cir, [edges[1]], assemble=True, compile_steps=True)
    assert r0.ok and r1.ok, r0.summary() + r1.summary()
    t_cold, t_peer = r0.sim_elapsed_s, r1.sim_elapsed_s
    miss, hit = r0.deployments[0].report, r1.deployments[0].report
    assert not miss.compile_cache_hit and miss.artifact_bytes_published > 0
    assert hit.compile_cache_hit and hit.compile_skips == hit.n_compiled > 0
    # accounting identity: the cache hit changes WHEN bytes move (peer
    # artifact stripe, no compile), never the resolved-content columns
    for f in ("bytes_fetched", "bytes_delta_fetched", "chunks_hit",
              "chunks_missed", "cache_hits", "cache_misses",
              "n_components", "n_compiled", "bytes_total_components"):
        assert getattr(miss, f) == getattr(hit, f), f
    for res in (r0, r1):
        d = res.deployments[0]
        assert d.report.bytes_delta_fetched <= d.report.bytes_fetched
        assert res.node_traffic[d.node_id].bytes_total == \
            d.report.bytes_delta_fetched
    reduction = 100.0 * (1.0 - t_peer / t_cold)
    assert reduction >= COLD_PEER_MIN_REDUCTION_PCT, \
        f"peer cold start only {reduction:.1f}% faster " \
        f"(floor {COLD_PEER_MIN_REDUCTION_PCT:.0f}%): " \
        f"cold {t_cold:.2f}s vs peer {t_peer:.2f}s virtual"
    row = {
        "cold_ready_s": t_cold,
        "peer_ready_s": t_peer,
        "ready_reduction_pct": reduction,
        "compile_skips": float(hit.compile_skips),
        "artifact_mib": hit.artifact_bytes_fetched / 2**20,
        "accounting_identical": 1.0,
    }
    if not quiet:
        print(f"-- cold vs peer ({ARCH} serve): first edge {t_cold:.1f}s, "
              f"second {t_peer:.1f}s virtual (-{reduction:.1f}%), "
              f"{hit.compile_skips} compile(s) skipped, accounting identical")
    return row


def snapshot_restore(service=None, quiet: bool = False) -> Dict[str, float]:
    """Scale an edge to zero after its cold build, then restore it from the
    snapshot: pin replay + resident chunks + compile-cache hit must land
    READY >= 80% cheaper than the cold build, in sub-second virtual time."""
    service = service or catalog.build_service()
    cir = PreBuilder(service).prebuild(ARCHS[ARCH], entrypoint="serve")
    net, fd, cloud, edges = _fleet(service, 1)
    assert fd.deploy(cir, [cloud]).ok
    r0 = fd.deploy(cir, [edges[0]], assemble=True, compile_steps=True)
    assert r0.ok, r0.summary()
    t_cold = r0.sim_elapsed_s
    snap = snapshot_instance(r0.deployments[0].instance)

    t0 = net.clock.now
    restored = restore_instance(snap, fd.node_builder("edge-0"))
    t_restore = net.clock.now - t0
    rep = restored.report
    assert restored.stage == "complete"
    assert rep.locked and rep.compile_cache_hit
    assert rep.bytes_delta_fetched == 0          # store still resident
    reduction = 100.0 * (1.0 - t_restore / t_cold)
    assert reduction >= RESTORE_MIN_REDUCTION_PCT, \
        f"restore only {reduction:.1f}% cheaper than cold " \
        f"(floor {RESTORE_MIN_REDUCTION_PCT:.0f}%)"
    assert t_restore < 1.0, \
        f"restore took {t_restore:.2f}s virtual (sub-second required)"
    row = {
        "cold_ready_s": t_cold,
        "restore_ready_s": t_restore,
        "restore_reduction_pct": reduction,
        "restore_refetched_bytes": float(rep.bytes_delta_fetched),
    }
    if not quiet:
        print(f"-- snapshot restore: cold {t_cold:.1f}s vs restore "
              f"{t_restore:.3f}s virtual (-{reduction:.1f}%), "
              f"0 bytes refetched")
    return row


def poisson_autoscale(service=None, quiet: bool = False,
                      smoke: bool = False) -> Dict[str, float]:
    """Bursty Poisson request trace against autoscaling edge instances.

    The event loop runs on its own virtual timeline; every provisioning
    cost it charges is *measured live* on the simnet (a real deploy or
    restore advancing the virtual clock), not assumed.  Instances that sit
    idle past ``IDLE_RETIRE_S`` scale to zero behind a snapshot; a later
    burst restores them.  Reports p50/p99 time-to-READY over the cold
    provisioning events and the fleet compile-cache hit rate.
    """
    service = service or catalog.build_service()
    cir = PreBuilder(service).prebuild(ARCHS[ARCH], entrypoint="serve")
    n_req = AUTOSCALE_SMOKE_REQUESTS if smoke else AUTOSCALE_N_REQUESTS
    net, fd, cloud, edges = _fleet(service, AUTOSCALE_N_EDGES)
    assert fd.deploy(cir, [cloud]).ok

    # bursty arrivals: a slow trickle punctuated by dense bursts, so the
    # fleet repeatedly scales up from zero and back down (seeded: the
    # trace — and every virtual timing under it — is deterministic)
    rng = np.random.default_rng(0)
    arrivals, t = [], 0.0
    while len(arrivals) < n_req:
        t += float(rng.exponential(IDLE_RETIRE_S * 3))      # quiet gap
        for _ in range(int(rng.integers(3, 7))):            # then a burst
            t += float(rng.exponential(0.4))
            arrivals.append(t)
    arrivals = arrivals[:n_req]

    # node -> {"state": zero|snap|up, "free_at": float, "snap": snapshot}
    nodes = {f"edge-{i}": {"state": "zero", "free_at": 0.0, "snap": None,
                           "spec": edges[i]} for i in range(len(edges))}
    ready_times: List[float] = []    # provisioning cost per cold scale-up
    latencies: List[float] = []      # request arrival -> instance READY
    cold_deploys = restores = 0

    def provision(nd: Dict) -> float:
        """Bring one scaled-to-zero node up; returns virtual cost."""
        nonlocal cold_deploys, restores
        if nd["snap"] is not None:
            t0 = net.clock.now
            inst = restore_instance(nd["snap"], fd.node_builder(
                fd.topology.node_for(nd["spec"].platform_id)))
            restores += 1
            cost = net.clock.now - t0
        else:
            res = fd.deploy(cir, [nd["spec"]], assemble=True,
                            compile_steps=True)
            assert res.ok, res.summary()
            inst = res.deployments[0].instance
            cold_deploys += 1
            cost = res.sim_elapsed_s
        nd["snap"] = snapshot_instance(inst)     # retire cheaply later
        nd["state"] = "up"
        return cost

    for at in arrivals:
        # scale-to-zero sweep: anything idle past the timeout retires
        for nd in nodes.values():
            if nd["state"] == "up" and nd["free_at"] + IDLE_RETIRE_S < at:
                nd["state"] = "snap"
        up = [nd for nd in nodes.values() if nd["state"] == "up"]
        idle = [nd for nd in up if nd["free_at"] <= at]
        if idle:
            nd, wait = idle[0], 0.0
        else:
            down = [nodes[k] for k in sorted(nodes)
                    if nodes[k]["state"] != "up"]
            if down:
                nd = down[0]
                wait = provision(nd)
                ready_times.append(wait)
            else:                                # saturated: queue
                nd = min(up, key=lambda n: n["free_at"])
                wait = nd["free_at"] - at
        latencies.append(wait)
        nd["free_at"] = at + wait + SERVICE_TIME_S

    makespan = max(nd["free_at"] for nd in nodes.values())
    stats = fd.compile_cache.stats
    assert cold_deploys >= 1 and restores >= 1, \
        f"trace never exercised scale-to-zero ({cold_deploys} cold, " \
        f"{restores} restores)"
    assert stats.hit_rate > 0.0, "fleet compile cache never hit"
    # every scale-up after the first must ride the fleet cache: no cold
    # provisioning event repays the first node's full compile
    assert max(ready_times) == ready_times[0], \
        "a later cold start paid more than the first (cache not shared)"
    row = {
        "n_requests": float(n_req),
        "cold_deploys": float(cold_deploys),
        "restores": float(restores),
        "p50_ready_s": _pct(ready_times, 50),
        "p99_ready_s": _pct(ready_times, 99),
        "p99_latency_s": _pct(latencies, 99),
        "instances_per_s": (cold_deploys + restores) / makespan,
        "compile_hit_rate": stats.hit_rate,
        "makespan_s": makespan,
    }
    if not quiet:
        print(f"-- poisson autoscale ({n_req} reqs, {len(edges)} edges): "
              f"{cold_deploys} cold + {restores} restore(s); ready p50 "
              f"{row['p50_ready_s']:.2f}s / p99 {row['p99_ready_s']:.2f}s "
              f"virtual; compile hit rate {stats.hit_rate * 100:.0f}%; "
              f"{row['instances_per_s']:.3f} instances/s")
    return row


def write_bench_coldstart(path: Optional[str] = None,
                          smoke: bool = False,
                          rows: Optional[Dict] = None) -> str:
    """Record the cold-start trajectory (CI artifact + the committed
    regression-gate baseline)."""
    path = path or os.environ.get("BENCH_COLDSTART_PATH",
                                  "BENCH_coldstart.json")
    if rows is None:
        rows = collect(smoke=smoke, quiet=True)
    payload = {
        "config": {
            "smoke": smoke,
            "arch": ARCH,
            "cold_peer_min_reduction_pct": COLD_PEER_MIN_REDUCTION_PCT,
            "restore_min_reduction_pct": RESTORE_MIN_REDUCTION_PCT,
            "autoscale_n_edges": AUTOSCALE_N_EDGES,
        },
        "cold_vs_peer": rows["cold_vs_peer"],
        "snapshot": rows["snapshot"],
        "autoscale": rows["autoscale"],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def collect(smoke: bool = False, quiet: bool = False,
            service=None) -> Dict[str, Dict]:
    """All phases; smoke shortens the Poisson trace but keeps every
    assertion (the reductions ARE the claims under test)."""
    service = service or catalog.build_service()
    return {
        "cold_vs_peer": cold_vs_peer(service, quiet=quiet),
        "snapshot": snapshot_restore(service, quiet=quiet),
        "autoscale": poisson_autoscale(service, quiet=quiet, smoke=smoke),
    }


def main(smoke: bool = False) -> List[str]:
    rows = collect(smoke=smoke, quiet=True)
    write_bench_coldstart(smoke=smoke, rows=rows)
    cp, sn, au = rows["cold_vs_peer"], rows["snapshot"], rows["autoscale"]
    return [
        csv_row(
            "coldstart.cold_vs_peer", 0.0,
            f"cold={cp['cold_ready_s']:.1f}s;peer={cp['peer_ready_s']:.1f}s;"
            f"reduction={cp['ready_reduction_pct']:.1f}%"),
        csv_row(
            "coldstart.snapshot_restore", 0.0,
            f"cold={sn['cold_ready_s']:.1f}s;"
            f"restore={sn['restore_ready_s']:.3f}s;"
            f"reduction={sn['restore_reduction_pct']:.1f}%"),
        csv_row(
            "coldstart.autoscale", 0.0,
            f"p50={au['p50_ready_s']:.2f}s;p99={au['p99_ready_s']:.2f}s;"
            f"hit_rate={au['compile_hit_rate'] * 100:.0f}%;"
            f"inst_per_s={au['instances_per_s']:.3f}"),
    ]


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    rows = collect(smoke=smoke)
    out = write_bench_coldstart(smoke=smoke, rows=rows)
    print(f"wrote {out}")
