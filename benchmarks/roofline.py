"""Framework roofline table (EXPERIMENTS.md §Roofline) from the dry-run
artifacts.  Run ``python -m repro.launch.dryrun --all`` first; this
benchmark aggregates whatever artifacts exist."""
from __future__ import annotations

from typing import List

from repro.launch.roofline import analyze, fmt_table, load_artifacts

from .common import csv_row


def run(quiet: bool = False):
    arts = [a for a in load_artifacts() if "skipped" not in a]
    rows = [analyze(a) for a in arts]
    if not quiet and rows:
        print(fmt_table(rows))
    if not quiet and not rows:
        print("no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first")
    return rows


def main() -> List[str]:
    rows = run(quiet=True)
    if not rows:
        return [csv_row("roofline", 0.0, "no_artifacts")]
    by_dom = {}
    for r in rows:
        by_dom[r["dominant"]] = by_dom.get(r["dominant"], 0) + 1
    best = max(rows, key=lambda r: r["roofline_fraction"])
    return [csv_row(
        "roofline", 0.0,
        f"cells={len(rows)};dominant={by_dom};"
        f"best={best['arch']}x{best['shape']}="
        f"{best['roofline_fraction']:.3f}")]


if __name__ == "__main__":
    run()
