"""CI bench-regression gate.

Re-runs the smoke benchmarks and compares their key metrics against the
**committed** ``BENCH_*.json`` baselines with per-metric tolerance bands,
exiting non-zero on regression — a perf regression fails the PR instead of
silently shipping a worse baseline artifact.

Baselines are snapshotted into memory *before* the fresh runs, because the
fresh results are written to the same ``BENCH_*.json`` paths when
``--write`` is given or ``CI`` is set (so the CI artifact upload records
the fresh trajectory); plain local runs write to a temp directory and
leave the committed baselines untouched.

Tolerances are per metric: byte-accounting metrics are deterministic and
get tight bands; wall-clock metrics (ready-reduction, fetch speedup) get
wide bands sized for noisy shared CI runners — the gate exists to catch a
*collapsed* pipeline (overlap gone, peers never selected, delta fetch
re-transferring everything), not 5% scheduler jitter.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import tempfile
from typing import Callable, Dict, List, Optional

FETCH = "BENCH_fetch.json"
PIPELINE = "BENCH_pipeline.json"
DISTRIBUTION = "BENCH_distribution.json"
CHURN = "BENCH_churn.json"
SCALE = "BENCH_scale.json"
COLDSTART = "BENCH_coldstart.json"
PLACEMENT = "BENCH_placement.json"
INTEGRITY = "BENCH_integrity.json"
HETERO = "BENCH_hetero.json"
CROSSPLATFORM = "BENCH_crossplatform.json"
BASELINES = (FETCH, PIPELINE, DISTRIBUTION, CHURN, SCALE, COLDSTART,
             PLACEMENT, INTEGRITY, HETERO, CROSSPLATFORM)


@dataclasses.dataclass
class Check:
    metric: str
    baseline: Optional[float]
    fresh: Optional[float]
    higher_is_better: bool
    rel_tol: float                  # allowed fractional slack off baseline
    abs_limit: Optional[float] = None   # hard bound regardless of baseline

    @property
    def skipped(self) -> bool:
        """Only a missing *baseline* skips a check (the PR introducing a
        new benchmark cannot compare against history).  A baseline whose
        fresh counterpart went missing is a FAILURE — otherwise renaming a
        metric would silently disarm the gate."""
        return self.baseline is None

    @property
    def bound(self) -> Optional[float]:
        if self.baseline is None:
            return self.abs_limit
        if self.higher_is_better:
            b = self.baseline * (1.0 - self.rel_tol)
            return max(b, self.abs_limit) if self.abs_limit is not None else b
        b = self.baseline * (1.0 + self.rel_tol)
        return min(b, self.abs_limit) if self.abs_limit is not None else b

    @property
    def ok(self) -> bool:
        if self.skipped:
            return True
        if self.fresh is None:
            return False        # metric vanished from the fresh run
        assert self.bound is not None
        return self.fresh >= self.bound if self.higher_is_better \
            else self.fresh <= self.bound

    def row(self) -> str:
        if self.skipped:
            return f"  SKIP  {self.metric:58s} (no baseline)"
        if self.fresh is None:
            return (f"  FAIL  {self.metric:58s} missing from the fresh run "
                    f"(baseline {self.baseline:.3f})")
        arrow = ">=" if self.higher_is_better else "<="
        return (f"  {'ok' if self.ok else 'FAIL':4s}  {self.metric:58s} "
                f"{self.fresh:12.3f} {arrow} {self.bound:10.3f} "
                f"(baseline {self.baseline:.3f})")


def _get(d: Optional[Dict], *path: str) -> Optional[float]:
    for k in path:
        if not isinstance(d, dict) or k not in d:
            return None
        d = d[k]
    return float(d) if isinstance(d, (int, float)) else None


def _load(path: str) -> Optional[Dict]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def run_fresh(out_dir: str) -> Dict[str, Dict]:
    """Re-run the smoke benchmarks, writing their JSON into ``out_dir``."""
    from . import build_time, churn, coldstart, cross_platform, \
        distribution, hetero, integrity, placement, scale

    print("== re-running smoke benchmarks (this is the gate's evidence) ==")
    delta = build_time.delta_redeploy(quiet=True)
    conc = build_time.fetch_concurrency(widths=(1, 8), quiet=True)
    fleet = build_time.fleet_fetch(quiet=True)
    fetch_path = build_time.write_bench_fetch(
        path=os.path.join(out_dir, FETCH), smoke=True,
        delta=delta, concurrency=conc, fleet=fleet)
    pipe = build_time.pipeline_overlap(quiet=True)
    pipe_path = build_time.write_bench_pipeline(
        path=os.path.join(out_dir, PIPELINE), smoke=True, rows=pipe)
    dist = distribution.edge_fanout(quiet=True)
    dist_path = distribution.write_bench_distribution(
        path=os.path.join(out_dir, DISTRIBUTION), smoke=True, rows=dist)
    churn_rows = churn.policy_comparison(quiet=True)
    churn.accounting_identity(quiet=True)
    churn_path = churn.write_bench_churn(
        path=os.path.join(out_dir, CHURN), smoke=True, rows=churn_rows)
    scale_rows = scale.collect(smoke=True, quiet=True)
    scale_path = scale.write_bench_scale(
        path=os.path.join(out_dir, SCALE), smoke=True, rows=scale_rows)
    cold_rows = coldstart.collect(smoke=True, quiet=True)
    cold_path = coldstart.write_bench_coldstart(
        path=os.path.join(out_dir, COLDSTART), smoke=True, rows=cold_rows)
    place_rows = placement.collect(smoke=True, quiet=True)
    place_path = placement.write_bench_placement(
        path=os.path.join(out_dir, PLACEMENT), smoke=True, rows=place_rows)
    # SBOM rides along with the bench artifacts (R-096 provenance)
    integ_rows = integrity.collect(
        smoke=True, quiet=True,
        sbom_path=os.path.join(out_dir, "SBOM_smoke.json"))
    integ_path = integrity.write_bench_integrity(
        path=os.path.join(out_dir, INTEGRITY), smoke=True, rows=integ_rows)
    het_rows = hetero.collect(smoke=True, quiet=True)
    het_path = hetero.write_bench_hetero(
        path=os.path.join(out_dir, HETERO), smoke=True, rows=het_rows)
    xp_rows = cross_platform.collect(smoke=True, quiet=True)
    xp_path = cross_platform.write_bench_crossplatform(
        path=os.path.join(out_dir, CROSSPLATFORM), smoke=True, rows=xp_rows)
    return {FETCH: _load(fetch_path), PIPELINE: _load(pipe_path),
            DISTRIBUTION: _load(dist_path), CHURN: _load(churn_path),
            SCALE: _load(scale_path), COLDSTART: _load(cold_path),
            PLACEMENT: _load(place_path), INTEGRITY: _load(integ_path),
            HETERO: _load(het_path), CROSSPLATFORM: _load(xp_path)}


def build_checks(base: Dict[str, Optional[Dict]],
                 fresh: Dict[str, Optional[Dict]]) -> List[Check]:
    checks: List[Check] = []

    def add(fname: str, metric_path: List[str], higher: bool, tol: float,
            abs_limit: Optional[float] = None,
            reduce_avg: Optional[Callable[[Dict], Optional[float]]] = None
            ) -> None:
        b, f = base.get(fname), fresh.get(fname)
        if reduce_avg is not None:
            bv = reduce_avg(b) if b is not None else None
            fv = reduce_avg(f) if f is not None else None
        else:
            bv, fv = _get(b, *metric_path), _get(f, *metric_path)
        checks.append(Check(
            metric=f"{fname}:{'.'.join(metric_path)}",
            baseline=bv, fresh=fv, higher_is_better=higher, rel_tol=tol,
            abs_limit=abs_limit))

    # -- chunk-delta fetch: deterministic byte accounting, tight band ----
    def avg_delta_saved(doc: Dict) -> Optional[float]:
        rows = doc.get("delta_redeploy", {})
        common = [a for a in rows
                  if a in (fresh.get(FETCH) or {}).get("delta_redeploy", {})
                  and a in (base.get(FETCH) or {}).get("delta_redeploy", {})]
        if not common:
            return None
        return sum(rows[a]["delta_saved_pct"] for a in common) / len(common)

    add(FETCH, ["delta_redeploy", "avg_delta_saved_pct"], True, 0.10,
        reduce_avg=avg_delta_saved)
    # singleflight invariant: a fleet must never pay for a chunk twice
    add(FETCH, ["fleet_fetch", "double_charged_bytes"], False, 0.0,
        abs_limit=0.0)
    # wall-clock: wide band, catches a serialized pool, not jitter
    add(FETCH, ["fetch_concurrency", "8", "speedup_vs_serial"], True, 0.65)

    # -- event-driven pipeline: wall-clock, wide band --------------------
    add(PIPELINE, ["avg_ready_reduction_pct"], True, 0.55, abs_limit=25.0)

    # -- peer distribution: deterministic byte accounting ----------------
    add(DISTRIBUTION, ["avg_peer_offload_ratio"], True, 0.10)
    add(DISTRIBUTION, ["avg_upstream_vs_baseline_pct"], False, 0.15,
        abs_limit=40.0)

    # -- store-lifecycle churn: deterministic byte accounting ------------
    # cheapest-to-restore must keep beating lru's upstream wire bytes ...
    add(CHURN, ["ctr_vs_lru_upstream_reduction_pct"], True, 0.20,
        abs_limit=15.0)
    # ... and the churn hit-rate must not collapse (eviction gone rogue)
    add(CHURN, ["ctr_hit_rate"], True, 0.10)

    # -- discrete-event scale: the 200-node smoke-time claim -------------
    # wall clock: wide band for shared runners, hard 30 s ceiling — the
    # number that makes a 200-node fleet deployable in a CI smoke job
    add(SCALE, ["scale", "wall_s"], False, 1.5, abs_limit=30.0)
    add(SCALE, ["scale", "peer_offload_ratio"], True, 0.15)
    # per-node accounting must stay byte-identical across transports
    add(SCALE, ["identity", "ok"], True, 0.0, abs_limit=1.0)
    # hub death mid-deploy: must converge, and the fault-recovery wire
    # overhead (extra registry bytes / fleet wire bytes) must stay small
    add(SCALE, ["faults", "node_loss", "converged"], True, 0.0,
        abs_limit=1.0)
    add(SCALE, ["faults", "node_loss", "extra_upstream_pct"], False, 0.75,
        abs_limit=15.0)

    # -- scale-to-zero cold starts: virtual-time, deterministic ----------
    # the second cold node must keep riding the fleet compile cache (the
    # benchmark's own floor is 60%; the gate holds the committed margin)
    add(COLDSTART, ["cold_vs_peer", "ready_reduction_pct"], True, 0.10,
        abs_limit=60.0)
    add(COLDSTART, ["cold_vs_peer", "accounting_identical"], True, 0.0,
        abs_limit=1.0)
    # snapshot restore must stay a near-free pin replay
    add(COLDSTART, ["snapshot", "restore_reduction_pct"], True, 0.05,
        abs_limit=80.0)
    # p99 cold-READY under the bursty trace, and the cache hit rate that
    # keeps it there — a collapsed cache shows up in both
    add(COLDSTART, ["autoscale", "p99_ready_s"], False, 0.25)
    add(COLDSTART, ["autoscale", "compile_hit_rate"], True, 0.10)

    # -- demand-driven placement: virtual-time, deterministic ------------
    # speculation must keep beating reactive fetch on the rotating trace
    # (the benchmark's own floor is 40%; the gate holds the margin)
    add(PLACEMENT, ["trace", "p95_ready_reduction_pct"], True, 0.15,
        abs_limit=40.0)
    # ... without flooding the WAN registry link to do it
    add(PLACEMENT, ["trace", "speculation_wire_overhead_pct"], False, 0.0,
        abs_limit=25.0)
    # the migration serve gap must stay a fraction of a cold re-deploy
    add(PLACEMENT, ["migration", "migration_downtime_ratio"], False, 0.25,
        abs_limit=0.20)

    # -- trust & integrity: byzantine peering + attestation --------------
    # verify-on-receipt must stay noise on the fetch path: the metric is
    # floored at 0.1 in the benchmark, so with the wide rel_tol the
    # effective bound is the hard 3% ceiling, never a noise-scaled one
    add(INTEGRITY, ["overhead", "verify_overhead_pct"], False, 50.0,
        abs_limit=3.0)
    # the invariants: nothing corrupt ever commits, accounting identities
    # survive byzantine peers, the liar gets quarantined, forged
    # attestations die at plan time — all hard, tolerance-free gates
    add(INTEGRITY, ["chaos", "corrupt_chunks_committed"], False, 0.0,
        abs_limit=0.0)
    add(INTEGRITY, ["chaos", "corrupt_chunks_rejected"], True, 0.90)
    add(INTEGRITY, ["chaos", "identity_ok"], True, 0.0, abs_limit=1.0)
    add(INTEGRITY, ["chaos", "quarantined"], True, 0.0, abs_limit=1.0)
    add(INTEGRITY, ["attestation", "tamper_rejected"], True, 0.0,
        abs_limit=1.0)

    # -- performance-portable hetero fleet: virtual-time, deterministic --
    # the §13 split must keep eliminating >= 50% of the cross-platform
    # compiled wire (the benchmark's own floor; the gate holds the
    # committed margin on top)
    add(HETERO, ["split", "wire_reduction_pct"], True, 0.10,
        abs_limit=50.0)
    add(HETERO, ["split", "accounting_identical"], True, 0.0,
        abs_limit=1.0)
    # the shared IR must be lowered exactly once fleet-wide — a second
    # published copy means the sharing path collapsed
    add(HETERO, ["ir_once", "ir_published_copies"], False, 0.0,
        abs_limit=1.0)
    add(HETERO, ["identity", "ir_columns_zero_when_off"], True, 0.0,
        abs_limit=1.0)

    # -- paper §5.3 cross-platform deploys: deterministic cost model -----
    add(CROSSPLATFORM, ["summary", "avg_reduction_pct"], True, 0.10,
        abs_limit=60.0)
    add(CROSSPLATFORM, ["summary", "distinct_variant_sets"], True, 0.0,
        abs_limit=4.0)
    return checks


def main(argv: List[str]) -> int:
    base = {name: _load(name) for name in BASELINES}
    missing = [n for n, d in base.items() if d is None]
    if missing:
        print(f"warning: no committed baseline for {', '.join(missing)} — "
              f"its checks will be skipped", file=sys.stderr)

    write_here = "--write" in argv or bool(os.environ.get("CI"))
    if write_here:
        fresh = run_fresh(".")
    else:
        with tempfile.TemporaryDirectory() as td:
            fresh = run_fresh(td)

    checks = build_checks(base, fresh)
    print("\n== bench-regression gate ==")
    for c in checks:
        print(c.row())
    failed = [c for c in checks if not c.ok]
    if failed:
        print(f"\n{len(failed)} metric(s) regressed beyond tolerance. "
              f"If this is an intentional trade-off, refresh the committed "
              f"BENCH_*.json baselines in the same PR (run the full "
              f"benchmarks, not --smoke) and say why in the PR description.")
        return 1
    print("\nall metrics within tolerance of the committed baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
