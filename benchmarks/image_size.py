"""Paper Fig. 6 — image size: CIR vs conventional platform-specific image.

Per architecture (the 10-arch suite is our app benchmark): CIR wire bytes,
the conventional image bytes (same resolved content, bundled), the bytes a
cold lazy-build fetches, and the reduction percentages."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.configs import ARCHS
from repro.core import tpu_single_pod

from .common import conventional_for, csv_row, fresh_builder


def run(entrypoint: str = "serve", quiet: bool = False) -> Dict[str, Dict]:
    lb, pb = fresh_builder()
    spec = tpu_single_pod()
    rows: Dict[str, Dict] = {}
    for arch_id in ARCHS:
        cir = pb.prebuild(ARCHS[arch_id], entrypoint=entrypoint)
        conv = conventional_for(cir, lb, spec)
        rows[arch_id] = {
            "cir_bytes": cir.size_bytes(),
            "image_bytes": conv.image_bytes,
            "reduction_pct": 100.0 * (1 - cir.size_bytes()
                                      / conv.image_bytes),
        }
    if not quiet:
        print(f"{'arch':24s} {'CIR':>10s} {'conv image':>14s} {'reduction':>10s}")
        for a, r in rows.items():
            print(f"{a:24s} {r['cir_bytes']:>9d}B "
                  f"{r['image_bytes']/2**20:>11.0f}MiB "
                  f"{r['reduction_pct']:>9.2f}%")
        avg = sum(r["reduction_pct"] for r in rows.values()) / len(rows)
        print(f"{'average':24s} {'':>10s} {'':>14s} {avg:>9.2f}%  "
              f"(paper: ~95%+)")
    return rows


def main() -> List[str]:
    t0 = time.perf_counter()
    rows = run(quiet=True)
    dt_us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    avg = sum(r["reduction_pct"] for r in rows.values()) / len(rows)
    return [csv_row("image_size.fig6", dt_us,
                    f"avg_reduction={avg:.2f}%;archs={len(rows)}")]


if __name__ == "__main__":
    run()
