"""Paper Table 1 + Fig. 10 — storage sharing at four granularities, passive
vs active, plus the pairwise sharing matrix over the 10-arch suite."""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from repro.configs import ARCHS
from repro.core import tpu_single_pod

from .common import SMOKE_ARCHS as _SMOKE_ARCHS, csv_row, fresh_builder


def _suite(entrypoint: str, archs: Optional[Sequence[str]] = None):
    """passive = each app imaged per platform on its own node (10 archs ×
    3 platforms, like the paper's registry of per-platform images); active
    = one deployment node with a shared local store the deployability
    evaluator prefers."""
    from repro.core import cpu_smoke, gpu_server
    archs = list(archs or ARCHS)
    spec = tpu_single_pod()
    passive, _ = fresh_builder()
    for arch_id in archs:
        for pspec in (spec, cpu_smoke(), gpu_server()):
            lb, pb = fresh_builder()
            inst = lb.build(
                pb.prebuild(ARCHS[arch_id], entrypoint=entrypoint), pspec,
                assemble=False)
            for c in inst.bundle.components():
                passive.store.put(c)
            passive.store.record_build(
                f"{arch_id}@{pspec.platform_id}", inst.bundle.components())

    active, pb = fresh_builder()
    fetched = []
    for arch_id in archs:
        inst = active.build(
            pb.prebuild(ARCHS[arch_id], entrypoint=entrypoint), spec,
            assemble=False)
        fetched.append(inst.report.bytes_fetched)
        active.store.record_build(arch_id, inst.bundle.components())
    return (passive.store.sharing_report(), active.store.sharing_report(),
            fetched, active.store.pairwise_sharing(),
            active.store.chunk_stats)


def _fleet():
    """One CIR deployed to 3 heterogeneous platforms through FleetDeployer:
    the shared store means later platforms pay only their platform delta."""
    from repro.core import catalog, cpu_smoke, gpu_server
    from repro.core import PreBuilder
    from repro.deploy import FleetDeployer

    svc = catalog.build_service()
    pb = PreBuilder(svc)
    fd = FleetDeployer(svc)
    specs = [tpu_single_pod(), cpu_smoke(), gpu_server()]
    results = {}
    for arch_id in ("gemma2-9b", "starcoder2-3b", "phi4-mini-3.8b"):
        res = fd.deploy(pb.prebuild(ARCHS[arch_id], entrypoint="train"),
                        specs)
        results[arch_id] = res
    return fd, results


def run(quiet: bool = False,
        archs: Optional[Sequence[str]] = None) -> Dict[str, Dict]:
    # env+code suite (the paper's packages story) and serve suite (weights
    # dominate — the worst case for sharing)
    passive_rep, active_rep, fetched, pairwise, chunk_live = _suite(
        "train", archs)
    sp, sa, sf, _, serve_chunk_live = _suite("serve", archs)
    fd, fleet_res = _fleet()

    rows = {"passive": passive_rep, "active": active_rep,
            "active_fetched_bytes": fetched,
            "serve_passive": sp, "serve_active": sa,
            "pairwise_avg": sum(pairwise.values()) / max(len(pairwise), 1),
            "live_chunk_stats": chunk_live.as_dict(),
            "serve_live_chunk_stats": serve_chunk_live.as_dict(),
            "fleet_sharing_rate": fd.store.stats.sharing_rate,
            "fleet_store_stats": fd.store.stats.as_dict(),
            "fleet_chunk_stats": fd.store.chunk_stats.as_dict(),
            "fleet_fetched_bytes": {a: r.bytes_fetched_total
                                    for a, r in fleet_res.items()},
            "fleet_delta_bytes": {a: r.bytes_delta_total
                                  for a, r in fleet_res.items()},
            "fleet_component_bytes": {a: r.bytes_components_total
                                      for a, r in fleet_res.items()}}
    if not quiet:
        print("granularity   bytes-saved  objects     (train suite, passive)")
        for g in ("layer", "file", "chunk", "component"):
            r = passive_rep[g]
            print(f"  {g:10s} {r['bytes_saved_pct']:10.2f}% "
                  f"{r['before_objects']:>9d} -> {r['after_objects']:<9d}")
        ar = active_rep["component"]
        print(f"  component-ACTIVE {ar['bytes_saved_pct']:6.2f}%  "
              f"(paper: 46–70%)")
        print(f"  serve suite (weights dominate): passive component "
              f"{sp['component']['bytes_saved_pct']:.2f}%, active "
              f"{sa['component']['bytes_saved_pct']:.2f}%")
        first, rest = fetched[0], sum(fetched[1:]) / (len(fetched) - 1)
        print(f"first build fetched {first/2**20:.1f} MiB; subsequent "
              f"builds avg {rest/2**20:.3f} MiB (active reuse)")
        print(f"pairwise component-sharing rate (Fig 10 avg): "
              f"{rows['pairwise_avg']*100:.1f}%")
        cl = rows["live_chunk_stats"]
        print(f"live chunk store (active node): "
              f"{cl['chunks_stored']} chunks stored, "
              f"{cl['chunks_hit']} hit, delta sharing "
              f"{cl['delta_sharing_rate']*100:.1f}% on top of components")
        print(f"fleet deploy (1 CIR -> 3 platforms, 3 archs): sharing rate "
              f"{rows['fleet_sharing_rate']*100:.1f}% across the fleet store")
        fc = rows["fleet_chunk_stats"]
        print(f"  fleet chunk layer: {fc['chunks_waited']} chunks deduped "
              f"in flight, delta sharing "
              f"{fc['delta_sharing_rate']*100:.1f}%")
        for a, b in rows["fleet_fetched_bytes"].items():
            tot = rows["fleet_component_bytes"][a]
            wire = rows["fleet_delta_bytes"][a]
            print(f"  {a:20s} fetched {b/2**20:8.1f} MiB "
                  f"(wire {wire/2**20:8.1f} MiB) of "
                  f"{tot/2**20:8.1f} MiB referenced")
    return rows


def main() -> List[str]:
    rows = run(quiet=True)
    p = rows["passive"]
    return [csv_row(
        "sharing.table1", 0.0,
        f"layer={p['layer']['bytes_saved_pct']:.1f}%;"
        f"file={p['file']['bytes_saved_pct']:.1f}%;"
        f"chunk={p['chunk']['bytes_saved_pct']:.1f}%;"
        f"component={p['component']['bytes_saved_pct']:.1f}%;"
        f"active={rows['active']['component']['bytes_saved_pct']:.1f}%;"
        f"pairwise={rows['pairwise_avg']*100:.1f}%;"
        f"fleet={rows['fleet_sharing_rate']*100:.1f}%;"
        f"fleet_chunk_delta="
        f"{rows['fleet_chunk_stats']['delta_sharing_rate']*100:.1f}%")]


if __name__ == "__main__":
    import sys
    run(archs=_SMOKE_ARCHS if "--smoke" in sys.argv else None)
