"""Trust & integrity under byzantine peers: verify-on-receipt for free.

Peer-to-peer chunk distribution moves the trust boundary: a stripe no
longer comes from the registry you authenticated with, it comes from
whichever node the ``PeerIndex`` said was cheapest.  Verify-on-receipt
(docs/cir-format.md §12) digest-checks every peer-sourced stripe before
commit, retracts and re-sources on mismatch, and quarantines repeat
offenders fleet-wide.  This benchmark pins the three claims that make
that defensible as a *default*:

  * *verify overhead* — the receipt check on the hot fetch path costs
    under ``VERIFY_OVERHEAD_CEILING_PCT`` of fetch time (same fleet,
    verification on vs off, min-of-repeats);
  * *byzantine chaos* — with ``N_LIARS``/``N_EDGES`` (25%) of the
    content-holding peers serving corrupt stripes, every build still
    converges with ZERO corrupt chunks committed, per-node byte
    accounting identities intact, and the liar quarantined — the
    convergence time (virtual seconds from first lie to fleet-wide
    blacklist) is reported;
  * *attestation gate* — a tampered manifest attestation is rejected at
    plan time, before a single byte is fetched.

Also emits the CycloneDX-shaped SBOM of the smoke CIR's resolved closure
(``SBOM_smoke.json``, R-096) so CI archives provenance next to the bench
artifacts.  Writes ``BENCH_integrity.json`` (CI artifact +
regression-gate baseline; see ``benchmarks.check_regression``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
from typing import Dict, List, Optional

from repro.configs import ARCHS
from repro.core import (AttestationError, HMACSigner, LazyBuilder,
                        PreBuilder, SimNetwork, catalog, cpu_smoke,
                        tpu_single_pod, write_sbom)
from repro.deploy import FleetDeployer, FleetTopology

from .common import csv_row

ARCH = "phi4-mini-3.8b"
N_EDGES = 4                      # + 1 cloud seed
N_LIARS = 1                      # 1 of 4 content holders lie -> 25%
VERIFY_OVERHEAD_CEILING_PCT = 3.0
OVERHEAD_REPEATS = 5             # min-of-N per arm (3 under --smoke)
OVERHEAD_LINK_BPS = 1e9          # fast-LAN links: a *conservative* floor —
#                                  slower wire only shrinks the digest
#                                  pass's share of the fetch path
SECRET = b"integrity-bench-secret"


def _fanout(n_edges: int) -> FleetTopology:
    return FleetTopology.edge_fanout(n_edges, cloud_edge_bps=200e6,
                                     edge_edge_bps=100e6)


def _place(topo: FleetTopology, n_edges: int):
    cloud = tpu_single_pod()
    topo.place(cloud.platform_id, "cloud")
    edges = []
    for i in range(n_edges):
        s = dataclasses.replace(cpu_smoke(), platform_id=f"edge-host-{i}")
        topo.place(s.platform_id, f"edge-{i}")
        edges.append(s)
    return cloud, edges


# ---------------------------------------------------------------------------
# verify overhead: the receipt check must be noise on the fetch path
# ---------------------------------------------------------------------------

def verify_overhead(service=None, repeats: int = OVERHEAD_REPEATS,
                    quiet: bool = False) -> Dict[str, float]:
    """Same fan-out deployed with verification on vs off over the
    *threaded* transport with ``simulate_links=True`` — transfers sleep
    real wall clock at the topology's link bandwidths, so the fetch path
    costs what a wire costs and the digest pass competes against
    transfer time, exactly as deployed.  The metric is
    min-of-``repeats`` summed per-task fetch time; the headline
    assertion: under ``VERIFY_OVERHEAD_CEILING_PCT``."""
    service = service or catalog.build_service()
    cir = PreBuilder(service).prebuild(ARCHS[ARCH], entrypoint="serve")

    def one(verify: bool) -> Dict[str, float]:
        topo = FleetTopology.edge_fanout(
            N_EDGES, cloud_edge_bps=OVERHEAD_LINK_BPS,
            edge_edge_bps=OVERHEAD_LINK_BPS / 2,
            edge_upstream_bps=OVERHEAD_LINK_BPS / 2)
        cloud, edges = _place(topo, N_EDGES)
        fd = FleetDeployer(service, topology=topo, simulate_links=True,
                           max_workers=4, fetch_workers=2,
                           verify_receipts=verify)
        assert fd.deploy(cir, [cloud]).ok
        res = fd.deploy(cir, edges)
        assert res.ok, res.summary()
        peer_chunks = sum(t.chunks_from_peers
                          for t in res.node_traffic.values())
        return {"fetch_s": res.fetch_serial_s_total,
                "peer_chunks": float(peer_chunks)}

    # interleave the arms so drift in the shared service / host hits both
    on_s, off_s, peer_chunks = [], [], 0.0
    for _ in range(repeats):
        r_on, r_off = one(True), one(False)
        on_s.append(r_on["fetch_s"])
        off_s.append(r_off["fetch_s"])
        peer_chunks = r_on["peer_chunks"]
    fetch_on, fetch_off = min(on_s), min(off_s)
    raw_pct = 100.0 * (fetch_on - fetch_off) / max(fetch_off, 1e-12)
    # negative raw overhead is scheduler noise; the *gated* metric is
    # floored at 0.1 so the committed baseline keeps the regression bound
    # at the 3% ceiling instead of noise-scaling it toward zero
    pct = max(raw_pct, 0.1)
    assert peer_chunks > 0, "no peer-sourced chunks — nothing was verified"
    assert pct < VERIFY_OVERHEAD_CEILING_PCT, \
        f"verify-on-receipt costs {pct:.2f}% of the fetch path " \
        f"(ceiling {VERIFY_OVERHEAD_CEILING_PCT}%)"
    row = {
        "fetch_verify_s": fetch_on,
        "fetch_noverify_s": fetch_off,
        "verify_overhead_raw_pct": raw_pct,
        "verify_overhead_pct": pct,
        "chunks_verified": peer_chunks,
    }
    if not quiet:
        print(f"-- verify overhead ({N_EDGES} edges, {ARCH} serve, "
              f"min of {repeats})")
        print(f"   fetch path {fetch_on * 1e3:.1f} ms verified vs "
              f"{fetch_off * 1e3:.1f} ms trusting -> "
              f"{raw_pct:+.2f}% ({peer_chunks:.0f} peer chunks checked, "
              f"ceiling {VERIFY_OVERHEAD_CEILING_PCT:.0f}%)")
    return row


# ---------------------------------------------------------------------------
# byzantine chaos: 25% lying peers, zero corrupt commits
# ---------------------------------------------------------------------------

def byzantine_chaos(service=None, n_edges: int = N_EDGES,
                    quiet: bool = False) -> Dict[str, float]:
    """Seed the cloud and one edge honestly, then flip that edge
    byzantine (``N_LIARS`` of ``n_edges`` content holders = 25%) and
    deploy the remaining edges through it.  Every corrupt stripe must be
    rejected on receipt and re-sourced honestly: builds all converge,
    nothing corrupt is committed, the accounting identity holds, and the
    liar ends up quarantined fleet-wide."""
    service = service or catalog.build_service()
    cir = PreBuilder(service).prebuild(ARCHS[ARCH], entrypoint="serve")
    topo = _fanout(n_edges)
    cloud, edges = _place(topo, n_edges)
    net = SimNetwork(topo)
    fleet = FleetDeployer(service, topology=topo, simnet=net,
                          max_workers=4, fetch_workers=2)

    # count every chunk the tamper hook corrupted in flight: any flagged
    # chunk NOT matched by a store-side rejection was committed corrupt
    flagged = {"chunks": 0}
    for node_id in topo.node_ids():
        p = fleet.node_peering(node_id)
        orig = p.tamper_hook

        def hook(src, chunks, _orig=orig):
            out = _orig(src, chunks)
            flagged["chunks"] += len(out)
            return out

        p.tamper_hook = hook

    # wave 1: honest seeding — the future liar becomes a content holder
    assert fleet.deploy(cir, [cloud, edges[0]]).ok
    liars = [f"edge-{i}" for i in range(N_LIARS)]
    fleet.mark_byzantine(liars)
    t_mark = net.clock.now

    # wave 2: the rest of the fleet pulls through a mesh that is 25% lies
    res = fleet.deploy(cir, edges[N_LIARS:])
    assert res.ok, res.summary()

    rejected = sum(fleet.node_peering(n).store.chunk_stats.corrupt_rejected
                   for n in topo.node_ids())
    committed = flagged["chunks"] - rejected
    identity_ok = all(
        d.report.bytes_delta_fetched <= d.report.bytes_fetched
        and res.node_traffic[d.node_id].bytes_total
        == d.report.bytes_delta_fetched
        for d in res.deployments)
    quarantined = set(res.quarantined_nodes)
    conv = {fleet.quarantine.quarantined_at[n] - t_mark
            for n in quarantined if n in fleet.quarantine.quarantined_at}
    conv_s = max(conv) if conv else float("nan")

    assert flagged["chunks"] > 0, "the liar was never asked for a stripe"
    assert committed == 0, \
        f"{committed} corrupt chunk(s) slipped past verify-on-receipt"
    assert identity_ok, res.summary()
    assert quarantined == set(liars), \
        f"expected quarantine of {liars}, got {sorted(quarantined)}"
    row = {
        "n_nodes": float(n_edges + 1),
        "liar_pct": 100.0 * N_LIARS / n_edges,
        "builds_ok": 1.0,
        "corrupt_chunks_rejected": float(res.corrupt_chunks_total),
        "corrupt_chunks_committed": float(committed),
        "corrupt_bytes_discarded": float(res.corrupt_bytes_total),
        "identity_ok": 1.0 if identity_ok else 0.0,
        "quarantined": float(len(quarantined)),
        "quarantine_convergence_s": conv_s,
        "peer_fallbacks": float(res.peer_fallbacks_total),
    }
    if not quiet:
        print(f"-- byzantine chaos ({n_edges + 1} nodes, "
              f"{row['liar_pct']:.0f}% lying peers)")
        print(f"   {res.corrupt_chunks_total} corrupt chunk(s) rejected, "
              f"{committed} committed, quarantined "
              f"{sorted(quarantined)} after {conv_s:.1f}s virtual "
              f"({res.peer_fallbacks_total} honest re-pulls)")
    return row


# ---------------------------------------------------------------------------
# attestation gate: tampered manifests die at plan time
# ---------------------------------------------------------------------------

def attestation_gate(service=None, quiet: bool = False) -> Dict[str, float]:
    """Sign a manifest, verify it through a require-attestation builder,
    then forge the signature: the forgery must be rejected *before any
    fetch is scheduled* (the upstream served-bytes counter is the
    witness)."""
    service = service or catalog.build_service()
    cir = PreBuilder(service).prebuild(ARCHS[ARCH], entrypoint="serve")
    spec = cpu_smoke()

    minter = LazyBuilder(service, signer=HMACSigner(SECRET))
    inst = minter.build(cir, spec, assemble=False)
    att = minter.attest(inst)

    verifier = LazyBuilder(service, signer=HMACSigner(SECRET),
                           require_attestation=True)
    ok = verifier.build_from_lock(cir, inst.lock, spec, assemble=False,
                                  attestation=att)
    assert ok.report.attestation_verified

    forged = dataclasses.replace(att, signature="0" * len(att.signature))
    gated = LazyBuilder(service, signer=HMACSigner(SECRET),
                        require_attestation=True)
    served_before = service.bytes_served
    try:
        gated.build_from_lock(cir, inst.lock, spec, assemble=False,
                              attestation=forged)
        rejected = 0.0
    except AttestationError:
        rejected = 1.0
    fetch_free = service.bytes_served == served_before \
        and not gated.store.digests()
    assert rejected == 1.0, "forged attestation was accepted"
    assert fetch_free, "the rejected build still scheduled a fetch"
    if not quiet:
        print(f"-- attestation gate: verified ok, forgery rejected at "
              f"plan time (0 bytes fetched)")
    return {"verified_ok": 1.0, "tamper_rejected": rejected,
            "fetch_free_reject": 1.0 if fetch_free else 0.0}


# ---------------------------------------------------------------------------
# SBOM emission (R-096): provenance rides the CI artifacts
# ---------------------------------------------------------------------------

def sbom_emission(service=None, path: Optional[str] = None,
                  quiet: bool = False) -> Dict[str, float]:
    """Emit the CycloneDX-shaped SBOM of the smoke CIR's resolved closure
    and pin its determinism (two emissions, byte-identical)."""
    service = service or catalog.build_service()
    cir = PreBuilder(service).prebuild(ARCHS[ARCH], entrypoint="serve")
    builder = LazyBuilder(service)
    inst = builder.build(cir, cpu_smoke(), assemble=False)
    sbom = builder.sbom(inst)
    assert sbom == builder.sbom(inst), "SBOM emission is not deterministic"
    path = path or os.environ.get("SBOM_PATH", "SBOM_smoke.json")
    write_sbom(path, sbom)
    if not quiet:
        print(f"-- sbom: {len(sbom['components'])} components -> {path}")
    return {"components": float(len(sbom["components"])),
            "deterministic": 1.0}


# ---------------------------------------------------------------------------

def write_bench_integrity(path: Optional[str] = None,
                          smoke: bool = False,
                          rows: Optional[Dict] = None) -> str:
    """Record the trust & integrity trajectory (CI artifact + the
    committed regression-gate baseline)."""
    path = path or os.environ.get("BENCH_INTEGRITY_PATH",
                                  "BENCH_integrity.json")
    if rows is None:
        rows = collect(smoke=smoke, quiet=True)
    payload = {
        "config": {
            "smoke": smoke,
            "arch": ARCH,
            "n_edges": N_EDGES,
            "n_liars": N_LIARS,
            "verify_ceiling_pct": VERIFY_OVERHEAD_CEILING_PCT,
        },
        "overhead": rows["overhead"],
        "chaos": rows["chaos"],
        "attestation": rows["attestation"],
        "sbom": rows["sbom"],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def collect(smoke: bool = False, quiet: bool = False,
            service=None, sbom_path: Optional[str] = None
            ) -> Dict[str, Dict]:
    """All phases; smoke trims the overhead arms to 3 repeats — the
    chaos and attestation scenarios ARE the claim and always run."""
    service = service or catalog.build_service()
    repeats = 3 if smoke else OVERHEAD_REPEATS
    return {
        "overhead": verify_overhead(service, repeats=repeats, quiet=quiet),
        "chaos": byzantine_chaos(service, quiet=quiet),
        "attestation": attestation_gate(service, quiet=quiet),
        "sbom": sbom_emission(service, path=sbom_path, quiet=quiet),
    }


def main(smoke: bool = False) -> List[str]:
    rows = collect(smoke=smoke, quiet=True)
    write_bench_integrity(smoke=smoke, rows=rows)
    ov, ch = rows["overhead"], rows["chaos"]
    return [
        csv_row(
            "integrity.verify_overhead", 0.0,
            f"overhead={ov['verify_overhead_raw_pct']:+.2f}%;"
            f"chunks={ov['chunks_verified']:.0f};"
            f"ceiling={VERIFY_OVERHEAD_CEILING_PCT:.0f}%"),
        csv_row(
            "integrity.byzantine_chaos", 0.0,
            f"liars={ch['liar_pct']:.0f}%;"
            f"rejected={ch['corrupt_chunks_rejected']:.0f};"
            f"committed={ch['corrupt_chunks_committed']:.0f};"
            f"quarantine={ch['quarantine_convergence_s']:.1f}s"),
    ]


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    rows = collect(smoke=smoke)
    out = write_bench_integrity(smoke=smoke, rows=rows)
    print(f"wrote {out}")
