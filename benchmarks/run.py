"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one line per table).
``--smoke`` runs the CI-sized variant of benchmarks that support one
(the churn suite skips its concurrent phase; the scale suite keeps the
full 200-node fan-out — that IS the smoke-time claim — but runs only the
hub-death fault scenario)."""
from __future__ import annotations

import inspect
import sys
import time
import traceback


def main(smoke: bool = False) -> None:
    from . import (bandwidth, build_time, churn, coldstart, cross_platform,
                   distribution, hetero, image_size, placement, roofline,
                   scale, sharing)
    mods = [image_size, build_time, bandwidth, cross_platform, sharing,
            distribution, churn, scale, coldstart, placement, hetero,
            roofline]
    print("name,us_per_call,derived")
    failures = 0
    for mod in mods:
        t0 = time.perf_counter()
        try:
            if smoke and "smoke" in inspect.signature(mod.main).parameters:
                rows = mod.main(smoke=True)
            else:
                rows = mod.main()
            dt_us = (time.perf_counter() - t0) * 1e6
            for row in rows:
                name, _, derived = row.split(",", 2)
                print(f"{name},{dt_us/max(len(rows),1):.1f},{derived}")
        except Exception:
            failures += 1
            print(f"{mod.__name__},0,FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
