"""Peer-to-peer chunk distribution across a fleet topology — the sky/edge
fan-out scenario (paper §1's "cloud-edge continuum" deployment, made
measurable).

One CIR is deployed to 1 cloud seed + N edge nodes.  Every node has its own
chunk store; the cloud's registry link is fat, the edges' registry links are
thin, but cloud↔edge and edge↔edge peer links are fast.  With peer
distribution on, the cloud pulls the content once from upstream and the
edges source their chunks from the cloud (and from each other, mid-build,
via commit-time announcements) — total upstream wire bytes approach the
1-node cost instead of scaling with N.  The no-peer baseline runs the exact
same per-node plumbing with source selection forced upstream, so per-node
chunk accounting is byte-identical between the two runs and the comparison
isolates *where* bytes came from, which is the entire claim.

Wall-clock columns deploy again with per-link simulated sleeps (bandwidths
scaled so the suite stays CI-sized; the ratios, not the absolute seconds,
are the measurement).

Writes ``BENCH_distribution.json`` (CI artifact + regression-gate baseline;
see ``benchmarks.check_regression``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
from typing import Dict, List, Optional, Sequence

from repro.configs import ARCHS
from repro.core import PreBuilder, catalog, cpu_smoke, tpu_single_pod
from repro.deploy import FleetDeployer, FleetTopology

from .common import SMOKE_ARCHS as _SMOKE_ARCHS, csv_row

# Simulated link speeds for the wall-clock columns (bytes/s): exactly the
# ``FleetTopology.edge_fanout`` real-world shape (10 Gbps cloud uplink /
# 50 Mbps edge uplink / 1-2 Gbps peering), uniformly scaled 200x so
# multi-GB suites finish in CI-sized wall time — one factor for every
# link, so the measured ratios transfer to the real shape.
SIM_SCALE = 200.0
SIM_CLOUD_UPSTREAM_BPS = 1.25e9 * SIM_SCALE
SIM_EDGE_UPSTREAM_BPS = 6.25e6 * SIM_SCALE
SIM_CLOUD_EDGE_BPS = 125e6 * SIM_SCALE
SIM_EDGE_EDGE_BPS = 2.5e8 * SIM_SCALE

# Acceptance floor: with 1 cloud + 4 edges, peer distribution must cut total
# upstream wire bytes to at most this fraction of the no-peer baseline.
UPSTREAM_VS_BASELINE_CEILING_PCT = 40.0


def _fanout_topology(n_edges: int, simulate: bool) -> FleetTopology:
    if simulate:
        return FleetTopology.edge_fanout(
            n_edges,
            cloud_upstream_bps=SIM_CLOUD_UPSTREAM_BPS,
            edge_upstream_bps=SIM_EDGE_UPSTREAM_BPS,
            cloud_edge_bps=SIM_CLOUD_EDGE_BPS,
            edge_edge_bps=SIM_EDGE_EDGE_BPS)
    return FleetTopology.edge_fanout(n_edges)


def _edge_specs(n_edges: int):
    return [dataclasses.replace(cpu_smoke(), platform_id=f"edge-host-{i}")
            for i in range(n_edges)]


def _deploy_fanout(arch_id: str, n_edges: int, use_peers: bool,
                   simulate: bool = False) -> Dict[str, Dict]:
    """One full fan-out: cloud seed deploys first (its content is what the
    edges will peer from), then every edge concurrently.  Returns per-node
    traffic and accounting columns plus fleet walls."""
    svc = catalog.build_service()
    pb = PreBuilder(svc)
    cir = pb.prebuild(ARCHS[arch_id], entrypoint="serve")
    topo = _fanout_topology(n_edges, simulate)
    cloud_spec = tpu_single_pod()
    edge_specs = _edge_specs(n_edges)
    topo.place(cloud_spec.platform_id, "cloud")
    for i, s in enumerate(edge_specs):
        topo.place(s.platform_id, f"edge-{i}")
    fd = FleetDeployer(svc, topology=topo, use_peers=use_peers,
                       simulate_links=simulate)
    seed_res = fd.deploy(cir, [cloud_spec])
    assert seed_res.ok, seed_res.summary()
    edge_res = fd.deploy(cir, edge_specs)
    assert edge_res.ok, edge_res.summary()

    per_node: Dict[str, Dict] = {}
    for res in (seed_res, edge_res):
        for d in res.deployments:
            t = res.node_traffic[d.node_id]
            assert t.bytes_total == d.report.bytes_delta_fetched, \
                f"{d.node_id}: wire split {t.bytes_total} != delta " \
                f"{d.report.bytes_delta_fetched}"
            assert d.report.bytes_delta_fetched <= d.report.bytes_fetched, \
                f"{d.node_id}: delta exceeds component fetch bytes"
            per_node[d.node_id] = {
                "bytes_from_upstream": t.bytes_from_upstream,
                "bytes_from_peers": t.bytes_from_peers,
                "peer_sources": dict(t.peer_sources),
                "peer_fallbacks": t.peer_fallbacks,
                "bytes_delta_fetched": d.report.bytes_delta_fetched,
                "bytes_fetched": d.report.bytes_fetched,
                "chunks_hit": d.report.chunks_hit,
                "chunks_missed": d.report.chunks_missed,
            }
    upstream = sum(n["bytes_from_upstream"] for n in per_node.values())
    peer = sum(n["bytes_from_peers"] for n in per_node.values())
    return {
        "per_node": per_node,
        "upstream_bytes": upstream,
        "peer_bytes": peer,
        "peer_offload_ratio": peer / (upstream + peer)
        if upstream + peer else 0.0,
        "peer_fallbacks": sum(n["peer_fallbacks"] for n in per_node.values()),
        "seed_wall_s": seed_res.wall_s,
        "edge_wall_s": edge_res.wall_s,
        "edge_ready_s_wall": edge_res.ready_s_wall,
    }


def edge_fanout(archs: Sequence[str] = _SMOKE_ARCHS, n_edges: int = 4,
                quiet: bool = False) -> Dict[str, Dict]:
    """The headline scenario: byte accounting with peers vs the no-peer
    baseline (identical per-node chunk columns required), then the same
    fan-out again on simulated links for the wall-clock ratio."""
    rows: Dict[str, Dict] = {}
    for arch_id in archs:
        peer = _deploy_fanout(arch_id, n_edges, use_peers=True)
        base = _deploy_fanout(arch_id, n_edges, use_peers=False)
        # source selection moves bytes between links; it must not change
        # what each node fetches
        acct = ("bytes_delta_fetched", "bytes_fetched", "chunks_hit",
                "chunks_missed")
        for node, cols in peer["per_node"].items():
            for f in acct:
                assert cols[f] == base["per_node"][node][f], \
                    f"{arch_id}/{node}: {f} differs peer={cols[f]} " \
                    f"baseline={base['per_node'][node][f]}"
        sim_peer = _deploy_fanout(arch_id, n_edges, use_peers=True,
                                  simulate=True)
        sim_base = _deploy_fanout(arch_id, n_edges, use_peers=False,
                                  simulate=True)
        ratio_pct = 100.0 * peer["upstream_bytes"] / base["upstream_bytes"]
        rows[arch_id] = {
            "n_edges": n_edges,
            "upstream_bytes_peer": peer["upstream_bytes"],
            "upstream_bytes_baseline": base["upstream_bytes"],
            "upstream_vs_baseline_pct": ratio_pct,
            "peer_bytes": peer["peer_bytes"],
            "peer_offload_ratio": peer["peer_offload_ratio"],
            "peer_fallbacks": peer["peer_fallbacks"],
            "per_node_accounting_identical": True,
            "per_node": peer["per_node"],
            "sim_edge_wall_peer_s": sim_peer["edge_wall_s"],
            "sim_edge_wall_baseline_s": sim_base["edge_wall_s"],
            "sim_edge_wall_reduction_pct": 100.0 * (
                1 - sim_peer["edge_wall_s"]
                / max(sim_base["edge_wall_s"], 1e-12)),
        }
        assert ratio_pct <= UPSTREAM_VS_BASELINE_CEILING_PCT, \
            f"{arch_id}: peer distribution left {ratio_pct:.1f}% of " \
            f"baseline upstream bytes on the registry link " \
            f"(ceiling {UPSTREAM_VS_BASELINE_CEILING_PCT}%)"
    if not quiet:
        print(f"-- edge fan-out (1 cloud seed + {n_edges} edge nodes, "
              f"serve CIRs)")
        print(f"{'arch':24s} {'base upstr':>10s} {'peer upstr':>10s} "
              f"{'ratio':>6s} {'offload':>8s} {'sim wall':>15s}")
        for a, r in rows.items():
            print(f"{a:24s} {r['upstream_bytes_baseline']/2**30:>8.2f} G "
                  f"{r['upstream_bytes_peer']/2**30:>8.2f} G "
                  f"{r['upstream_vs_baseline_pct']:>5.1f}% "
                  f"{r['peer_offload_ratio']*100:>7.1f}% "
                  f"{r['sim_edge_wall_baseline_s']:>6.2f}s"
                  f"->{r['sim_edge_wall_peer_s']:.2f}s")
        avg = sum(r["upstream_vs_baseline_pct"] for r in rows.values()) \
            / len(rows)
        print(f"avg upstream wire vs no-peer baseline: {avg:.1f}%   "
              f"(ceiling {UPSTREAM_VS_BASELINE_CEILING_PCT}%; ideal "
              f"{100.0 / (n_edges + 1):.1f}% at N={n_edges})")
    return rows


def fanout_sweep(arch_id: str = "starcoder2-3b",
                 edge_counts: Sequence[int] = (2, 4, 8),
                 quiet: bool = False) -> Dict[int, Dict]:
    """Upstream bytes vs N: with peers the total stays near the 1-node
    cost, so the per-node upstream share drops near-linearly with N."""
    rows: Dict[int, Dict] = {}
    for n in edge_counts:
        peer = _deploy_fanout(arch_id, n, use_peers=True)
        base = _deploy_fanout(arch_id, n, use_peers=False)
        rows[n] = {
            "upstream_bytes_peer": peer["upstream_bytes"],
            "upstream_bytes_baseline": base["upstream_bytes"],
            "upstream_vs_baseline_pct": 100.0 * peer["upstream_bytes"]
            / base["upstream_bytes"],
            "peer_offload_ratio": peer["peer_offload_ratio"],
        }
    if not quiet:
        print(f"-- fan-out sweep ({arch_id}): upstream bytes vs edge count")
        for n, r in rows.items():
            base_g = r["upstream_bytes_baseline"] / 2**30
            peer_g = r["upstream_bytes_peer"] / 2**30
            print(f"  N={n:2d}  baseline={base_g:6.2f} G  "
                  f"peers={peer_g:6.2f} G "
                  f"({r['upstream_vs_baseline_pct']:.1f}%)")
    return rows


def write_bench_distribution(path: Optional[str] = None,
                             smoke: bool = False,
                             rows: Optional[Dict] = None,
                             sweep: Optional[Dict] = None) -> str:
    """Record the distribution trajectory (CI artifact + the committed
    regression-gate baseline)."""
    path = path or os.environ.get("BENCH_DISTRIBUTION_PATH",
                                  "BENCH_distribution.json")
    if rows is None:
        rows = edge_fanout(quiet=True)
    if sweep is None and not smoke:
        sweep = fanout_sweep(quiet=True)
    payload = {
        "config": {
            "smoke": smoke, "n_edges": 4,
            "sim_bps": {"cloud_upstream": SIM_CLOUD_UPSTREAM_BPS,
                        "edge_upstream": SIM_EDGE_UPSTREAM_BPS,
                        "cloud_edge": SIM_CLOUD_EDGE_BPS,
                        "edge_edge": SIM_EDGE_EDGE_BPS},
        },
        "edge_fanout": rows,
        "avg_peer_offload_ratio": sum(
            r["peer_offload_ratio"] for r in rows.values()) / len(rows),
        "avg_upstream_vs_baseline_pct": sum(
            r["upstream_vs_baseline_pct"] for r in rows.values()) / len(rows),
    }
    if sweep is not None:
        payload["fanout_sweep"] = sweep
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def main() -> List[str]:
    rows = edge_fanout(quiet=True)
    sweep = fanout_sweep(quiet=True)
    write_bench_distribution(rows=rows, sweep=sweep)
    avg_ratio = sum(r["upstream_vs_baseline_pct"] for r in rows.values()) \
        / len(rows)
    avg_off = sum(r["peer_offload_ratio"] for r in rows.values()) / len(rows)
    return [
        csv_row("distribution.edge_fanout", 0.0,
                f"upstream_vs_baseline={avg_ratio:.1f}%;"
                f"peer_offload={avg_off * 100:.1f}%;"
                f"sweep_n8={sweep[8]['upstream_vs_baseline_pct']:.1f}%"),
    ]


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    rows = edge_fanout()
    print()
    sweep = None
    if not smoke:
        sweep = fanout_sweep()
        print()
    out = write_bench_distribution(smoke=smoke, rows=rows, sweep=sweep)
    print(f"wrote {out}")
