"""Shared cost model + helpers for the benchmark suite.

Wall-clock components we can MEASURE offline (resolution, fetch
bookkeeping, assembly) are measured; network transfer is byte-accounted and
simulated at a parameterized link bandwidth (the paper's 10–1000 Mbps
sweeps); the conventional builder's package-install work is MODELED with
documented constants calibrated against the paper's own observations:

  INSTALL_BPS  — 20 MB/s: pip/dpkg download-unpack-compile throughput.
    The paper's Fig 7 shows a persistent ~100 s Docker-vs-CIR gap that
    bandwidth cannot remove, on ~2 GB of packages → ~20 MB/s.
  UNPACK_BPS   — 150 MB/s: layer-by-layer image unpacking (paper §2:
    at high bandwidth, deployment is limited by sequential unpacking).

The conventional ("docker-like") build of one application:
  pull base env bytes → for each manager group, sequentially download and
  install its components (no cross-manager parallelism — paper Fig 3).
The CIR path: pre-build (measured) → push CIR → lazy-build = max(resolve,
parallel fetch of missing components) + assemble (components are
pre-compiled, so no install stage).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.configs import ARCHS
from repro.core import (CIR, ChunkedComponentStore, LazyBuilder,
                        LocalComponentStore, PreBuilder, SpecSheet,
                        tpu_single_pod)
from repro.core import catalog

INSTALL_BPS = 20e6
UNPACK_BPS = 150e6

MBPS = 1e6 / 8  # bytes/s per Mbps

# Reduced arch set for CI benchmark smoke runs (one per weight scale)
SMOKE_ARCHS = ("gemma2-9b", "starcoder2-3b", "phi4-mini-3.8b")


def fresh_builder(link_mbps: float = 500.0, host_spec: Optional[SpecSheet]
                  = None, fetch_workers: int = 8,
                  fetch_simulate_bps: Optional[float] = None
                  ) -> Tuple[LazyBuilder, PreBuilder]:
    svc = catalog.build_service()
    lb = LazyBuilder(svc, ChunkedComponentStore(),
                     link_bandwidth_bps=link_mbps * 1e6,
                     fetch_workers=fetch_workers,
                     fetch_simulate_bps=fetch_simulate_bps)
    if host_spec is not None:
        seed_host_components(lb, host_spec)
    return lb, PreBuilder(svc)


def bump_asset_version(service, arch_id: str,
                       new_version: str = "2025.12.2") -> str:
    """Simulate an upstream weight refresh: re-register the newest weights
    component of ``arch_id`` under a bumped version (same size, same name).
    Chunk ids of the shared fraction survive the bump, so a re-deploy
    fetches only the delta."""
    name = f"weights-{arch_id}"
    versions = service.vq("asset", name)     # pulls the upstream if needed
    latest = versions[-1]
    for env in service.registry.eq("asset", name, latest):
        c = service.registry.cq("asset", name, latest, env)
        service.registry.register(dataclasses.replace(
            c, version=new_version, context={f"weights.{arch_id}": new_version}))
    return new_version


def seed_host_components(lb: LazyBuilder, spec: SpecSheet) -> None:
    """Deployment platforms come with their accelerator runtime installed
    (TPU VMs ship libtpu; the paper reuses host GPU libs via
    libnvidia-container).  The lazy-builder therefore treats the platform's
    ``env`` components as locally cached; conventional images must bundle
    them."""
    for c in lb.service.registry.all_components():
        if c.manager != "env":
            continue
        if not c.requires or c.env_satisfied(spec.context()):
            lb.store.put(c)


@dataclasses.dataclass
class ConventionalModel:
    """Docker/Buildah/Apptainer-analog timings for one application."""
    image_bytes: int                  # full platform-specific image
    package_bytes: int                # compressed packages to install
    base_bytes: int                   # base image (env components)
    weight_bytes: int
    squashfs_penalty: float = 0.0     # apptainer-style CPU compression

    def build_time(self, bw_bps: float, cores: int = 4) -> float:
        """Sequential: pull base, then per-group download+install.  The
        install stage covers the runtime env too (pip install jax[tpu] /
        apt — what the CIR converters did once, offline)."""
        t = self.base_bytes / bw_bps
        t += self.package_bytes / bw_bps            # serialized downloads
        t += (self.package_bytes + self.base_bytes) \
            / (INSTALL_BPS * max(cores, 1) / 4)
        t += self.weight_bytes / bw_bps
        t += self.squashfs_penalty * self.image_bytes / (INSTALL_BPS *
                                                         max(cores, 1))
        return t

    def push_time(self, bw_bps: float) -> float:
        return self.image_bytes / bw_bps

    def pull_time(self, bw_bps: float) -> float:
        return self.image_bytes / bw_bps + self.image_bytes / UNPACK_BPS


def conventional_for(cir: CIR, lb: LazyBuilder, spec: SpecSheet
                     ) -> ConventionalModel:
    """Derive the conventional image's composition from the SAME resolved
    component set the lazy-builder uses (identical content, different
    packaging) — the CIR-locked comparison of §5.4."""
    inst = lb.build(cir, spec, assemble=False)
    comps = inst.bundle.components()
    base = sum(c.size_bytes for c in comps if c.manager == "env")
    weights = sum(c.size_bytes for c in comps if c.manager == "asset")
    packages = sum(c.size_bytes for c in comps
                   if c.manager not in ("env", "asset"))
    return ConventionalModel(
        image_bytes=base + weights + packages,
        package_bytes=packages, base_bytes=base, weight_bytes=weights)


def lazy_deploy_time(report, bw_bps: float) -> float:
    """Paper's lazy-build deployment: CIR pull + parallel delta fetch
    overlapped with resolution, then assembly (no install — components are
    pre-compiled).  Wire bytes are chunk-delta bytes when the chunk store
    served the build.  Orchestrated builds additionally credit the
    *measured* stage overlap (assemble/jit running under the asset tail);
    compile_s is in the stage sum because overlap_s may include it."""
    net = (report.bytes_cir + report.bytes_wire_fetched) / bw_bps
    stage_sum = report.fetch_s + report.assemble_s + report.compile_s
    overlap = min(getattr(report, "overlap_s", 0.0), stage_sum)
    return max(report.resolve_s, net) + stage_sum - overlap


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
