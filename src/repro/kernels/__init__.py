"""Pallas TPU kernels for the perf-critical compute layers.

<name>.py   — pl.pallas_call + BlockSpec kernel (TPU target)
ops.py      — jit'd wrappers matching the model-layer kernel interfaces
ref.py      — pure-jnp oracles the tests assert against
"""
from .ops import (pallas_attention, pallas_rmsnorm, pallas_wkv6,  # noqa: F401
                  set_interpret)
