"""Pallas TPU flash attention (GQA, causal, sliding-window, logit softcap).

Design (TPU-native, not a CUDA port):
  * grid = (batch, q_heads, nq, nk) — the kv dimension is innermost, so the
    online-softmax state (m, l, acc) lives in VMEM scratch and persists
    across the kv loop; the output block is written once, on the last kv
    step (the canonical TPU flash pattern).
  * BlockSpec tiles: q/out (1, 1, block_q, d), k/v (1, 1, block_k, d) — the
    working set is 2·bq·d + 2·bk·d + bq·bk floats, sized to fit VMEM with
    MXU-aligned (multiples of 128) matmul dims.
  * GQA is handled by the k/v index_map (query head → kv head, ih // g):
    no K/V replication in HBM, the MXU sees one query head per step.
  * Causal + sliding-window blocks that are fully masked are *skipped*
    (pl.when), so the kernel does ~half the matmuls of the dense version
    and a window kernel touches only O(window/block_k) kv blocks per row.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  block_q: int, block_k: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level mask culling: skip kv blocks that cannot contribute
    q_lo = iq * block_q
    q_hi = q_lo + block_q - 1
    k_lo = ik * block_k
    k_hi = k_lo + block_k - 1
    live = jnp.bool_(True)
    if causal:
        live &= q_hi >= k_lo                 # some query sees this kv block
    if window:
        live &= q_lo - k_hi < window         # block not entirely out-of-window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0, 0, :, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "softcap",
                     "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, scale: float, causal: bool = True,
                    window: int = 0, softcap: float = 0.0,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = True):
    """q: (b, hq, sq, d); k/v: (b, hkv, skv, d).  Returns (b, hq, sq, d)."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    dv = v.shape[-1]            # MLA: v head dim may differ from qk head dim
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    nq, nk = sq // bq, skv // bk

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=bq, block_k=bk, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, dv),
                         lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dv),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, dv), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max m
            pltpu.VMEM((bq,), jnp.float32),       # running denom l
            pltpu.VMEM((bq, dv), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
