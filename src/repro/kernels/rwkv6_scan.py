"""Pallas TPU kernel for the chunked WKV6 recurrence (RWKV6 'Finch').

TPU adaptation of the (GPU, element-parallel) official kernel: instead of one
thread per channel running the recurrence serially, the sequence is split
into chunks of L tokens.  Within a chunk everything is (L, K)/(L, V) matmuls
on the MXU; across chunks only the (K, V) state is carried — it lives in
VMEM scratch and persists over the sequential chunk grid dimension.

    y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T         with 0 < w < 1

Every exponential computed here has exponent ≤ 0 (decays multiply), so the
chunked form is overflow-safe in f32 regardless of sequence length.

grid = (batch, heads, n_chunks); chunk dim is innermost/sequential.
Blocks: r/k/w (1, 1, L, K), v (1, 1, L, V), u (1, K) per head,
state scratch (K, V) f32.  L defaults to 64 — MXU-aligned, and the
(L, L) intra-chunk matrix stays tiny in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                 y_ref, sout_ref, S_scr, *, L: int, nchunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        S_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)           # (L, K)
    k = k_ref[0, 0].astype(jnp.float32)           # (L, K)
    v = v_ref[0, 0].astype(jnp.float32)           # (L, V)
    lw = lw_ref[0, 0].astype(jnp.float32)         # (L, K) log-decay (≤ 0)
    u = u_ref[0].astype(jnp.float32)              # (K,)
    S = S_scr[...]                                 # (K, V)

    sw = jnp.cumsum(lw, axis=0) - lw              # exclusive cumsum
    sw_end = sw[-1] + lw[-1]                      # total chunk decay (K,)

    # intra-chunk: exponent(t, j, k) = sw_t - sw_j - lw_j  (≤ 0 for j < t)
    expo = sw[:, None, :] - sw[None, :, :] - lw[None, :, :]
    ti = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    tri = (tj < ti)[:, :, None]                   # strictly causal
    decay = jnp.where(tri, jnp.exp(jnp.minimum(expo, 0.0)), 0.0)
    A = jnp.einsum("tk,jk,tjk->tj", r, k, decay)  # (L, L)
    y = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # current-token bonus: diag(u)
    y += jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True) * v
    # inter-chunk: query the carried state
    q = r * jnp.exp(sw)
    y += jax.lax.dot_general(q, S, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    # state update: S' = diag(exp(sw_end)) S + Σ_j (k_j · e^{sw_end-sw_j-lw_j}) v_j^T
    k2 = k * jnp.exp(sw_end[None, :] - sw - lw)
    S_new = jnp.exp(sw_end)[:, None] * S + jax.lax.dot_general(
        k2, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    S_scr[...] = S_new
    y_ref[0, 0, :, :] = y.astype(y_ref.dtype)

    @pl.when(ic == nchunks - 1)
    def _final():
        sout_ref[0, 0, :, :] = S_new


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, w, u, state=None, *, chunk: int = 64,
                interpret: bool = True):
    """r,k,w: (b, h, s, K); v: (b, h, s, V); u: (h, K).
    Returns (y (b, h, s, V), final_state (b, h, K, V) f32)."""
    b, h, s, K = r.shape
    V = v.shape[-1]
    L = min(chunk, s)
    assert s % L == 0, (s, L)
    n = s // L
    if state is None:
        state = jnp.zeros((b, h, K, V), jnp.float32)
    lw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38))

    kernel = functools.partial(_wkv6_kernel, L=L, nchunks=n)
    y, s_out = pl.pallas_call(
        kernel,
        grid=(b, h, n),
        in_specs=[
            pl.BlockSpec((1, 1, L, K), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, L, K), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, L, V), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, L, K), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, K), lambda ib, ih, ic: (ih, 0)),
            pl.BlockSpec((1, 1, K, V), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, V), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, K, V), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, V), v.dtype),
            jax.ShapeDtypeStruct((b, h, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u, state)
    return y, s_out
