"""Pallas TPU fused RMSNorm.

Bandwidth-bound fusion: one HBM read of x, one write of y — versus the
unfused square/mean/rsqrt/mul chain that XLA may materialize in between.
Rows are tiled (block_rows, d); the weight block is broadcast to every row
block via a constant index_map.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float, plus_one: bool):
    x = x_ref[...].astype(jnp.float32)                 # (br, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = w_ref[...].astype(jnp.float32)
    scale = (1.0 + w) if plus_one else w
    o_ref[...] = (y * scale[None, :]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("eps", "plus_one", "block_rows", "interpret"))
def rmsnorm_pallas(x, w, *, eps: float = 1e-6, plus_one: bool = False,
                   block_rows: int = 256, interpret: bool = True):
    """x: (..., d); w: (d,)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n = x2.shape[0] // br

    kernel = functools.partial(_rmsnorm_kernel, eps=eps, plus_one=plus_one)
    y = pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, w)
    if pad:
        y = y[:rows]
    return y.reshape(orig_shape)
