"""jit'd wrappers adapting the Pallas kernels to the model-layer interfaces.

These are the payloads of the ``kernel/*`` uniform components with
``env='tpu-pallas'`` / ``env='cpu-interpret'``: the lazy-builder's
environment selection decides whether the model's ATTN_KERNELS /
WKV_IMPLS slots point here (Pallas) or to the lax/jnp variants.

On a backend without a TPU, ``interpret=True`` executes the kernel body in
Python via the Pallas interpreter — bit-accurate for correctness tests,
useless for speed; that asymmetry is exactly the deployability trade-off
Algorithm 1 scores.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .rwkv6_scan import wkv6_pallas

_INTERPRET = True   # flipped by the catalog when specSheet.backend == 'tpu'


def set_interpret(value: bool) -> None:
    global _INTERPRET
    _INTERPRET = bool(value)


def _pad_to(x, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if not pad:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def pallas_attention(q, k, v, *, scale, causal=True, window=0, softcap=0.0,
                     q_offset=0, kv_len=None, block_q=512, block_k=512):
    """ATTN_KERNELS-compatible wrapper around the Pallas flash kernel.

    Falls back to the blocked-lax path for ragged decode shapes (q_offset /
    kv_len), which the train/prefill kernel does not model.
    """
    if q_offset != 0 or kv_len is not None:
        from ..models.attention import lax_flash_attention
        return lax_flash_attention(q, k, v, scale=scale, causal=causal,
                                   window=window, softcap=softcap,
                                   q_offset=q_offset, kv_len=kv_len)
    sq, skv = q.shape[2], k.shape[2]
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    if sq % bq or skv % bk:
        from ..models.attention import naive_attention
        return naive_attention(q, k, v, scale=scale, causal=causal,
                               window=window, softcap=softcap)
    return flash_attention(q, k, v, scale=scale, causal=causal,
                           window=window, softcap=softcap,
                           block_q=bq, block_k=bk, interpret=_INTERPRET)


def pallas_wkv6(r, k, v, w, u, state=None, chunk: int = 64):
    """WKV_IMPLS-compatible wrapper; sequential fallback for odd lengths."""
    s = r.shape[2]
    if s % min(chunk, s):
        from ..models.ssm import wkv6_sequential
        return wkv6_sequential(r, k, v, w, u, state)
    y, s_out = wkv6_pallas(r, k, v, w, u, state,
                           chunk=min(chunk, s), interpret=_INTERPRET)
    return y, s_out


def pallas_rmsnorm(x, w, eps: float = 1e-6, plus_one: bool = False):
    from .rmsnorm import rmsnorm_pallas
    return rmsnorm_pallas(x, w, eps=eps, plus_one=plus_one,
                          interpret=_INTERPRET)
