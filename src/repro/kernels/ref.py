"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *correctness* definitions: small, obvious, unblocked.  The
kernel tests sweep shapes/dtypes and assert_allclose kernel-vs-ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


# ---------------------------------------------------------------------------
# Attention (GQA, causal, optional sliding window + logit softcap)
# ---------------------------------------------------------------------------

def attention_ref(q, k, v, *, scale: float, causal: bool = True,
                  window: int = 0, softcap: float = 0.0):
    """q: (b, hq, sq, d); k/v: (b, hkv, skv, d) with hq % hkv == 0."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    dv = v.shape[-1]            # MLA: v head dim may differ from qk head dim
    g = hq // hkv
    qf = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, dv).astype(v.dtype)


# ---------------------------------------------------------------------------
# WKV6 (RWKV 'Finch' recurrence with data-dependent decay)
# ---------------------------------------------------------------------------

def wkv6_ref(r, k, v, w, u, state=None):
    """Exact sequential recurrence.

    r,k,w: (b, h, s, K); v: (b, h, s, V); u: (h, K); state: (b, h, K, V).
    y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    b, h, s, K = r.shape
    V = v.shape[-1]
    S = (jnp.zeros((b, h, K, V), jnp.float32) if state is None
         else state.astype(jnp.float32))
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    uf = u.astype(jnp.float32)[None]
    ys = []
    for t in range(s):
        kv = kf[:, :, t, :, None] * vf[:, :, t, None, :]
        y = jnp.einsum("bhk,bhkv->bhv", rf[:, :, t], S + uf[..., None] * kv)
        ys.append(y)
        S = wf[:, :, t, :, None] * S + kv
    return jnp.stack(ys, axis=2).astype(v.dtype), S


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_ref(x, w, *, eps: float = 1e-6, plus_one: bool = False):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (y * scale).astype(x.dtype)
