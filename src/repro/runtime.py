"""Fault-tolerant training runtime.

The driver owns the full loop: data → step → metrics → checkpoint, plus the
three failure paths a 1000+-node deployment hits daily:

  * node failure   — any exception from the step (or injected
    ``SimulatedFailure``) triggers restart-from-checkpoint; the lockfile
    guarantees the re-assembled container is bit-identical (paper §3.3).
  * stragglers     — a per-step deadline (k × trailing-median step time);
    overruns are counted and surface in metrics, standing in for the
    re-dispatch a real multi-host scheduler would do.
  * elastic rescale — the paper's own story: the *same CIR* is lazily
    re-built for the surviving mesh (new specSheet), and the checkpoint is
    restored with the new sharding (reshard-on-restore).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .checkpoint import CheckpointManager
from .data import DataConfig, SyntheticPipeline


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests/chaos benchmarks)."""


@dataclasses.dataclass
class RuntimeConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    keep_checkpoints: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0     # deadline = factor × median step time
    straggler_grace: int = 5          # steps before the watchdog arms
    max_restarts: int = 8


@dataclasses.dataclass
class RunResult:
    steps_done: int
    final_loss: float
    losses: List[float]
    restarts: int
    straggler_events: int
    wall_s: float


class TrainDriver:
    def __init__(self, *, train_step: Callable, init_state: Callable,
                 batch_fn: Callable[[int], Mapping[str, Any]],
                 ckpt_dir: str, cfg: Optional[RuntimeConfig] = None,
                 failure_hook: Optional[Callable[[int], None]] = None):
        """``train_step(state, batch) -> (state, metrics)`` (jitted outside);
        ``init_state()`` builds the step-0 state; ``batch_fn(step)`` is the
        stateless data pipeline; ``failure_hook(step)`` may raise."""
        self.train_step = train_step
        self.init_state = init_state
        self.batch_fn = batch_fn
        self.cfg = cfg or RuntimeConfig()
        self.ckpt = CheckpointManager(ckpt_dir, keep=self.cfg.keep_checkpoints)
        self.failure_hook = failure_hook
        self.restarts = 0
        self.straggler_events = 0

    # ------------------------------------------------------------------
    def _resume(self, shardings=None) -> Tuple[int, Any]:
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0, self.init_state()
        step, state, _ = self.ckpt.restore(latest, shardings=shardings)
        return step, state

    def run(self, shardings=None) -> RunResult:
        cfg = self.cfg
        t_start = time.perf_counter()
        losses: List[float] = []
        step_times: List[float] = []
        attempt = 0
        while True:
            try:
                step, state = self._resume(shardings)
                while step < cfg.total_steps:
                    if self.failure_hook is not None:
                        self.failure_hook(step)
                    batch = self.batch_fn(step)
                    t0 = time.perf_counter()
                    state, metrics = self.train_step(state, batch)
                    loss = float(jax.device_get(metrics["loss"]))
                    dt = time.perf_counter() - t0
                    step_times.append(dt)
                    # straggler watchdog
                    if len(step_times) > cfg.straggler_grace:
                        med = statistics.median(step_times[-50:])
                        if dt > cfg.straggler_factor * med:
                            self.straggler_events += 1
                    losses.append(loss)
                    step += 1
                    if step % cfg.checkpoint_every == 0 \
                            or step == cfg.total_steps:
                        self.ckpt.save(step, state)
                self.ckpt.wait()
                return RunResult(
                    steps_done=step, final_loss=losses[-1] if losses else
                    float("nan"), losses=losses, restarts=self.restarts,
                    straggler_events=self.straggler_events,
                    wall_s=time.perf_counter() - t_start)
            except SimulatedFailure:
                attempt += 1
                self.restarts += 1
                if attempt > cfg.max_restarts:
                    raise
                # restart: fall through to _resume() from latest checkpoint
                continue


# ---------------------------------------------------------------------------
# Elastic rescale: same CIR, new platform → rebuild + reshard-restore
# ---------------------------------------------------------------------------

def elastic_rescale(builder, cir, lock, new_spec, new_mesh, ckpt_dir: str,
                    state_shardings_fn: Callable[[Any, Any], Any]):
    """Re-lazy-build ``cir`` for ``new_spec`` and restore the latest
    checkpoint with the new platform's shardings.

    Returns (container, step, state).  ``state_shardings_fn(container,
    mesh)`` maps the rebuilt container to the new state sharding pytree.
    """
    container = builder.build(cir, new_spec, mesh=new_mesh)
    mgr = CheckpointManager(ckpt_dir)
    shardings = state_shardings_fn(container, new_mesh)
    step, state, _ = mgr.restore(shardings=shardings)
    return container, step, state
