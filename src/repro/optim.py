"""Optimizer substrate: AdamW with sharded/quantized moments, cosine+warmup
schedule, global-norm clipping, microbatch accumulation, and int8
error-feedback gradient compression.

Distributed-optimization notes (1000+-node posture):
  * ZeRO-1: moment tensors get an extra batch-axis sharding via
    ``sharding.zero1_axes`` — the optimizer state never replicates.
  * 8-bit moments (block-wise absmax quantization, 128-wide blocks) cut
    optimizer HBM 4x — what makes deepseek-v3-scale training fit per chip.
  * int8 error-feedback compression bounds the bytes a cross-pod (DCI)
    gradient exchange would move; the quantization error is carried forward
    so the update stays unbiased in the long run (EF-SGD style).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


# ---------------------------------------------------------------------------
# Block-wise int8 quantization (moments + gradient compression)
# ---------------------------------------------------------------------------

_BLOCK = 128


def _q8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (f32) -> (int8 codes shaped LIKE x, f32 block scales).

    Blocks run along the LAST dim only, so the codes tensor keeps the
    param's shape — and therefore its sharding.  (A flattened layout breaks
    the moment↔param sharding correspondence and forces SPMD into full
    rematerialization — measured at 4.9 TiB/device temps on deepseek-v3.)
    """
    xf = x.astype(jnp.float32)
    orig_shape = xf.shape
    if xf.ndim == 0:
        xf = xf[None]
    last = xf.shape[-1]
    pad = (-last) % _BLOCK
    if pad:
        widths = [(0, 0)] * (xf.ndim - 1) + [(0, pad)]
        xp = jnp.pad(xf, widths)
    else:
        xp = xf
    nblk = xp.shape[-1] // _BLOCK
    blk = xp.reshape(xp.shape[:-1] + (nblk, _BLOCK))
    scale = jnp.max(jnp.abs(blk), axis=-1) / 127.0          # (..., nblk)
    codes = jnp.round(blk / jnp.maximum(scale[..., None], 1e-12))
    codes = codes.reshape(xp.shape).astype(jnp.int8)
    if pad:
        codes = codes[..., :last]
    codes = codes.reshape(orig_shape)
    if not orig_shape:
        scale = scale.reshape(())
    return codes, scale


def _dq8(codes: jax.Array, scale: jax.Array, shape) -> jax.Array:
    cf = codes.astype(jnp.float32)
    if cf.ndim == 0:
        return (cf * scale).reshape(shape)
    last = cf.shape[-1]
    pad = (-last) % _BLOCK
    if pad:
        widths = [(0, 0)] * (cf.ndim - 1) + [(0, pad)]
        cf = jnp.pad(cf, widths)
    nblk = cf.shape[-1] // _BLOCK
    blk = cf.reshape(cf.shape[:-1] + (nblk, _BLOCK))
    y = (blk * scale[..., None]).reshape(cf.shape)
    if pad:
        y = y[..., :last]
    return y.reshape(shape)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AdamWConfig:
    lr: Callable = cosine_schedule(3e-4, 100, 10000)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moments: str = "f32"           # f32 | bf16 | int8


def _moment_init(leaf, kind: str):
    if kind == "int8":
        z = jnp.zeros(leaf.shape, jnp.float32)
        c, s = _q8(z)
        return {"q": c, "s": s, "_shape": None}  # shape kept statically
    dt = jnp.bfloat16 if kind == "bf16" else jnp.float32
    return jnp.zeros(leaf.shape, dt)


def adamw_init(params, cfg: AdamWConfig):
    if cfg.moments == "int8":
        m = jax.tree.map(lambda p: dict(q=_q8(jnp.zeros_like(p, jnp.float32))[0],
                                        s=_q8(jnp.zeros_like(p, jnp.float32))[1]),
                         params)
        v = jax.tree.map(lambda p: dict(q=_q8(jnp.zeros_like(p, jnp.float32))[0],
                                        s=_q8(jnp.zeros_like(p, jnp.float32))[1]),
                         params)
    else:
        dt = jnp.bfloat16 if cfg.moments == "bf16" else jnp.float32
        m = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
        v = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return {"step": jnp.zeros((), jnp.int32), "m": m, "v": v}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = cfg.lr(step)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-12)) \
        if cfg.clip_norm else 1.0
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        if cfg.moments == "int8":
            mf = _dq8(m["q"], m["s"], p.shape)
            vf = _dq8(v["q"], v["s"], p.shape)
        else:
            mf, vf = m.astype(jnp.float32), v.astype(jnp.float32)
        mf = b1 * mf + (1 - b1) * g
        vf = b2 * vf + (1 - b2) * jnp.square(g)
        mh, vh = mf / bc1, vf / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if cfg.moments == "int8":
            qm, sm = _q8(mf)
            qv, sv = _q8(vf)
            return new_p, dict(q=qm, s=sm), dict(q=qv, s=sv)
        dt = m.dtype
        return new_p, mf.astype(dt), vf.astype(dt)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    is_moment = lambda x: isinstance(x, dict) and set(x) == {"q", "s"}
    flat_m = jax.tree_util.tree_flatten(state["m"], is_leaf=is_moment)[0]
    flat_v = jax.tree_util.tree_flatten(state["v"], is_leaf=is_moment)[0]
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}, \
        {"grad_norm": gn, "lr": lr}


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression (cross-pod DCI hop)
# ---------------------------------------------------------------------------

def ef_compress_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress(grads, err):
    """Returns (compressed-and-decompressed grads, new error state).

    What actually crosses the wire in a real deployment is (int8 codes +
    f32/block scales) = ~25% of f32 bytes; we model that in the roofline's
    DCI term.  The residual is carried so the sequence of updates is
    unbiased (error feedback)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = _q8(gf)
        deq = _dq8(q, s, gf.shape)
        return deq.astype(g.dtype), gf - deq
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree_util.tree_unflatten(tdef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(tdef, [o[1] for o in out]))


# ---------------------------------------------------------------------------
# Train-state + step builder
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainStepConfig:
    microbatch: int = 0            # 0 = whole batch at once
    compress: bool = False         # int8 EF on gradients
    adamw: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


def train_state_init(model, key, opt_cfg: AdamWConfig,
                     compress: bool = False):
    params = model.init(key)
    state = {"params": params, "opt": adamw_init(params, opt_cfg)}
    if compress:
        state["ef_err"] = ef_compress_init(params)
    return state


def build_train_step(model, ts_cfg: TrainStepConfig):
    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state, batch):
        params = state["params"]
        if ts_cfg.microbatch and ts_cfg.microbatch > 1:
            n = ts_cfg.microbatch
            B = batch["tokens"].shape[0] if "tokens" in batch else \
                next(iter(batch.values())).shape[0]

            def mb_slice(x, i):
                # slice the BATCH axis: leaves are (B, ...) or — for
                # M-RoPE positions — (3, B, S)
                if x.shape[0] == B:
                    return x.reshape((n, -1) + x.shape[1:])[i]
                if x.ndim >= 2 and x.shape[1] == B:
                    return x.reshape(
                        (x.shape[0], n, -1) + x.shape[2:])[:, i]
                return x

            def micro(i, carry):
                g_acc, l_acc, m_acc = carry
                mb = jax.tree.map(lambda x: mb_slice(x, i), batch)
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b / n, g_acc, g)
                return g_acc, l_acc + l / n, jax.tree.map(
                    lambda a, b: a + b / n, m_acc, m)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            # metrics template via eval_shape: no extra fwd/bwd compute
            mb0 = jax.tree.map(lambda x: mb_slice(x, 0), batch)
            _, m_shape = jax.eval_shape(loss_fn, params, mb0)
            m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m_shape)
            grads, loss, metrics = jax.lax.fori_loop(
                0, n, micro, (g0, jnp.zeros(()), m0))
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        new_state = dict(state)
        if ts_cfg.compress:
            grads, new_err = ef_compress(grads, state["ef_err"])
            new_state["ef_err"] = new_err
        new_p, new_opt, om = adamw_update(params, grads, state["opt"],
                                          ts_cfg.adamw)
        new_state["params"] = new_p
        new_state["opt"] = new_opt
        metrics = dict(metrics, loss=loss, **om)
        return new_state, metrics

    return train_step
