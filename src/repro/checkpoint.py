"""Checkpointing: atomic, async, content-addressed at *component* granularity.

Design for 1000+-node restartability:
  * atomic — write to <dir>.tmp then os.replace; a crash mid-save never
    corrupts the latest checkpoint.
  * async — device→host transfer happens on the caller thread (cheap),
    serialization + fsync on a background thread; training never blocks on
    the filesystem.
  * resharding restore — arrays are stored unsharded (per top-level bucket);
    restore places them onto whatever mesh/sharding the *new* platform's
    lazy-build produced.  Elastic re-scale = lazy-rebuild + this restore.
  * component-granular dedup — each top-level param bucket ("embed",
    "blocks", "opt.m", ...) is hashed; unchanged buckets are hard-linked
    from the previous checkpoint instead of rewritten (the paper's
    component-level sharing applied to checkpoints).
"""
from __future__ import annotations

import concurrent.futures as _fut
import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if isinstance(tree, Mapping):
        for k in sorted(tree.keys()):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]) -> Any:
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = _fut.ThreadPoolExecutor(max_workers=1) if async_save \
            else None
        self._pending: Optional[_fut.Future] = None
        self._lock = threading.Lock()

    # -- save ------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[Dict] = None
             ) -> str:
        """Snapshot to host memory now; write in the background."""
        flat = _flatten(state)
        host = {k: np.asarray(v) for k, v in flat.items()}
        if self._pool is None:
            return self._write(step, host, extra or {})
        self.wait()
        self._pending = self._pool.submit(self._write, step, host,
                                          extra or {})
        return os.path.join(self.dir, f"step_{step:08d}")

    def wait(self) -> None:
        with self._lock:
            if self._pending is not None:
                self._pending.result()
                self._pending = None

    def _bucket_of(self, path: str) -> str:
        return path.split("/", 1)[0]

    def _write(self, step: int, host: Dict[str, np.ndarray],
               extra: Dict) -> str:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        prev = self._latest_dir(exclude=final)
        prev_manifest = {}
        if prev:
            try:
                with open(os.path.join(prev, "manifest.json")) as f:
                    prev_manifest = json.load(f)["buckets"]
            except Exception:
                prev_manifest = {}

        buckets: Dict[str, Dict[str, np.ndarray]] = {}
        for path, arr in host.items():
            buckets.setdefault(self._bucket_of(path), {})[path] = arr

        manifest: Dict[str, Any] = {"step": step, "extra": extra,
                                    "buckets": {}, "time": time.time()}
        for name, arrs in sorted(buckets.items()):
            h = hashlib.sha256()
            for path in sorted(arrs):
                h.update(path.encode())
                h.update(arrs[path].tobytes())
            digest = h.hexdigest()
            fn = f"{name}.npz"
            dst = os.path.join(tmp, fn)
            if prev and prev_manifest.get(name, {}).get("digest") == digest:
                # component-level sharing: hard-link the unchanged bucket
                try:
                    os.link(os.path.join(prev, fn), dst)
                except OSError:
                    np.savez(dst, **{p.replace("/", "|"): a
                                     for p, a in arrs.items()})
            else:
                np.savez(dst, **{p.replace("/", "|"): a
                                 for p, a in arrs.items()})
            manifest["buckets"][name] = {
                "digest": digest, "file": fn,
                "bytes": sum(a.nbytes for a in arrs.values())}

        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    # -- restore ------------------------------------------------------------
    def _latest_dir(self, exclude: Optional[str] = None) -> Optional[str]:
        if not os.path.isdir(self.dir):
            return None
        cands = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        cands = [os.path.join(self.dir, d) for d in cands]
        cands = [d for d in cands if d != exclude
                 and os.path.exists(os.path.join(d, "manifest.json"))]
        return cands[-1] if cands else None

    def latest_step(self) -> Optional[int]:
        self.wait()          # a pending async save IS the latest checkpoint
        d = self._latest_dir()
        if d is None:
            return None
        with open(os.path.join(d, "manifest.json")) as f:
            return int(json.load(f)["step"])

    def restore(self, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[int, Any, Dict]:
        """Returns (step, state, extra).  ``shardings`` (a pytree matching
        the state, of NamedSharding) re-places arrays on the new mesh —
        the resharding path used by elastic re-scale."""
        self.wait()
        d = (os.path.join(self.dir, f"step_{step:08d}") if step is not None
             else self._latest_dir())
        if d is None or not os.path.exists(d):
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat: Dict[str, np.ndarray] = {}
        for name, info in manifest["buckets"].items():
            with np.load(os.path.join(d, info["file"])) as z:
                for key in z.files:
                    flat[key.replace("|", "/")] = z[key]
        state = _unflatten(flat)
        if shardings is not None:
            flat_s = _flatten(shardings)
            state = _unflatten({
                k: jax.device_put(v, flat_s[k]) if k in flat_s
                else jnp.asarray(v)
                for k, v in _flatten(state).items()})
        return int(manifest["step"]), state, manifest.get("extra", {})

    # -- gc ----------------------------------------------------------------
    def _gc(self) -> None:
        cands = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in cands[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    def sharing_stats(self) -> Dict[str, int]:
        """Bytes saved by bucket-level hard-linking across kept checkpoints."""
        seen_inodes = set()
        total = unique = 0
        for d in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, d)
            if not d.startswith("step_") or not os.path.isdir(full):
                continue
            for fn in os.listdir(full):
                if not fn.endswith(".npz"):
                    continue
                st = os.stat(os.path.join(full, fn))
                total += st.st_size
                if st.st_ino not in seen_inodes:
                    seen_inodes.add(st.st_ino)
                    unique += st.st_size
        return {"total_bytes": total, "unique_bytes": unique,
                "saved_bytes": total - unique}
