"""repro.deploy — the deployment-service layer on top of the lazy-builder.

One CIR, many platforms: ``FleetDeployer`` drives the staged build pipeline
concurrently across N heterogeneous SpecSheets — through one shared
``LocalComponentStore`` (the single-host fast path), or across a
``FleetTopology`` of nodes with per-node stores, per-link bandwidths and
peer-to-peer chunk distribution (the cloud-edge continuum scenario): a
``PeerIndex`` gossips which node holds which committed chunks, and every
node's fetch engine prefers the cheapest peer over the upstream registry.
"""
from .fleet import (FleetDeployer, FleetResult,  # noqa: F401
                    MigrationReport, PlatformDeployment)
from .placement import (DemandModel, PlacementPlanner,  # noqa: F401
                        ReplicationOrder, SpeculationStats,
                        speculative_replicate)
from .topology import (QUARANTINE_DECAY_S,  # noqa: F401
                       QUARANTINE_THRESHOLD, ChunkIntegrityError, FleetNode,
                       FleetTopology, NodePeering, NodeTraffic, PeerIndex,
                       PeerTransferError, Quarantine, TopologyError)
