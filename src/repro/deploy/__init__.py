"""repro.deploy — the deployment-service layer on top of the lazy-builder.

One CIR, many platforms: ``FleetDeployer`` drives the staged build pipeline
concurrently across N heterogeneous SpecSheets, sharing fetched components
through one ``LocalComponentStore`` and resolutions through one
``BuildPlanCache``, so the second-and-later platforms pay only their
platform-specific delta (the cloud-edge continuum scenario).
"""
from .fleet import (FleetDeployer, FleetResult,  # noqa: F401
                    PlatformDeployment)
