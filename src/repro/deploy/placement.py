"""Demand-driven chunk placement: speculative replication ahead of demand.

Reactive fetch — the default everywhere else in this repo — moves content
only when a build demands it, so every demand shift (the paper's
sky-computing scenario: diurnal/regional rotation across edge nodes) pays
full cold-miss latency before the first byte lands.  This module closes
that gap with a *continuous placement decision* (see "Continuous Reasoning
for Adaptive Container Image Distribution in the Cloud-Edge Continuum",
PAPERS.md): a ``PlacementPlanner`` watches where deploys actually land,
predicts where they will land next, and pre-positions the missing chunk
stripes there — **ahead of demand** — through the very same
``NodePeering`` source-selection path real builds use.

The safety story is the ``spec:`` soft lease (``repro.core.store``): every
speculative byte is committed under it, which puts the chunks in the FIRST
eviction tier (priority order under pressure: spec < warm < build-pin), so
a wrong prediction can never displace pinned build content or
demand-fetched bytes — it is simply the first thing evicted, counted in
``LifecycleStats.spec_wasted_bytes``.  A real build's plan *promotes* the
chunks out of the tier and drains them into ``spec_hit_bytes``; the
speculative wire itself lands in dedicated ``NodeTraffic.spec_*`` columns,
never in ``bytes_total`` — which keeps the per-deploy accounting identity
(``bytes_total == Σ bytes_delta_fetched``) byte-identical whether the
planner is enabled or not.

``benchmarks/placement.py`` drives a rotating-demand trace on the virtual
clock and gates the headline claim: speculative replication cuts p95
time-to-READY ≥40% vs reactive-only at ≤25% extra upstream wire.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..core.chunkstore import ChunkedComponentStore
from ..core.component import UniformComponent
from ..core.lazybuild import _FETCH_PRIORITY
from ..core.store import SPEC_LEASE_PREFIX

# Default per-node wire budget of one planner round: speculation must be a
# bounded background activity, not an unmetered firehose ahead of demand.
DEFAULT_WIRE_BUDGET_BYTES = 256 * 2**20

# Demand scores below this are noise — not worth a replication order.
MIN_DEMAND_SCORE = 0.05

# Spec-lease id sequence (one lease per (node, content key) pairing).
_SPEC_SEQ = itertools.count(1)


@dataclasses.dataclass
class SpeculationStats:
    """Byte-exact outcome of one speculative replication pass."""
    bytes_fetched: int = 0            # speculative wire this pass moved
    bytes_already_present: int = 0    # planned bytes the store already held
    chunks_fetched: int = 0
    budget_denied_bytes: int = 0      # claims released unfetched (budget)
    orders_executed: int = 0
    orders_skipped: int = 0           # capacity/pressure-skipped orders

    def merge(self, other: "SpeculationStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))


def speculative_replicate(store: ChunkedComponentStore,
                          comps: Sequence[UniformComponent],
                          lease_id: str,
                          peering: Optional[Any] = None,
                          service: Optional[Any] = None,
                          budget_bytes: Optional[int] = None
                          ) -> SpeculationStats:
    """Pre-position ``comps``' missing chunks into ``store`` under the
    ``spec:`` soft lease ``lease_id``.

    The transfer path is the node's ordinary peer-first source selection
    (``peering.fetch_spec_stripe`` — spec traffic columns, upstream
    fallback included); without a peering layer the bytes are charged to
    ``service`` directly.  Claims are made through ``plan_fetch(...,
    speculative=True)`` so singleflight dedup against concurrent real
    builds holds: a chunk a build is already fetching is left to that
    build (free), and a build waiting on *our* transfer gets the bytes
    counted as an immediate speculation hit.  ``budget_bytes`` caps the
    bytes fetched this pass — claims beyond it are aborted, not queued.
    """
    if not lease_id.startswith(SPEC_LEASE_PREFIX):
        raise ValueError(f"speculative lease id must start with "
                         f"{SPEC_LEASE_PREFIX!r}, got {lease_id!r}")
    stats = SpeculationStats()
    if not store.lease_active(lease_id):
        store.acquire_build_lease(lease_id, comps)
    budget = math.inf if budget_bytes is None else int(budget_bytes)
    ordered = sorted(comps,
                     key=lambda c: (_FETCH_PRIORITY.get(c.manager, 3),
                                    c.digest()))
    for c in ordered:
        if budget <= 0:
            break
        plan = store.plan_fetch(c, speculative=True)
        stats.bytes_already_present += plan.bytes_hit
        take: List[Tuple[Any, Any]] = []
        rest: List[Tuple[Any, Any]] = []
        used = 0
        for ch, ev in plan.claimed:
            if used + ch.size <= budget:
                take.append((ch, ev))
                used += ch.size
            else:
                rest.append((ch, ev))
                stats.budget_denied_bytes += ch.size
        if rest:
            # over-budget claims are released now — the content stays
            # incomplete and the next build (or round) re-plans it
            store.abort_chunks(rest, component=c)
        if not take:
            continue
        try:
            if peering is not None:
                peering.fetch_spec_stripe(c, take)
            elif service is not None:
                service.fetch_chunks(c, used, len(take))
        except BaseException:
            store.abort_chunks(take, component=c)
            raise
        store.commit_chunks(take, component=c, speculative=True)
        if peering is not None:
            peering.announce_chunks([ch for ch, _ev in take])
        budget -= used
        stats.bytes_fetched += used
        stats.chunks_fetched += len(take)
    return stats


# ---------------------------------------------------------------------------
# Demand model: recent-deploy EWMA + optional oracle trace
# ---------------------------------------------------------------------------

class DemandModel:
    """Per-(node, content key) demand estimate.

    Two signals, summed:

      * **EWMA of observed deploys** — every ``observe`` bumps the
        (node, key) score by 1 and prior mass decays with ``halflife_s``,
        so a node that deployed a CIR recently and repeatedly scores high.
        This is the online signal a production planner runs on.
      * **Oracle trace** (optional) — ``(t, node_id, key)`` events of
        *future* demand within ``horizon_s`` of now score 1.0 each.
        Benchmarks use it to model a scheduler that knows the diurnal
        rotation; real deployments can feed it from a forecast.

    Scores are unitless priorities — the planner orders replication by
    them; it never interprets magnitudes beyond the ``MIN_DEMAND_SCORE``
    noise floor.
    """

    def __init__(self, halflife_s: float = 600.0,
                 horizon_s: float = 600.0,
                 oracle: Optional[Sequence[Tuple[float, str, str]]] = None):
        if halflife_s <= 0 or horizon_s < 0:
            raise ValueError("halflife_s must be > 0 and horizon_s >= 0")
        self.halflife_s = halflife_s
        self.horizon_s = horizon_s
        self.oracle: List[Tuple[float, str, str]] = \
            sorted(oracle) if oracle else []
        self._scores: Dict[Tuple[str, str], Tuple[float, float]] = {}
        #              ^ (node, key) -> (score, last-update time)

    def observe(self, node_id: str, key: str, now: float) -> None:
        """A deploy of ``key`` landed on ``node_id`` at ``now``."""
        k = (node_id, key)
        score, t0 = self._scores.get(k, (0.0, now))
        self._scores[k] = (self._decay(score, now - t0) + 1.0, now)

    def _decay(self, score: float, dt: float) -> float:
        if dt <= 0:
            return score
        return score * 0.5 ** (dt / self.halflife_s)

    def predict(self, now: float) -> Dict[Tuple[str, str], float]:
        """(node, key) -> demand score at ``now`` (EWMA + oracle window)."""
        out = {k: self._decay(s, now - t0)
               for k, (s, t0) in self._scores.items()}
        for t, node_id, key in self.oracle:
            if now <= t < now + self.horizon_s:
                k = (node_id, key)
                out[k] = out.get(k, 0.0) + 1.0
        return out


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplicationOrder:
    """One prioritized pre-positioning decision: put ``key``'s missing
    chunks on ``node_id``.  ``est_bytes`` is the store-verified missing
    byte count at plan time; ``est_transfer_s`` its cost over the node's
    best available link (peer if one exists, else upstream)."""
    node_id: str
    key: str
    priority: float
    est_bytes: int
    est_transfer_s: float
    components: Tuple[UniformComponent, ...]


class PlacementPlanner:
    """Continuous demand-driven chunk placement over a topology-mode fleet.

    Consumes live fleet state — ``PeerIndex`` holdings (implicitly, via
    each store's missing-chunk scan and the peering layer's source
    selection), per-node ``capacity_bytes`` and ``LifecycleStats``
    pressure, per-link bytes/s — plus a pluggable :class:`DemandModel`,
    and emits prioritized :class:`ReplicationOrder` s executed as
    speculative replication under ``spec:`` soft leases.

    Attach to a deployer with ``PlacementPlanner(deployer, ...)`` (the
    constructor registers itself via ``deployer.attach_planner``); from
    then on every successful deploy is observed as a demand signal, and
    each ``run_round()`` call plans + executes one replication pass —
    benchmarks and services call it between deploys (e.g. on a timer).
    """

    def __init__(self, deployer: Any,
                 demand: Optional[DemandModel] = None,
                 wire_budget_bytes: int = DEFAULT_WIRE_BUDGET_BYTES,
                 min_score: float = MIN_DEMAND_SCORE):
        if getattr(deployer, "topology", None) is None:
            raise ValueError("PlacementPlanner needs a topology-mode "
                             "FleetDeployer (per-node stores + peerings)")
        if wire_budget_bytes <= 0:
            raise ValueError("wire_budget_bytes must be positive")
        self.deployer = deployer
        self.demand = demand if demand is not None else DemandModel()
        self.wire_budget_bytes = wire_budget_bytes
        self.min_score = min_score
        self.stats = SpeculationStats()
        # fleet-wide default bundle per key, plus the exact bundle each
        # node was observed deploying: one CIR resolves to different
        # component sets per platform class, and an order for a node must
        # replicate the variant THAT node would demand, not whichever
        # platform deployed last
        self._content: Dict[str, Tuple[UniformComponent, ...]] = {}
        self._node_content: Dict[Tuple[str, str],
                                 Tuple[UniformComponent, ...]] = {}
        self._leases: Dict[Tuple[str, str], str] = {}
        deployer.attach_planner(self)

    # -- clock ----------------------------------------------------------
    def now(self) -> float:
        simnet = getattr(self.deployer, "simnet", None)
        if simnet is not None:
            return simnet.now
        return time.monotonic()

    # -- demand intake --------------------------------------------------
    def register(self, key: str,
                 comps: Sequence[UniformComponent]) -> None:
        """Teach the planner what content ``key`` (a CIR digest) resolves
        to — an oracle-driven benchmark registers up front; the deploy
        observation path does it automatically."""
        self._content[key] = tuple(comps)

    def observe(self, node_id: str, key: str,
                comps: Sequence[UniformComponent],
                now: Optional[float] = None) -> None:
        """A deploy of ``key`` landed on ``node_id`` — the planner's
        online demand signal (``FleetDeployer.deploy`` calls this for
        every successful topology-mode deployment)."""
        self.register(key, comps)
        self._node_content[(node_id, key)] = tuple(comps)
        self.demand.observe(node_id, key,
                            self.now() if now is None else now)

    # -- planning -------------------------------------------------------
    def _best_bps(self, node_id: str) -> float:
        topo = self.deployer.topology
        peer_bps = [topo.bandwidth(node_id, p)
                    for p in topo.peers_of(node_id)]
        candidates = [b for b in peer_bps if b] + \
            [topo.node(node_id).upstream_bps]
        return max(candidates)

    def plan(self, now: Optional[float] = None) -> List[ReplicationOrder]:
        """Emit prioritized replication orders for predicted-hot
        (node, key) pairs whose content is not fully resident.

        Capacity discipline: a node whose total capacity cannot ever hold
        the content is skipped outright, and a node already under pin
        pressure (``LifecycleStats.pin_denied_evictions`` — pins hold it
        over budget, so speculative bytes would be evicted on arrival) is
        skipped for this round rather than churned.
        """
        now = self.now() if now is None else now
        topo = self.deployer.topology
        orders: List[ReplicationOrder] = []
        scores = self.demand.predict(now)
        for (node_id, key), score in scores.items():
            if score < self.min_score:
                continue
            comps = self._node_content.get((node_id, key),
                                           self._content.get(key))
            if comps is None or node_id not in topo.node_ids():
                continue
            store = self.deployer.node_store(node_id)
            est = sum(ch.size for c in comps
                      for ch in store.missing_chunks(c))
            if est == 0:
                continue               # already fully resident
            cap = topo.node(node_id).capacity_bytes
            total = sum(c.size_bytes for c in comps)
            if cap is not None and total > cap:
                self.stats.orders_skipped += 1
                continue               # can never fit — don't churn it
            if store.lifecycle_stats.pin_denied_evictions and \
                    cap is not None and store.resident_chunk_bytes >= cap:
                self.stats.orders_skipped += 1
                continue               # pinned over budget: arrival = waste
            orders.append(ReplicationOrder(
                node_id=node_id, key=key, priority=score, est_bytes=est,
                est_transfer_s=est / self._best_bps(node_id),
                components=comps))
        # highest demand first; cheaper transfer breaks ties, then ids for
        # determinism
        orders.sort(key=lambda o: (-o.priority, o.est_transfer_s,
                                   o.node_id, o.key))
        return orders

    # -- execution ------------------------------------------------------
    def _lease_for(self, node_id: str, key: str) -> str:
        k = (node_id, key)
        lease = self._leases.get(k)
        if lease is None:
            lease = f"{SPEC_LEASE_PREFIX}{key[:16]}#{next(_SPEC_SEQ)}"
            self._leases[k] = lease
        return lease

    def execute(self, orders: Sequence[ReplicationOrder]
                ) -> SpeculationStats:
        """Run ``orders`` in priority order under per-node wire budgets.
        Each node spends at most ``wire_budget_bytes`` per call — a hot
        prediction cannot starve the node's real traffic for the round."""
        passed = SpeculationStats()
        budgets: Dict[str, int] = {}
        for o in orders:
            budget = budgets.get(o.node_id, self.wire_budget_bytes)
            if budget <= 0:
                passed.orders_skipped += 1
                continue
            store = self.deployer.node_store(o.node_id)
            peering = self.deployer.node_peering(o.node_id)
            st = speculative_replicate(
                store, list(o.components),
                self._lease_for(o.node_id, o.key),
                peering=peering, budget_bytes=budget)
            budgets[o.node_id] = budget - st.bytes_fetched
            st.orders_executed = 1
            passed.merge(st)
        self.stats.merge(passed)
        return passed

    def run_round(self, now: Optional[float] = None) -> SpeculationStats:
        """One planner tick: predict, order, replicate."""
        return self.execute(self.plan(now))

    # -- lease lifecycle ------------------------------------------------
    def release(self, node_id: str, key: str) -> bool:
        """Drop the spec lease for (node, key): remaining un-demanded
        content loses its tier marking (it stays resident until pressure
        or demand decides)."""
        lease = self._leases.pop((node_id, key), None)
        if lease is None:
            return False
        return self.deployer.node_store(node_id).release_build(lease)

    def release_all(self) -> int:
        n = 0
        for node_id, key in list(self._leases):
            n += bool(self.release(node_id, key))
        return n
