"""FleetDeployer — deploy one CIR across N heterogeneous platforms.

The deployment service substrate the paper's cross-platform story implies:
a single pre-built CIR is lazily built for every platform in a fleet
(TPU pod, GPU server, CPU edge node, …) concurrently.  All builds share

  * one ``LocalComponentStore``  — components fetched for the first
    platform are free for every later one (*fleet active sharing*);
  * one ``BuildPlanCache``       — re-deploying to a platform class whose
    plan is already cached skips resolution entirely.

Byte accounting follows the seed's offline model: nothing real crosses a
network, but every fetched component is charged its wire size, so the
fleet sharing rate and per-platform deltas are exact.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from ..core.chunkstore import ChunkedComponentStore
from ..core.cir import CIR
from ..core.lazybuild import (BuildPlanCache, BuildReport, ContainerInstance,
                              LazyBuilder)
from ..core.registry import UniformComponentService
from ..core.spec import SpecSheet
from ..core.store import LocalComponentStore


@dataclasses.dataclass
class PlatformDeployment:
    """Outcome of deploying the CIR to one platform of the fleet.

    ``ready_s`` is the wall time until the instance reached lifecycle READY
    (deployable — the weight tail may still have been streaming); ``wall_s``
    runs until COMPLETE.  ``report`` is present even for failed builds that
    got past resolution, so fleet byte accounting can include their partial
    fetch work instead of silently dropping it.
    """
    platform_id: str
    instance: Optional[ContainerInstance]
    error: Optional[str] = None
    wall_s: float = 0.0
    ready_s: float = 0.0
    report: Optional[BuildReport] = None

    @property
    def ok(self) -> bool:
        return self.error is None


@dataclasses.dataclass
class FleetResult:
    cir_name: str
    deployments: List[PlatformDeployment]
    wall_s: float
    bytes_fetched_total: int          # component-level bytes of fleet misses
    bytes_components_total: int       # what N independent nodes would pull
    sharing_rate: float               # store dedup over THIS deploy's puts
    plan_cache_hits: int
    # -- chunk-level delta-fetch columns --------------------------------
    bytes_delta_total: int = 0        # wire bytes: missing chunks only
    chunks_hit_total: int = 0
    chunks_missed_total: int = 0
    chunks_waited_total: int = 0      # singleflight: in flight elsewhere
    fetch_serial_s_total: float = 0.0  # sum of per-task fetch times
    fetch_s_wall: float = 0.0         # slowest build's fetch wall time
    fetch_concurrency: int = 1
    # -- lifecycle wall-clock accounting (event-driven orchestrator) ----
    n_failed: int = 0                 # platforms whose build did not finish
    ready_s_wall: float = 0.0         # slowest platform's wall to READY
    stage_walls: Dict[str, float] = dataclasses.field(default_factory=dict)
    #                                 ^ per-stage max wall offset across fleet

    @property
    def ok(self) -> bool:
        return all(d.ok for d in self.deployments)

    def instance(self, platform_id: str) -> ContainerInstance:
        for d in self.deployments:
            if d.platform_id == platform_id:
                assert d.instance is not None, d.error
                return d.instance
        raise KeyError(platform_id)

    def summary(self) -> str:
        lines = [f"fleet deploy of {self.cir_name}: "
                 f"{sum(d.ok for d in self.deployments)}/"
                 f"{len(self.deployments)} platforms "
                 f"({self.n_failed} failed), "
                 f"sharing rate {self.sharing_rate * 100:.1f}%, "
                 f"{self.plan_cache_hits} plan-cache hits"]
        if self.chunks_hit_total or self.chunks_missed_total:
            lines.append(
                f"  chunk delta: {self.bytes_delta_total / 2**20:.1f} MiB "
                f"on the wire ({self.chunks_missed_total} chunks fetched, "
                f"{self.chunks_hit_total} hit, "
                f"{self.chunks_waited_total} deduped in flight), "
                f"fetch {self.fetch_s_wall * 1e3:.1f} ms wall vs "
                f"{self.fetch_serial_s_total * 1e3:.1f} ms serial "
                f"@ width {self.fetch_concurrency}")
        if self.ready_s_wall:
            lines.append(
                f"  lifecycle: fleet READY at {self.ready_s_wall * 1e3:.1f} "
                f"ms, COMPLETE at {self.wall_s * 1e3:.1f} ms"
                + (f" (asset tail overlapped "
                   f"{(self.wall_s - self.ready_s_wall) * 1e3:.1f} ms)"
                   if self.wall_s > self.ready_s_wall else ""))
        for d in self.deployments:
            if d.ok:
                rep = d.instance.report
                lines.append(
                    f"  {d.platform_id:20s} fetched "
                    f"{rep.bytes_wire_fetched / 2**20:8.1f} MiB "
                    f"({'plan-replay' if rep.plan_cache_hit else 'resolved'})")
            else:
                partial = f", partial fetch {d.report.bytes_wire_fetched}B" \
                    if d.report is not None else ""
                lines.append(f"  {d.platform_id:20s} FAILED: "
                             f"{d.error}{partial}")
        return "\n".join(lines)


class FleetDeployer:
    """Deploys one CIR to many SpecSheets through a shared staged pipeline.

    A single ``LazyBuilder`` (one store, one plan cache) serves every
    platform; per-platform builds run on a thread pool.  The store and the
    registry are lock-protected, and resolution is read-mostly, so
    concurrent builds are safe — they just interleave their fetch
    accounting, which is exactly the sharing the fleet report measures.
    """

    def __init__(self, service: UniformComponentService,
                 store: Optional[LocalComponentStore] = None,
                 plan_cache: Optional[BuildPlanCache] = None,
                 link_bandwidth_bps: float = 500e6,
                 max_workers: int = 8,
                 fetch_workers: int = 8,
                 fetch_simulate_bps: Optional[float] = None,
                 overlap: bool = True):
        self.store = store if store is not None else ChunkedComponentStore()
        self.plan_cache = plan_cache or BuildPlanCache()
        self.builder = LazyBuilder(service, self.store,
                                   link_bandwidth_bps=link_bandwidth_bps,
                                   plan_cache=self.plan_cache,
                                   fetch_workers=fetch_workers,
                                   fetch_simulate_bps=fetch_simulate_bps)
        self.max_workers = max_workers
        self.overlap = overlap

    # ------------------------------------------------------------------
    def deploy(self, cir: CIR, specs: Sequence[SpecSheet],
               mesh: Any = None,
               overrides: Optional[Mapping[str, Any]] = None,
               assemble: bool = False,
               compile_steps: bool = False) -> FleetResult:
        """Deploy ``cir`` to every platform in ``specs`` concurrently.

        Each platform's build runs non-blocking through the event-driven
        orchestrator; the deployer waits on the instance *lifecycle* —
        recording the wall to READY (deployable) separately from COMPLETE
        (weight tail landed, accounting final) — instead of blocking on
        ``build()`` returning.
        """
        hits_before = self.plan_cache.stats.hits
        stored_before = self.store.stats.bytes_stored
        requested_before = self.store.stats.bytes_requested
        t0 = time.perf_counter()

        def one(spec: SpecSheet) -> PlatformDeployment:
            t = time.perf_counter()
            inst: Optional[ContainerInstance] = None
            ready_s = 0.0
            try:
                inst = self.builder.build(
                    cir, spec, mesh=mesh, overrides=overrides,
                    assemble=assemble, compile_steps=compile_steps,
                    overlap=self.overlap, block=False)
                inst.wait("ready")
                ready_s = time.perf_counter() - t
                inst.wait("complete")
                return PlatformDeployment(spec.platform_id, inst,
                                          wall_s=time.perf_counter() - t,
                                          ready_s=ready_s,
                                          report=inst.report)
            except Exception as e:  # noqa: BLE001 — per-platform isolation
                # a build that got past resolution leaves a partial report:
                # its fetch bytes are real work the fleet totals must count,
                # and a build that reached READY before the tail failed
                # keeps its measured time-to-deployable
                return PlatformDeployment(
                    spec.platform_id, None,
                    error=f"{type(e).__name__}: {e}",
                    wall_s=time.perf_counter() - t,
                    ready_s=ready_s,
                    report=inst.report if inst is not None else None)

        workers = max(1, min(self.max_workers, len(specs)))
        if workers == 1:
            deployments = [one(s) for s in specs]
        else:
            with ThreadPoolExecutor(max_workers=workers) as pool:
                deployments = list(pool.map(one, specs))

        # all reports — failed builds' partial fetch work included, so the
        # fleet cannot overstate sharing by dropping bytes it transferred
        reports = [d.report for d in deployments if d.report is not None]
        fetched = sum(r.bytes_fetched for r in reports)
        total = sum(r.bytes_total_components for r in reports)
        # sharing over THIS deploy only (the store may serve many deploys)
        req = self.store.stats.bytes_requested - requested_before
        stored = self.store.stats.bytes_stored - stored_before
        stage_walls: Dict[str, float] = {}
        for r in reports:
            for stage, off in r.stage_s.items():
                stage_walls[stage] = max(stage_walls.get(stage, 0.0), off)
        return FleetResult(
            cir_name=cir.name,
            deployments=deployments,
            wall_s=time.perf_counter() - t0,
            bytes_fetched_total=fetched,
            bytes_components_total=total,
            sharing_rate=(1.0 - stored / req) if req else 0.0,
            plan_cache_hits=self.plan_cache.stats.hits - hits_before,
            bytes_delta_total=sum(r.bytes_delta_fetched for r in reports),
            chunks_hit_total=sum(r.chunks_hit for r in reports),
            chunks_missed_total=sum(r.chunks_missed for r in reports),
            chunks_waited_total=sum(r.chunks_waited for r in reports),
            fetch_serial_s_total=sum(r.fetch_serial_s for r in reports),
            fetch_s_wall=max((r.fetch_s for r in reports), default=0.0),
            fetch_concurrency=max((r.fetch_concurrency for r in reports),
                                  default=1),
            n_failed=sum(not d.ok for d in deployments),
            ready_s_wall=max((d.ready_s for d in deployments if d.ok),
                             default=0.0),
            stage_walls=stage_walls,
        )

    # ------------------------------------------------------------------
    def warm(self, cir: CIR, specs: Sequence[SpecSheet],
             overrides: Optional[Mapping[str, Any]] = None) -> int:
        """Pre-populate the plan cache + store for a fleet (no assembly).

        Returns the number of platforms whose plans are now cached — a
        deployment service calls this off the hot path so real deploys
        replay plans and hit the store.
        """
        res = self.deploy(cir, specs, overrides=overrides, assemble=False)
        return sum(d.ok for d in res.deployments)
