"""FleetDeployer — deploy one CIR across N heterogeneous platforms.

The deployment service substrate the paper's cross-platform story implies:
a single pre-built CIR is lazily built for every platform in a fleet
(TPU pod, GPU server, CPU edge node, …) concurrently.  All builds share

  * one ``LocalComponentStore``  — components fetched for the first
    platform are free for every later one (*fleet active sharing*);
  * one ``BuildPlanCache``       — re-deploying to a platform class whose
    plan is already cached skips resolution entirely.

Byte accounting follows the seed's offline model: nothing real crosses a
network, but every fetched component is charged its wire size, so the
fleet sharing rate and per-platform deltas are exact.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from ..core.chunkstore import ChunkedComponentStore
from ..core.cir import CIR
from ..core.lazybuild import (BuildPlanCache, ContainerInstance, LazyBuilder)
from ..core.registry import UniformComponentService
from ..core.spec import SpecSheet
from ..core.store import LocalComponentStore


@dataclasses.dataclass
class PlatformDeployment:
    """Outcome of deploying the CIR to one platform of the fleet."""
    platform_id: str
    instance: Optional[ContainerInstance]
    error: Optional[str] = None
    wall_s: float = 0.0

    @property
    def ok(self) -> bool:
        return self.error is None


@dataclasses.dataclass
class FleetResult:
    cir_name: str
    deployments: List[PlatformDeployment]
    wall_s: float
    bytes_fetched_total: int          # component-level bytes of fleet misses
    bytes_components_total: int       # what N independent nodes would pull
    sharing_rate: float               # store dedup over THIS deploy's puts
    plan_cache_hits: int
    # -- chunk-level delta-fetch columns --------------------------------
    bytes_delta_total: int = 0        # wire bytes: missing chunks only
    chunks_hit_total: int = 0
    chunks_missed_total: int = 0
    chunks_waited_total: int = 0      # singleflight: in flight elsewhere
    fetch_serial_s_total: float = 0.0  # sum of per-task fetch times
    fetch_s_wall: float = 0.0         # slowest build's fetch wall time
    fetch_concurrency: int = 1

    @property
    def ok(self) -> bool:
        return all(d.ok for d in self.deployments)

    def instance(self, platform_id: str) -> ContainerInstance:
        for d in self.deployments:
            if d.platform_id == platform_id:
                assert d.instance is not None, d.error
                return d.instance
        raise KeyError(platform_id)

    def summary(self) -> str:
        lines = [f"fleet deploy of {self.cir_name}: "
                 f"{sum(d.ok for d in self.deployments)}/"
                 f"{len(self.deployments)} platforms, "
                 f"sharing rate {self.sharing_rate * 100:.1f}%, "
                 f"{self.plan_cache_hits} plan-cache hits"]
        if self.chunks_hit_total or self.chunks_missed_total:
            lines.append(
                f"  chunk delta: {self.bytes_delta_total / 2**20:.1f} MiB "
                f"on the wire ({self.chunks_missed_total} chunks fetched, "
                f"{self.chunks_hit_total} hit, "
                f"{self.chunks_waited_total} deduped in flight), "
                f"fetch {self.fetch_s_wall * 1e3:.1f} ms wall vs "
                f"{self.fetch_serial_s_total * 1e3:.1f} ms serial "
                f"@ width {self.fetch_concurrency}")
        for d in self.deployments:
            if d.ok:
                rep = d.instance.report
                lines.append(
                    f"  {d.platform_id:20s} fetched "
                    f"{rep.bytes_wire_fetched / 2**20:8.1f} MiB "
                    f"({'plan-replay' if rep.plan_cache_hit else 'resolved'})")
            else:
                lines.append(f"  {d.platform_id:20s} FAILED: {d.error}")
        return "\n".join(lines)


class FleetDeployer:
    """Deploys one CIR to many SpecSheets through a shared staged pipeline.

    A single ``LazyBuilder`` (one store, one plan cache) serves every
    platform; per-platform builds run on a thread pool.  The store and the
    registry are lock-protected, and resolution is read-mostly, so
    concurrent builds are safe — they just interleave their fetch
    accounting, which is exactly the sharing the fleet report measures.
    """

    def __init__(self, service: UniformComponentService,
                 store: Optional[LocalComponentStore] = None,
                 plan_cache: Optional[BuildPlanCache] = None,
                 link_bandwidth_bps: float = 500e6,
                 max_workers: int = 8,
                 fetch_workers: int = 8,
                 fetch_simulate_bps: Optional[float] = None):
        self.store = store if store is not None else ChunkedComponentStore()
        self.plan_cache = plan_cache or BuildPlanCache()
        self.builder = LazyBuilder(service, self.store,
                                   link_bandwidth_bps=link_bandwidth_bps,
                                   plan_cache=self.plan_cache,
                                   fetch_workers=fetch_workers,
                                   fetch_simulate_bps=fetch_simulate_bps)
        self.max_workers = max_workers

    # ------------------------------------------------------------------
    def deploy(self, cir: CIR, specs: Sequence[SpecSheet],
               mesh: Any = None,
               overrides: Optional[Mapping[str, Any]] = None,
               assemble: bool = False,
               compile_steps: bool = False) -> FleetResult:
        """Deploy ``cir`` to every platform in ``specs`` concurrently."""
        hits_before = self.plan_cache.stats.hits
        stored_before = self.store.stats.bytes_stored
        requested_before = self.store.stats.bytes_requested
        t0 = time.perf_counter()

        def one(spec: SpecSheet) -> PlatformDeployment:
            t = time.perf_counter()
            try:
                inst = self.builder.build(
                    cir, spec, mesh=mesh, overrides=overrides,
                    assemble=assemble, compile_steps=compile_steps)
                return PlatformDeployment(spec.platform_id, inst,
                                          wall_s=time.perf_counter() - t)
            except Exception as e:  # noqa: BLE001 — per-platform isolation
                return PlatformDeployment(spec.platform_id, None,
                                          error=f"{type(e).__name__}: {e}",
                                          wall_s=time.perf_counter() - t)

        workers = max(1, min(self.max_workers, len(specs)))
        if workers == 1:
            deployments = [one(s) for s in specs]
        else:
            with ThreadPoolExecutor(max_workers=workers) as pool:
                deployments = list(pool.map(one, specs))

        reports = [d.instance.report for d in deployments if d.ok]
        fetched = sum(r.bytes_fetched for r in reports)
        total = sum(r.bytes_total_components for r in reports)
        # sharing over THIS deploy only (the store may serve many deploys)
        req = self.store.stats.bytes_requested - requested_before
        stored = self.store.stats.bytes_stored - stored_before
        return FleetResult(
            cir_name=cir.name,
            deployments=deployments,
            wall_s=time.perf_counter() - t0,
            bytes_fetched_total=fetched,
            bytes_components_total=total,
            sharing_rate=(1.0 - stored / req) if req else 0.0,
            plan_cache_hits=self.plan_cache.stats.hits - hits_before,
            bytes_delta_total=sum(r.bytes_delta_fetched for r in reports),
            chunks_hit_total=sum(r.chunks_hit for r in reports),
            chunks_missed_total=sum(r.chunks_missed for r in reports),
            chunks_waited_total=sum(r.chunks_waited for r in reports),
            fetch_serial_s_total=sum(r.fetch_serial_s for r in reports),
            fetch_s_wall=max((r.fetch_s for r in reports), default=0.0),
            fetch_concurrency=max((r.fetch_concurrency for r in reports),
                                  default=1),
        )

    # ------------------------------------------------------------------
    def warm(self, cir: CIR, specs: Sequence[SpecSheet],
             overrides: Optional[Mapping[str, Any]] = None) -> int:
        """Pre-populate the plan cache + store for a fleet (no assembly).

        Returns the number of platforms whose plans are now cached — a
        deployment service calls this off the hot path so real deploys
        replay plans and hit the store.
        """
        res = self.deploy(cir, specs, overrides=overrides, assemble=False)
        return sum(d.ok for d in res.deployments)
