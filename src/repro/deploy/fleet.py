"""FleetDeployer — deploy one CIR across N heterogeneous platforms.

The deployment service substrate the paper's cross-platform story implies:
a single pre-built CIR is lazily built for every platform in a fleet
(TPU pod, GPU server, CPU edge node, …) concurrently.  All builds share

  * one ``LocalComponentStore``  — components fetched for the first
    platform are free for every later one (*fleet active sharing*);
  * one ``BuildPlanCache``       — re-deploying to a platform class whose
    plan is already cached skips resolution entirely.

Byte accounting follows the seed's offline model: nothing real crosses a
network, but every fetched component is charged its wire size, so the
fleet sharing rate and per-platform deltas are exact.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Mapping, Optional, Sequence, Set, Tuple

from ..core.chunkstore import ChunkedComponentStore
from ..core.cir import CIR
from ..core.compilecache import CompileCache
from ..core.irmodule import ir_module_component
from ..core.lazybuild import (BuildPlanCache, BuildReport, ContainerInstance,
                              LazyBuilder)
from ..core.registry import UniformComponentService
from ..core.simnet import SimNetwork
from ..core.snapshot import restore_instance, snapshot_instance
from ..core.spec import SpecSheet
from ..core.store import (EVICTION_POLICIES, SPEC_LEASE_PREFIX,
                          LocalComponentStore)
from .placement import speculative_replicate
from .topology import (FleetTopology, NodePeering, NodeTraffic, PeerIndex,
                       Quarantine)

# Migration hand-off lease ids (pin the source content for the transfer
# window) and post-migration retirement spec leases share one sequence.
import itertools
_MIGRATE_SEQ = itertools.count(1)


@dataclasses.dataclass
class PlatformDeployment:
    """Outcome of deploying the CIR to one platform of the fleet.

    ``ready_s`` is the wall time until the instance reached lifecycle READY
    (deployable — the weight tail may still have been streaming); ``wall_s``
    runs until COMPLETE.  ``report`` is present even for failed builds that
    got past resolution, so fleet byte accounting can include their partial
    fetch work instead of silently dropping it.  ``node_id`` names the
    topology node that built this platform (None on the shared-store path).
    """
    platform_id: str
    instance: Optional[ContainerInstance]
    error: Optional[str] = None
    wall_s: float = 0.0
    ready_s: float = 0.0
    report: Optional[BuildReport] = None
    node_id: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.error is None


@dataclasses.dataclass
class FleetResult:
    cir_name: str
    deployments: List[PlatformDeployment]
    wall_s: float
    bytes_fetched_total: int          # component-level bytes of fleet misses
    bytes_components_total: int       # what N independent nodes would pull
    sharing_rate: float               # store dedup over THIS deploy's puts
    plan_cache_hits: int
    # -- chunk-level delta-fetch columns --------------------------------
    bytes_delta_total: int = 0        # wire bytes: missing chunks only
    chunks_hit_total: int = 0
    chunks_missed_total: int = 0
    chunks_waited_total: int = 0      # singleflight: in flight elsewhere
    fetch_serial_s_total: float = 0.0  # sum of per-task fetch times
    fetch_s_wall: float = 0.0         # slowest build's fetch wall time
    fetch_concurrency: int = 1
    # -- lifecycle wall-clock accounting (event-driven orchestrator) ----
    n_failed: int = 0                 # platforms whose build did not finish
    ready_s_wall: float = 0.0         # slowest platform's wall to READY
    stage_walls: Dict[str, float] = dataclasses.field(default_factory=dict)
    #                                 ^ per-stage max wall offset across fleet
    # -- peer-distribution columns (topology mode) ----------------------
    bytes_upstream_total: int = 0     # wire bytes pulled over registry links
    bytes_peer_total: int = 0         # wire bytes served node-to-node
    peer_fallbacks_total: int = 0     # failed peer pulls re-routed upstream
    node_traffic: Dict[str, NodeTraffic] = dataclasses.field(
        default_factory=dict)         # node id -> this deploy's wire split
    # -- store-lifecycle columns (capacity-bounded nodes) ---------------
    evicted_bytes_total: int = 0      # bytes evicted across stores, this
    #                                   deploy (capacity churn)
    pin_denied_evictions_total: int = 0   # passes pins kept over budget
    refetch_bytes_total: int = 0      # re-fetched bytes of evicted content
    #                                   (the wire price of churn)
    # -- orchestration health -------------------------------------------
    listener_errors_total: int = 0    # swallowed readiness-callback raises
    #                                   across the fleet's builds
    # -- simulated-transport columns (simnet mode) ----------------------
    sim_elapsed_s: float = 0.0        # virtual time this deploy advanced
    faults_fired_total: int = 0       # fault activations virtual time passed
    link_retries_total: int = 0       # transient-link backoff retries
    # -- fleet compile-cache columns (compiled-artifact components) -----
    compile_cache_hits_total: int = 0     # builds that restored an exec
    compile_skips_total: int = 0          # step compiles skipped fleet-wide
    artifact_bytes_fetched_total: int = 0  # compiled-artifact peer wire
    artifact_bytes_published_total: int = 0  # freshly compiled bytes stored
    # -- performance-portable IR columns (core/irmodule.py, docs §13) ----
    # All zero (and their summary line absent) when the split is off, so
    # every pre-§13 column stays byte-identical with it disabled.
    ir_shared_bytes_total: int = 0        # shared-IR bytes sourced fleet-wide
    ir_bytes_published_total: int = 0     # IR modules lowered + published
    platform_tail_bytes_total: int = 0    # per-platform bytes (tail+autotune)
    # -- speculative-placement columns (PlacementPlanner, docs §11) ------
    # Window: since the end of the previous deploy() — pre-positioning
    # runs *between* deploys, and its hits land during this one.  All
    # zero (and their summary lines absent) when no planner is attached,
    # so the existing columns stay byte-identical with it disabled.
    bytes_speculative: int = 0            # speculative wire, all sources
    bytes_speculative_upstream: int = 0   # ... over registry links
    bytes_speculative_peer: int = 0       # ... over peer links
    speculation_hit_bytes: int = 0        # speculated bytes demand used
    speculation_wasted_bytes: int = 0     # speculated bytes evicted unused
    # -- live-migration columns (FleetDeployer.migrate) ------------------
    migrations_total: int = 0             # hand-offs since previous deploy
    migration_downtime_s: float = 0.0     # summed serve-gap (virtual when
    #                                       a simnet clock drives the fleet)
    # -- trust & integrity columns (verify-on-receipt, docs §12) ---------
    corrupt_chunks_total: int = 0         # peer chunks failing the receipt
    #                                       digest check (discarded, never
    #                                       committed)
    corrupt_bytes_total: int = 0          # their bytes — NOT part of
    #                                       bytes_peer_total
    quarantined_nodes: List[str] = dataclasses.field(default_factory=list)
    #                                       ^ nodes blacklisted at deploy end

    @property
    def ok(self) -> bool:
        return all(d.ok for d in self.deployments)

    @property
    def peer_offload_ratio(self) -> float:
        """Fraction of the fleet's wire bytes that peers (not the upstream
        registry) served — the distribution benchmark's headline metric."""
        total = self.bytes_upstream_total + self.bytes_peer_total
        return self.bytes_peer_total / total if total else 0.0

    def instance(self, platform_id: str) -> ContainerInstance:
        for d in self.deployments:
            if d.platform_id == platform_id:
                assert d.instance is not None, d.error
                return d.instance
        raise KeyError(platform_id)

    def summary(self) -> str:
        lines = [f"fleet deploy of {self.cir_name}: "
                 f"{sum(d.ok for d in self.deployments)}/"
                 f"{len(self.deployments)} platforms "
                 f"({self.n_failed} failed), "
                 f"sharing rate {self.sharing_rate * 100:.1f}%, "
                 f"{self.plan_cache_hits} plan-cache hits"]
        if self.chunks_hit_total or self.chunks_missed_total:
            lines.append(
                f"  chunk delta: {self.bytes_delta_total / 2**20:.1f} MiB "
                f"on the wire ({self.chunks_missed_total} chunks fetched, "
                f"{self.chunks_hit_total} hit, "
                f"{self.chunks_waited_total} deduped in flight), "
                f"fetch {self.fetch_s_wall * 1e3:.1f} ms wall vs "
                f"{self.fetch_serial_s_total * 1e3:.1f} ms serial "
                f"@ width {self.fetch_concurrency}")
        if self.ready_s_wall:
            lines.append(
                f"  lifecycle: fleet READY at {self.ready_s_wall * 1e3:.1f} "
                f"ms, COMPLETE at {self.wall_s * 1e3:.1f} ms"
                + (f" (asset tail overlapped "
                   f"{(self.wall_s - self.ready_s_wall) * 1e3:.1f} ms)"
                   if self.wall_s > self.ready_s_wall else ""))
        if self.evicted_bytes_total or self.refetch_bytes_total or \
                self.pin_denied_evictions_total:
            lines.append(
                f"  store churn: {self.evicted_bytes_total / 2**20:.1f} MiB "
                f"evicted, {self.refetch_bytes_total / 2**20:.1f} MiB "
                f"re-fetched, {self.pin_denied_evictions_total} "
                f"pin-denied eviction passes")
        if self.sim_elapsed_s or self.faults_fired_total or \
                self.link_retries_total:
            lines.append(
                f"  simulated transport: {self.sim_elapsed_s:.2f} s virtual "
                f"({self.faults_fired_total} faults fired, "
                f"{self.link_retries_total} link retries)")
        if self.compile_cache_hits_total or self.compile_skips_total or \
                self.artifact_bytes_published_total:
            lines.append(
                f"  compile cache: {self.compile_cache_hits_total} exec "
                f"restore(s), {self.compile_skips_total} step compile(s) "
                f"skipped, artifacts "
                f"{self.artifact_bytes_fetched_total / 2**20:.1f} MiB from "
                f"peers / {self.artifact_bytes_published_total / 2**20:.1f} "
                f"MiB published")
        if self.ir_shared_bytes_total or self.ir_bytes_published_total or \
                self.platform_tail_bytes_total:
            lines.append(
                f"  IR split: {self.ir_shared_bytes_total / 2**20:.1f} MiB "
                f"shared IR sourced, "
                f"{self.ir_bytes_published_total / 2**20:.1f} MiB lowered + "
                f"published, platform tails "
                f"{self.platform_tail_bytes_total / 2**20:.1f} MiB")
        if self.bytes_speculative or self.speculation_hit_bytes or \
                self.speculation_wasted_bytes:
            lines.append(
                f"  speculation: {self.bytes_speculative / 2**20:.1f} MiB "
                f"pre-positioned "
                f"({self.bytes_speculative_peer / 2**20:.1f} MiB peers, "
                f"{self.bytes_speculative_upstream / 2**20:.1f} MiB "
                f"upstream), {self.speculation_hit_bytes / 2**20:.1f} MiB "
                f"hit by demand, "
                f"{self.speculation_wasted_bytes / 2**20:.1f} MiB evicted "
                f"unused")
        if self.migrations_total:
            lines.append(
                f"  migrations: {self.migrations_total} hand-off(s), "
                f"{self.migration_downtime_s * 1e3:.1f} ms total downtime")
        if self.corrupt_chunks_total or self.quarantined_nodes:
            lines.append(
                f"  integrity: {self.corrupt_chunks_total} corrupt chunk(s) "
                f"rejected on receipt "
                f"({self.corrupt_bytes_total / 2**20:.1f} MiB discarded), "
                f"quarantined: "
                f"{', '.join(self.quarantined_nodes) or 'none'}")
        if self.listener_errors_total:
            lines.append(f"  {self.listener_errors_total} readiness-listener "
                         f"error(s) swallowed")
        if self.node_traffic:
            lines.append(
                f"  peer distribution: "
                f"{self.bytes_upstream_total / 2**20:.1f} MiB upstream, "
                f"{self.bytes_peer_total / 2**20:.1f} MiB from peers "
                f"({self.peer_offload_ratio * 100:.1f}% offloaded, "
                f"{self.peer_fallbacks_total} peer fallbacks)")
            for node_id, t in sorted(self.node_traffic.items()):
                lines.append(
                    f"    {node_id:18s} upstream "
                    f"{t.bytes_from_upstream / 2**20:8.1f} MiB, peers "
                    f"{t.bytes_from_peers / 2**20:8.1f} MiB"
                    + (f", speculative {t.spec_bytes_total / 2**20:.1f} MiB"
                       if t.spec_bytes_total else "")
                    + (f" (from {', '.join(sorted(t.peer_sources))})"
                       if t.peer_sources else ""))
        for d in self.deployments:
            if d.ok:
                rep = d.instance.report
                lines.append(
                    f"  {d.platform_id:20s} fetched "
                    f"{rep.bytes_wire_fetched / 2**20:8.1f} MiB "
                    f"({'plan-replay' if rep.plan_cache_hit else 'resolved'})")
            else:
                partial = f", partial fetch {d.report.bytes_wire_fetched}B" \
                    if d.report is not None else ""
                lines.append(f"  {d.platform_id:20s} FAILED: "
                             f"{d.error}{partial}")
        return "\n".join(lines)


@dataclasses.dataclass
class MigrationReport:
    """Outcome of one live hand-off (``FleetDeployer.migrate``).

    ``downtime_s`` is the serve gap: from the moment the source instance
    stops serving until the restored target instance reaches READY —
    virtual seconds when a simnet clock drives the fleet.  The pre-fetch
    happens *before* the gap opens (that is the whole point), so
    ``prefetch_s``/``prefetch_bytes`` are reported separately;
    ``restore_delta_bytes`` is what still had to move inside the gap.
    """
    platform_id: str
    source_node: str
    target_node: str
    downtime_s: float
    prefetch_s: float
    prefetch_bytes: int
    prefetch_bytes_already_present: int
    restore_delta_bytes: int
    compile_cache_hit: bool
    decommissioned: bool
    instance: ContainerInstance


class FleetDeployer:
    """Deploys one CIR to many SpecSheets through a shared staged pipeline.

    **Shared-store mode** (default, ``topology=None``): a single
    ``LazyBuilder`` (one store, one plan cache) serves every platform;
    per-platform builds run on a thread pool.  The store and the registry
    are lock-protected, and resolution is read-mostly, so concurrent builds
    are safe — they just interleave their fetch accounting, which is
    exactly the sharing the fleet report measures.

    **Topology mode** (``topology=FleetTopology(...)``): every node of the
    topology gets its *own* ``ChunkedComponentStore`` and builder (per-node
    singleflight preserved); a fleet-wide ``PeerIndex`` learns which node
    holds which committed chunks (announced on stripe commit and on the
    orchestrator's per-component readiness events), and each node's fetch
    engine sources chunks from the cheapest linked peer that holds them,
    falling back to the upstream registry on a miss or a failed peer
    transfer.  Specs must be placed on nodes (``topology.place``);
    ``FleetResult.node_traffic`` reports the per-node upstream-vs-peer wire
    split.  The plan cache stays fleet-wide (it is control-plane metadata,
    not content).  ``use_peers=False`` keeps the per-node plumbing but
    routes every chunk upstream — the byte-identical no-peer baseline of
    the distribution benchmark.  ``simulate_links=True`` sleeps transfers
    at the topology's per-link bandwidths for wall-clock studies.

    **Simulated transport** (``simnet=SimNetwork(topology, ...)``): link
    time advances a shared *virtual* clock instead of sleeping — a
    200-node WAN fan-out deploys in milliseconds of wall clock with
    byte accounting identical to the threaded path — and the network's
    ``FaultPlan`` injects node-loss / link-flap / partition faults as
    events (``FleetResult`` reports ``sim_elapsed_s``,
    ``faults_fired_total`` and ``link_retries_total``).
    """

    def __init__(self, service: UniformComponentService,
                 store: Optional[LocalComponentStore] = None,
                 plan_cache: Optional[BuildPlanCache] = None,
                 link_bandwidth_bps: float = 500e6,
                 max_workers: int = 8,
                 fetch_workers: int = 8,
                 fetch_simulate_bps: Optional[float] = None,
                 overlap: bool = True,
                 topology: Optional[FleetTopology] = None,
                 use_peers: bool = True,
                 simulate_links: bool = False,
                 eviction_policy: str = "lru",
                 simnet: Optional[SimNetwork] = None,
                 compile_cache: Optional[CompileCache] = None,
                 verify_receipts: bool = True,
                 quarantine: Optional[Quarantine] = None,
                 ir_components: bool = False):
        if eviction_policy not in EVICTION_POLICIES:
            raise ValueError(f"unknown eviction policy {eviction_policy!r} "
                             f"(one of {EVICTION_POLICIES})")
        if simnet is not None:
            if topology is None:
                raise ValueError("simnet needs a topology (its links are "
                                 "what the virtual clock models)")
            if simnet.topology is not topology:
                raise ValueError("simnet was built for a different topology")
            if simulate_links:
                raise ValueError("simulate_links sleeps real wall clock; "
                                 "simnet is virtual time — pick one")
        # `is None`, not truthiness: both caches define __len__, so an
        # empty caller-supplied cache is falsy and `or` would silently
        # swap in a fresh one behind the caller's back
        self.plan_cache = BuildPlanCache() if plan_cache is None \
            else plan_cache
        # the compiled-executable index is fleet-wide control-plane state,
        # exactly like the plan cache: one node's compile is every same-
        # platform-class peer's hit (the bytes still move peer-to-peer)
        self.compile_cache = CompileCache() if compile_cache is None \
            else compile_cache
        # performance-portable split (docs §13, opt-in): every node
        # builder compiles a shared platform-neutral IR module plus a
        # per-platform artifact tail instead of one monolithic executable
        self.ir_components = ir_components
        self.max_workers = max_workers
        self.overlap = overlap
        self.topology = topology
        self.simnet = simnet
        self.peer_index: Optional[PeerIndex] = None
        # trust layer (docs §12): verify-on-receipt is on by default; one
        # fleet-wide Quarantine collects strikes against lying peers on
        # the fleet's clock (virtual under a simnet, so decay and
        # convergence are measured in virtual time)
        self.quarantine: Optional[Quarantine] = None
        self._byzantine: Set[str] = set()
        self._node_stores: Dict[str, ChunkedComponentStore] = {}
        self._node_peerings: Dict[str, NodePeering] = {}
        self._node_builders: Dict[str, LazyBuilder] = {}
        self._warm_leases: Dict[str, str] = {}   # warm base id -> lease id
        self._warm_gen = 0
        # speculative placement + migration bookkeeping: the planner (if
        # any) attaches via attach_planner; marks anchor the "since end of
        # previous deploy" windows of FleetResult's speculation/migration
        # columns (planner rounds and migrations run *between* deploys)
        self.planner: Optional[Any] = None
        self._spec_mark: Tuple[int, int, int, int, int] = (0, 0, 0, 0, 0)
        self._migrations_total = 0
        self._migration_downtime_s = 0.0
        self._migration_mark: Tuple[int, float] = (0, 0.0)
        if topology is None:
            # a caller-supplied store keeps its own policy; the default
            # store gets the requested one
            self.store: Optional[LocalComponentStore] = \
                store if store is not None \
                else ChunkedComponentStore(eviction_policy=eviction_policy)
            self.builder: Optional[LazyBuilder] = LazyBuilder(
                service, self.store,
                link_bandwidth_bps=link_bandwidth_bps,
                plan_cache=self.plan_cache,
                fetch_workers=fetch_workers,
                fetch_simulate_bps=fetch_simulate_bps,
                compile_cache=self.compile_cache,
                ir_components=ir_components)
            return
        if store is not None:
            raise ValueError(
                "topology mode builds one store per node — do not pass a "
                "shared store")
        self.store = None
        self.builder = None
        self.quarantine = quarantine if quarantine is not None \
            else Quarantine(clock=self._clock_now)
        self.peer_index = PeerIndex(quarantine=self.quarantine)
        for node_id in topology.node_ids():
            # the node's capacity bounds its store; eviction retracts this
            # node's PeerIndex announcements before dropping bytes, and the
            # cheapest-to-restore policy consults the peering layer for
            # which chunks a linked peer could restore
            st = ChunkedComponentStore(
                capacity_bytes=topology.node(node_id).capacity_bytes,
                eviction_policy=eviction_policy)
            peering = NodePeering(node_id, topology, self.peer_index,
                                  service, st,
                                  peer_stores=self._node_stores,
                                  enabled=use_peers,
                                  simulate=simulate_links,
                                  transport=simnet.transport_for(node_id)
                                  if simnet is not None else None,
                                  verify_receipts=verify_receipts,
                                  quarantine=self.quarantine,
                                  tamper_hook=self._tamper_hook)
            st.eviction_listeners.append(peering.on_chunks_evicted)
            st.peer_probe_batch = peering.peer_held_subset
            lb = LazyBuilder(service, st,
                             link_bandwidth_bps=link_bandwidth_bps,
                             plan_cache=self.plan_cache,
                             fetch_workers=fetch_workers,
                             fetch_simulate_bps=None,
                             peering=peering,
                             compile_cache=self.compile_cache,
                             ir_components=ir_components)
            lb.readiness_listeners.append(peering.on_component_ready)
            self._node_stores[node_id] = st
            self._node_peerings[node_id] = peering
            self._node_builders[node_id] = lb
        if simnet is not None:
            # when virtual time passes a node-loss fault, the dead node's
            # advertisements leave the index — later selections route
            # around it instead of burning a retract-and-fallback each
            simnet.on_node_loss(self.peer_index.drop_node)

    # ------------------------------------------------------------------
    def node_store(self, node_id: str) -> ChunkedComponentStore:
        return self._node_stores[node_id]

    def node_builder(self, node_id: str) -> LazyBuilder:
        """One topology node's builder — the restore path of a scaled-to-
        zero instance rebuilds through the node that will run it."""
        return self._node_builders[node_id]

    def node_traffic(self, node_id: str) -> NodeTraffic:
        """Cumulative (all deploys) wire split of one node."""
        return self._node_peerings[node_id].traffic

    def node_peering(self, node_id: str) -> NodePeering:
        """One topology node's chunk-source router (the speculative
        replication executor fetches through it)."""
        return self._node_peerings[node_id]

    # -- byzantine chaos injection (docs §12) ---------------------------
    def _tamper_hook(self, src: str, chunks: Sequence[Any]) -> List[str]:
        """The fleet's receipt-tamper model: a node marked byzantine
        corrupts EVERY chunk it serves (the strongest adversary — weaker
        ones only quarantine slower).  Installed on every peering; an
        empty byzantine set makes it a no-op."""
        if src in self._byzantine:
            return [ch.id for ch in chunks]
        return []

    def mark_byzantine(self, node_ids: Sequence[str]) -> None:
        """Turn ``node_ids`` into lying peers: chunks they serve from now
        on arrive corrupted and fail verify-on-receipt.  Chaos-test
        injection only — honest recovery (retract, re-source, quarantine)
        runs through the production code path."""
        unknown = [n for n in node_ids if n not in self._node_peerings]
        if unknown:
            raise ValueError(f"unknown topology node(s): {unknown}")
        self._byzantine.update(node_ids)

    def clear_byzantine(self) -> None:
        self._byzantine.clear()

    def attach_planner(self, planner: Any) -> None:
        """Register a ``PlacementPlanner``: every successful topology-mode
        deployment from here on feeds its demand model."""
        if self.topology is None:
            raise ValueError("a placement planner needs topology mode")
        self.planner = planner

    def _stores(self) -> List[LocalComponentStore]:
        return [self.store] if self.store is not None \
            else list(self._node_stores.values())

    def _lifecycle_totals(self) -> Tuple[int, int, int]:
        """(evicted_bytes, pin_denied_evictions, refetch_bytes) summed
        across this deployer's stores — cumulative; deploy() reports the
        per-deploy delta."""
        ev = pd = rf = 0
        for s in self._stores():
            ls = s.lifecycle_stats
            ev += ls.evicted_bytes
            pd += ls.pin_denied_evictions
            rf += ls.refetch_bytes
        return ev, pd, rf

    def _spec_totals(self) -> Tuple[int, int, int, int, int]:
        """(spec_bytes, hit, wasted, upstream wire, peer wire) summed
        across stores + peerings — cumulative; deploy() reports the delta
        since the end of the previous deploy."""
        sb = hb = wb = 0
        for s in self._stores():
            ls = s.lifecycle_stats
            sb += ls.spec_bytes
            hb += ls.spec_hit_bytes
            wb += ls.spec_wasted_bytes
        up = sum(p.traffic.spec_bytes_from_upstream
                 for p in self._node_peerings.values())
        pe = sum(p.traffic.spec_bytes_from_peers
                 for p in self._node_peerings.values())
        return sb, hb, wb, up, pe

    def _clock_now(self) -> float:
        """The fleet's time base: the virtual clock under a simnet, wall
        clock otherwise — migration downtime is measured on it."""
        return self.simnet.now if self.simnet is not None \
            else time.perf_counter()

    def _builder_for(self, spec: SpecSheet) -> Tuple[LazyBuilder,
                                                     Optional[str]]:
        if self.topology is None:
            assert self.builder is not None
            return self.builder, None
        node_id = self.topology.node_for(spec.platform_id)
        return self._node_builders[node_id], node_id

    # ------------------------------------------------------------------
    def deploy(self, cir: CIR, specs: Sequence[SpecSheet],
               mesh: Any = None,
               overrides: Optional[Mapping[str, Any]] = None,
               assemble: bool = False,
               compile_steps: bool = False) -> FleetResult:
        """Deploy ``cir`` to every platform in ``specs`` concurrently.

        Each platform's build runs non-blocking through the event-driven
        orchestrator; the deployer waits on the instance *lifecycle* —
        recording the wall to READY (deployable) separately from COMPLETE
        (weight tail landed, accounting final) — instead of blocking on
        ``build()`` returning.
        """
        hits_before = self.plan_cache.stats.hits
        stored_before = sum(s.stats.bytes_stored for s in self._stores())
        requested_before = sum(s.stats.bytes_requested
                               for s in self._stores())
        traffic_before = {n: p.traffic.snapshot()
                          for n, p in self._node_peerings.items()}
        lc_before = self._lifecycle_totals()
        sim_before = (self.simnet.clock.now, self.simnet.faults_fired) \
            if self.simnet is not None else (0.0, 0)
        # placement is validated up front: a misplaced spec is a caller
        # error, not a per-platform deployment failure
        builders = [self._builder_for(s) for s in specs]
        t0 = time.perf_counter()

        def one(spec: SpecSheet, builder: LazyBuilder,
                node_id: Optional[str]) -> PlatformDeployment:
            t = time.perf_counter()
            inst: Optional[ContainerInstance] = None
            ready_s = 0.0
            try:
                inst = builder.build(
                    cir, spec, mesh=mesh, overrides=overrides,
                    assemble=assemble, compile_steps=compile_steps,
                    overlap=self.overlap, block=False)
                inst.wait("ready")
                ready_s = time.perf_counter() - t
                inst.wait("complete")
                return PlatformDeployment(spec.platform_id, inst,
                                          wall_s=time.perf_counter() - t,
                                          ready_s=ready_s,
                                          report=inst.report,
                                          node_id=node_id)
            except Exception as e:  # noqa: BLE001 — per-platform isolation
                # a build that got past resolution leaves a partial report:
                # its fetch bytes are real work the fleet totals must count,
                # and a build that reached READY before the tail failed
                # keeps its measured time-to-deployable
                return PlatformDeployment(
                    spec.platform_id, None,
                    error=f"{type(e).__name__}: {e}",
                    wall_s=time.perf_counter() - t,
                    ready_s=ready_s,
                    report=inst.report if inst is not None else None,
                    node_id=node_id)

        workers = max(1, min(self.max_workers, len(specs)))
        if workers == 1:
            deployments = [one(s, b, n) for s, (b, n) in zip(specs, builders)]
        else:
            with ThreadPoolExecutor(max_workers=workers) as pool:
                deployments = list(pool.map(
                    lambda sb: one(sb[0], sb[1][0], sb[1][1]),
                    zip(specs, builders)))

        # all reports — failed builds' partial fetch work included, so the
        # fleet cannot overstate sharing by dropping bytes it transferred
        reports = [d.report for d in deployments if d.report is not None]
        fetched = sum(r.bytes_fetched for r in reports)
        total = sum(r.bytes_total_components for r in reports)
        # sharing over THIS deploy only (the store may serve many deploys)
        req = sum(s.stats.bytes_requested
                  for s in self._stores()) - requested_before
        stored = sum(s.stats.bytes_stored
                     for s in self._stores()) - stored_before
        stage_walls: Dict[str, float] = {}
        for r in reports:
            for stage, off in r.stage_s.items():
                stage_walls[stage] = max(stage_walls.get(stage, 0.0), off)
        node_traffic = {n: p.traffic.snapshot().since(traffic_before[n])
                        for n, p in self._node_peerings.items()}
        lc_after = self._lifecycle_totals()
        # demand intake for the placement planner: every successful deploy
        # is a demand observation for (node, CIR) — the planner's EWMA
        if self.planner is not None:
            key = cir.digest()
            for d in deployments:
                if d.ok and d.node_id is not None:
                    self.planner.observe(
                        d.node_id, key,
                        list(d.instance.bundle.components()))
        # speculation/migration columns: delta since the end of the
        # PREVIOUS deploy (planner rounds + migrations run between
        # deploys; their hits land during this one) — existing columns
        # keep their call-time windows untouched
        spec_now = self._spec_totals()
        spec_delta = tuple(a - b for a, b in zip(spec_now, self._spec_mark))
        self._spec_mark = spec_now
        mig_delta = (self._migrations_total - self._migration_mark[0],
                     self._migration_downtime_s - self._migration_mark[1])
        self._migration_mark = (self._migrations_total,
                                self._migration_downtime_s)
        return FleetResult(
            cir_name=cir.name,
            deployments=deployments,
            wall_s=time.perf_counter() - t0,
            bytes_fetched_total=fetched,
            bytes_components_total=total,
            sharing_rate=(1.0 - stored / req) if req else 0.0,
            plan_cache_hits=self.plan_cache.stats.hits - hits_before,
            bytes_delta_total=sum(r.bytes_delta_fetched for r in reports),
            chunks_hit_total=sum(r.chunks_hit for r in reports),
            chunks_missed_total=sum(r.chunks_missed for r in reports),
            chunks_waited_total=sum(r.chunks_waited for r in reports),
            fetch_serial_s_total=sum(r.fetch_serial_s for r in reports),
            fetch_s_wall=max((r.fetch_s for r in reports), default=0.0),
            fetch_concurrency=max((r.fetch_concurrency for r in reports),
                                  default=1),
            n_failed=sum(not d.ok for d in deployments),
            ready_s_wall=max((d.ready_s for d in deployments if d.ok),
                             default=0.0),
            stage_walls=stage_walls,
            bytes_upstream_total=sum(t.bytes_from_upstream
                                     for t in node_traffic.values()),
            bytes_peer_total=sum(t.bytes_from_peers
                                 for t in node_traffic.values()),
            peer_fallbacks_total=sum(t.peer_fallbacks
                                     for t in node_traffic.values()),
            node_traffic=node_traffic,
            evicted_bytes_total=lc_after[0] - lc_before[0],
            pin_denied_evictions_total=lc_after[1] - lc_before[1],
            refetch_bytes_total=lc_after[2] - lc_before[2],
            listener_errors_total=sum(r.listener_errors for r in reports),
            sim_elapsed_s=self.simnet.clock.now - sim_before[0]
            if self.simnet is not None else 0.0,
            faults_fired_total=self.simnet.faults_fired - sim_before[1]
            if self.simnet is not None else 0,
            link_retries_total=sum(t.link_retries
                                   for t in node_traffic.values()),
            compile_cache_hits_total=sum(r.compile_cache_hit
                                         for r in reports),
            compile_skips_total=sum(r.compile_skips for r in reports),
            artifact_bytes_fetched_total=sum(r.artifact_bytes_fetched
                                             for r in reports),
            artifact_bytes_published_total=sum(r.artifact_bytes_published
                                               for r in reports),
            ir_shared_bytes_total=sum(r.ir_shared_bytes for r in reports),
            ir_bytes_published_total=sum(r.ir_bytes_published
                                         for r in reports),
            platform_tail_bytes_total=sum(r.platform_tail_bytes
                                          for r in reports),
            bytes_speculative=spec_delta[0],
            speculation_hit_bytes=spec_delta[1],
            speculation_wasted_bytes=spec_delta[2],
            bytes_speculative_upstream=spec_delta[3],
            bytes_speculative_peer=spec_delta[4],
            migrations_total=mig_delta[0],
            migration_downtime_s=mig_delta[1],
            corrupt_chunks_total=sum(t.corrupt_chunks
                                     for t in node_traffic.values()),
            corrupt_bytes_total=sum(t.corrupt_bytes
                                    for t in node_traffic.values()),
            quarantined_nodes=sorted(self.quarantine.active())
            if self.quarantine is not None else [],
        )

    # ------------------------------------------------------------------
    def migrate(self, inst: ContainerInstance, target_node: str,
                mesh: Any = None,
                decommission: bool = True) -> MigrationReport:
        """Live hand-off of a running serve instance to ``target_node``.

        Protocol (docs/cir-format.md §11):

          1. **Snapshot** the source instance (``core/snapshot.py``) — the
             restorable control-plane record; requires COMPILED or later.
          2. **Pin the source** content under a ``migrate:`` hand-off
             lease: the cheapest chunk source for the transfer must not be
             evicted mid-hand-off.
          3. **Pre-fetch to the target** under a ``spec:`` soft lease
             (peer-first, speculative traffic columns) while the source
             keeps serving — the expensive byte movement happens *outside*
             the serve gap.
          4. **Hand off**: the source stops serving; the snapshot restores
             on the target's builder (pin replay + chunk-delta fetch +
             compile-cache hit).  The gap from stop to target-READY is the
             measured ``downtime_s`` (virtual time under a simnet).
          5. **Flip placement** to the target, release the target's spec
             lease (restore demand already promoted the content) and the
             source's hand-off lease.
          6. **Decommission** (optional): retract the source's
             announcements for the migrated chunks — strictly node-scoped,
             so the target's (and any third node's) announcements survive
             — and demote the source's now-idle copy to the speculative
             eviction tier, making it the first thing churn reclaims.
        """
        if self.topology is None:
            raise ValueError("migrate() needs topology mode (per-node "
                             "stores and placement)")
        snap = snapshot_instance(inst)
        platform_id = snap.platform_id
        source_node = self.topology.node_for(platform_id)
        if target_node not in self.topology.node_ids():
            raise ValueError(f"unknown target node {target_node!r}")
        if target_node == source_node:
            raise ValueError(f"instance already runs on {target_node!r}")
        comps = list(inst.bundle.components())
        src_store = self._node_stores[source_node]
        tgt_store = self._node_stores[target_node]
        seq = next(_MIGRATE_SEQ)
        handoff_lease = f"migrate:{platform_id}#{seq}"
        src_store.acquire_build_lease(handoff_lease, comps)
        spec_lease = f"{SPEC_LEASE_PREFIX}{inst.cir.digest()[:16]}#mig{seq}"
        try:
            t_pre = self._clock_now()
            pre = speculative_replicate(
                tgt_store, comps, spec_lease,
                peering=self._node_peerings[target_node])
            prefetch_s = self._clock_now() - t_pre
            # -- the serve gap opens: source stops, target restores ------
            t_gap = self._clock_now()
            new_inst = restore_instance(snap,
                                        self._node_builders[target_node],
                                        mesh=mesh, overlap=self.overlap,
                                        block=False)
            new_inst.wait("ready")
            downtime_s = self._clock_now() - t_gap
            self.topology.place(platform_id, target_node)
            new_inst.wait("complete")   # weight tail streams while serving
        finally:
            tgt_store.release_build(spec_lease)
            src_store.release_build(handoff_lease)
        if decommission:
            # node-scoped retraction: only the SOURCE's advertisements go;
            # the target's announcements for the same chunk ids — landed
            # during prefetch/restore — stay authoritative
            assert self.peer_index is not None
            chunk_ids = [ch.id for c in comps
                         for ch in src_store.chunks_of(c)]
            self.peer_index.retract(source_node, chunk_ids)
            # the source's idle copy becomes first-evictable (spec tier);
            # a later demand hit would promote it right back
            src_store.acquire_build_lease(
                f"{SPEC_LEASE_PREFIX}retired:{platform_id}#{seq}", comps)
        self._migrations_total += 1
        self._migration_downtime_s += downtime_s
        return MigrationReport(
            platform_id=platform_id,
            source_node=source_node,
            target_node=target_node,
            downtime_s=downtime_s,
            prefetch_s=prefetch_s,
            prefetch_bytes=pre.bytes_fetched,
            prefetch_bytes_already_present=pre.bytes_already_present,
            restore_delta_bytes=new_inst.report.bytes_delta_fetched,
            compile_cache_hit=bool(new_inst.report.compile_cache_hit),
            decommissioned=decommission,
            instance=new_inst,
        )

    # ------------------------------------------------------------------
    def warm(self, cir: CIR, specs: Sequence[SpecSheet],
             overrides: Optional[Mapping[str, Any]] = None,
             precompile: bool = False) -> int:
        """Pre-populate the plan cache + store for a fleet (no assembly).

        ``precompile=True`` additionally assembles and compiles each spec
        on the seed: the per-platform-class compiled artifacts land in the
        fleet compile cache and the seed's store (announced to peers), so
        the first *real* cold deploy of every platform class skips its XLA
        compile and pulls the executable over a peer link.  The artifacts
        are pinned together with the warmed content.

        Returns the number of platforms whose plans are now cached — a
        deployment service calls this off the hot path so real deploys
        replay plans and hit the store.

        Under a topology, warming targets the **cloud seed node only**:
        every platform's plan lands in the fleet-wide plan cache, but all
        content is fetched into the seed's store (and announced), so the
        edge nodes' first real deploys replay plans and source their
        chunks from the seed over peer links instead of their slow
        upstream — warming an edge node over its own thin registry link
        is exactly what the topology exists to avoid.

        Warmed content is **pinned** (a ``warm:<cir digest>`` lease on the
        warmed store): on a capacity-bounded node, a churny workload must
        not silently evict the seed content edges are about to peer off.
        The pin is acquired as soon as the build's components are known
        (usually while the build's own plan-time lease §8 still holds) and
        then *verified*: anything a concurrent deploy's eviction managed to
        take in the hand-over race is re-fetched under the already-held
        warm pin, which cannot be evicted again — so warm() returning
        means the content is resident AND pinned.  A re-warm acquires the
        new lease generation before releasing the old one.
        ``release_warm`` drops the lease when the CIR is retired.
        """
        if self.topology is None:
            assert self.store is not None
            builder, store = self.builder, self.store
        else:
            seed = self.topology.seed
            assert seed is not None, "topology has no nodes"
            builder, store = self._node_builders[seed], \
                self._node_stores[seed]
        ok = 0
        comps: Dict[str, Any] = {}
        insts = []
        for spec in specs:
            # non-blocking: every spec's build is launched up front (they
            # run concurrently on their driver threads) and resolution is
            # done when build() returns, so all components can be pinned
            # while the builds — and their plan-time leases — are in flight
            try:
                inst = builder.build(cir, spec, overrides=overrides,
                                     assemble=precompile,
                                     compile_steps=precompile,
                                     overlap=self.overlap,
                                     block=False)
            except Exception:  # noqa: BLE001 — per-platform isolation
                continue
            insts.append((spec, inst))
            for c in inst.bundle.components():
                comps[c.digest()] = c
        if comps:
            self._pin_warm(store, cir, list(comps.values()))
        for spec, inst in insts:
            try:
                inst.wait("complete")
                # a build's lease can release (lifecycle COMPLETE on the
                # driver thread) before our pin landed — verify, and
                # re-land anything evicted in that window under the pin
                if self._warmed_missing(store,
                                        inst.bundle.components()):
                    builder.build(cir, spec, overrides=overrides,
                                  assemble=False, overlap=self.overlap)
                ok += 1
            except Exception:  # noqa: BLE001 — per-platform isolation
                continue
        if precompile:
            # re-pin with the freshly published executables included: the
            # seed must hold them for peers exactly as long as it holds the
            # warmed content they accompany (overlap-then-release keeps the
            # original pin alive until the wider one is in place)
            arts = self.compile_cache.artifacts()
            art_comps: Dict[str, Any] = {}
            for _spec, inst in insts:
                if inst.compile_key is None:
                    continue
                art = arts.get(inst.compile_key)
                if art is None:
                    continue
                art_comps[art.component.digest()] = art.component
                if art.autotune is not None:
                    art_comps[art.autotune.digest()] = art.autotune
                if self.ir_components:
                    # the shared IR module the tails were lowered from must
                    # stay peer-sourceable exactly as long as the tails do
                    ir = ir_module_component(inst.lock, art.entry_names)
                    art_comps[ir.digest()] = ir
            if art_comps:
                self._pin_warm(store, cir,
                               list(comps.values()) + list(art_comps.values()))
        return ok

    @staticmethod
    def _warmed_missing(store: LocalComponentStore, comps) -> bool:
        """Did any warmed content go absent before the warm pin landed?"""
        if isinstance(store, ChunkedComponentStore):
            return any(store.missing_chunks(c) for c in comps)
        return any(not store.has(c) for c in comps)

    def _pin_warm(self, store: LocalComponentStore, cir: CIR,
                  comps: Sequence[Any]) -> None:
        """Pin warmed content under a fresh generation-suffixed lease, then
        release the previous generation: overlap-then-release, so neither a
        re-warm nor the per-spec pin growth above ever leaves a window
        where already-warmed content is unpinned."""
        base = f"warm:{cir.digest()[:16]}"
        self._warm_gen += 1
        new_id = f"{base}#{self._warm_gen}"
        store.acquire_build_lease(new_id, comps)
        old_id = self._warm_leases.get(base)
        if old_id is not None:
            store.release_build(old_id)
        self._warm_leases[base] = new_id

    def release_warm(self, cir: CIR) -> bool:
        """Release the pin lease ``warm()`` took for ``cir`` (the seed
        content becomes evictable again)."""
        base = f"warm:{cir.digest()[:16]}"
        lease = self._warm_leases.pop(base, None)
        if lease is None:
            return False
        if self.topology is None:
            assert self.store is not None
            return self.store.release_build(lease)
        seed = self.topology.seed
        return seed is not None and \
            self._node_stores[seed].release_build(lease)
