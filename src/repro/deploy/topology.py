"""Fleet topology + peer-to-peer chunk distribution (the sky/edge scenario).

The paper's headline deployment is one CIR across heterogeneous nodes of a
cloud-edge continuum with minimal wire traffic.  ``FleetDeployer``'s shared
store models a *single* deployment host; this module models a *fleet of
hosts*:

  * ``FleetTopology``   — named nodes (each with its own upstream link to
    the component registry) plus symmetric peer links with per-link
    bandwidths (cloud↔edge, edge↔edge).
  * ``PeerIndex``       — the fleet-wide gossip table: which node holds
    which committed chunks.  Nodes announce chunks as stripes commit and
    whole components when the orchestrator's readiness event proves their
    content present; announcements are derived from actual store presence,
    so a failed transfer can never advertise content a node does not hold.
  * ``NodePeering``     — one node's chunk-source selector, plugged into
    the ``FetchEngine``: every claimed stripe is split by source, peers
    holding a chunk are preferred over the upstream registry (cheapest —
    highest-bandwidth — link first), and a peer that fails mid-transfer is
    retracted from the index and the chunks re-pulled from upstream, so
    one node's crash degrades a neighbour to upstream cost, never to a
    failed build.

Accounting: a node's ``NodeTraffic`` splits its wire bytes into
upstream-vs-peer (summing exactly to the build reports'
``bytes_delta_fetched``), and only upstream pulls charge the component
service — peer transfers never touch the registry link, which is the
metric the edge fan-out benchmark (``benchmarks/distribution.py``) drives
to near-``1/N``.

Trust (docs §12): peer-sourced stripes are **verified on receipt** —
every received chunk is digest-checked against its content-derived id
before the engine may commit it.  A corrupt stripe raises
``ChunkIntegrityError`` (a ``PeerTransferError``): the holder is
retracted, the chunks re-sourced upstream, and the lying node takes a
``Quarantine`` strike; past the threshold it is blacklisted fleet-wide in
the ``PeerIndex`` (with time decay, so a repaired node is readmitted).
Corrupt bytes land in dedicated ``NodeTraffic.corrupt_*`` columns and are
never folded into ``bytes_from_peers``, so the ``bytes_total ==
Σ bytes_delta_fetched`` identity survives byzantine peers.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Set, Tuple)

from ..core.chunkstore import ChunkedComponentStore
from ..core.component import UniformComponent
from ..core.registry import UniformComponentService
from ..core.simnet import LinkDownError, NodeDownError, WallClockTransport
from ..core.store import Chunk

# Default node↔registry link when a node does not declare one (500 Mbps —
# the benchmark suite's representative WAN link).  All ``*_bps`` values in
# this module are BYTES/s, matching ``FetchEngine.simulate_bps``.
DEFAULT_UPSTREAM_BPS = 500e6 / 8

# Transient-link-fault retry policy: an upstream pull that hits a
# ``LinkDownError`` (simulated transport, flapping WAN uplink) backs off
# in *virtual* time — base doubling per attempt — and retries; the fault
# is permanent for the build once the attempts are exhausted.
LINK_RETRY_BACKOFF_S = 0.05
MAX_LINK_RETRIES = 10

# Byzantine-peer policy (docs §12): a node whose served stripes fail
# verify-on-receipt THRESHOLD times inside the DECAY window is quarantined
# fleet-wide — no peer selects it as a source.  Strikes age out, so a node
# that stops serving corrupt content is readmitted after DECAY_S without a
# new strike (operators repair nodes; a permanent blacklist would bleed
# fleet capacity forever on one bit-flip burst).
QUARANTINE_THRESHOLD = 3
QUARANTINE_DECAY_S = 300.0


class TopologyError(ValueError):
    pass


class PeerTransferError(RuntimeError):
    """A peer-to-peer chunk transfer failed (peer crashed, link dropped, or
    the peer no longer holds the advertised content)."""


class ChunkIntegrityError(PeerTransferError):
    """A peer-sourced stripe failed verify-on-receipt: one or more received
    chunks did not hash to their content-derived ids.  Subclasses
    ``PeerTransferError`` so the standard recovery (retract the holder,
    re-source upstream) applies — plus a ``Quarantine`` strike against the
    lying node and dedicated ``corrupt_*`` accounting."""

    def __init__(self, src: str, corrupt_ids: Sequence[str],
                 corrupt_bytes: int):
        super().__init__(
            f"peer {src!r} served {len(corrupt_ids)} corrupt chunk(s) "
            f"({corrupt_bytes} bytes) — discarded before commit")
        self.src = src
        self.corrupt_ids = list(corrupt_ids)
        self.corrupt_bytes = corrupt_bytes


class Quarantine:
    """Fleet-wide blacklist of nodes that serve corrupt chunks (docs §12).

    Strike-based with time decay: ``record_corruption`` timestamps a strike
    against the node; a node is quarantined while it has ``threshold`` or
    more strikes younger than ``decay_s``.  No strike is ever needed to
    *serve* — only corrupt receipts add strikes — so honest nodes are
    unaffected, and a quarantined node naturally decays back to eligible
    once it stops lying.  ``clock`` is injectable (the fleet passes its
    virtual clock under simnet, so decay and convergence run in virtual
    time); defaults to ``time.monotonic``.
    """

    def __init__(self, threshold: int = QUARANTINE_THRESHOLD,
                 decay_s: float = QUARANTINE_DECAY_S,
                 clock: Optional[Callable[[], float]] = None):
        if threshold < 1:
            raise ValueError("quarantine threshold must be >= 1")
        self.threshold = threshold
        self.decay_s = decay_s
        self._clock = clock if clock is not None else time.monotonic
        self._strikes: Dict[str, List[float]] = {}
        # node -> virtual/wall time it FIRST crossed the threshold — kept
        # across decay so chaos benchmarks can report convergence time
        self.quarantined_at: Dict[str, float] = {}
        self._lock = threading.Lock()

    def _live_strikes(self, node_id: str, now: float) -> List[float]:
        """Prune strikes past the decay window (caller holds the lock)."""
        live = [t for t in self._strikes.get(node_id, ())
                if now - t < self.decay_s]
        if live:
            self._strikes[node_id] = live
        else:
            self._strikes.pop(node_id, None)
        return live

    def record_corruption(self, node_id: str) -> bool:
        """Register one corrupt-stripe strike; returns whether the node is
        now quarantined."""
        now = self._clock()
        with self._lock:
            live = self._live_strikes(node_id, now)
            live.append(now)
            self._strikes[node_id] = live
            if len(live) >= self.threshold:
                self.quarantined_at.setdefault(node_id, now)
                return True
            return False

    def strikes(self, node_id: str) -> int:
        now = self._clock()
        with self._lock:
            return len(self._live_strikes(node_id, now))

    def is_quarantined(self, node_id: str) -> bool:
        now = self._clock()
        with self._lock:
            return len(self._live_strikes(node_id, now)) >= self.threshold

    def active(self) -> Set[str]:
        """The currently quarantined node ids (one snapshot, for batch
        source selection)."""
        now = self._clock()
        with self._lock:
            return {n for n in list(self._strikes)
                    if len(self._live_strikes(n, now)) >= self.threshold}


@dataclasses.dataclass(frozen=True)
class FleetNode:
    """One deployment host of the fleet.  ``capacity_bytes`` bounds the
    node's chunk store (None == unbounded — the classic datacenter host);
    a bounded node evicts under churn, see ``docs/cir-format.md`` §8."""
    node_id: str
    upstream_bps: float = DEFAULT_UPSTREAM_BPS   # node ↔ registry link
    capacity_bytes: Optional[int] = None         # store budget (disk)


class FleetTopology:
    """Nodes, per-link bandwidths, and platform placement.

    Links are symmetric and direct (no multi-hop routing): a node can pull
    chunks from a peer only if an explicit link exists.  ``seed`` names the
    node that ``FleetDeployer.warm()`` pre-populates — conventionally the
    cloud node whose upstream link is cheap.
    """

    def __init__(self) -> None:
        self._nodes: Dict[str, FleetNode] = {}
        self._links: Dict[frozenset, float] = {}
        self._placement: Dict[str, str] = {}     # platform_id -> node_id
        self.seed: Optional[str] = None

    # -- construction ---------------------------------------------------
    def add_node(self, node_id: str,
                 upstream_bps: float = DEFAULT_UPSTREAM_BPS,
                 seed: bool = False,
                 capacity_bytes: Optional[int] = None) -> FleetNode:
        if node_id in self._nodes:
            raise TopologyError(f"node {node_id!r} already exists")
        if capacity_bytes is not None and capacity_bytes <= 0:
            raise TopologyError("capacity_bytes must be positive (or None)")
        node = FleetNode(node_id, upstream_bps=upstream_bps,
                         capacity_bytes=capacity_bytes)
        self._nodes[node_id] = node
        if seed or self.seed is None:
            self.seed = node_id
        return node

    def link(self, a: str, b: str, bps: float) -> None:
        """Declare a symmetric peer link between nodes ``a`` and ``b``."""
        for n in (a, b):
            if n not in self._nodes:
                raise TopologyError(f"unknown node {n!r}")
        if a == b:
            raise TopologyError("a node cannot link to itself")
        if bps <= 0:
            raise TopologyError("link bandwidth must be positive")
        self._links[frozenset((a, b))] = bps

    def place(self, platform_id: str, node_id: str) -> None:
        """Assign a platform (SpecSheet.platform_id) to a node."""
        if node_id not in self._nodes:
            raise TopologyError(f"unknown node {node_id!r}")
        self._placement[platform_id] = node_id

    # -- queries --------------------------------------------------------
    def node_ids(self) -> List[str]:
        return list(self._nodes)

    def node(self, node_id: str) -> FleetNode:
        return self._nodes[node_id]

    def bandwidth(self, a: str, b: str) -> Optional[float]:
        """Peer-link bandwidth between ``a`` and ``b``; None if unlinked."""
        return self._links.get(frozenset((a, b)))

    def peers_of(self, node_id: str) -> List[str]:
        return sorted(n for key in self._links for n in key
                      if node_id in key and n != node_id)

    def node_for(self, platform_id: str) -> str:
        try:
            return self._placement[platform_id]
        except KeyError:
            raise TopologyError(
                f"platform {platform_id!r} is not placed on any node — "
                f"call topology.place(platform_id, node_id)") from None

    # -- canonical shapes -----------------------------------------------
    @classmethod
    def edge_fanout(cls, n_edges: int,
                    cloud_id: str = "cloud",
                    cloud_upstream_bps: float = 1.25e9,
                    edge_upstream_bps: float = 6.25e6,
                    cloud_edge_bps: float = 125e6,
                    edge_edge_bps: float = 2.5e8,
                    edge_capacity_bytes: Optional[int] = None,
                    cloud_capacity_bytes: Optional[int] = None
                    ) -> "FleetTopology":
        """One cloud seed + N edge nodes: edges have a slow registry link
        (50 Mbps default) but fast local links to the cloud (1 Gbps) and
        faster still to each other (same-site LAN, 2 Gbps) — the sky/edge
        fan-out of the distribution benchmark.  Bandwidths are bytes/s.
        ``edge_capacity_bytes`` bounds every edge's chunk store (the tight
        disks of the churn benchmark); the cloud is unbounded by default."""
        topo = cls()
        topo.add_node(cloud_id, upstream_bps=cloud_upstream_bps, seed=True,
                      capacity_bytes=cloud_capacity_bytes)
        edges = [f"edge-{i}" for i in range(n_edges)]
        for e in edges:
            topo.add_node(e, upstream_bps=edge_upstream_bps,
                          capacity_bytes=edge_capacity_bytes)
            topo.link(cloud_id, e, cloud_edge_bps)
        for i, a in enumerate(edges):
            for b in edges[i + 1:]:
                topo.link(a, b, edge_edge_bps)
        return topo

    @classmethod
    def hetero_edge(cls, platform_classes: Sequence[str] = ("cpu", "gpu",
                                                            "tpu"),
                    cloud_id: str = "cloud",
                    cloud_upstream_bps: float = 1.25e9,
                    edge_upstream_bps: float = 6.25e6,
                    cloud_edge_bps: float = 125e6,
                    edge_edge_bps: float = 2.5e8,
                    edge_capacity_bytes: Optional[int] = None,
                    cloud_capacity_bytes: Optional[int] = None
                    ) -> "FleetTopology":
        """One cloud seed + one edge node per platform *class*: the
        genuinely heterogeneous continuum (cpu-host + gpu + tpu in one
        topology) of the §13 hetero benchmark.  Node ids are
        ``{class}-edge``; the link shape matches ``edge_fanout`` — every
        edge links the cloud and every other edge, so the shared IR can
        flow once fleet-wide while each platform tail stays inside its
        class."""
        topo = cls()
        topo.add_node(cloud_id, upstream_bps=cloud_upstream_bps, seed=True,
                      capacity_bytes=cloud_capacity_bytes)
        edges = [f"{p}-edge" for p in platform_classes]
        for e in edges:
            topo.add_node(e, upstream_bps=edge_upstream_bps,
                          capacity_bytes=edge_capacity_bytes)
            topo.link(cloud_id, e, cloud_edge_bps)
        for i, a in enumerate(edges):
            for b in edges[i + 1:]:
                topo.link(a, b, edge_edge_bps)
        return topo


# ---------------------------------------------------------------------------
# Peer index (fleet-wide chunk gossip)
# ---------------------------------------------------------------------------

class PeerIndex:
    """Which node holds which committed chunks.

    Announcements come from two places: the fetch engine announces each
    stripe the moment its chunks commit (so a peer can serve a large asset
    while the announcer is still mid-build), and the orchestrator's
    per-component readiness event announces the whole component once its
    content is proven present.  Both paths verify against the announcing
    node's store, so the index can only ever over-forget, never over-claim.

    An optional ``Quarantine`` filters *source selection* (``best_many``):
    a blacklisted node is never chosen as a pull source, fleet-wide, the
    moment it crosses the threshold.  ``holders``/``holders_many`` stay
    unfiltered on purpose — the eviction oracle (``peer_holds``) asks
    "does the content exist elsewhere", and a quarantined node's copy
    still exists; only pulls from it are refused.
    """

    def __init__(self, quarantine: Optional[Quarantine] = None) -> None:
        self._holders: Dict[str, Set[str]] = {}     # chunk id -> node ids
        self.quarantine = quarantine
        self._lock = threading.Lock()

    def announce(self, node_id: str, chunk_ids: Sequence[str]) -> None:
        with self._lock:
            for cid in chunk_ids:
                self._holders.setdefault(cid, set()).add(node_id)

    def retract(self, node_id: str, chunk_ids: Sequence[str]) -> None:
        """Forget ``node_id`` as a holder of ``chunk_ids`` (a transfer from
        it failed): later source selections fall back to other peers or
        upstream instead of retrying a dead advertisement.

        Strictly node-scoped: a chunk's entry is only dropped when its
        holder set empties, so retracting a migration *source* (or an
        evicting node) can never orphan the target's — or any third
        node's — announcements for the same chunk ids, even mid-flight."""
        with self._lock:
            for cid in chunk_ids:
                holders = self._holders.get(cid)
                if holders is not None:
                    holders.discard(node_id)
                    if not holders:
                        del self._holders[cid]

    def drop_node(self, node_id: str) -> None:
        """Forget every advertisement of a node (it left the fleet).
        Node-scoped like ``retract``: other holders of the same chunks keep
        their entries — dropping a migration source mid-handoff leaves the
        target's announcements (including ones landing concurrently, which
        serialize on the index lock) fully intact."""
        with self._lock:
            for cid in [cid for cid, h in self._holders.items()
                        if node_id in h]:
                self._holders[cid].discard(node_id)
                if not self._holders[cid]:
                    del self._holders[cid]

    def holders(self, chunk_id: str) -> Tuple[str, ...]:
        with self._lock:
            return tuple(sorted(self._holders.get(chunk_id, ())))

    def holders_many(self, chunk_ids: Sequence[str]
                     ) -> Dict[str, Tuple[str, ...]]:
        """Batch holder lookup under one lock acquisition (unsorted — the
        callers below only test membership)."""
        with self._lock:
            return {cid: tuple(self._holders.get(cid, ()))
                    for cid in chunk_ids}

    def best_many(self, chunk_ids: Sequence[str],
                  link_bps: Mapping[str, float],
                  exclude: str) -> Dict[str, Optional[str]]:
        """Per-chunk cheapest holder among ``link_bps``'s peers (highest
        bandwidth, node-id tie-break), ``None`` where no linked peer
        advertises the chunk.  One lock acquisition for a whole stripe,
        iterating the smaller of (linked peers, holders) per chunk — at
        fleet scale a popular chunk has hundreds of holders but a node
        only a handful of links, so selection must not walk the holder
        set per chunk."""
        out: Dict[str, Optional[str]] = {}
        # one quarantine snapshot per stripe, taken before the index lock
        # (the two locks never nest the other way)
        banned: Set[str] = self.quarantine.active() \
            if self.quarantine is not None else set()
        with self._lock:
            for cid in chunk_ids:
                holders = self._holders.get(cid)
                best: Optional[Tuple[float, str]] = None
                if holders:
                    if len(link_bps) < len(holders):
                        cands = ((p, bps) for p, bps in link_bps.items()
                                 if p in holders)
                    else:
                        cands = ((p, link_bps[p]) for p in holders
                                 if p in link_bps)
                    for peer, bps in cands:
                        if peer == exclude or peer in banned:
                            continue
                        if best is None or (-bps, peer) < best:
                            best = (-bps, peer)
                out[cid] = best[1] if best is not None else None
        return out

    def chunks_held(self, node_id: str) -> int:
        with self._lock:
            return sum(1 for h in self._holders.values() if node_id in h)

    def __len__(self) -> int:
        with self._lock:
            return len(self._holders)


# ---------------------------------------------------------------------------
# Per-node traffic accounting + source selection
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class NodeTraffic:
    """One node's wire-byte split.  ``bytes_from_upstream +
    bytes_from_peers`` equals the node's builds' ``bytes_delta_fetched``
    sum — source selection moves bytes between links, it never changes how
    many are transferred."""
    node_id: str
    bytes_from_upstream: int = 0
    bytes_from_peers: int = 0
    chunks_from_upstream: int = 0
    chunks_from_peers: int = 0
    peer_fallbacks: int = 0          # failed peer pulls re-routed upstream
    link_retries: int = 0            # transient-link-fault backoff retries
    peer_sources: Dict[str, int] = dataclasses.field(default_factory=dict)
    #                                ^ peer node -> bytes pulled from it
    # Compiled-artifact transfers (fleet compile cache) are tracked apart
    # from resolved-content traffic: they never count into ``bytes_total``,
    # which keeps the bytes_total == bytes_delta_fetched identity intact
    # whether or not a build hit the compile cache.
    artifact_bytes_from_peers: int = 0
    artifact_chunks_from_peers: int = 0
    # Speculative pre-positioning (placement planner / migration prefetch,
    # docs §11) is likewise tracked apart from demand traffic: nothing a
    # build *demanded* moved, so these never count into ``bytes_total`` —
    # the bytes_total == bytes_delta_fetched identity holds with the
    # planner enabled or disabled.
    spec_bytes_from_upstream: int = 0
    spec_bytes_from_peers: int = 0
    spec_chunks: int = 0
    # Verify-on-receipt rejections (docs §12): chunks a peer served that
    # failed the digest check.  Discarded before commit and re-sourced
    # upstream, so these bytes are NEVER part of ``bytes_from_peers`` (the
    # honest re-pull is) — the bytes_total == bytes_delta_fetched identity
    # holds with byzantine peers in the fleet.
    corrupt_chunks: int = 0
    corrupt_bytes: int = 0
    # Performance-portable IR transfers (docs §13) are likewise kept out
    # of ``bytes_total``: the shared IR module and the per-platform tail
    # (split executable + autotune table) ride the artifact-style
    # peer-only path in their own columns, so the wire split proves how
    # many of a deploy's derived bytes were platform-neutral vs
    # platform-specific — and every column is zero with the split off.
    ir_shared_bytes: int = 0         # shared-IR bytes pulled from peers
    ir_chunks_from_peers: int = 0
    platform_tail_bytes: int = 0     # tail + autotune bytes from peers

    @property
    def bytes_total(self) -> int:
        return self.bytes_from_upstream + self.bytes_from_peers

    @property
    def spec_bytes_total(self) -> int:
        return self.spec_bytes_from_upstream + self.spec_bytes_from_peers

    @property
    def peer_offload_ratio(self) -> float:
        """Fraction of this node's wire bytes served by peers."""
        return self.bytes_from_peers / self.bytes_total \
            if self.bytes_total else 0.0

    def as_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["bytes_total"] = self.bytes_total
        d["peer_offload_ratio"] = self.peer_offload_ratio
        d["spec_bytes_total"] = self.spec_bytes_total
        return d

    def snapshot(self) -> "NodeTraffic":
        return dataclasses.replace(self, peer_sources=dict(self.peer_sources))

    def since(self, before: "NodeTraffic") -> "NodeTraffic":
        """The traffic delta accrued after ``before`` was snapshotted."""
        return NodeTraffic(
            node_id=self.node_id,
            bytes_from_upstream=self.bytes_from_upstream
            - before.bytes_from_upstream,
            bytes_from_peers=self.bytes_from_peers - before.bytes_from_peers,
            chunks_from_upstream=self.chunks_from_upstream
            - before.chunks_from_upstream,
            chunks_from_peers=self.chunks_from_peers
            - before.chunks_from_peers,
            peer_fallbacks=self.peer_fallbacks - before.peer_fallbacks,
            link_retries=self.link_retries - before.link_retries,
            peer_sources={p: b - before.peer_sources.get(p, 0)
                          for p, b in self.peer_sources.items()
                          if b - before.peer_sources.get(p, 0)},
            artifact_bytes_from_peers=self.artifact_bytes_from_peers
            - before.artifact_bytes_from_peers,
            artifact_chunks_from_peers=self.artifact_chunks_from_peers
            - before.artifact_chunks_from_peers,
            spec_bytes_from_upstream=self.spec_bytes_from_upstream
            - before.spec_bytes_from_upstream,
            spec_bytes_from_peers=self.spec_bytes_from_peers
            - before.spec_bytes_from_peers,
            spec_chunks=self.spec_chunks - before.spec_chunks,
            corrupt_chunks=self.corrupt_chunks - before.corrupt_chunks,
            corrupt_bytes=self.corrupt_bytes - before.corrupt_bytes,
            ir_shared_bytes=self.ir_shared_bytes - before.ir_shared_bytes,
            ir_chunks_from_peers=self.ir_chunks_from_peers
            - before.ir_chunks_from_peers,
            platform_tail_bytes=self.platform_tail_bytes
            - before.platform_tail_bytes,
        )


class NodePeering:
    """One node's chunk-source router, plugged into its ``FetchEngine``.

    ``fetch_stripe`` splits a claimed stripe by best source: a peer that
    holds the chunk and shares a link with this node beats the upstream
    registry; among candidate peers the highest-bandwidth link wins
    (node-id tie-break, deterministic).  A peer pull is verified against
    the peer's actual store — an advertisement the peer cannot honour (it
    crashed mid-transfer, or the injection hook below says the link died)
    raises ``PeerTransferError``: the peer is retracted from the index for
    those chunks and they are re-pulled from upstream.  With ``enabled=
    False`` every chunk routes upstream through the same code path, which
    is what makes the no-peer baseline byte-identical per node.

    Link time runs through a **transport**: ``simulate=True`` installs
    the real-sleep ``WallClockTransport`` (each pull sleeps ``bytes /
    link_bps`` on the node's upstream link or the chosen peer link, so
    wall-clock benchmarks see real link asymmetry); a ``simnet``-backed
    ``SimTransport`` advances virtual time instead and may raise injected
    fault errors — a ``NodeDownError``/``LinkDownError`` on a peer pull
    degrades to ``PeerTransferError`` (retract + upstream fallback), a
    transient ``LinkDownError`` on the upstream link is retried with
    exponential virtual backoff (counted in ``NodeTraffic.link_retries``)
    and only fails the build once ``MAX_LINK_RETRIES`` is exhausted.
    Accounting is identical under any transport (or none).
    """

    def __init__(self, node_id: str, topology: FleetTopology,
                 index: PeerIndex, service: UniformComponentService,
                 store: ChunkedComponentStore,
                 peer_stores: Mapping[str, ChunkedComponentStore],
                 enabled: bool = True,
                 simulate: bool = False,
                 transport: Optional[Any] = None,
                 max_link_retries: int = MAX_LINK_RETRIES,
                 link_retry_backoff_s: float = LINK_RETRY_BACKOFF_S,
                 verify_receipts: bool = True,
                 quarantine: Optional[Quarantine] = None,
                 tamper_hook: Optional[
                     Callable[[str, Sequence[Chunk]], Sequence[str]]] = None):
        self.node_id = node_id
        self.topology = topology
        self.index = index
        self.service = service
        self.store = store
        self.peer_stores = peer_stores
        self.enabled = enabled
        self.simulate = simulate
        # verify-on-receipt policy (docs §12): digest-check every
        # peer-sourced chunk before the engine may commit it.  The
        # quarantine collects strikes against lying sources; tamper_hook
        # is the chaos-injection point — (src, chunks) -> ids that
        # "arrived corrupted" — used by tests and the byzantine benchmark
        # instead of monkeypatching transfer internals.
        self.verify_receipts = verify_receipts
        self.quarantine = quarantine
        self.tamper_hook = tamper_hook
        if transport is None and simulate:
            transport = WallClockTransport()
        self.transport = transport
        self.max_link_retries = max_link_retries
        self.link_retry_backoff_s = link_retry_backoff_s
        self.traffic = NodeTraffic(node_id)
        self._lock = threading.Lock()

    # -- announcements (store-verified, can never over-claim) -----------
    def announce_chunks(self, chunks: Sequence[Chunk]) -> None:
        present = self.store.present_chunks([ch.id for ch in chunks])
        self.index.announce(self.node_id, present)
        # a capacity eviction can interleave between the presence check and
        # the announce landing (its retract-before-drop fires first, so our
        # announce would re-add chunks already gone): re-verify and retract
        # anything that went absent — the index may over-forget, never
        # over-claim
        still = set(self.store.present_chunks(present))
        stale = [cid for cid in present if cid not in still]
        if stale:
            self.index.retract(self.node_id, stale)

    def on_component_ready(self, c: UniformComponent) -> None:
        """Orchestrator readiness listener: a component's content was just
        proven present — announce every chunk the store actually holds
        (a degraded-timeout readiness signal announces only what landed)."""
        self.announce_chunks(self.store.chunks_of(c))

    # -- eviction hooks (store lifecycle, docs/cir-format.md §8) --------
    def on_chunks_evicted(self, chunk_ids: Sequence[str]) -> None:
        """Store eviction listener.  Fired — under the store lock — BEFORE
        the bytes are dropped, so this node's advertisements are retracted
        while the content is still present: a peer that races the eviction
        either transfers in time or sees a store-verified failure and
        falls back upstream; the index never over-claims.  Must not call
        back into the store (it holds the store lock)."""
        self.index.retract(self.node_id, list(chunk_ids))

    def peer_holds(self, chunk_id: str) -> bool:
        """Cheapest-to-restore eviction oracle: does a *linked* peer still
        hold this chunk?  If yes, evicting it is cheap — restoring costs a
        peer link, not the upstream registry.  May run under the store
        lock; touches only the index and the topology."""
        for peer in self.index.holders(chunk_id):
            if peer == self.node_id:
                continue
            if self.topology.bandwidth(self.node_id, peer) is not None:
                return True
        return False

    def peer_held_subset(self, chunk_ids: Sequence[str]) -> Set[str]:
        """Batch form of ``peer_holds`` — one index snapshot for a whole
        eviction pass instead of a cross-lock round-trip per chunk.  May
        run under the store lock."""
        linked = set(self.topology.peers_of(self.node_id))
        out: Set[str] = set()
        for cid, holders in self.index.holders_many(chunk_ids).items():
            if any(h != self.node_id and h in linked for h in holders):
                out.add(cid)
        return out

    # -- source selection -----------------------------------------------
    def _best_source(self, chunk_id: str) -> Optional[str]:
        best: Optional[Tuple[float, str]] = None
        for peer in self.index.holders(chunk_id):
            if peer == self.node_id:
                continue
            if self.quarantine is not None \
                    and self.quarantine.is_quarantined(peer):
                continue
            bps = self.topology.bandwidth(self.node_id, peer)
            if bps is None:
                continue
            if best is None or (-bps, peer) < best:
                best = (-bps, peer)
        return best[1] if best is not None else None

    def select(self, chunks: Sequence[Chunk]
               ) -> List[Tuple[Optional[str], List[Chunk]]]:
        """Group ``chunks`` by chosen source (None == upstream registry),
        preserving first-seen source order.  Selection is batched: one
        index lock acquisition per stripe (``PeerIndex.best_many``), so
        a 200-node fleet — where a hot chunk's holder set approaches the
        fleet size — selects in O(chunks × links), not O(chunks ×
        holders)."""
        if not self.enabled:
            return [(None, list(chunks))] if chunks else []
        link_bps = {p: self.topology.bandwidth(self.node_id, p)
                    for p in self.topology.peers_of(self.node_id)}
        best = self.index.best_many([ch.id for ch in chunks], link_bps,
                                    exclude=self.node_id)
        groups: Dict[Optional[str], List[Chunk]] = {}
        order: List[Optional[str]] = []
        for ch in chunks:
            src = best[ch.id]
            if src not in groups:
                groups[src] = []
                order.append(src)
            groups[src].append(ch)
        return [(src, groups[src]) for src in order]

    # -- transfers ------------------------------------------------------
    def _peer_pull(self, src: str, component: UniformComponent,
                   chunks: Sequence[Chunk]) -> None:
        """Pull ``chunks`` from peer ``src``.  Tests monkeypatch this to
        inject mid-transfer failures; the real implementation fails when
        the peer does not actually hold what the index advertised, or
        when the transport's fault plan kills the source node or the
        peer link inside the transfer window."""
        peer_store = self.peer_stores.get(src)
        if peer_store is None:
            raise PeerTransferError(f"peer {src!r} is gone")
        missing = [ch.id for ch in chunks if not peer_store.has_chunk(ch.id)]
        if missing:
            raise PeerTransferError(
                f"peer {src!r} no longer holds {len(missing)} advertised "
                f"chunk(s)")
        if self.transport is not None:
            nbytes = sum(ch.size for ch in chunks)
            bps = self.topology.bandwidth(self.node_id, src)
            try:
                self.transport.peer_transfer(src, nbytes, bps=bps)
            except NodeDownError as e:
                if e.node_id == self.node_id:
                    # *this* node died — no fallback can save its build
                    raise
                raise PeerTransferError(str(e)) from e
            except LinkDownError as e:
                # a peer-link outage is not worth waiting out: upstream
                # fallback converges the build now
                raise PeerTransferError(str(e)) from e
        if self.verify_receipts:
            self._verify_stripe(src, chunks)

    def _verify_stripe(self, src: str, chunks: Sequence[Chunk]) -> None:
        """Verify-on-receipt (docs §12): re-hash every received chunk and
        check it against its content-derived id.

        Chunk ids ARE content digests (length-prefixed sha256 piece
        digests, §5), so verification is one sha256 over the received
        bytes per chunk — modeled here as a streaming digest pass over
        the stripe, one hash update per received chunk (content is
        virtual, its cost is not).  ``tamper_hook`` decides which chunks
        "arrived corrupted"; a hit bumps the store's ``corrupt_rejected``
        counter and raises ``ChunkIntegrityError`` BEFORE the engine can
        commit anything from this stripe."""
        corrupt: List[str] = []
        # the receipt-side digest pass — the <3%-overhead cost the
        # integrity benchmark gates
        digest = hashlib.sha256()
        for ch in chunks:
            digest.update(ch.id.encode())
        digest.hexdigest()
        if self.tamper_hook is not None:
            corrupt = list(self.tamper_hook(src, chunks))
        if corrupt:
            sizes = {ch.id: ch.size for ch in chunks}
            nbytes = sum(sizes.get(cid, 0) for cid in corrupt)
            self.store.chunk_stats.corrupt_rejected += len(corrupt)
            raise ChunkIntegrityError(src, corrupt, nbytes)

    def _upstream_pull(self, component: UniformComponent,
                       chunks: Sequence[Chunk], staged: NodeTraffic) -> None:
        nbytes = sum(ch.size for ch in chunks)
        if self.transport is not None:
            bps = self.topology.node(self.node_id).upstream_bps
            attempt = 0
            while True:
                try:
                    self.transport.upstream_transfer(nbytes, bps=bps)
                    break
                except LinkDownError:
                    # transient WAN flap: back off in (virtual) time and
                    # retry — there is no alternative source for content
                    # no peer holds, so the uplink fault is only fatal
                    # once the budget is exhausted
                    attempt += 1
                    if attempt > self.max_link_retries:
                        raise
                    staged.link_retries += 1
                    self.transport.backoff(
                        self.link_retry_backoff_s * 2 ** (attempt - 1))
        self.service.fetch_chunks(component, nbytes, len(chunks))
        staged.bytes_from_upstream += nbytes
        staged.chunks_from_upstream += len(chunks)

    def fetch_stripe(self, component: UniformComponent,
                     stripe: Sequence[Tuple[Chunk, threading.Event]]) -> None:
        """Transfer one claimed stripe, peer-first with upstream fallback.
        Called by the fetch engine before it commits the stripe.

        Traffic is staged locally and folded into ``self.traffic`` only
        once the whole stripe succeeded: the engine aborts a failed stripe
        (its bytes never reach ``bytes_delta_fetched``), so a partially
        transferred group must not be counted either — that is what keeps
        ``NodeTraffic.bytes_total`` equal to the builds' delta-byte sum
        even across failures and retries.
        """
        staged = self._pull_groups(component, [ch for ch, _ev in stripe])
        with self._lock:
            t = self.traffic
            t.bytes_from_upstream += staged.bytes_from_upstream
            t.bytes_from_peers += staged.bytes_from_peers
            t.chunks_from_upstream += staged.chunks_from_upstream
            t.chunks_from_peers += staged.chunks_from_peers
            t.peer_fallbacks += staged.peer_fallbacks
            t.link_retries += staged.link_retries
            t.corrupt_chunks += staged.corrupt_chunks
            t.corrupt_bytes += staged.corrupt_bytes
            for src, nbytes in staged.peer_sources.items():
                t.peer_sources[src] = t.peer_sources.get(src, 0) + nbytes

    def _pull_groups(self, component: UniformComponent,
                     chunks: Sequence[Chunk]) -> NodeTraffic:
        """Source-split transfer body shared by the demand and speculative
        stripe paths: peer-first with store-verified fallback to upstream.
        Returns the *staged* traffic — the caller decides which columns of
        ``self.traffic`` it folds into (demand vs ``spec_*``)."""
        staged = NodeTraffic(self.node_id)
        for src, group in self.select(chunks):
            if src is None:
                self._upstream_pull(component, group, staged)
                continue
            nbytes = sum(ch.size for ch in group)
            try:
                self._peer_pull(src, component, group)
            except PeerTransferError as e:
                # a dead peer must not poison later selections: retract its
                # advertisement and pay the upstream price for these chunks
                self.index.retract(src, [ch.id for ch in group])
                if isinstance(e, ChunkIntegrityError):
                    # a LYING peer additionally takes a quarantine strike;
                    # its corrupt bytes are discarded (never peer bytes) —
                    # the honest upstream re-pull below is what counts
                    staged.corrupt_chunks += len(e.corrupt_ids)
                    staged.corrupt_bytes += e.corrupt_bytes
                    if self.quarantine is not None:
                        self.quarantine.record_corruption(src)
                staged.peer_fallbacks += 1
                self._upstream_pull(component, group, staged)
                continue
            staged.bytes_from_peers += nbytes
            staged.chunks_from_peers += len(group)
            staged.peer_sources[src] = \
                staged.peer_sources.get(src, 0) + nbytes
        return staged

    def fetch_spec_stripe(self, component: UniformComponent,
                          stripe: Sequence[Tuple[Chunk, threading.Event]]
                          ) -> None:
        """Transfer a *speculative* stripe (placement pre-positioning or
        migration prefetch, docs §11) over the same peer-first source
        selection as ``fetch_stripe``, but folded into the ``spec_*``
        traffic columns: no build demanded these bytes, so they must not
        contaminate ``bytes_total`` — that identity is what lets the fleet
        accounting stay byte-identical with the planner disabled.  Fallback
        and retry behaviour (retraction, upstream re-route, virtual
        backoff) are shared with the demand path."""
        staged = self._pull_groups(component, [ch for ch, _ev in stripe])
        with self._lock:
            t = self.traffic
            t.spec_bytes_from_upstream += staged.bytes_from_upstream
            t.spec_bytes_from_peers += staged.bytes_from_peers
            t.spec_chunks += staged.chunks_from_upstream \
                + staged.chunks_from_peers
            t.peer_fallbacks += staged.peer_fallbacks
            t.link_retries += staged.link_retries
            t.corrupt_chunks += staged.corrupt_chunks
            t.corrupt_bytes += staged.corrupt_bytes

    def _peer_only_pull(self, component: UniformComponent,
                        chunks: Sequence[Chunk]
                        ) -> Optional[Tuple[int, int]]:
        """Shared body of the derived-component transfers (compiled
        artifacts, §13 platform tails and IR modules): linked peers ONLY.

        Derived components are born on fleet nodes — the upstream
        registry never stores them — so there is no upstream fallback:
        this returns ``None`` unless *every* chunk can be sourced from a
        peer, and the caller rebuilds the content locally (then
        re-publishes).  A peer that cannot honour its advertisement is
        retracted, exactly as on the resolved-content path.  Returns
        ``(bytes, chunks)`` on success.

        A ``NodeDownError`` naming *this* node propagates — its build is
        dead and must fail, not silently rebuild on a dead node.
        """
        if not chunks:
            return (0, 0)
        if not self.enabled:
            return None
        staged_bytes = 0
        groups = self.select(chunks)
        if any(src is None for src, _chs in groups):
            return None                # no linked peer holds part of it
        for src, chs in groups:
            try:
                self._peer_pull(src, component, chs)
            except PeerTransferError as e:
                self.index.retract(src, [ch.id for ch in chs])
                if isinstance(e, ChunkIntegrityError):
                    # a corrupt derived stripe strikes the liar exactly
                    # like resolved content — the caller rebuilds locally
                    with self._lock:
                        self.traffic.corrupt_chunks += len(e.corrupt_ids)
                        self.traffic.corrupt_bytes += e.corrupt_bytes
                    if self.quarantine is not None:
                        self.quarantine.record_corruption(src)
                return None
            staged_bytes += sum(ch.size for ch in chs)
        return staged_bytes, len(chunks)

    def fetch_artifact_stripe(self, component: UniformComponent,
                              stripe: Sequence[Tuple[Chunk, threading.Event]]
                              ) -> bool:
        """Transfer a compiled-artifact stripe from linked peers ONLY
        (``_peer_only_pull``).  Successful transfers land in the
        ``artifact_*`` traffic columns, never in ``bytes_total``."""
        res = self._peer_only_pull(component, [ch for ch, _ev in stripe])
        if res is None:
            return False
        with self._lock:
            self.traffic.artifact_bytes_from_peers += res[0]
            self.traffic.artifact_chunks_from_peers += res[1]
        return True

    def fetch_tail_stripe(self, component: UniformComponent,
                          stripe: Sequence[Tuple[Chunk, threading.Event]]
                          ) -> bool:
        """Platform-tail variant (docs §13): the same peer-only transfer
        as ``fetch_artifact_stripe``, additionally folded into
        ``platform_tail_bytes`` — the per-node proof that with the IR
        split on, the only platform-specific wire bytes a node pulls are
        the tail executable and its autotune table."""
        res = self._peer_only_pull(component, [ch for ch, _ev in stripe])
        if res is None:
            return False
        with self._lock:
            self.traffic.artifact_bytes_from_peers += res[0]
            self.traffic.artifact_chunks_from_peers += res[1]
            self.traffic.platform_tail_bytes += res[0]
        return True

    def fetch_ir_stripe(self, component: UniformComponent,
                        stripe: Sequence[Tuple[Chunk, threading.Event]]
                        ) -> bool:
        """Shared-IR variant (docs §13): the same peer-only transfer as
        ``fetch_artifact_stripe``, landing in ``ir_shared_bytes`` /
        ``ir_chunks_from_peers`` — the platform-neutral module is lowered
        once fleet-wide, so these bytes appear at most once per node and
        never cross into ``bytes_total``."""
        res = self._peer_only_pull(component, [ch for ch, _ev in stripe])
        if res is None:
            return False
        with self._lock:
            self.traffic.ir_shared_bytes += res[0]
            self.traffic.ir_chunks_from_peers += res[1]
        return True
