"""Param-spec trees, norms, position embeddings, shared model utilities.

Parameters are nested dicts of arrays.  Modules declare nested dicts of
``P`` specs (shape + *logical axes* + init); ``init_tree`` materializes them
and ``axes_tree`` mirrors the structure with logical-axis tuples, so the
sharding plan can map every leaf without drift.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed | small
    scale: float = 1.0
    dtype: Optional[str] = None   # None -> the tree-level dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


SpecTree = Dict[str, Any]   # nested dict of P


def _leaf_init(key, p: P, dtype) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    fan_in = p.shape[0] if p.shape else 1
    if p.init == "embed":
        std = 0.02
    elif p.init == "small":
        std = 0.02
    else:
        std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, p.shape, jnp.float32) * std * p.scale
            ).astype(dtype)


def init_tree(key: jax.Array, specs: SpecTree, dtype=jnp.float32) -> Dict:
    leaves, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(key, len(leaves))
    vals = [_leaf_init(k, p, jnp.dtype(p.dtype) if p.dtype else dtype)
            for k, p in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def eval_shape_tree(specs: SpecTree, dtype=jnp.float32) -> Dict:
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(
            p.shape, jnp.dtype(p.dtype) if p.dtype else dtype),
        specs, is_leaf=lambda x: isinstance(x, P))


def axes_tree(specs: SpecTree) -> Dict:
    return jax.tree.map(lambda p: p.axes, specs,
                        is_leaf=lambda x: isinstance(x, P))


def stacked(specs: SpecTree, n: int) -> SpecTree:
    """Prefix every leaf with a scanned 'layer' dimension."""
    return jax.tree.map(
        lambda p: P((n,) + p.shape, ("layer",) + p.axes, p.init, p.scale,
                    p.dtype),
        specs, is_leaf=lambda x: isinstance(x, P))


def count_params(specs: SpecTree) -> int:
    tot = 0
    for p in jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        n = 1
        for s in p.shape:
            n *= s
        tot += n
    return tot


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-6, plus_one: bool = False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w) if plus_one else w
    return (y * scale).astype(dt)


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w + b).astype(dt)


def norm_spec(cfg, d: Optional[int] = None) -> SpecTree:
    d = d or cfg.d_model
    if cfg.norm == "rms":
        return {"w": P((d,), ("embed",),
                       "zeros" if cfg.arch_id.startswith("gemma") else "ones")}
    return {"w": P((d,), ("embed",), "ones"),
            "b": P((d,), ("embed",), "zeros")}


def apply_norm(params, x, cfg):
    if cfg.norm == "rms":
        return rms_norm(x, params["w"],
                        plus_one=cfg.arch_id.startswith("gemma"))
    return layer_norm(x, params["w"], params["b"])


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE / partial / M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float64) / dim))


def apply_rope(x, positions, theta: float = 10000.0,
               partial: float = 1.0,
               mrope_sections: Tuple[int, ...] = ()):
    """x: (..., seq, heads, head_dim); positions: (batch, seq) int or
    (3, batch, seq) for M-RoPE."""
    hd = x.shape[-1]
    rot = int(hd * partial)
    rot -= rot % 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    freqs = jnp.asarray(rope_freqs(rot, theta), jnp.float32)   # (rot/2,)

    if mrope_sections:
        # Qwen2-VL M-RoPE: frequency slots split across (t, h, w) sections.
        assert positions.ndim == 3, "M-RoPE needs (3, batch, seq) positions"
        secs = list(mrope_sections)
        assert sum(secs) == rot // 2, (secs, rot)
        pos_parts = []
        start = 0
        for i, s in enumerate(secs):
            pos_parts.append(
                positions[i][..., None].astype(jnp.float32) * freqs[start:start + s])
            start += s
        ang = jnp.concatenate(pos_parts, axis=-1)      # (b, s, rot/2)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs   # (b, s, rot/2)

    cos = jnp.cos(ang)[..., None, :]   # (b, s, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    y = jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)
    return jnp.concatenate([y, x_pass], axis=-1) if x_pass.shape[-1] else y


def sinusoidal_pos(positions, dim: int) -> jax.Array:
    """MusicGen-style absolute sinusoidal embeddings; positions (b, s)."""
    half = dim // 2
    freqs = jnp.asarray(rope_freqs(2 * half, 10000.0), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embed_spec(cfg) -> SpecTree:
    sp: SpecTree = {"tok": P((cfg.vocab, cfg.d_model), ("vocab", "embed"), "embed")}
    return sp


def head_spec(cfg) -> SpecTree:
    if cfg.tie_embeddings:
        return {}
    return {"w": P((cfg.d_model, cfg.vocab), ("embed", "vocab"), "normal")}


def embed_tokens(params, tokens, cfg):
    e = params["tok"][tokens]          # (b, s, d)
    if cfg.arch_id.startswith("gemma"):
        e = e * jnp.asarray(math.sqrt(cfg.d_model), e.dtype)
    return e


def lm_logits(head_params, embed_params, x, cfg):
    if cfg.tie_embeddings:
        w = embed_params["tok"].T
    else:
        w = head_params["w"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)


def cross_entropy(logits, labels, mask=None):
    """logits f32 (b, s, v); labels int (b, s)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(nll.dtype)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)
