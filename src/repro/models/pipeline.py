"""GPipe-style pipeline parallelism as a composable combinator.

``pipeline_apply`` runs a per-layer function over S pipeline stages laid
out along one mesh axis, streaming M microbatches through a shard_map +
``jax.lax.ppermute`` schedule.  It is registered as the
``parallel/pipeline`` uniform component (opt-in via the ``workload =
'pipeline'`` override): the production cells use DP×TP which dominates at
the assigned sizes, but the combinator is the building block a
depth-starved topology (many pods, few chips each) would select.

Schedule (forward only; the driver wraps it in jax.grad as usual):
  T = M + S - 1 ticks.  At tick t, stage s processes microbatch t - s
  (when 0 ≤ t - s < M); between ticks, activations rotate one stage along
  the axis with ppermute.  Bubble fraction = (S-1)/T, the GPipe bound.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(layer_fn: Callable, stage_params, x, *, mesh: Mesh,
                   axis: str = "model", microbatches: int = None):
    """Apply ``layers_per_stage × n_stages`` layers to ``x`` via pipeline
    stages on mesh axis ``axis``.

    layer_fn      : (params_one_layer, x) -> x
    stage_params  : pytree with leading dims (n_stages, layers_per_stage)
                    — stage dim sharded over ``axis``
    x             : (batch, ...) activations; batch % microbatches == 0
    Returns x after all layers, same sharding as the input.
    """
    S = mesh.shape[axis]
    M = microbatches or S
    B = x.shape[0]
    assert B % M == 0, (B, M)

    in_specs = (
        jax.tree.map(lambda _: P(axis), stage_params,
                     is_leaf=lambda l: hasattr(l, "shape")),
        P(),             # x replicated into the pipeline entry
    )
    out_specs = P()

    def run(params_local, x_full):
        # params_local: (1, layers_per_stage, ...) — this stage's layers
        p_stage = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        mb = x_full.reshape((M, B // M) + x_full.shape[1:])

        def stage_compute(xb):
            def body(h, p_layer):
                return layer_fn(p_layer, h), None
            h, _ = jax.lax.scan(body, xb, p_stage)
            return h

        # state: the activation each stage currently holds
        state = jnp.zeros_like(mb[0])
        out = jnp.zeros_like(mb)
        T = M + S - 1
        perm = [(i, (i + 1) % S) for i in range(S)]

        def tick(t, carry):
            state, out = carry
            m_in = t                      # microbatch entering stage 0
            # stage 0 ingests a fresh microbatch while it has supply
            take = jnp.logical_and(stage == 0, m_in < M)
            fresh = jax.lax.dynamic_index_in_dim(
                mb, jnp.clip(m_in, 0, M - 1), keepdims=False)
            state = jnp.where(take, fresh, state)
            # every stage processes what it holds (bubble ticks compute
            # throwaway values on zeros — the GPipe bubble)
            state = stage_compute(state)
            # last stage emits microbatch t - (S - 1)
            m_out = t - (S - 1)
            emit = jnp.logical_and(stage == S - 1,
                                   jnp.logical_and(m_out >= 0, m_out < M))
            out = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, state, jnp.clip(m_out, 0, M - 1), 0),
                lambda o: o, out)
            # rotate activations to the next stage
            state = jax.lax.ppermute(state, axis, perm)
            return state, out

        _, out = jax.lax.fori_loop(0, T, tick, (state, out))
        # only stage S-1 wrote emitted values (zeros elsewhere): psum
        # broadcasts them to every stage
        out = jax.lax.psum(out, axis)
        return out.reshape((B,) + x_full.shape[1:])

    fn = shard_map(run, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    return fn(stage_params, x)


def bubble_fraction(n_stages: int, microbatches: int) -> float:
    return (n_stages - 1) / (microbatches + n_stages - 1)
