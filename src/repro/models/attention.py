"""Attention: GQA (naive / chunked-flash / pallas), MLA, sliding window,
softcap, M-RoPE; training and decode (KV cache) paths.

The *kernel* actually used is a uniform component (kernel/flash-attention)
selected by the lazy-builder: ``naive`` for tiny smoke shapes, ``lax-flash``
(chunked online-softmax, VMEM-bounded) for compiled CPU/dry-run targets, and
the Pallas TPU kernel when the specSheet says a real TPU is present.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import P, SpecTree, apply_rope
from .sharding import shard

NEG_INF = -2.0 ** 30   # finite: keeps masked softmax NaN-free on empty rows


# ---------------------------------------------------------------------------
# Core attention kernels (q: (b, hq, sq, d); k/v: (b, hkv, skv, d))
# ---------------------------------------------------------------------------

def naive_attention(q, k, v, *, scale, causal=True, window=0, softcap=0.0,
                    q_offset=0, kv_len=None):
    """``q_offset`` / ``kv_len`` may be scalars or (b,) vectors — the vector
    form supports slot-based continuous batching where every sequence in the
    batch sits at its own decode depth."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    q = q.reshape(b, hkv, g, sq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    skv = k.shape[2]
    qo = jnp.asarray(q_offset)
    per_slot = qo.ndim > 0 or (kv_len is not None
                               and jnp.asarray(kv_len).ndim > 0)
    if per_slot:
        # masks shaped (b, 1, 1, sq, skv)
        qpos = qo.reshape(-1, 1, 1)[..., None] \
            + jnp.arange(sq)[None, None, :, None]          # (b,1,sq,1)
        kpos = jnp.arange(skv)[None, None, None, :]
        mask = jnp.ones((b, 1, sq, skv), bool)
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= qpos - kpos < window
        if kv_len is not None:
            kl = jnp.asarray(kv_len).reshape(-1, 1, 1, 1)
            mask &= kpos < kl
        mask = mask[:, :, None, :, :]                      # (b,1,1,sq,skv)
    else:
        qpos = q_offset + jnp.arange(sq)[:, None]
        kpos = jnp.arange(skv)[None, :]
        mask = jnp.ones((sq, skv), bool)
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= qpos - kpos < window
        if kv_len is not None:
            mask &= kpos < kv_len
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(v.dtype)


def lax_flash_attention(q, k, v, *, scale, causal=True, window=0,
                        softcap=0.0, q_offset=0, kv_len=None,
                        block_q=512, block_k=1024):
    """Chunked online-softmax attention: scan over q blocks, inner scan over
    kv blocks.  Working set per step is (bq, bk) — the XLA analogue of the
    Pallas kernel's VMEM tiling, used for compiled dry-run/roofline paths."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    if sq % bq or skv % bk:
        return naive_attention(q, k, v, scale=scale, causal=causal,
                               window=window, softcap=softcap,
                               q_offset=q_offset, kv_len=kv_len)
    nq, nk = sq // bq, skv // bk
    dv = v.shape[-1]           # MLA: v head dim may differ from qk head dim
    qr = q.reshape(b, hkv, g, nq, bq, d).astype(jnp.float32)
    kr = k.reshape(b, hkv, nk, bk, d).astype(jnp.float32)
    vr = v.reshape(b, hkv, nk, bk, dv).astype(jnp.float32)

    def q_block(carry, qi):
        qb, iq = qi            # (b,hkv,g,bq,d), scalar index
        m0 = jnp.full((b, hkv, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, bq, dv), jnp.float32)

        def kv_block(c, kj):
            m, l, acc = c
            kb, vb, jk = kj
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb) * scale
            if softcap:
                s = softcap * jnp.tanh(s / softcap)
            qpos = q_offset + iq * bq + jnp.arange(bq)[:, None]
            kpos = jk * bk + jnp.arange(bk)[None, :]
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= qpos >= kpos
            if window:
                mask &= qpos - kpos < window
            if kv_len is not None:
                mask &= kpos < kv_len
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vb)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0),
            (jnp.moveaxis(kr, 2, 0), jnp.moveaxis(vr, 2, 0),
             jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-37)
        return carry, out

    _, outs = jax.lax.scan(
        q_block, None,
        (jnp.moveaxis(qr, 3, 0), jnp.arange(nq)))   # (nq, b,hkv,g,bq,dv)
    o = jnp.moveaxis(outs, 0, 3).reshape(b, hq, sq, dv)
    return o.astype(v.dtype)


ATTN_KERNELS: Dict[str, Any] = {
    "naive": naive_attention,
    "lax-flash": lax_flash_attention,
}


def register_attention_kernel(name: str, fn) -> None:
    ATTN_KERNELS[name] = fn


# ---------------------------------------------------------------------------
# GQA module
# ---------------------------------------------------------------------------

def gqa_spec(cfg) -> SpecTree:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    sp: SpecTree = {
        "wq": P((d, h * hd), ("embed", "heads")),
        "wk": P((d, kv * hd), ("embed", "kv_heads")),
        "wv": P((d, kv * hd), ("embed", "kv_heads")),
        "wo": P((h * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        sp["bq"] = P((h * hd,), ("heads",), "zeros")
        sp["bk"] = P((kv * hd,), ("kv_heads",), "zeros")
        sp["bv"] = P((kv * hd,), ("kv_heads",), "zeros")
    return sp


def _proj(x, w, b=None):
    y = jnp.einsum("bsd,df->bsf", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def gqa_attention(params, x, cfg, *, positions, kernel="lax-flash",
                  window=0, cache=None, cache_pos=None,
                  query_scale: Optional[float] = None):
    """Returns (out, new_cache).  Train: cache=None.  Decode: cache is
    {'k': (b, kv, S, hd), 'v': ...} updated at cache_pos (int32 scalar)."""
    b, s, dm = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = _proj(x, params["wq"], params.get("bq")).reshape(b, s, h, hd)
    k = _proj(x, params["wk"], params.get("bk")).reshape(b, s, kv, hd)
    v = _proj(x, params["wv"], params.get("bv")).reshape(b, s, kv, hd)

    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.partial_rotary,
                       cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.partial_rotary,
                       cfg.mrope_sections)
    q = jnp.swapaxes(q, 1, 2)   # (b, h, s, hd)
    k = jnp.swapaxes(k, 1, 2)
    v = jnp.swapaxes(v, 1, 2)
    q = shard(q, "act_batch", "act_heads", "act_seq", None)

    scale = query_scale if query_scale is not None else 1.0 / math.sqrt(hd)
    fn = ATTN_KERNELS[kernel]
    new_cache = None
    if cache is None:
        o = fn(q, k, v, scale=scale, causal=True, window=window,
               softcap=cfg.attn_softcap)
    else:
        cache_len = cache["k"].shape[2]
        ring = bool(window) and cache_len <= window
        per_slot = jnp.asarray(cache_pos).ndim > 0
        if ring:
            # sliding-window ring buffer: the cache holds only `window`
            # entries; token t lives in slot t % window.  128x smaller
            # local-layer caches for long-context decode.
            if s == 1:
                slot = jnp.asarray(cache_pos) % window
                if per_slot:
                    upd = jax.vmap(
                        lambda c, n, p: jax.lax.dynamic_update_slice(
                            c, n, (0, p, 0)))
                    ck = upd(cache["k"], k.astype(cache["k"].dtype), slot)
                    cv = upd(cache["v"], v.astype(cache["v"].dtype), slot)
                else:
                    ck = jax.lax.dynamic_update_slice(
                        cache["k"], k.astype(cache["k"].dtype),
                        (0, 0, slot, 0))
                    cv = jax.lax.dynamic_update_slice(
                        cache["v"], v.astype(cache["v"].dtype),
                        (0, 0, slot, 0))
                new_cache = {"k": ck, "v": cv}
                kv_len = jnp.minimum(jnp.asarray(cache_pos) + 1, window)
                o = naive_attention(q, ck, cv, scale=scale, causal=False,
                                    softcap=cfg.attn_softcap, kv_len=kv_len)
            else:
                # prefill: attend within the chunk, keep the last `window`
                # tokens (requires s % window == 0 or s <= window so slot
                # layout stays aligned)
                assert s % window == 0 or s < window, (s, window)
                o = fn(q, k, v, scale=scale, causal=True, window=window,
                       softcap=cfg.attn_softcap)
                if s >= window:
                    ck = k[:, :, -window:, :].astype(cache["k"].dtype)
                    cv = v[:, :, -window:, :].astype(cache["v"].dtype)
                else:
                    ck = jax.lax.dynamic_update_slice(
                        cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
                    cv = jax.lax.dynamic_update_slice(
                        cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
                new_cache = {"k": ck, "v": cv}
        else:
            if per_slot:
                # continuous batching: each slot writes at its own position
                upd = jax.vmap(lambda c, n, p: jax.lax.dynamic_update_slice(
                    c, n, (0, p, 0)))
                ck = upd(cache["k"], k.astype(cache["k"].dtype), cache_pos)
                cv = upd(cache["v"], v.astype(cache["v"].dtype), cache_pos)
            else:
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype),
                    (0, 0, cache_pos, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype),
                    (0, 0, cache_pos, 0))
            new_cache = {"k": ck, "v": cv}
            if s == 1:   # decode: one query over the cache, O(S) per step
                o = naive_attention(q, ck, cv, scale=scale, causal=False,
                                    window=window, softcap=cfg.attn_softcap,
                                    q_offset=cache_pos, kv_len=cache_pos + 1)
            else:        # prefill chunk: causal within the chunk
                o = fn(q, ck, cv, scale=scale, causal=True, window=window,
                       softcap=cfg.attn_softcap, q_offset=cache_pos,
                       kv_len=cache_pos + s)
    o = jnp.swapaxes(o, 1, 2).reshape(b, s, h * hd)
    out = jnp.einsum("bsf,fd->bsd", o, params["wo"].astype(o.dtype))
    return shard(out, "act_batch", "act_seq", "act_embed"), new_cache


def gqa_cache_spec(cfg, batch: int, max_seq: int) -> SpecTree:
    kv, hd = cfg.n_kv, cfg.head_dim
    ax = ("cache_batch", "cache_heads", "cache_seq", None)
    return {"k": P((batch, kv, max_seq, hd), ax, "zeros"),
            "v": P((batch, kv, max_seq, hd), ax, "zeros")}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 Multi-head Latent Attention)
# ---------------------------------------------------------------------------

def mla_spec(cfg) -> SpecTree:
    d, h = cfg.d_model, cfg.n_heads
    ql, kvl = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_a": P((d, ql), ("embed", "lora")),
        "q_norm": P((ql,), ("lora",), "ones"),
        "wq_b": P((ql, h * (dn + dr)), ("lora", "heads")),
        "wkv_a": P((d, kvl + dr), ("embed", "lora")),
        "kv_norm": P((kvl,), ("lora",), "ones"),
        "wkv_b": P((kvl, h * (dn + dv)), ("lora", "heads")),
        "wo": P((h * dv, d), ("heads", "embed")),
    }


def _rms(x, w):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) * w).astype(x.dtype)


def mla_attention(params, x, cfg, *, positions, kernel="lax-flash",
                  cache=None, cache_pos=None, **_):
    """Train path decompresses K/V per head and runs flash; decode path keeps
    the cache *compressed* (c_kv + k_rope) — the MLA memory saving — and
    absorbs the up-projections into the query/output."""
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvl = cfg.kv_lora_rank

    q_lat = _rms(_proj(x, params["wq_a"]), params["q_norm"])
    q = _proj(q_lat, params["wq_b"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = _proj(x, params["wkv_a"])                # (b, s, kvl + dr)
    c_kv = _rms(kv_a[..., :kvl], params["kv_norm"])
    k_rope = apply_rope(kv_a[..., kvl:][:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]     # (b, s, dr) shared

    scale = 1.0 / math.sqrt(dn + dr)
    wkv_b = params["wkv_b"].reshape(kvl, h, dn + dv)

    if cache is None:
        kv = jnp.einsum("bsl,lhe->bshe", c_kv, wkv_b.astype(c_kv.dtype))
        k_nope, v = kv[..., :dn], kv[..., dn:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))],
            axis=-1)
        qf = jnp.swapaxes(jnp.concatenate([q_nope, q_rope], -1), 1, 2)
        kf = jnp.swapaxes(k, 1, 2)
        vf = jnp.swapaxes(v, 1, 2)
        qf = shard(qf, "act_batch", "act_heads", "act_seq", None)
        fn = ATTN_KERNELS[kernel]
        o = fn(qf, kf, vf, scale=scale, causal=True)
        o = jnp.swapaxes(o, 1, 2)
        new_cache = None
    else:
        per_slot = jnp.asarray(cache_pos).ndim > 0
        if per_slot:
            upd = jax.vmap(lambda c, n, p: jax.lax.dynamic_update_slice(
                c, n, (p, 0)))
            cc = upd(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype),
                     cache_pos)
            cr = upd(cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
                     cache_pos)
        else:
            cc = jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype),
                (0, cache_pos, 0))
            cr = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
                (0, cache_pos, 0))
        new_cache = {"c_kv": cc, "k_rope": cr}
        w_uk, w_uv = wkv_b[:, :, :dn], wkv_b[:, :, dn:]
        # absorb: q_c = q_nope @ w_uk^T  -> compressed-space query
        q_c = jnp.einsum("bshd,lhd->bshl", q_nope, w_uk.astype(q_nope.dtype))
        s_c = jnp.einsum("bshl,bTl->bhsT", q_c.astype(jnp.float32),
                         cc.astype(jnp.float32))
        s_r = jnp.einsum("bshd,bTd->bhsT", q_rope.astype(jnp.float32),
                         cr.astype(jnp.float32))
        att = (s_c + s_r) * scale
        S = cc.shape[1]
        if per_slot:
            qpos = (jnp.asarray(cache_pos).reshape(-1, 1, 1)
                    + jnp.arange(s)[None, :, None])         # (b, s, 1)
            kpos = jnp.arange(S)[None, None, :]
            mask = (kpos <= qpos) & (
                kpos < jnp.asarray(cache_pos).reshape(-1, 1, 1) + s)
            mask = mask[:, None]                            # (b, 1, s, S)
        else:
            qpos = cache_pos + jnp.arange(s)[:, None]
            kpos = jnp.arange(S)[None, :]
            mask = ((kpos <= qpos) & (kpos < cache_pos + s))[None, None]
        att = jnp.where(mask, att, NEG_INF)
        p = jax.nn.softmax(att, axis=-1)
        o_c = jnp.einsum("bhsT,bTl->bshl", p, cc.astype(jnp.float32))
        o = jnp.einsum("bshl,lhd->bshd", o_c, w_uv.astype(jnp.float32))
        o = o.astype(x.dtype)

    o = o.reshape(b, s, h * dv)
    out = jnp.einsum("bsf,fd->bsd", o, params["wo"].astype(o.dtype))
    return shard(out, "act_batch", "act_seq", "act_embed"), new_cache


def mla_cache_spec(cfg, batch: int, max_seq: int) -> SpecTree:
    return {
        "c_kv": P((batch, max_seq, cfg.kv_lora_rank),
                  ("cache_batch", "cache_seq", None), "zeros"),
        "k_rope": P((batch, max_seq, cfg.qk_rope_dim),
                    ("cache_batch", "cache_seq", None), "zeros"),
    }
