"""Logical-axis sharding plans (GSPMD) + activation constraint helper.

A *plan* maps logical axis names (declared by each param spec / activation
site) onto physical mesh axes.  Plans are uniform components — the
lazy-builder's deployability logic picks the variant fitting the platform
(pure-TP when the model replicates into HBM, FSDP+TP otherwise, SP rules for
long-context decode).
"""
from __future__ import annotations

import contextvars
import dataclasses
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

AxisVal = Union[None, str, Tuple[str, ...]]


# ---------------------------------------------------------------------------
# Rule sets
# ---------------------------------------------------------------------------

def _batch_axes(mesh_axes: Sequence[str]) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh_axes)


def tp_rules(mesh_axes: Sequence[str]) -> Dict[str, AxisVal]:
    """Pure tensor parallel over 'model'; params replicated across data."""
    b = _batch_axes(mesh_axes)
    return {
        # params
        "vocab": "model", "embed": None, "mlp": "model", "heads": "model",
        "kv_heads": "model", "head_dim": None, "expert": "model",
        "expert_mlp": None, "layer": None, "lora": None, "state": None,
        "conv": None, "inner": "model",
        # activations
        "act_batch": b, "act_seq": None, "act_embed": None,
        "act_heads": "model", "act_kv_heads": "model", "act_vocab": "model",
        "act_mlp": "model", "act_inner": "model", "act_expert": "model",
        # kv-cache / recurrent state
        "cache_batch": b, "cache_seq": None, "cache_heads": "model",
        # optimizer-state extra sharding (ZeRO-1) target axis
        "_zero1": b,
    }


def fsdp_tp_rules(mesh_axes: Sequence[str]) -> Dict[str, AxisVal]:
    """TP over 'model' + param FSDP over the batch axes ('embed' dim)."""
    r = tp_rules(mesh_axes)
    b = _batch_axes(mesh_axes)
    r.update({"embed": b, "expert_mlp": None})
    return r


def decode_rules(mesh_axes: Sequence[str]) -> Dict[str, AxisVal]:
    """Batched decode: KV cache sequence-sharded over 'model' (flash-decode —
    GSPMD turns the seq-contracted attention einsum into partial softmax
    sums + an all-reduce), batch over the data axes.  Sequence sharding
    beats head sharding here because kv_heads rarely divides the model axis
    while seq_len always does."""
    r = fsdp_tp_rules(mesh_axes)
    b = _batch_axes(mesh_axes)
    r.update({
        "cache_batch": b, "cache_seq": "model", "cache_heads": None,
    })
    return r


def sp_decode_rules(mesh_axes: Sequence[str]) -> Dict[str, AxisVal]:
    """Long-context decode (batch=1): the KV cache / recurrent state is the
    entire footprint, so its sequence dim shards over EVERY mesh axis."""
    r = fsdp_tp_rules(mesh_axes)
    b = _batch_axes(mesh_axes)
    r.update({
        "cache_batch": None, "cache_seq": b + ("model",),
        "cache_heads": None, "act_batch": None,
    })
    return r


def dp_rules(mesh_axes: Sequence[str]) -> Dict[str, AxisVal]:
    """Pure data parallelism over EVERY mesh axis: params replicated, the
    batch sharded 256-way.  The right plan for models small enough to
    replicate — TP of a 1.5 GB model over 16 chips leaves each matmul too
    skinny to pay for its resharding collectives.  Optimizer moments stay
    ZeRO-1-sharded over the whole mesh."""
    all_axes = tuple(a for a in ("pod", "data", "model") if a in mesh_axes)
    r = {k: None for k in tp_rules(mesh_axes)}
    r.update({
        "act_batch": all_axes, "cache_batch": all_axes,
        "_zero1": all_axes,
    })
    return r


def prefill_sp_rules(mesh_axes: Sequence[str]) -> Dict[str, AxisVal]:
    """Prefill sequence parallelism: activations shard over 'model' on the
    SEQUENCE dim instead of heads/mlp.  For GQA with tiny kv (kv_heads <
    model axis), head-sharding degenerates to replication + per-layer
    all-gathers; seq-sharding keeps every matmul fully local and only the
    (small) K/V tensors are gathered for causal attention."""
    r = fsdp_tp_rules(mesh_axes)
    r.update({
        "act_seq": "model", "act_heads": None, "act_mlp": None,
        "act_vocab": None, "act_inner": None,
        "cache_seq": "model", "cache_heads": None,
    })
    return r


RULE_SETS = {
    "tp": tp_rules,
    "fsdp-tp": fsdp_tp_rules,
    "decode": decode_rules,
    "sp-decode": sp_decode_rules,
    "prefill-sp": prefill_sp_rules,
    "dp": dp_rules,
}


@dataclasses.dataclass
class ShardingPlan:
    name: str
    mesh: Mesh
    rules: Dict[str, AxisVal]

    def spec(self, logical: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> PartitionSpec:
        """When ``shape`` is given, mesh axes that do not divide the dim are
        dropped (replicated) — 12 heads never shard over a 16-way axis."""
        used = set()
        parts = []
        for i, ax in enumerate(logical):
            val = self.rules.get(ax) if ax else None
            if val is None:
                parts.append(None)
                continue
            axes = (val,) if isinstance(val, str) else tuple(val)
            axes = tuple(a for a in axes
                         if a in self.mesh.axis_names and a not in used)
            if shape is not None:
                kept = []
                dim = shape[i]
                for a in axes:
                    n = self.mesh.shape[a]
                    if dim % n == 0 and dim >= n:
                        kept.append(a)
                        dim //= n
                axes = tuple(kept)
            used.update(axes)
            if not axes:
                parts.append(None)
            elif len(axes) == 1:
                parts.append(axes[0])
            else:
                parts.append(axes)
        return PartitionSpec(*parts)

    def sharding(self, logical: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape))

    def tree_shardings(self, axes_tree) -> Any:
        return jax.tree.map(
            lambda ax: self.sharding(ax), axes_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x))


# ---------------------------------------------------------------------------
# Activation-constraint context: model code calls shard(x, 'act_batch',
# 'act_seq', 'act_embed'); a plan must be active for it to take effect.
# ---------------------------------------------------------------------------

_ACTIVE_PLAN: contextvars.ContextVar[Optional[ShardingPlan]] = \
    contextvars.ContextVar("repro_sharding_plan", default=None)


class use_plan:
    def __init__(self, plan: Optional[ShardingPlan]):
        self.plan = plan

    def __enter__(self):
        self._tok = _ACTIVE_PLAN.set(self.plan)
        return self.plan

    def __exit__(self, *exc):
        _ACTIVE_PLAN.reset(self._tok)


def current_plan() -> Optional[ShardingPlan]:
    return _ACTIVE_PLAN.get()


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    plan = _ACTIVE_PLAN.get()
    if plan is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    return jax.lax.with_sharding_constraint(
        x, plan.sharding(logical, x.shape))


# ---------------------------------------------------------------------------
# ZeRO-1: optimizer-state sharding = param sharding + batch-axis sharding on
# the largest unsharded dimension.
# ---------------------------------------------------------------------------

def zero1_axes(axes: Tuple[Optional[str], ...], plan: ShardingPlan,
               shape: Tuple[int, ...]) -> Tuple[Optional[str], ...]:
    target = plan.rules.get("_zero1") or ()
    if isinstance(target, str):
        target = (target,)
    target = tuple(a for a in target if a in plan.mesh.axis_names)
    if not target:
        return axes
    n = 1
    for a in target:
        n *= plan.mesh.shape[a]
    # find largest dim whose logical axis maps to nothing and divides n
    best, best_size = -1, 0
    spec = plan.spec(axes)
    for i, (dim, ax) in enumerate(zip(shape, spec)):
        if ax is None and dim % n == 0 and dim > best_size:
            best, best_size = i, dim
    if best < 0:
        return axes
    new_axes = list(axes)
    new_axes[best] = "_zero1"
    return tuple(new_axes)
