"""FFN variants: gated (SwiGLU/GeGLU) and pointwise (GELU) MLPs, plus MoE.

MoE uses capacity-based *grouped-GEMM* dispatch: per-expert top-C token
selection (stable lax.top_k), a single batched einsum over the expert axis,
and scatter-add combine.  With the expert axis sharded over 'model' (EP),
GSPMD runs each shard's experts locally and all-reduces the combine — the
collective pattern of expert parallelism, with *honest* FLOPs
(≈ tokens × top_k × capacity_factor × expert FLOPs, no one-hot einsums).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import P, SpecTree, gelu
from .sharding import shard


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------

def ffn_spec(cfg, d_ff: Optional[int] = None) -> SpecTree:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.ffn in ("swiglu", "geglu"):
        return {"w_gate": P((d, f), ("embed", "mlp")),
                "w_up": P((d, f), ("embed", "mlp")),
                "w_down": P((f, d), ("mlp", "embed"))}
    return {"w_up": P((d, f), ("embed", "mlp")),
            "b_up": P((f,), ("mlp",), "zeros"),
            "w_down": P((f, d), ("mlp", "embed")),
            "b_down": P((d,), ("embed",), "zeros")}


def ffn_apply(params, x, cfg):
    if cfg.ffn in ("swiglu", "geglu"):
        g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(x.dtype))
        u = jnp.einsum("...d,df->...f", x, params["w_up"].astype(x.dtype))
        act = jax.nn.silu(g) if cfg.ffn == "swiglu" else gelu(g)
        h = act * u
    else:
        h = gelu(jnp.einsum("...d,df->...f", x,
                            params["w_up"].astype(x.dtype))
                 + params["b_up"].astype(x.dtype))
    if h.ndim == 3:
        h = shard(h, "act_batch", "act_seq", "act_mlp")
    y = jnp.einsum("...f,fd->...d", h, params["w_down"].astype(x.dtype))
    if "b_down" in params:
        y = y + params["b_down"].astype(x.dtype)
    if y.ndim == 3:
        y = shard(y, "act_batch", "act_seq", "act_embed")
    return y


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_spec(cfg) -> SpecTree:
    d, f, E = cfg.d_model, cfg.moe_ff, cfg.num_experts
    gated = cfg.ffn in ("swiglu", "geglu")
    sp: SpecTree = {
        "router": P((d, E), ("embed", "expert"), "small"),
        "w_up": P((E, d, f), ("expert", "embed", "expert_mlp")),
        "w_down": P((E, f, d), ("expert", "expert_mlp", "embed")),
    }
    if gated:
        sp["w_gate"] = P((E, d, f), ("expert", "embed", "expert_mlp"))
    if cfg.router_scale:
        sp["router_bias"] = P((E,), ("expert",), "zeros")
    if cfg.shared_experts:
        sp["shared"] = ffn_spec(cfg, cfg.moe_ff * cfg.shared_experts)
    return sp


def _route(params, xf, cfg):
    """xf: (T, d) → top-k ids (T, k), weights (T, k), router probs (T, E)."""
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    if cfg.router_scale:        # deepseek-v3: sigmoid scores + selection bias
        scores = jax.nn.sigmoid(logits)
        sel = scores + params["router_bias"][None, :]
        _, ids = jax.lax.top_k(sel, cfg.top_k)
        w = jnp.take_along_axis(scores, ids, axis=-1)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(jnp.sum(scores, -1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, ids = jax.lax.top_k(probs, cfg.top_k)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    return ids, w, probs


def _aux_loss(ids, probs, cfg):
    """Switch-style load-balance loss."""
    E = cfg.num_experts
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.float32)      # (T, k, E)
    frac = jnp.mean(jnp.sum(onehot, axis=1), axis=0)        # tokens per expert
    imp = jnp.mean(probs, axis=0)
    return E * jnp.sum(frac * imp)


def moe_grouped(params, x, cfg, capacity_factor: float = 1.25,
                combine_dtype: str = "f32", slot_dp_shard: bool = False):
    """Grouped-GEMM capacity MoE.  x: (b, s, d) → (y, aux_loss).

    ``combine_dtype='bf16'`` keeps dispatch/combine slot tensors in the
    activation dtype end-to-end (halves the slot-space HBM traffic and the
    combine all-reduce bytes); 'f32' is the conservative default.
    ``slot_dp_shard`` additionally shards the capacity dim of the slot
    tensors over the data axes, steering GSPMD from replicated-slot
    all-reduces toward all-to-all-style exchange."""
    b, s, d = x.shape
    T = b * s
    E, k, f = cfg.num_experts, cfg.top_k, cfg.moe_ff
    gated = "w_gate" in params
    xf = x.reshape(T, d)

    ids, w, probs = _route(params, xf, cfg)
    aux = _aux_loss(ids, probs, cfg)

    C = max(8, int(math.ceil(T * k * capacity_factor / E)))
    C = min(T, ((C + 7) // 8) * 8)

    # per-expert membership score + routing weight  (E, T)
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.float32)       # (T, k, E)
    member = jnp.max(onehot, axis=1).T                        # (E, T) in {0,1}
    wmat = jnp.einsum("tke,tk->et", onehot, w)                # (E, T)

    # stable top-C token pick per expert (ties keep lowest index = FIFO)
    member = shard(member, "act_expert", None)
    gate_vals, idx = jax.lax.top_k(member, C)                 # (E, C)
    idx = shard(idx, "act_expert", None)
    gate = jnp.take_along_axis(wmat, idx, axis=1) * gate_vals  # 0 for padding

    slot_c = "act_batch" if slot_dp_shard else None
    xg = jnp.take(xf, idx.reshape(-1), axis=0).reshape(E, C, d)
    xg = shard(xg, "act_expert", slot_c, None)
    up = jnp.einsum("ecd,edf->ecf", xg, params["w_up"].astype(xg.dtype))
    if gated:
        g = jnp.einsum("ecd,edf->ecf", xg, params["w_gate"].astype(xg.dtype))
        act = jax.nn.silu(g) if cfg.ffn == "swiglu" else gelu(g)
        h = act * up
    else:
        h = gelu(up)
    yo = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(h.dtype))
    if combine_dtype == "bf16":
        yo = yo.astype(x.dtype) * gate[..., None].astype(x.dtype)
    else:
        yo = yo.astype(jnp.float32) * gate[..., None]
    if slot_dp_shard:
        yo = shard(yo, "act_expert", "act_batch", None)

    y = jnp.zeros((T, d), yo.dtype).at[idx.reshape(-1)].add(
        yo.reshape(E * C, d)).astype(x.dtype)
    y = shard(y.reshape(b, s, d), "act_batch", "act_seq", "act_embed")

    if cfg.shared_experts:
        y = y + ffn_apply(params["shared"], x, cfg)
    return y, aux


def moe_dense(params, x, cfg):
    """Small-scale oracle: every expert on every token, gate-weighted.
    Selected only for tiny smoke configs (deployability gates on size)."""
    b, s, d = x.shape
    T = b * s
    xf = x.reshape(T, d)
    ids, w, probs = _route(params, xf, cfg)
    aux = _aux_loss(ids, probs, cfg)
    E = cfg.num_experts
    gmat = jnp.zeros((T, E), jnp.float32).at[
        jnp.arange(T)[:, None], ids].set(w)                  # (T, E)
    up = jnp.einsum("td,edf->etf", xf, params["w_up"].astype(xf.dtype))
    if "w_gate" in params:
        g = jnp.einsum("td,edf->etf", xf, params["w_gate"].astype(xf.dtype))
        act = jax.nn.silu(g) if cfg.ffn == "swiglu" else gelu(g)
        h = act * up
    else:
        h = gelu(up)
    yo = jnp.einsum("etf,efd->etd", h, params["w_down"].astype(h.dtype))
    y = jnp.einsum("etd,te->td", yo.astype(jnp.float32), gmat)
    y = y.reshape(b, s, d).astype(x.dtype)
    if cfg.shared_experts:
        y = y + ffn_apply(params["shared"], x, cfg)
    return y, aux


MOE_IMPLS = {"grouped": moe_grouped, "dense": moe_dense}
