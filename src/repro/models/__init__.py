"""repro.models — the model zoo substrate (functional param-dict modules)."""
from .transformer import Model, Stack, Variants, build_model  # noqa: F401
from .sharding import (RULE_SETS, ShardingPlan, current_plan, shard,  # noqa: F401
                       use_plan, zero1_axes)
