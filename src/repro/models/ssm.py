"""State-space blocks: Mamba (Jamba's SSM layer) and RWKV6 'Finch' time/channel
mix with data-dependent decay.

Training uses a chunked WKV6 formulation (intra-chunk matmuls + inter-chunk
state carry — exponents are ≤0 by construction so it is overflow-safe);
decode carries O(1) recurrent state.  The sequential recurrence doubles as
the oracle for the chunked/Pallas variants.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import P, SpecTree, rms_norm
from .sharding import shard

# ---------------------------------------------------------------------------
# WKV6 core: r,k,w: (b, h, s, K); v: (b, h, s, V); u: (h, K)
# recurrence: y_t = r_t·(S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
# ---------------------------------------------------------------------------

def wkv6_sequential(r, k, v, w, u, state=None):
    b, h, s, K = r.shape
    V = v.shape[-1]
    if state is None:
        state = jnp.zeros((b, h, K, V), jnp.float32)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    wf = w.astype(jnp.float32)
    uf = u.astype(jnp.float32)[None, :, :]          # (1, h, K)

    def step(S, t):
        rt, kt, vt, wt = t                          # (b,h,K)/(b,h,V)
        kv = kt[..., :, None] * vt[..., None, :]    # (b,h,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S + uf[..., None] * kv)
        S_new = wt[..., None] * S + kv
        return S_new, y

    xs = (jnp.moveaxis(rf, 2, 0), jnp.moveaxis(kf, 2, 0),
          jnp.moveaxis(vf, 2, 0), jnp.moveaxis(wf, 2, 0))
    S, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 2).astype(v.dtype), S


def wkv6_chunked(r, k, v, w, u, state=None, chunk: int = 32):
    """Chunked parallel WKV6.  All exponentials have exponent ≤ 0."""
    b, h, s, K = r.shape
    V = v.shape[-1]
    if s % chunk or s <= chunk:
        return wkv6_sequential(r, k, v, w, u, state)
    if state is None:
        state = jnp.zeros((b, h, K, V), jnp.float32)
    n = s // chunk
    L = chunk
    rf = r.astype(jnp.float32).reshape(b, h, n, L, K)
    kf = k.astype(jnp.float32).reshape(b, h, n, L, K)
    vf = v.astype(jnp.float32).reshape(b, h, n, L, V)
    lw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38)
                 ).reshape(b, h, n, L, K)
    uf = u.astype(jnp.float32)[None, :, None, :]     # (1, h, 1, K)

    sw = jnp.cumsum(lw, axis=3) - lw                 # exclusive cumsum
    sw_end = sw[..., -1, :] + lw[..., -1, :]         # total chunk decay

    tri = jnp.tril(jnp.ones((L, L), bool), k=-1)     # j < t

    def chunk_step(S, xs):
        rc, kc, vc, lwc, swc, swe = xs
        # intra-chunk: exponent(t,j,k) = sw_t - sw_j - lw_j  (≤ 0 for j < t)
        expo = swc[..., :, None, :] - swc[..., None, :, :] - lwc[..., None, :, :]
        expo = jnp.where(tri[None, None, :, :, None], expo, -jnp.inf)
        A = jnp.einsum("bhtk,bhjk,bhtjk->bhtj", rc, kc, jnp.exp(expo))
        y = jnp.einsum("bhtj,bhjv->bhtv", A, vc)
        # current-step bonus
        a = jnp.sum(rc * uf * kc, axis=-1)           # (b,h,L)
        y += a[..., None] * vc
        # inter-chunk: query the carried state
        q = rc * jnp.exp(swc)
        y += jnp.einsum("bhtk,bhkv->bhtv", q, S)
        # state update
        kk2 = kc * jnp.exp(swe[..., None, :] - swc - lwc)   # exponent ≤ 0
        S_new = jnp.exp(swe)[..., None] * S + jnp.einsum(
            "bhjk,bhjv->bhkv", kk2, vc)
        return S_new, y

    xs = tuple(jnp.moveaxis(t, 2, 0) for t in (rf, kf, vf, lw, sw))
    xs = xs + (jnp.moveaxis(sw_end, 2, 0),)
    S, ys = jax.lax.scan(chunk_step, state, xs)
    y = jnp.moveaxis(ys, 0, 2).reshape(b, h, s, V)
    return y.astype(v.dtype), S


WKV_IMPLS = {"sequential": wkv6_sequential, "chunked": wkv6_chunked}


def register_wkv_impl(name, fn):
    WKV_IMPLS[name] = fn


# ---------------------------------------------------------------------------
# RWKV6 block (time-mix + channel-mix)
# ---------------------------------------------------------------------------

_TM_LORA = 32
_TD_LORA = 64


def rwkv6_spec(cfg) -> SpecTree:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    h = d // hs
    return {
        "tm": {
            "maa_x": P((d,), ("embed",), "zeros"),
            "maa": P((5, d), (None, "embed"), "zeros"),       # w,k,v,r,g
            "maa_w1": P((d, 5 * _TM_LORA), ("embed", None), "small"),
            "maa_w2": P((5, _TM_LORA, d), (None, None, "embed"), "small"),
            "decay": P((d,), ("embed",), "zeros"),
            "decay_w1": P((d, _TD_LORA), ("embed", None), "small"),
            "decay_w2": P((_TD_LORA, d), (None, "embed"), "small"),
            "faaaa": P((h, hs), ("heads", None), "zeros"),
            "wr": P((d, d), ("embed", "heads")),
            "wk": P((d, d), ("embed", "heads")),
            "wv": P((d, d), ("embed", "heads")),
            "wg": P((d, d), ("embed", "heads")),
            "wo": P((d, d), ("heads", "embed")),
            "ln_w": P((d,), ("embed",), "ones"),
            "ln_b": P((d,), ("embed",), "zeros"),
        },
        "cm": {
            "maa_k": P((d,), ("embed",), "zeros"),
            "maa_r": P((d,), ("embed",), "zeros"),
            "wk": P((d, cfg.d_ff), ("embed", "mlp")),
            "wv": P((cfg.d_ff, d), ("mlp", "embed")),
            "wr": P((d, d), ("embed", "embed2")),
        },
    }


def _token_shift(x, prev):
    """shift right by one; position 0 sees ``prev`` (zeros at seq start)."""
    shifted = jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)
    return shifted


def rwkv6_time_mix(p, x, cfg, state=None, wkv_impl="chunked"):
    """x: (b, s, d).  state: None (train, zero init) or dict with
    'shift' (b, d) and 'wkv' (b, h, K, V)."""
    b, s, d = x.shape
    hs = cfg.rwkv_head_size
    h = d // hs
    prev = state["shift"] if state is not None else jnp.zeros((b, d), x.dtype)
    xx = _token_shift(x, prev)
    sx = xx - x

    xxx = x + sx * p["maa_x"].astype(x.dtype)
    mixed = jnp.tanh(jnp.einsum("bsd,dr->bsr", xxx, p["maa_w1"].astype(x.dtype)))
    mixed = mixed.reshape(b, s, 5, _TM_LORA)
    offs = jnp.einsum("bsfr,frd->fbsd", mixed, p["maa_w2"].astype(x.dtype))
    maa = p["maa"].astype(x.dtype)
    xw = x + sx * (maa[0] + offs[0])
    xk = x + sx * (maa[1] + offs[1])
    xv = x + sx * (maa[2] + offs[2])
    xr = x + sx * (maa[3] + offs[3])
    xg = x + sx * (maa[4] + offs[4])

    r = jnp.einsum("bsd,dk->bsk", xr, p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,dk->bsk", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dk->bsk", xv, p["wv"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,dk->bsk", xg, p["wg"].astype(x.dtype)))

    dd = jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, p["decay_w1"].astype(x.dtype)))
    dd = jnp.einsum("bsr,rd->bsd", dd, p["decay_w2"].astype(x.dtype))
    w = jnp.exp(-jnp.exp((p["decay"].astype(jnp.float32)
                          + dd.astype(jnp.float32))))        # (b,s,d) in (0,1)

    def heads(t):
        return jnp.swapaxes(t.reshape(b, s, h, hs), 1, 2)
    rh, kh, vh, wh = heads(r), heads(k), heads(v), heads(w.astype(x.dtype))
    rh = shard(rh, "act_batch", "act_heads", "act_seq", None)

    wkv_state = state["wkv"] if state is not None else None
    fn = WKV_IMPLS[wkv_impl]
    y, S = fn(rh, kh, vh, wh, p["faaaa"], wkv_state)
    y = jnp.swapaxes(y, 1, 2).reshape(b, s, d)

    # per-head group norm
    yg = y.reshape(b, s, h, hs).astype(jnp.float32)
    mu = jnp.mean(yg, -1, keepdims=True)
    var = jnp.var(yg, -1, keepdims=True)
    yg = (yg - mu) * jax.lax.rsqrt(var + 64e-5)
    y = (yg.reshape(b, s, d) * p["ln_w"] + p["ln_b"]).astype(x.dtype)

    out = jnp.einsum("bsk,kd->bsd", y * g, p["wo"].astype(x.dtype))
    new_state = {"shift": x[:, -1, :], "wkv": S}
    return shard(out, "act_batch", "act_seq", "act_embed"), new_state


def rwkv6_channel_mix(p, x, cfg, state=None):
    b, s, d = x.shape
    prev = state if state is not None else jnp.zeros((b, d), x.dtype)
    xx = _token_shift(x, prev)
    sx = xx - x
    xk = x + sx * p["maa_k"].astype(x.dtype)
    xr = x + sx * p["maa_r"].astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    k = shard(k, "act_batch", "act_seq", "act_mlp")
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"].astype(x.dtype))
    rgate = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr,
                                      p["wr"].astype(x.dtype)))
    return rgate * kv, x[:, -1, :]


def rwkv6_state_spec(cfg, batch: int) -> SpecTree:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    h = d // hs
    return {
        "tm_shift": P((batch, d), ("cache_batch", None), "zeros"),
        "wkv": P((batch, h, hs, hs),
                 ("cache_batch", "cache_heads", None, None), "zeros",
                 dtype="float32"),
        "cm_shift": P((batch, d), ("cache_batch", None), "zeros"),
    }


# ---------------------------------------------------------------------------
# Mamba block (Jamba SSM layer)
# ---------------------------------------------------------------------------

def mamba_spec(cfg) -> SpecTree:
    d = cfg.d_model
    din = d * cfg.ssm_expand
    N = cfg.ssm_state
    dt_rank = max(1, d // 16)
    return {
        "w_in": P((d, 2 * din), ("embed", "inner")),
        "conv": P((din, cfg.ssm_conv), ("inner", "conv"), "small"),
        "conv_b": P((din,), ("inner",), "zeros"),
        "w_x": P((din, dt_rank + 2 * N), ("inner", None)),
        "dt_norm": P((dt_rank,), (None,), "ones"),
        "b_norm": P((N,), (None,), "ones"),
        "c_norm": P((N,), (None,), "ones"),
        "w_dt": P((dt_rank, din), (None, "inner")),
        "dt_bias": P((din,), ("inner",), "zeros"),
        "a_log": P((din, N), ("inner", "state"), "small"),
        "dparam": P((din,), ("inner",), "ones"),
        "w_out": P((din, d), ("inner", "embed")),
    }


def mamba_block(p, x, cfg, state=None):
    """x: (b, s, d).  state: None or {'conv': (b, din, conv-1),
    'ssm': (b, din, N)} for decode."""
    b, s, d = x.shape
    din = d * cfg.ssm_expand
    N = cfg.ssm_state
    dt_rank = max(1, d // 16)
    K = cfg.ssm_conv

    xz = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(x.dtype))
    xs, z = jnp.split(xz, 2, axis=-1)           # (b, s, din)
    xs = shard(xs, "act_batch", "act_seq", "act_inner")

    # causal depthwise conv over seq
    prev = (state["conv"] if state is not None
            else jnp.zeros((b, din, K - 1), x.dtype))
    xt = jnp.swapaxes(xs, 1, 2)                 # (b, din, s)
    xpad = jnp.concatenate([prev, xt], axis=-1)
    new_conv = xpad[..., -(K - 1):] if K > 1 else prev
    conv_w = p["conv"].astype(x.dtype)
    xc = sum(xpad[..., i:i + s] * conv_w[:, i][None, :, None]
             for i in range(K)) + p["conv_b"].astype(x.dtype)[None, :, None]
    xc = jax.nn.silu(jnp.swapaxes(xc, 1, 2))    # (b, s, din)

    xdb = jnp.einsum("bsi,ie->bse", xc, p["w_x"].astype(x.dtype))
    dt, B, C = jnp.split(xdb, [dt_rank, dt_rank + N], axis=-1)
    dt = rms_norm(dt, p["dt_norm"])
    B = rms_norm(B, p["b_norm"]).astype(jnp.float32)
    C = rms_norm(C, p["c_norm"]).astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt, p["w_dt"].astype(x.dtype))
        + p["dt_bias"].astype(x.dtype)).astype(jnp.float32)   # (b, s, din)

    A = -jnp.exp(p["a_log"].astype(jnp.float32))              # (din, N)
    dA = jnp.exp(dt[..., None] * A[None, None])               # (b, s, din, N)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * B[:, :, None, :]

    h0 = (state["ssm"].astype(jnp.float32) if state is not None
          else jnp.zeros((b, din, N), jnp.float32))

    def step(h, t):
        dA_t, dBx_t, C_t = t
        h = dA_t * h + dBx_t
        y = jnp.einsum("bin,bn->bi", h, C_t)
        return h, y

    xs_scan = (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBx, 1, 0),
               jnp.moveaxis(C, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs_scan)
    y = jnp.moveaxis(ys, 0, 1)                                # (b, s, din)
    y = y + xc.astype(jnp.float32) * p["dparam"].astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"].astype(x.dtype))
    new_state = {"conv": new_conv, "ssm": h.astype(jnp.float32)}
    return shard(out, "act_batch", "act_seq", "act_embed"), new_state


def mamba_state_spec(cfg, batch: int) -> SpecTree:
    din = cfg.d_model * cfg.ssm_expand
    return {
        "conv": P((batch, din, cfg.ssm_conv - 1),
                  ("cache_batch", "act_inner", None), "zeros"),
        "ssm": P((batch, din, cfg.ssm_state),
                 ("cache_batch", "act_inner", "state"), "zeros",
                 dtype="float32"),
    }
