"""Model assembly: layer patterns → scanned stacks → LM (+MTP) heads.

Families
  dense-lm   : uniform attention+FFN layers (optionally alternating
               local/global sliding-window — gemma2)
  moe-lm     : attention + MoE layers (optionally a dense prefix — deepseek)
  ssm-lm     : RWKV6 time-mix + channel-mix
  hybrid-lm  : Jamba period-8 super-blocks (1 attn : 7 mamba, MoE every 2nd)
  audio-lm   : dense decoder over precomputed EnCodec frame embeddings (stub)
  vlm-lm     : dense decoder with M-RoPE + injected patch embeddings (stub)

Layers are stacked and driven by ``lax.scan`` (small HLO, fast compile, the
MaxText idiom); KV caches / recurrent states ride along as scan xs/ys.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .attention import (gqa_attention, gqa_cache_spec, gqa_spec,
                        mla_attention, mla_cache_spec, mla_spec)
from .common import (P, SpecTree, apply_norm, axes_tree, cross_entropy,
                     embed_spec, embed_tokens, eval_shape_tree, head_spec,
                     init_tree, lm_logits, norm_spec, sinusoidal_pos, softcap,
                     stacked)
from .ffn import MOE_IMPLS, ffn_apply, ffn_spec, moe_spec
from .sharding import shard
from .ssm import (mamba_block, mamba_spec, mamba_state_spec,
                  rwkv6_channel_mix, rwkv6_spec, rwkv6_state_spec,
                  rwkv6_time_mix)


@dataclasses.dataclass
class Variants:
    attn_kernel: str = "lax-flash"
    moe_impl: str = "grouped"
    wkv_impl: str = "chunked"
    remat: str = "full"            # none | full | dots
    capacity_factor: float = 1.25
    moe_combine: str = "f32"       # f32 | bf16 slot tensors / combine
    moe_slot_dp: bool = False      # shard slot capacity dim over data


@dataclasses.dataclass
class Stack:
    """One scanned group of identical layers."""
    name: str
    n: int
    spec: SpecTree                              # per-layer (unstacked)
    apply: Callable                             # (p, x, positions, cache, pos) -> (x, cache, aux)
    cache_spec: Callable                        # (batch, max_seq) -> SpecTree or None


@dataclasses.dataclass
class Model:
    cfg: Any
    variants: Variants
    stacks: Tuple[Stack, ...]
    specs: SpecTree                             # full stacked param tree
    mtp: bool = False

    # -- params ---------------------------------------------------------
    def init(self, key, dtype=None):
        import numpy as _np
        dt = jnp.dtype(dtype or self.cfg.dtype)
        return init_tree(key, self.specs, dt)

    def param_axes(self):
        return axes_tree(self.specs)

    def param_shapes(self, dtype=None):
        dt = jnp.dtype(dtype or self.cfg.dtype)
        return eval_shape_tree(self.specs, dt)

    # -- caches ----------------------------------------------------------
    def cache_specs(self, batch: int, max_seq: int) -> SpecTree:
        out: SpecTree = {}
        for st in self.stacks:
            cs = st.cache_spec(batch, max_seq)
            if cs is not None:
                out[st.name] = stacked(cs, st.n)
        return out

    def init_cache(self, batch: int, max_seq: int, dtype=None):
        dt = jnp.dtype(dtype or self.cfg.dtype)
        return init_tree(jax.random.PRNGKey(0),
                         self.cache_specs(batch, max_seq), dt)

    def cache_axes(self, batch: int, max_seq: int):
        return axes_tree(self.cache_specs(batch, max_seq))

    # -- forward ----------------------------------------------------------
    def backbone(self, params, x, positions, cache=None, cache_pos=0):
        """x: (b, s, d) embeddings → (h, new_cache, aux)."""
        aux = jnp.zeros((), jnp.float32)
        new_cache: Dict[str, Any] = {}
        for st in self.stacks:
            body = st.apply
            if self.variants.remat != "none" and cache is None:
                policy = None
                if self.variants.remat == "dots":
                    policy = jax.checkpoint_policies.checkpoint_dots
                body = jax.checkpoint(body, policy=policy,
                                      static_argnums=())
            st_cache = cache.get(st.name) if cache is not None else None

            def scan_fn(carry, xs, _body=body):
                h, a = carry
                p, c = xs
                h, c_new, a_l = _body(p, h, positions, c, cache_pos)
                return (h, a + a_l), c_new

            stacked_params = params[st.name]
            (x, aux), c_out = jax.lax.scan(
                scan_fn, (x, aux), (stacked_params, st_cache))
            if st_cache is not None:
                new_cache[st.name] = c_out
        return x, (new_cache if cache is not None else None), aux

    def logits_fn(self, params, embeds, positions, cache=None, cache_pos=0):
        h, new_cache, aux = self.backbone(params, embeds, positions,
                                          cache, cache_pos)
        h = apply_norm(params["final_norm"], h, self.cfg)
        logits = lm_logits(params.get("head", {}), params["embed"], h,
                           self.cfg)
        logits = shard(logits, "act_batch", "act_seq", "act_vocab")
        return logits, h, new_cache, aux

    # -- embedding frontends ----------------------------------------------
    def embed(self, params, batch):
        cfg = self.cfg
        if cfg.family == "audio-lm":
            # frontend stub: precomputed EnCodec frame embeddings
            e = batch["embeds"].astype(jnp.dtype(cfg.dtype))
            pos2d = batch["positions"] if batch["positions"].ndim == 2 \
                else batch["positions"][0]
            e = e + sinusoidal_pos(pos2d, cfg.d_model).astype(e.dtype)
            return e
        e = embed_tokens(params["embed"], batch["tokens"], cfg)
        if cfg.family == "vlm-lm" and "vis_embeds" in batch:
            ve = batch["vis_embeds"].astype(e.dtype)
            e = jax.lax.dynamic_update_slice(e, ve, (0, 0, 0))
        return e

    # -- train loss ---------------------------------------------------------
    def loss(self, params, batch):
        cfg = self.cfg
        e = self.embed(params, batch)
        e = shard(e, "act_batch", "act_seq", "act_embed")
        positions = batch["positions"]
        logits, h, _, aux = self.logits_fn(params, e, positions)
        loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
        metrics = {"ce": loss, "aux": aux}
        total = loss + 0.01 * aux
        if self.mtp and "mtp" in params:
            mtp_loss = self._mtp_loss(params, h, e, batch)
            metrics["mtp"] = mtp_loss
            total = total + 0.1 * mtp_loss
        return total, metrics

    def _mtp_loss(self, params, h, e, batch):
        """DeepSeek-V3 multi-token prediction: one extra block predicts
        token t+2 from (norm(h_t), norm(emb_{t+1}))."""
        cfg = self.cfg
        p = params["mtp"]
        h_in = apply_norm(p["norm_h"], h, cfg)
        e_next = jnp.roll(e, -1, axis=1)
        e_in = apply_norm(p["norm_e"], e_next, cfg)
        x = jnp.einsum("bsd,de->bse",
                       jnp.concatenate([h_in, e_in], -1),
                       p["proj"].astype(h.dtype))
        positions = batch["positions"]
        x, _, _ = self._mtp_block_apply(p["block"], x, positions)
        x = apply_norm(params["final_norm"], x, cfg)
        logits = lm_logits(params.get("head", {}), params["embed"], x, cfg)
        labels2 = jnp.roll(batch["labels"], -1, axis=1)
        mask = jnp.ones_like(labels2, jnp.float32).at[:, -2:].set(0.0)
        return cross_entropy(logits, labels2, mask)

    # populated by build_model for MTP archs
    _mtp_block_apply: Callable = None

    # -- serving -------------------------------------------------------------
    def prefill(self, params, batch, cache):
        """Prefill computes the LM head for the LAST position only — the
        full-sequence vocab projection (b, s, V) is pure waste at prefill
        (s=32k × vocab=256k would dwarf the backbone's own traffic)."""
        e = self.embed(params, batch)
        positions = batch["positions"]
        h, cache, _ = self.backbone(params, e, positions, cache, 0)
        h_last = apply_norm(params["final_norm"], h[:, -1:, :], self.cfg)
        logits = lm_logits(params.get("head", {}), params["embed"], h_last,
                           self.cfg)
        return logits[:, 0, :], cache

    def decode_step(self, params, tokens, positions, cache, cache_pos):
        """tokens: (b, 1); positions: (b, 1) or (3, b, 1)."""
        batch = {"tokens": tokens}
        if self.cfg.family == "audio-lm":
            # decode feeds embeddings: frontends decode via embedding table
            e = params["embed"]["tok"][tokens]
            pos2d = positions if positions.ndim == 2 else positions[0]
            e = e + sinusoidal_pos(pos2d, self.cfg.d_model).astype(e.dtype)
        else:
            e = embed_tokens(params["embed"], tokens, self.cfg)
        logits, _, cache, _ = self.logits_fn(params, e, positions, cache,
                                             cache_pos)
        return logits[:, -1, :], cache


# ---------------------------------------------------------------------------
# Block builders
# ---------------------------------------------------------------------------

def _attn_block_spec(cfg, window: bool) -> SpecTree:
    sp: SpecTree = {"norm1": norm_spec(cfg),
                    "attn": mla_spec(cfg) if cfg.attention == "mla"
                    else gqa_spec(cfg)}
    if cfg.post_norms:
        sp["post1"] = norm_spec(cfg)
    return sp


def _ffn_part_spec(cfg, moe: bool) -> SpecTree:
    sp: SpecTree = {"norm2": norm_spec(cfg),
                    "ffn": moe_spec(cfg) if moe else ffn_spec(cfg)}
    if cfg.post_norms:
        sp["post2"] = norm_spec(cfg)
    return sp


def _make_attn_ffn_block(cfg, v: Variants, *, moe: bool, window: int):
    attn_fn = mla_attention if cfg.attention == "mla" else gqa_attention
    moe_fn = MOE_IMPLS[v.moe_impl]
    if v.moe_impl == "grouped":
        moe_fn = functools.partial(moe_fn,
                                   capacity_factor=v.capacity_factor,
                                   combine_dtype=v.moe_combine,
                                   slot_dp_shard=v.moe_slot_dp)
    qscale = None
    if cfg.arch_id.startswith("gemma"):
        qscale = (cfg.d_model / cfg.n_heads) ** -0.5   # query_pre_attn_scalar

    def apply(p, x, positions, cache, cache_pos):
        h = apply_norm(p["norm1"], x, cfg)
        a, new_cache = attn_fn(p["attn"], h, cfg, positions=positions,
                               kernel=v.attn_kernel, window=window,
                               cache=cache, cache_pos=cache_pos,
                               query_scale=qscale)
        if cfg.post_norms:
            a = apply_norm(p["post1"], a, cfg)
        x = x + a
        h = apply_norm(p["norm2"], x, cfg)
        if moe:
            f, aux = moe_fn(p["ffn"], h, cfg)
        else:
            f, aux = ffn_apply(p["ffn"], h, cfg), jnp.zeros((), jnp.float32)
        if cfg.post_norms:
            f = apply_norm(p["post2"], f, cfg)
        x = x + f
        return x, new_cache, aux

    spec = {**_attn_block_spec(cfg, window > 0), **_ffn_part_spec(cfg, moe)}
    return spec, apply


def _attn_cache_spec_fn(cfg):
    def fn(batch, max_seq):
        if cfg.attention == "mla":
            return mla_cache_spec(cfg, batch, max_seq)
        return gqa_cache_spec(cfg, batch, max_seq)
    return fn


# -- dense / moe stacks -------------------------------------------------------

def _uniform_stacks(cfg, v: Variants) -> Tuple[Stack, ...]:
    stacks = []
    if cfg.alt_local_global:
        # gemma2: scanned super-block = [local(window), global]
        spec_l, apply_l = _make_attn_ffn_block(cfg, v, moe=False,
                                               window=cfg.sliding_window)
        spec_g, apply_g = _make_attn_ffn_block(cfg, v, moe=False, window=0)

        def apply(p, x, positions, cache, cache_pos):
            cl = cache.get("local") if cache else None
            cg = cache.get("global") if cache else None
            x, c1, a1 = apply_l(p["local"], x, positions, cl, cache_pos)
            x, c2, a2 = apply_g(p["global"], x, positions, cg, cache_pos)
            nc = {"local": c1, "global": c2} if cache is not None else None
            return x, nc, a1 + a2

        cs = _attn_cache_spec_fn(cfg)

        def cache_spec(batch, max_seq):
            # local layers only ever see `window` tokens: ring-buffer cache
            local_len = min(max_seq, cfg.sliding_window) if cfg.sliding_window \
                else max_seq
            return {"local": cs(batch, local_len), "global": cs(batch, max_seq)}

        return (Stack("blocks", cfg.num_layers // 2,
                      {"local": spec_l, "global": spec_g}, apply, cache_spec),)

    if cfg.is_moe and cfg.first_dense_layers:
        spec_d, apply_d = _make_attn_ffn_block(cfg, v, moe=False, window=0)
        spec_m, apply_m = _make_attn_ffn_block(cfg, v, moe=True, window=0)
        cs = _attn_cache_spec_fn(cfg)
        stacks.append(Stack(
            "dense", cfg.first_dense_layers, spec_d,
            lambda p, x, pos, c, cp: apply_d(p, x, pos, c, cp),
            lambda b, s: cs(b, s)))
        stacks.append(Stack(
            "moe", cfg.num_layers - cfg.first_dense_layers, spec_m,
            lambda p, x, pos, c, cp: apply_m(p, x, pos, c, cp),
            lambda b, s: cs(b, s)))
        return tuple(stacks)

    moe = cfg.is_moe
    spec, apply = _make_attn_ffn_block(cfg, v, moe=moe,
                                       window=cfg.sliding_window
                                       if not cfg.alt_local_global else 0)
    cs = _attn_cache_spec_fn(cfg)
    return (Stack("blocks", cfg.num_layers, spec, apply,
                  lambda b, s: cs(b, s)),)


# -- rwkv stack ----------------------------------------------------------------

def _rwkv_stacks(cfg, v: Variants) -> Tuple[Stack, ...]:
    spec = {"norm1": norm_spec(cfg), "norm2": norm_spec(cfg),
            **rwkv6_spec(cfg)}

    def apply(p, x, positions, cache, cache_pos):
        tm_state = None
        if cache is not None:
            tm_state = {"shift": cache["tm_shift"], "wkv": cache["wkv"]}
        h = apply_norm(p["norm1"], x, cfg)
        a, tm_new = rwkv6_time_mix(p["tm"], h, cfg, tm_state, v.wkv_impl)
        x = x + a
        h = apply_norm(p["norm2"], x, cfg)
        cm_state = cache["cm_shift"] if cache is not None else None
        f, cm_new = rwkv6_channel_mix(p["cm"], h, cfg, cm_state)
        x = x + f
        nc = None
        if cache is not None:
            nc = {"tm_shift": tm_new["shift"], "wkv": tm_new["wkv"],
                  "cm_shift": cm_new}
        return x, nc, jnp.zeros((), jnp.float32)

    return (Stack("blocks", cfg.num_layers, spec, apply,
                  lambda b, s: rwkv6_state_spec(cfg, b)),)


# -- jamba hybrid stack ----------------------------------------------------------

def _hybrid_stacks(cfg, v: Variants) -> Tuple[Stack, ...]:
    period = cfg.attn_period
    n_super = cfg.num_layers // period
    moe_fn = MOE_IMPLS[v.moe_impl]
    if v.moe_impl == "grouped":
        moe_fn = functools.partial(moe_fn,
                                   capacity_factor=v.capacity_factor,
                                   combine_dtype=v.moe_combine,
                                   slot_dp_shard=v.moe_slot_dp)

    sub_specs: SpecTree = {}
    for i in range(period):
        is_attn = (i == cfg.attn_offset)
        is_moe = cfg.is_moe and (i % cfg.moe_every == 1)
        sp: SpecTree = {"norm1": norm_spec(cfg)}
        sp["mix"] = gqa_spec(cfg) if is_attn else mamba_spec(cfg)
        sp["norm2"] = norm_spec(cfg)
        sp["ffn"] = moe_spec(cfg) if is_moe else ffn_spec(cfg)
        sub_specs[f"l{i}"] = sp

    def apply(p, x, positions, cache, cache_pos):
        aux = jnp.zeros((), jnp.float32)
        nc: Dict[str, Any] = {}
        for i in range(period):
            sp = p[f"l{i}"]
            is_attn = (i == cfg.attn_offset)
            is_moe = cfg.is_moe and (i % cfg.moe_every == 1)
            ci = cache.get(f"l{i}") if cache is not None else None
            h = apply_norm(sp["norm1"], x, cfg)
            if is_attn:
                a, c_new = gqa_attention(sp["mix"], h, cfg,
                                         positions=positions,
                                         kernel=v.attn_kernel,
                                         cache=ci, cache_pos=cache_pos)
            else:
                a, c_new = mamba_block(sp["mix"], h, cfg, ci)
                if cache is None:
                    c_new = None
            x = x + a
            h = apply_norm(sp["norm2"], x, cfg)
            if is_moe:
                f, a_l = moe_fn(sp["ffn"], h, cfg)
                aux = aux + a_l
            else:
                f = ffn_apply(sp["ffn"], h, cfg)
            x = x + f
            if cache is not None:
                nc[f"l{i}"] = c_new
        return x, (nc if cache is not None else None), aux

    def cache_spec(batch, max_seq):
        out: SpecTree = {}
        for i in range(period):
            if i == cfg.attn_offset:
                out[f"l{i}"] = gqa_cache_spec(cfg, batch, max_seq)
            else:
                out[f"l{i}"] = mamba_state_spec(cfg, batch)
        return out

    return (Stack("blocks", n_super, sub_specs, apply, cache_spec),)


# ---------------------------------------------------------------------------
# build_model — the Uniform Component Assembler's model half
# ---------------------------------------------------------------------------

def build_model(cfg, variants: Optional[Variants] = None) -> Model:
    v = variants or Variants()
    if cfg.family == "ssm-lm":
        stacks = _rwkv_stacks(cfg, v)
    elif cfg.family == "hybrid-lm":
        stacks = _hybrid_stacks(cfg, v)
    else:
        stacks = _uniform_stacks(cfg, v)

    specs: SpecTree = {"embed": embed_spec(cfg),
                       "final_norm": norm_spec(cfg)}
    hs = head_spec(cfg)
    if hs:
        specs["head"] = hs
    for st in stacks:
        specs[st.name] = stacked(st.spec, st.n)

    mtp_apply = None
    if cfg.mtp:
        blk_spec, blk_apply = _make_attn_ffn_block(cfg, v, moe=False, window=0)
        specs["mtp"] = {
            "norm_h": norm_spec(cfg), "norm_e": norm_spec(cfg),
            "proj": P((2 * cfg.d_model, cfg.d_model), ("embed", "embed")),
            "block": blk_spec,
        }
        def mtp_apply(p, x, positions, _apply=blk_apply):
            return _apply(p, x, positions, None, 0)

    m = Model(cfg=cfg, variants=v, stacks=tuple(stacks), specs=specs,
              mtp=cfg.mtp)
    m._mtp_block_apply = mtp_apply
    return m
