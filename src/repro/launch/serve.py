"""Serving launcher: lazy-build a CIR for serving and drive the
slot-based continuous-batching engine with synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve --arch codeqwen1.5-7b -n 16

Scale-to-zero support: ``--snapshot-out PATH`` writes an
ASSEMBLED+COMPILED snapshot once the instance is READY; ``--restore PATH``
rebuilds from such a snapshot — resolution is a pin replay, the fetch is a
chunk delta against the local store, and the compile stage restores the
executable through the compile cache — instead of a full cold build.

Provenance: ``--sbom-out PATH`` emits the CycloneDX-shaped SBOM of the
resolved dependency closure (docs/cir-format.md §12, R-096) once the
instance is READY.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from ..configs import ARCHS
from ..core import (CompileCache, InstanceSnapshot, LazyBuilder, PreBuilder,
                    SPEC_LEASE_PREFIX, probe_host, restore_instance,
                    snapshot_instance, write_sbom)
from ..core import catalog
from .mesh import make_smoke_mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b",
                    choices=sorted(ARCHS.keys()))
    ap.add_argument("-n", "--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--snapshot-out", metavar="PATH", default=None,
                    help="write an ASSEMBLED+COMPILED instance snapshot "
                         "once READY (restorable via --restore)")
    ap.add_argument("--restore", metavar="PATH", default=None,
                    help="restore a scaled-to-zero instance from a snapshot "
                         "instead of a full cold build")
    ap.add_argument("--sbom-out", metavar="PATH", default=None,
                    help="write the CycloneDX-shaped SBOM of the resolved "
                         "dependency closure once READY (docs §12, R-096)")
    ap.add_argument("--platform-report", action="store_true",
                    help="build with the §13 performance-portable split "
                         "(shared IR module + per-platform artifact tail + "
                         "autotune table) and print which of those "
                         "components were peer-shared vs locally built")
    ap.add_argument("--retire-spec", action="store_true",
                    help="after writing the snapshot, demote the instance's "
                         "content to the speculative eviction tier (a spec: "
                         "soft lease): it becomes the first thing capacity "
                         "pressure reclaims, and a restore promotes whatever "
                         "survived back to demand content")
    args = ap.parse_args(argv)
    if args.retire_spec and not args.snapshot_out:
        ap.error("--retire-spec requires --snapshot-out (retiring without "
                 "a snapshot would strand the instance)")

    svc = catalog.default_service()
    builder = LazyBuilder(svc, compile_cache=CompileCache(),
                          ir_components=args.platform_report)

    if args.restore:
        with open(args.restore) as f:
            snap = InstanceSnapshot.from_json(f.read())
        inst = restore_instance(snap, builder, mesh=make_smoke_mesh(1),
                                block=False)
        cir, cfg = inst.cir, inst.cir.arch_config()
    else:
        cfg = ARCHS[args.arch]
        if not args.full:
            cfg = cfg.reduced()
        cir = PreBuilder(svc).prebuild(cfg, entrypoint="serve")
        spec = probe_host(mesh_shape=(1,), mesh_axes=("data",))
        # non-blocking lazy-build: the orchestrator overlaps
        # assemble/compile with the weight-asset tail; we wait on
        # lifecycle stages, not build()
        inst = builder.build(cir, spec, mesh=make_smoke_mesh(1),
                             overrides={"workload": "decode"},
                             compile_steps=bool(args.snapshot_out
                                                or args.platform_report),
                             block=False)
    inst.wait("ready")
    verb = "restored" if args.restore else "lazy-built"
    print(f"{verb} {cir.name} for {inst.spec.platform_id}; "
          f"deployable at {inst.report.critical_path_s * 1e3:.1f} ms "
          f"(stage={inst.stage}, CIR={cir.size_bytes()}B)")
    if args.sbom_out:
        sbom = builder.sbom(inst)
        write_sbom(args.sbom_out, sbom)
        print(f"SBOM written to {args.sbom_out} "
              f"({len(sbom['components'])} components)")
    if args.platform_report:
        inst.wait("complete")
        rep = inst.report

        def src(shared: int, built: int) -> str:
            if shared:
                return f"shared ({shared / 2**20:.1f} MiB from the fleet)"
            if built:
                return f"locally built ({built / 2**20:.1f} MiB published)"
            return "resident (no bytes moved)"

        print("platform report (docs §13 split, "
              f"compile_key={(inst.compile_key or '')[:16]}):")
        print(f"  ir module      {src(rep.ir_shared_bytes, rep.ir_bytes_published)}")
        print(f"  platform tail  "
              f"{src(rep.artifact_bytes_fetched, rep.artifact_bytes_published)}")
        print(f"  autotune table "
              f"{src(rep.autotune_bytes_fetched, rep.autotune_bytes_published)}")
    # first weight use: block until the asset tail has fully landed
    inst.wait("weights")
    print(f"weights landed; fetched={inst.report.bytes_fetched}B "
          f"(overlap {inst.report.overlap_s * 1e3:.1f} ms)")
    if args.snapshot_out:
        with open(args.snapshot_out, "w") as f:
            f.write(snapshot_instance(inst).to_json())
        print(f"snapshot written to {args.snapshot_out} "
              f"(stage={inst.stage}, compile_key="
              f"{(inst.compile_key or '')[:16]})")
        if args.retire_spec:
            # scale-to-zero retirement: the content stays resident but
            # drops to the speculative eviction tier — first victim under
            # pressure, promoted back on the next demand (restore) hit
            builder.store.acquire_build_lease(
                f"{SPEC_LEASE_PREFIX}retired:{cir.digest()[:16]}",
                list(inst.bundle.components()))
            print("instance content demoted to the speculative eviction "
                  "tier (evictable first; restore promotes it back)")

    params = inst.model.init(jax.random.PRNGKey(0))
    engine = inst.entry["make_engine"](
        params, num_slots=args.slots, max_seq=args.max_seq,
        prefill_buckets=(32,))

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        ln = int(rng.integers(4, 24))
        engine.submit(rng.integers(1, cfg.vocab, ln).tolist(),
                      max_new_tokens=args.max_new,
                      temperature=args.temperature)
    resp = engine.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in resp)
    print(f"{len(resp)} responses, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s, {engine._ticks} engine ticks)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
