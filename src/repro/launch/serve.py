"""Serving launcher: lazy-build a CIR for serving and drive the
slot-based continuous-batching engine with synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve --arch codeqwen1.5-7b -n 16
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from ..configs import ARCHS
from ..core import LazyBuilder, PreBuilder, probe_host
from ..core import catalog
from .mesh import make_smoke_mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b",
                    choices=sorted(ARCHS.keys()))
    ap.add_argument("-n", "--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if not args.full:
        cfg = cfg.reduced()

    svc = catalog.default_service()
    cir = PreBuilder(svc).prebuild(cfg, entrypoint="serve")
    spec = probe_host(mesh_shape=(1,), mesh_axes=("data",))
    # non-blocking lazy-build: the orchestrator overlaps assemble/compile
    # with the weight-asset tail; we wait on lifecycle stages, not build()
    inst = LazyBuilder(svc).build(cir, spec, mesh=make_smoke_mesh(1),
                                  overrides={"workload": "decode"},
                                  block=False)
    inst.wait("ready")
    print(f"lazy-built {cir.name} for {spec.platform_id}; "
          f"deployable at {inst.report.critical_path_s * 1e3:.1f} ms "
          f"(stage={inst.stage}, CIR={cir.size_bytes()}B)")
    # first weight use: block until the asset tail has fully landed
    inst.wait("weights")
    print(f"weights landed; fetched={inst.report.bytes_fetched}B "
          f"(overlap {inst.report.overlap_s * 1e3:.1f} ms)")

    params = inst.model.init(jax.random.PRNGKey(0))
    engine = inst.entry["make_engine"](
        params, num_slots=args.slots, max_seq=args.max_seq,
        prefill_buckets=(32,))

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        ln = int(rng.integers(4, 24))
        engine.submit(rng.integers(1, cfg.vocab, ln).tolist(),
                      max_new_tokens=args.max_new,
                      temperature=args.temperature)
    resp = engine.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in resp)
    print(f"{len(resp)} responses, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s, {engine._ticks} engine ticks)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
