"""End-to-end training launcher.

On the CPU container this drives a reduced config (``--reduced``, default);
the same code path lowers the full configs on the production mesh (that is
what ``dryrun.py`` proves).  The flow is the paper's: pre-build a CIR →
lazy-build it for the probed platform → run the assembled container under
the fault-tolerant driver.

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b --steps 50
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

import jax
import jax.numpy as jnp

from ..configs import ARCHS
from ..core import LazyBuilder, PreBuilder, probe_host
from ..core import catalog
from ..runtime import RuntimeConfig, TrainDriver
from .mesh import make_smoke_mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b",
                    choices=sorted(ARCHS.keys()))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="use the full (paper-size) config — needs real HW")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if not args.full:
        cfg = cfg.reduced()

    svc = catalog.default_service()
    cir = PreBuilder(svc).prebuild(cfg, entrypoint="train", seed=args.seed)
    print(f"CIR {cir.name} ({cir.size_bytes()} bytes on the wire)")

    spec = probe_host(mesh_shape=(1,), mesh_axes=("data",))
    mesh = make_smoke_mesh(1)
    inst = LazyBuilder(svc).build(
        cir, spec, mesh=mesh,
        overrides={"lr": args.lr, "total_steps": args.steps,
                   "warmup": max(args.steps // 10, 5)})
    print("lazy-built for", spec.platform_id, "| picks:",
          {c.name: c.env for c in inst.bundle.components()
           if c.manager in ("kernel", "parallel", "opt")})

    e = inst.entry
    step_fn = jax.jit(e["train_step"], donate_argnums=(0,))

    def batch_fn(step):
        b = e["batch_fn"](args.seq, args.batch, step=step, seed=args.seed)
        return {k: jnp.asarray(v) for k, v in b.items()}

    driver = TrainDriver(
        train_step=step_fn,
        init_state=lambda: e["init_state"](jax.random.PRNGKey(args.seed)),
        batch_fn=batch_fn,
        ckpt_dir=os.path.join(args.ckpt_dir, cfg.arch_id),
        cfg=RuntimeConfig(total_steps=args.steps,
                          checkpoint_every=args.checkpoint_every))
    t0 = time.perf_counter()
    res = driver.run()
    dt = time.perf_counter() - t0
    k = max(1, len(res.losses) // 10)
    print(f"steps={res.steps_done} wall={dt:.1f}s "
          f"loss {sum(res.losses[:k])/k:.4f} -> {sum(res.losses[-k:])/k:.4f} "
          f"restarts={res.restarts} stragglers={res.straggler_events}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
