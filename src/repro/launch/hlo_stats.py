"""Static analysis of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, so scan-based
models (every model here) under-report FLOPs/bytes by ~num_layers x.  This
module parses ``compiled.as_text()`` into its computations, resolves the
call graph (fusion/call/while/conditional), extracts trip counts from loop
conditions, and accumulates:

  * flops            — MXU matmul FLOPs (2·M·N·K per dot; vector-unit
                       elementwise flops are excluded, as is standard for
                       compute-roofline terms)
  * hbm_bytes        — Σ over executed top-level ops of operand+result
                       bytes (fusions counted at their boundary, the
                       HBM-traffic model XLA itself uses)
  * collective_bytes — Σ operand bytes of all-reduce / all-gather /
                       reduce-scatter / all-to-all / collective-permute,
                       per collective family

All counts are PER DEVICE (the partitioned module is per-device).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota", "broadcast", "reshape",
             "copy-done", "all-gather-done", "all-reduce-done",
             "collective-permute-done", "partition-id", "replica-id"}

# Pure elementwise ops: the CPU backend leaves many of these unfused at the
# top level, but on the TPU target they fuse into their consumers — counting
# their operand/result bytes would overstate HBM traffic ~10x.  The memory
# term therefore models TPU-style fusion: bytes are charged only at fusion
# boundaries, dots, collectives, data movement and reductions.
_ELEMENTWISE = {
    "convert", "multiply", "add", "subtract", "divide", "select", "minimum",
    "maximum", "negate", "tanh", "cosine", "sine", "exponential", "log",
    "rsqrt", "sqrt", "power", "compare", "and", "or", "not", "xor", "abs",
    "sign", "floor", "ceil", "round-nearest-even", "round-nearest-afz",
    "clamp", "is-finite", "exponential-minus-one", "log-plus-one", "tan",
    "logistic", "atan2", "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "clz", "popcnt", "real", "imag", "map",
}


# ---------------------------------------------------------------------------
# shape parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """Normalized ``compiled.cost_analysis()``: jax <= 0.4.37 returns one
    dict per device, newer jax a single dict.  Always returns a dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


def shape_elems(type_str: str) -> int:
    n = 1
    for d in shape_dims(type_str):
        n *= d
    return n


# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_type: str
    operands: List[str]
    attrs: str
    is_root: bool = False
    operand_str: str = ""


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    shapes: Dict[str, str]          # %name -> result type


_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(")
_OP_RE = re.compile(r"^\s+(ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+)$")


def _split_type_rest(s: str) -> Tuple[str, str]:
    s = s.lstrip()
    if s.startswith("("):
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return s[:i + 1], s[i + 1:].lstrip()
    i = s.find(" ")
    return s[:i], s[i + 1:].lstrip()


def _split_opcode(rest: str) -> Tuple[str, str, str]:
    """'dot(%a, %b), attrs' -> ('dot', '%a, %b', attrs)."""
    i = rest.find("(")
    opcode = rest[:i].strip()
    depth = 0
    for j in range(i, len(rest)):
        if rest[j] == "(":
            depth += 1
        elif rest[j] == ")":
            depth -= 1
            if depth == 0:
                return opcode, rest[i + 1:j], rest[j + 1:]
    return opcode, rest[i + 1:], ""


_OPERAND_RE = re.compile(r"%[\w.\-]+")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" "):
            m = _HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                if line.strip().startswith("ENTRY"):
                    entry_name = cur.name
                continue
            if line.strip() == "}":
                cur = None
                continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        is_root, name, rhs = bool(m.group(1)), m.group(2), m.group(3)
        rtype, rest = _split_type_rest(rhs)
        if "(" not in rest:
            continue
        opcode, operand_str, attrs = _split_opcode(rest)
        operands = _OPERAND_RE.findall(operand_str)
        cur.ops.append(Op(name, opcode, rtype, operands, attrs, is_root,
                          operand_str))
        cur.shapes[name] = rtype
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


# ---------------------------------------------------------------------------
# cost accumulation over the call graph
# ---------------------------------------------------------------------------

_CALLS_RE = re.compile(r"calls=(%[\w.\-]+)")
_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_APPLY_RE = re.compile(r"to_apply=(%[\w.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _fusion_hbm_bytes(op: Op, comp: Computation, callee: Optional[Computation]
                      ) -> float:
    """HBM traffic of a fusion at its boundary, recognizing the loop
    patterns that would otherwise be charged at full-buffer size per
    iteration:
      * root = dynamic-update-slice → in-place write of a slice into a
        loop-carried buffer (scan ys accumulation): charge 2×slice;
      * a fusion PARAMETER consumed only by dynamic-slice/gather inside the
        fusion → the loop reads one slice of the big operand, not all of
        it: charge 2×slice-result instead of the full operand.
    """
    out_b = shape_bytes(op.result_type)
    if callee is None:
        return out_b + sum(shape_bytes(comp.shapes.get(o, ""))
                           for o in op.operands)

    # map parameter index -> param op name, and find each param's consumers
    param_names: Dict[int, str] = {}
    for o2 in callee.ops:
        if o2.opcode == "parameter":
            try:
                param_names[int(o2.operand_str)] = o2.name
            except ValueError:
                pass
    consumers: Dict[str, List[Op]] = {}
    for o2 in callee.ops:
        for ref in o2.operands:
            consumers.setdefault(ref, []).append(o2)

    read_b = 0.0
    for i, operand in enumerate(op.operands):
        full = shape_bytes(comp.shapes.get(operand, ""))
        pname = param_names.get(i)
        cons = consumers.get(pname, []) if pname else []
        if cons and all(c.opcode in ("dynamic-slice", "gather")
                        for c in cons):
            sliced = sum(shape_bytes(callee.shapes.get(c.name, ""))
                         for c in cons)
            read_b += min(2.0 * sliced, full)
        else:
            read_b += full

    root = None
    for o2 in callee.ops:
        if o2.is_root:
            root = o2
            break
    if root is None and callee.ops:
        root = callee.ops[-1]
    if root is not None and root.opcode == "dynamic-update-slice":
        upd = (shape_bytes(callee.shapes.get(root.operands[1], ""))
               if len(root.operands) > 1 else 0)
        # the aliased big buffer passes through; subtract it from reads
        big_alias = max((shape_bytes(comp.shapes.get(o, ""))
                         for o in op.operands), default=0)
        return 2.0 * upd + max(read_b - big_alias, 0.0)
    if root is not None and root.opcode in ("dynamic-slice", "gather") \
            and read_b > 8 * out_b:
        return 2.0 * out_b
    return out_b + read_b


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    by_collective: Dict[str, float] = dataclasses.field(default_factory=dict)
    dots: int = 0
    collectives: int = 0

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.hbm_bytes += other.hbm_bytes * times
        self.collective_bytes += other.collective_bytes * times
        self.dots += int(other.dots * times)
        self.collectives += int(other.collectives * times)
        for k, v in other.by_collective.items():
            self.by_collective[k] = self.by_collective.get(k, 0.0) + v * times


def _trip_count_text(cond_text: str) -> int:
    """Largest s32 constant in the loop condition ≈ the trip count (jax
    scan/fori loops compare an s32 counter against the length)."""
    vals = [int(v) for v in re.findall(
        r"s32\[\][^=]*constant\((\d+)\)", cond_text)]
    return max(vals) if vals else 1


def module_cost(text: str) -> Cost:
    comps = parse_hlo(text)
    # keep raw per-computation text for trip-count extraction
    raw: Dict[str, List[str]] = {}
    cur = None
    for line in text.splitlines():
        if not line.startswith(" "):
            m = _HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                raw[cur] = []
                continue
            if line.strip() == "}":
                cur = None
                continue
        if cur is not None:
            raw[cur].append(line)

    memo: Dict[str, Cost] = {}

    def cost_of(name: str, depth: int = 0) -> Cost:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        c = Cost()
        if comp is None or depth > 64:
            memo[name] = c
            return c
        memo[name] = c          # break cycles defensively
        for op in comp.ops:
            out_b = shape_bytes(op.result_type)
            opnd_b = sum(shape_bytes(comp.shapes.get(o, "")) for o in
                         op.operands)
            oc = op.opcode
            if oc == "dot":
                k = 1
                m = _LHS_C_RE.search(op.attrs)
                lhs_t = comp.shapes.get(op.operands[0], "") \
                    if op.operands else ""
                lhs_dims = shape_dims(lhs_t)
                if m and m.group(1):
                    for d in m.group(1).split(","):
                        di = int(d)
                        if di < len(lhs_dims):
                            k *= lhs_dims[di]
                c.flops += 2.0 * shape_elems(op.result_type) * k
                c.dots += 1
                c.hbm_bytes += out_b + opnd_b
            elif oc == "convolution":
                # rare here; treat as dot over the kernel volume
                c.flops += 2.0 * shape_elems(op.result_type) * max(
                    1, shape_elems(comp.shapes.get(op.operands[1], "")))
                c.hbm_bytes += out_b + opnd_b
            elif oc in _COLLECTIVES or (oc.endswith("-start")
                                        and oc[:-6] in _COLLECTIVES):
                base = oc[:-6] if oc.endswith("-start") else oc
                if base in _COLLECTIVES:
                    cb = sum(shape_bytes(comp.shapes.get(o, ""))
                             for o in op.operands)
                    c.collective_bytes += cb
                    c.by_collective[base] = c.by_collective.get(base, 0.0) + cb
                    c.collectives += 1
                    c.hbm_bytes += out_b + opnd_b
            elif oc == "fusion":
                m = _CALLS_RE.search(op.attrs)
                callee = comps.get(m.group(1)) if m else None
                if m:
                    inner = cost_of(m.group(1), depth + 1)
                    # fusion boundary = its HBM traffic; inner dots count
                    c.flops += inner.flops
                    c.dots += inner.dots
                    c.collective_bytes += inner.collective_bytes
                    for k2, v in inner.by_collective.items():
                        c.by_collective[k2] = c.by_collective.get(k2, 0) + v
                c.hbm_bytes += _fusion_hbm_bytes(op, comp, callee)
            elif oc == "while":
                m_b = _BODY_RE.search(op.attrs)
                m_c = _COND_RE.search(op.attrs)
                trip = 1
                if m_c and m_c.group(1) in raw:
                    trip = _trip_count_text("\n".join(raw[m_c.group(1)]))
                if m_b:
                    c.add(cost_of(m_b.group(1), depth + 1), trip)
                if m_c:
                    c.add(cost_of(m_c.group(1), depth + 1), trip)
            elif oc in ("call", "custom-call"):
                m = _APPLY_RE.search(op.attrs) or _CALLS_RE.search(op.attrs)
                if m:
                    c.add(cost_of(m.group(1), depth + 1), 1.0)
                c.hbm_bytes += out_b + opnd_b
            elif oc == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}",
                                      op.attrs)
                names = _OPERAND_RE.findall(branches[0]) if branches else []
                m_t = re.search(r"true_computation=(%[\w.\-]+)", op.attrs)
                m_f = re.search(r"false_computation=(%[\w.\-]+)", op.attrs)
                names += [m.group(1) for m in (m_t, m_f) if m]
                if names:
                    worst = Cost()
                    for n2 in names:
                        cc = cost_of(n2, depth + 1)
                        if cc.flops >= worst.flops:
                            worst = cc
                    c.add(worst, 1.0)
                c.hbm_bytes += out_b + opnd_b
            elif oc in ("dynamic-slice", "gather", "slice"):
                # reads only the sliced region, not the operand tensor —
                # charging the full operand would make a seq-scan quadratic
                c.hbm_bytes += 2 * out_b
            elif oc in ("dynamic-update-slice", "scatter"):
                # in-place update: read+write of the update region only
                upd = (shape_bytes(comp.shapes.get(op.operands[1], ""))
                       if len(op.operands) > 1 else out_b)
                c.hbm_bytes += 2 * min(upd, out_b) if upd else out_b
            elif oc == "pad":
                c.hbm_bytes += out_b + (shape_bytes(
                    comp.shapes.get(op.operands[0], ""))
                    if op.operands else 0)
            elif oc in _FREE_OPS or oc in _ELEMENTWISE:
                pass
            else:
                # reduce / sort / copy / concatenate / transpose ...
                c.hbm_bytes += out_b + opnd_b
        memo[name] = c
        return c

    return cost_of("__entry__")


def collective_breakdown(text: str) -> Dict[str, float]:
    return dict(module_cost(text).by_collective)


def top_contributors(text: str, k: int = 20, metric: str = "hbm"
                     ) -> List[Tuple[float, str, str, str]]:
    """Per-op attribution: (total_metric, opcode, result_type, comp) sorted
    desc — the 'profile' view used by the perf hillclimb."""
    comps = parse_hlo(text)
    raw: Dict[str, List[str]] = {}
    cur = None
    for line in text.splitlines():
        if not line.startswith(" "):
            m = _HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                raw[cur] = []
                continue
            if line.strip() == "}":
                cur = None
                continue
        if cur is not None:
            raw[cur].append(line)

    # execution multiplicity of every computation
    mult: Dict[str, float] = {"__entry__": 1.0}
    entry = comps.get("__entry__")
    if entry is None:
        return []
    for nm, cp in comps.items():
        if cp is entry and nm != "__entry__":
            mult[nm] = 1.0      # the real ENTRY name
    fusion_callees: set = set()
    stack = [("__entry__", 1.0)]
    seen_depth = 0
    while stack and seen_depth < 100000:
        seen_depth += 1
        name, m0 = stack.pop()
        comp = comps.get(name)
        if comp is None:
            continue
        for op in comp.ops:
            for pat, factor_fn in (
                    (_CALLS_RE, lambda a: 1.0),
                    (_APPLY_RE, lambda a: 1.0),
                    (_BODY_RE, None), (_COND_RE, None)):
                mm = pat.search(op.attrs)
                if not mm:
                    continue
                callee = mm.group(1)
                if pat is _CALLS_RE and op.opcode == "fusion":
                    fusion_callees.add(callee)
                if pat in (_BODY_RE, _COND_RE):
                    mc = _COND_RE.search(op.attrs)
                    trip = _trip_count_text("\n".join(
                        raw.get(mc.group(1), []))) if mc else 1
                    f = float(trip)
                else:
                    f = 1.0
                new = m0 * f
                if mult.get(callee, 0.0) < new:
                    mult[callee] = new
                    stack.append((callee, new))

    rows: List[Tuple[float, str, str, str]] = []
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        if metric == "hbm" and name in fusion_callees:
            continue        # fusion internals are charged at the boundary
        m0 = mult.get(name, 0.0)
        if m0 <= 0:
            continue
        for op in comp.ops:
            out_b = shape_bytes(op.result_type)
            opnd_b = sum(shape_bytes(comp.shapes.get(o, ""))
                         for o in op.operands)
            if metric == "hbm":
                if op.opcode in ("dynamic-slice", "gather", "slice"):
                    val = 2 * out_b
                elif op.opcode in ("dynamic-update-slice", "scatter"):
                    upd = (shape_bytes(comp.shapes.get(op.operands[1], ""))
                           if len(op.operands) > 1 else out_b)
                    val = 2 * min(upd, out_b) if upd else out_b
                elif op.opcode == "fusion":
                    mm = _CALLS_RE.search(op.attrs)
                    val = _fusion_hbm_bytes(
                        op, comp, comps.get(mm.group(1)) if mm else None)
                elif op.opcode in _FREE_OPS or op.opcode in _ELEMENTWISE \
                        or op.opcode in ("while", "conditional"):
                    continue
                else:
                    val = out_b + opnd_b
            elif metric == "flops" and op.opcode == "dot":
                kk = 1
                mm = _LHS_C_RE.search(op.attrs)
                lhs_dims = shape_dims(comp.shapes.get(op.operands[0], ""))
                if mm and mm.group(1):
                    for d in mm.group(1).split(","):
                        if int(d) < len(lhs_dims):
                            kk *= lhs_dims[int(d)]
                val = 2.0 * shape_elems(op.result_type) * kk
            elif metric == "collective" and (
                    op.opcode in _COLLECTIVES
                    or (op.opcode.endswith("-start")
                        and op.opcode[:-6] in _COLLECTIVES)):
                val = opnd_b
            else:
                continue
            rows.append((val * m0, op.opcode, op.result_type[:60], name))
    rows.sort(reverse=True)
    return rows[:k]
