"""Launch layer: production meshes, multi-pod dry-run, roofline, drivers."""
