"""Production meshes + the assigned (architecture × input-shape) cell grid.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``xla_force_host_platform_device_count`` before first jax init and only
then calls it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


def _make_mesh(shape, axes):
    # AxisType landed in jax 0.4.38+; older jax defaults every axis to Auto
    # already, so omitting axis_types is equivalent there.
    import jax
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_smoke_mesh(devices: int = 1):
    return _make_mesh((devices,), ("data",))


# ---------------------------------------------------------------------------
# Assigned input shapes (identical across the LM-family archs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int
    long_context: bool = False

    @property
    def workload(self) -> str:
        if self.kind == "train":
            return "train"
        if self.long_context:
            return "long-decode"
        return "decode" if self.kind == "decode" else "prefill"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1,
                           long_context=True),
}


def applicable(cfg, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs?, reason).  long_500k needs sub-quadratic attention; pure
    full-attention archs skip it (recorded in DESIGN.md §4)."""
    if shape.long_context and not cfg.sub_quadratic:
        return False, "full-attention arch: O(S) KV decode at 500k is " \
                      "intractable; skipped per assignment rules"
    return True, ""


def live_cells(arch_ids: List[str], configs) -> List[Tuple[str, str]]:
    out = []
    for aid in arch_ids:
        cfg = configs[aid]
        for sname, sh in SHAPES.items():
            ok, _ = applicable(cfg, sh)
            if ok:
                out.append((aid, sname))
    return out


# ---------------------------------------------------------------------------
# Deployment-time workload adaptation (what the lazy-builder gets told)
# ---------------------------------------------------------------------------

def suggest_grad_accum(cfg, shape: ShapeSpec, spec) -> int:
    """Napkin model for the microbatch count: saved scan-boundary
    activations must fit an HBM budget.

        act_bytes ≈ tokens × d_model × 2 B × n_scan_boundaries / dp_shards
        logits    ≈ tokens × vocab × 4 B / (dp × tp)  (freed per microbatch)

    Pick the smallest power-of-two accum that brings act_bytes under ~1/3
    of per-chip HBM, capped so the per-microbatch batch stays ≥ 1 row.
    """
    if shape.kind != "train":
        return 0
    dp = spec.axis("data") * spec.axis("pod")
    tp = spec.axis("model")
    tokens = shape.seq_len * shape.global_batch
    boundaries = cfg.num_layers + 2
    act = tokens * cfg.d_model * 2 * boundaries / dp
    logits = tokens * cfg.vocab * 4 / (dp * tp)
    budget = spec.chip.hbm_bytes / 3.0
    need = (act + logits) / budget
    accum = 1
    while accum < need and accum < shape.global_batch // dp:
        accum *= 2
    return accum if accum > 1 else 0


def replicated_fit(cfg, spec) -> bool:
    """Can the model train fully replicated (pure DP over every axis)?
    Needs params(bf16) + grads(bf16) + f32 update transients ≲ 80 % HBM and
    one whole batch row per chip."""
    n = cfg.param_count()
    need = n * (2 + 2 + 2)          # params + grads + transient slack
    return need <= 0.8 * spec.chip.hbm_bytes


def build_overrides(cfg, shape: ShapeSpec, spec) -> Dict[str, object]:
    """The building-context overrides the launcher feeds the lazy-builder —
    this is the deployment-time, architecture-aware adaptation the paper
    advocates (the developer's CIR never mentions any of it).

    Beyond the workload tag and the grad-accum napkin model, two adaptive
    plan choices validated by the §Perf hillclimb:
      * prefill of kv-narrow GQA archs (kv_heads < model axis) switches to
        sequence-parallel prefill — head-sharding would degenerate into
        score-matrix all-reduces (measured 63 s/step on starcoder2);
      * small models that fit replicated train pure-DP over every axis —
        TP of a ~2 GB model leaves matmuls too skinny for their collectives
        (4.2x roofline-fraction win on musicgen).
    """
    ov: Dict[str, object] = {"workload": shape.workload}
    if shape.kind == "prefill" \
            and cfg.family in ("dense-lm", "moe-lm", "audio-lm", "vlm-lm") \
            and cfg.attention == "gqa" and cfg.n_kv < spec.axis("model"):
        ov["workload"] = "prefill-sp"
    if shape.kind == "train" and replicated_fit(cfg, spec) \
            and shape.global_batch >= spec.num_chips:
        ov["plan.force"] = "dp"
        return ov                     # pure DP: no microbatching needed
    ga = suggest_grad_accum(cfg, shape, spec)
    if ga:
        ov["grad_accum"] = ga
    return ov
