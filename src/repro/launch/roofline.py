"""Roofline analysis over dry-run artifacts.

Per (arch × shape × mesh):
    compute term    = HLO_FLOPs_per_device / peak_FLOP/s       [s]
    memory term     = HLO_bytes_per_device / HBM_bw            [s]
    collective term = collective_bytes_per_device / (links·bw) [s]

Hardware constants (TPU v5e target): 197 TFLOP/s bf16 per chip, 819 GB/s
HBM, ~50 GB/s per ICI link.  Collectives overlap across a chip's links only
partially in the worst case, so the collective term conservatively charges
one link (documented; ICI-rich topologies only improve it).  Inter-pod
(DCI) bytes are charged separately at the DCI bandwidth when a 'pod' axis
exists.

MODEL_FLOPS uses the standard estimators:
    train   : 6·N·T      (N = params, active for MoE; T = tokens)
    prefill : 2·N·T
    decode  : 2·N·B      (one token per sequence)
plus the attention term where it matters.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, List, Optional

from ..configs import ARCHS
from .mesh import SHAPES

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link
DCI_BW = 12.5e9              # B/s / chip across pods (4x25GbE per 4-chip host)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def model_flops_per_device(arch_id: str, shape_name: str, chips: int
                           ) -> float:
    cfg = ARCHS[arch_id]
    sh = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if sh.kind == "train":
        tokens = sh.seq_len * sh.global_batch
        return 6.0 * n_active * tokens / chips
    if sh.kind == "prefill":
        tokens = sh.seq_len * sh.global_batch
        return 2.0 * n_active * tokens / chips
    return 2.0 * n_active * sh.global_batch / chips


def analyze(artifact: Dict[str, Any]) -> Dict[str, Any]:
    arch, shape = artifact["arch"], artifact["shape"]
    chips = artifact["chips"]
    hs = artifact["hlo_stats"]
    t_compute = hs["flops_per_device"] / PEAK_FLOPS
    t_memory = hs["hbm_bytes_per_device"] / HBM_BW
    t_coll = hs["collective_bytes_per_device"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(arch, shape, chips)
    useful = mf / hs["flops_per_device"] if hs["flops_per_device"] else 0.0
    # roofline fraction: useful model flops per second achievable given the
    # bottleneck, as a fraction of peak
    step_time = max(terms.values())
    achievable = mf / step_time if step_time else 0.0
    return {
        "arch": arch, "shape": shape, "mesh": artifact["mesh"],
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_device": mf,
        "hlo_flops_per_device": hs["flops_per_device"],
        "useful_flops_ratio": useful,
        "roofline_fraction": achievable / PEAK_FLOPS,
        "peak_bytes_per_device": artifact["memory"]["peak_bytes"],
        "by_collective": hs.get("by_collective", {}),
    }


def load_artifacts(pattern: str = "*") -> List[Dict[str, Any]]:
    out = []
    for fn in sorted(glob.glob(os.path.join(ARTIFACT_DIR,
                                            pattern + ".json"))):
        with open(fn) as f:
            out.append(json.load(f))
    return out


def fmt_table(rows: List[Dict[str, Any]]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | coll s | "
           "dominant | MODEL/HLO flops | roofline frac | HBM GiB/chip |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3f} | {r['t_memory_s']:.3f} "
            f"| {r['t_collective_s']:.3f} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} "
            f"| {r['peak_bytes_per_device']/2**30:.1f} |")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pattern", default="*")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)
    arts = load_artifacts(args.pattern)
    rows = [analyze(a) for a in arts if "skipped" not in a]
    if args.json:
        print(json.dumps(rows, indent=1))
    else:
        print(fmt_table(rows))
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
