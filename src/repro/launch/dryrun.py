import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input-shape × mesh) cell:
  pre-build the arch's CIR → lazy-build it for the TPU-pod specSheet
  (the paper's deployment-time path, with workload overrides) →
  ``jax.jit(step, in_shardings=…).lower(*input_specs(...)).compile()`` →
  print ``memory_analysis()`` + ``cost_analysis()`` and persist the parsed
  HLO stats (FLOPs / HBM bytes / collective bytes, while-corrected) to
  ``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` for §Roofline.

NOTE: jit's in_shardings rejects kwargs, so the lowering is positional —
``input_specs()`` returns an ordered dict and we lower ``*specs.values()``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--quiet]
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs import ARCHS
from ..core import PreBuilder, LazyBuilder, tpu_multi_pod, tpu_single_pod
from ..core import catalog
from .hlo_stats import module_cost, xla_cost_analysis
from .mesh import (SHAPES, ShapeSpec, applicable, build_overrides,
                   make_production_mesh)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def input_specs(cfg, shape: ShapeSpec, entry: Dict[str, Any]
                ) -> Dict[str, Any]:
    """Ordered kwargs-dict of ShapeDtypeStructs for the cell's step fn."""
    B, S = shape.global_batch, shape.seq_len
    i32, f32 = jnp.int32, jnp.float32
    dt = jnp.dtype(cfg.dtype)

    def pos_struct(b, s):
        if cfg.mrope_sections:
            return jax.ShapeDtypeStruct((3, b, s), i32)
        return jax.ShapeDtypeStruct((b, s), i32)

    if shape.kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
            "positions": pos_struct(B, S),
            "mask": jax.ShapeDtypeStruct((B, S), f32),
        }
        if cfg.family == "audio-lm":
            batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), f32)
        if cfg.family == "vlm-lm":
            batch["vis_embeds"] = jax.ShapeDtypeStruct(
                (B, min(64, S), cfg.d_model), f32)
        state = jax.eval_shape(lambda: entry["init_state"](
            jax.random.PRNGKey(0)))
        return {"state": state, "batch": batch}

    model = entry["_model"]
    params = model.param_shapes()
    cache = jax.eval_shape(
        lambda: model.init_cache(B, S))
    if shape.kind == "prefill":
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "positions": pos_struct(B, S),
        }
        if cfg.family == "audio-lm":
            batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), f32)
        if cfg.family == "vlm-lm":
            batch["vis_embeds"] = jax.ShapeDtypeStruct(
                (B, min(64, S), cfg.d_model), f32)
        return {"params": params, "batch": batch, "cache": cache}

    # decode: one new token with a seq_len-deep cache
    return {
        "params": params,
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "positions": pos_struct(B, 1),
        "cache": cache,
        "cache_pos": jax.ShapeDtypeStruct((), i32),
    }


def _shardings_for(cfg, shape: ShapeSpec, entry, specs, plan
                   ) -> Tuple[Any, ...]:
    from ..core.catalog import make_batch_shardings, make_state_shardings
    from jax.sharding import NamedSharding, PartitionSpec

    repl = NamedSharding(plan.mesh, PartitionSpec())
    if shape.kind == "train":
        st = entry["state_shardings"]()
        b = entry["batch_shardings"](specs["batch"])
        return (st, b)
    psh = entry["param_shardings"]()
    csh = entry["cache_shardings"](shape.global_batch, shape.seq_len)
    if shape.kind == "prefill":
        bsh = entry["batch_shardings"](specs["batch"])
        return (psh, bsh, csh)
    tok_sh = entry["batch_shardings"](
        {"tokens": specs["tokens"], "positions": specs["positions"]})
    return (psh, tok_sh["tokens"], tok_sh["positions"], csh, repl)


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------

def run_cell(arch_id: str, shape_name: str, multi_pod: bool = False,
             quiet: bool = False, save: bool = True,
             overrides: Optional[Dict[str, Any]] = None,
             mesh=None, tag: str = "") -> Dict[str, Any]:
    cfg = ARCHS[arch_id]
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch_id, "shape": shape_name, "skipped": why}

    spec = tpu_multi_pod() if multi_pod else tpu_single_pod()
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)

    svc = catalog.default_service()
    pb = PreBuilder(svc)
    lb = LazyBuilder(svc)
    entrypoint = "train" if shape.kind == "train" else "serve"
    cir = pb.prebuild(cfg, entrypoint=entrypoint)
    ov = dict(build_overrides(cfg, shape, spec))
    ov.update(overrides or {})

    t0 = time.perf_counter()
    inst = lb.build(cir, spec, mesh=mesh, overrides=ov)
    entry = dict(inst.entry)
    entry["_model"] = inst.model
    build_s = time.perf_counter() - t0

    specs = input_specs(cfg, shape, entry)
    shardings = _shardings_for(cfg, shape, entry, specs, entry["plan"])

    if shape.kind == "train":
        fn = entry["train_step"]
        donate = (0,)
    elif shape.kind == "prefill":
        fn = entry["prefill"]
        donate = (2,)
    else:
        fn = entry["decode_step"]
        donate = (3,)

    t0 = time.perf_counter()
    with mesh:
        jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        lowered = jitted.lower(*specs.values())
        lower_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        compile_s = time.perf_counter() - t0

    ma = compiled.memory_analysis()
    ca = xla_cost_analysis(compiled)
    txt = compiled.as_text()
    hlo = module_cost(txt)

    result = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": spec.num_chips,
        "overrides": {k: v for k, v in ov.items()},
        "variant_picks": {f"{c.manager}:{c.name}": c.env
                          for c in inst.bundle.components()},
        "build_s": round(build_s, 3),
        "lower_s": round(lower_s, 3), "compile_s": round(compile_s, 3),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "peak_bytes": int(ma.argument_size_in_bytes
                              + ma.temp_size_in_bytes),
        },
        "xla_cost_analysis": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        },
        "hlo_stats": {
            "flops_per_device": hlo.flops,
            "hbm_bytes_per_device": hlo.hbm_bytes,
            "collective_bytes_per_device": hlo.collective_bytes,
            "by_collective": hlo.by_collective,
            "n_dots": hlo.dots, "n_collectives": hlo.collectives,
        },
        "hlo_chars": len(txt),
    }
    if not quiet:
        print(f"== {arch_id} × {shape_name} × {result['mesh']} "
              f"(compile {compile_s:.1f}s)")
        print(f"   memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f} "
              f"GiB  temp={ma.temp_size_in_bytes/2**30:.2f} GiB  "
              f"out={ma.output_size_in_bytes/2**30:.2f} GiB  per device")
        print(f"   cost_analysis:   flops={ca.get('flops', 0):.3e}  "
              f"bytes={ca.get('bytes accessed', 0):.3e} (scan bodies x1)")
        print(f"   hlo_stats:       flops={hlo.flops:.3e}  "
              f"hbm={hlo.hbm_bytes:.3e}  coll={hlo.collective_bytes:.3e} "
              f"B/device  {hlo.by_collective}")
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        base = f"{arch_id}__{shape_name}__{result['mesh']}{suffix}"
        with open(os.path.join(ARTIFACT_DIR, base + ".json"), "w") as f:
            json.dump(result, f, indent=1)
        # keep the compiled HLO so stats can be re-derived without
        # recompiling (parser iterations, per-op profiles)
        import gzip
        with gzip.open(os.path.join(ARTIFACT_DIR, base + ".hlo.gz"),
                       "wt") as f:
            f.write(txt)
    return result


def reparse_artifacts(pattern: str = "*") -> int:
    """Re-derive hlo_stats for every saved artifact from its stored HLO
    (used after hlo_stats refinements; no recompilation)."""
    import glob
    import gzip
    n = 0
    for fn in sorted(glob.glob(os.path.join(ARTIFACT_DIR,
                                            pattern + ".json"))):
        hlo_fn = fn[:-5] + ".hlo.gz"
        if not os.path.exists(hlo_fn):
            continue
        with gzip.open(hlo_fn, "rt") as f:
            txt = f.read()
        hlo = module_cost(txt)
        with open(fn) as f:
            result = json.load(f)
        result["hlo_stats"] = {
            "flops_per_device": hlo.flops,
            "hbm_bytes_per_device": hlo.hbm_bytes,
            "collective_bytes_per_device": hlo.collective_bytes,
            "by_collective": hlo.by_collective,
            "n_dots": hlo.dots, "n_collectives": hlo.collectives,
        }
        with open(fn, "w") as f:
            json.dump(result, f, indent=1)
        n += 1
    return n


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for aid in ARCHS:
            for sname in SHAPES:
                cells.append((aid, sname))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    failures = 0
    for aid, sname in cells:
        try:
            r = run_cell(aid, sname, multi_pod=args.multi_pod,
                         quiet=args.quiet, mesh=mesh)
            if "skipped" in r:
                print(f"-- {aid} × {sname}: SKIP ({r['skipped']})")
        except Exception:
            failures += 1
            print(f"!! {aid} × {sname} FAILED", file=sys.stderr)
            traceback.print_exc()
    print(f"done; {failures} failures / {len(cells)} cells")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
