"""rwkv6-1.6b 'Finch' [ssm] — attention-free, data-dependent decay.

24L d_model=2048 d_ff=7168 vocab=65536, head_size 64 → 32 heads
[arXiv:2404.05892; unverified].
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="rwkv6-1.6b",
    family="ssm-lm",
    num_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    head_dim=64,
    d_ff=7168,
    vocab=65536,
    attention="none",
    ffn="relu2",
    norm="ln",
    rwkv_head_size=64,
    dtype="bfloat16",
    notes="WKV6 chunked scan; O(1) decode state (no KV cache).",
)
