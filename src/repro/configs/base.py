"""ArchConfig: the 'application' a CIR packages.

One file per assigned architecture lives next to this module; each exports
``CONFIG`` built from the exact public numbers.  ``reduced()`` derives the
small same-family config used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Tuple


@dataclasses.dataclass
class ArchConfig:
    arch_id: str
    family: str                     # dense-lm | moe-lm | ssm-lm | hybrid-lm | audio-lm | vlm-lm
    num_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads

    # --- attention flavour
    attention: str = "gqa"          # gqa | mla | none
    sliding_window: int = 0         # 0 = full; gemma2 local layers use 4096
    alt_local_global: bool = False  # gemma2: alternate local/global layers
    attn_softcap: float = 0.0       # gemma2 logit soft-capping
    final_softcap: float = 0.0
    qkv_bias: bool = False          # qwen-family
    post_norms: bool = False        # gemma2: post-attn/post-ffn norms
    use_rope: bool = True           # musicgen: sinusoidal absolute instead
    rope_theta: float = 10000.0
    partial_rotary: float = 1.0     # phi4-mini: 0.75
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl M-RoPE

    # --- MLA (deepseek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- FFN flavour
    ffn: str = "swiglu"             # swiglu | geglu | gelu
    norm: str = "rms"               # rms | ln
    tie_embeddings: bool = False

    # --- MoE
    num_experts: int = 0
    top_k: int = 0
    shared_experts: int = 0
    moe_ff: int = 0                 # expert hidden dim (if != d_ff)
    first_dense_layers: int = 0     # deepseek: first k layers dense
    moe_every: int = 1              # jamba: MoE every other layer
    router_scale: bool = False      # deepseek sigmoid-routing w/ bias

    # --- SSM / RWKV
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    rwkv_head_size: int = 64
    attn_period: int = 0            # jamba: one attention layer per period
    attn_offset: int = 0

    # --- heads / extras
    mtp: bool = False               # deepseek multi-token prediction head
    frontend: str = ""              # "audio-frames" | "vision-patches" | ""
    codebooks: int = 0              # musicgen
    dtype: str = "bfloat16"
    max_seq: int = 8192

    # --- declared direct dependencies (pre-builder may extend/filter)
    extra_deps: Tuple[Tuple[str, str, str], ...] = ()
    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            self.head_dim = self.d_model // self.n_heads
        if self.moe_ff == 0:
            self.moe_ff = self.d_ff

    # ------------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape."""
        return self.family in ("ssm-lm", "hybrid-lm") or (
            self.alt_local_global and self.sliding_window > 0)

    def param_count(self) -> int:
        """Analytic parameter count (used for image-size + MODEL_FLOPS)."""
        d, L, V = self.d_model, self.num_layers, self.vocab
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        n_attn_layers = L
        n_ssm_layers = 0
        if self.attn_period:
            n_attn_layers = L // self.attn_period
            n_ssm_layers = L - n_attn_layers
        if self.family == "ssm-lm":
            n_attn_layers, n_ssm_layers = 0, L

        if self.attention == "mla":
            q = d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (
                self.qk_nope_dim + self.qk_rope_dim)
            kv = d * (self.kv_lora_rank + self.qk_rope_dim) + \
                self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
            o = self.n_heads * self.v_head_dim * d
            attn = q + kv + o
        elif self.attention == "gqa":
            attn = d * self.n_heads * self.head_dim \
                + 2 * d * self.n_kv * self.head_dim \
                + self.n_heads * self.head_dim * d
        else:
            attn = 0

        if self.family == "ssm-lm":        # rwkv6
            inner = d
            tm = 6 * d * 32 * 2 + d * inner * 4 + inner * d   # lora mixes + wkv proj
            cm = d * self.d_ff + self.d_ff * d
            per = tm + cm
            return emb + L * per

        gated = self.ffn in ("swiglu", "geglu")
        dense_ffn = d * self.d_ff * (3 if gated else 2)
        if self.is_moe:
            moe_ffn = self.num_experts * d * self.moe_ff * (3 if gated else 2)
            moe_ffn += self.shared_experts * d * self.moe_ff * (3 if gated else 2)
            moe_ffn += d * self.num_experts   # router
        else:
            moe_ffn = 0

        mamba = 0
        if n_ssm_layers:
            din = d * self.ssm_expand
            mamba = (d * din * 2            # in_proj (x, z)
                     + din * self.ssm_conv  # conv
                     + din * (self.ssm_state * 2 + 1)  # B,C,dt proj (x->)
                     + din                  # A? (din*state) actually
                     + din * self.ssm_state # A_log
                     + din * d)             # out_proj

        total = emb
        for i in range(L):
            is_attn = (self.attn_period == 0) or (i % self.attn_period == self.attn_offset)
            if self.family == "hybrid-lm":
                blk = attn if is_attn else mamba
            else:
                blk = attn
            if self.is_moe:
                use_moe = (i % self.moe_every == (self.moe_every - 1)) if self.moe_every > 1 \
                    else (i >= self.first_dense_layers)
                blk += moe_ffn if use_moe else dense_ffn
            else:
                blk += dense_ffn
            total += blk
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if not self.is_moe:
            return self.param_count()
        full = dataclasses.replace(
            self, num_experts=self.top_k, shared_experts=self.shared_experts)
        return full.param_count()

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        r = dataclasses.replace(
            self,
            num_layers=max(2, min(4, self.num_layers // 10 or 2)),
            d_model=128, n_heads=4, n_kv=min(self.n_kv, 2) or 2,
            head_dim=32, d_ff=256, vocab=512,
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_nope_dim=16 if self.qk_nope_dim else 0,
            qk_rope_dim=16 if self.qk_rope_dim else 0,
            v_head_dim=32 if self.v_head_dim else 0,
            num_experts=min(self.num_experts, 4),
            top_k=min(self.top_k, 2),
            moe_ff=128 if self.is_moe else 0,
            first_dense_layers=min(self.first_dense_layers, 1),
            sliding_window=64 if self.sliding_window else 0,
            ssm_state=8, rwkv_head_size=32,
            attn_period=min(self.attn_period, 4) if self.attn_period else 0,
            mrope_sections=(4, 6, 6) if self.mrope_sections else (),
            max_seq=256,
            dtype="float32",
        )
        if self.attn_period:
            r = dataclasses.replace(r, num_layers=max(r.num_layers, r.attn_period))
        return r

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        return d

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "ArchConfig":
        d = dict(d)
        for k in ("mrope_sections",):
            if k in d:
                d[k] = tuple(d[k])
        if "extra_deps" in d:
            d["extra_deps"] = tuple(tuple(x) for x in d["extra_deps"])
        return ArchConfig(**d)


FAMILY_MODEL_COMPONENT = {
    "dense-lm": "decoder-dense",
    "moe-lm": "decoder-moe",
    "ssm-lm": "decoder-rwkv",
    "hybrid-lm": "decoder-hybrid",
    "audio-lm": "decoder-audio",
    "vlm-lm": "decoder-vlm",
}
