"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536 [arXiv:2403.19887; hf].
Period-8 super-blocks: attention at offset 4, MoE on every 2nd layer.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="jamba-v0.1-52b",
    family="hybrid-lm",
    num_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    head_dim=128,
    d_ff=14336,
    vocab=65536,
    attention="gqa",
    use_rope=False,             # Jamba uses no positional encoding
    ffn="swiglu",
    norm="rms",
    num_experts=16,
    top_k=2,
    moe_ff=14336,
    moe_every=2,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    attn_period=8,
    attn_offset=4,
    dtype="bfloat16",
    notes="Sub-quadratic: only 4/32 layers carry a KV cache.",
)
