"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (vision frontend stubbed).

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936 [arXiv:2409.12191; hf].
``input_specs()`` provides precomputed patch embeddings + (3, b, s) M-RoPE
position ids; the ViT frontend is a stub per the assignment.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen2-vl-2b",
    family="vlm-lm",
    num_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv=2,
    head_dim=128,
    d_ff=8960,
    vocab=151936,
    attention="gqa",
    qkv_bias=True,
    mrope_sections=(16, 24, 24),
    ffn="swiglu",
    norm="rms",
    tie_embeddings=True,
    frontend="vision-patches",
    rope_theta=1000000.0,
    dtype="bfloat16",
)
