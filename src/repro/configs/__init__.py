"""Assigned architecture configs (exact public numbers) + lookup helpers."""
from typing import Dict, List

from .base import ArchConfig, FAMILY_MODEL_COMPONENT  # noqa: F401

from . import (codeqwen15_7b, dbrx_132b, deepseek_v3_671b, gemma2_9b,
               jamba_v01_52b, musicgen_medium, phi4_mini_38b, qwen2_vl_2b,
               rwkv6_16b, starcoder2_3b)

ARCHS: Dict[str, ArchConfig] = {
    m.CONFIG.arch_id: m.CONFIG
    for m in (deepseek_v3_671b, dbrx_132b, gemma2_9b, codeqwen15_7b,
              phi4_mini_38b, starcoder2_3b, musicgen_medium, rwkv6_16b,
              jamba_v01_52b, qwen2_vl_2b)
}

ARCH_IDS: List[str] = list(ARCHS.keys())


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return ARCHS[arch_id]
