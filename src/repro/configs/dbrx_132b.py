"""dbrx-132b [moe] — 16 experts top-4, fine-grained MoE.

40L d_model=6144 48H (GQA kv=8) d_ff=10752/expert vocab=100352
[hf:databricks/dbrx-base; unverified].
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="dbrx-132b",
    family="moe-lm",
    num_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    head_dim=128,
    d_ff=10752,
    vocab=100352,
    attention="gqa",
    ffn="swiglu",
    norm="ln",
    num_experts=16,
    top_k=4,
    moe_ff=10752,
    rope_theta=500000.0,
    dtype="bfloat16",
    notes="Every layer MoE (no dense prefix); softmax router.",
)
