"""starcoder2-3b [dense] — GQA kv=2, RoPE, LayerNorm, pointwise-GELU FFN.

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152 [arXiv:2402.19173; hf].
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="starcoder2-3b",
    family="dense-lm",
    num_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv=2,
    head_dim=128,
    d_ff=12288,
    vocab=49152,
    attention="gqa",
    qkv_bias=True,
    ffn="gelu",
    norm="ln",
    tie_embeddings=True,
    rope_theta=999999.4420358813,
    dtype="bfloat16",
)
