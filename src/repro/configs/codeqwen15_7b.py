"""codeqwen1.5-7b [dense] — qwen1.5 arch (MHA, qkv-bias, SwiGLU).

32L d_model=4096 32H (GQA kv=32 = MHA) d_ff=13440 vocab=92416
[hf:Qwen/CodeQwen1.5-7B; hf].
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="codeqwen1.5-7b",
    family="dense-lm",
    num_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=32,
    head_dim=128,
    d_ff=13440,
    vocab=92416,
    attention="gqa",
    qkv_bias=True,
    ffn="swiglu",
    norm="rms",
    rope_theta=1000000.0,
    dtype="bfloat16",
)
