"""phi4-mini-3.8b [dense] — RoPE (partial 0.75), SwiGLU, GQA, tied embeddings.

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064 [arXiv:2412.08905; hf].
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="phi4-mini-3.8b",
    family="dense-lm",
    num_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv=8,
    head_dim=128,
    d_ff=8192,
    vocab=200064,
    attention="gqa",
    partial_rotary=0.75,
    ffn="swiglu",
    norm="rms",
    tie_embeddings=True,
    rope_theta=10000.0,
    dtype="bfloat16",
)
