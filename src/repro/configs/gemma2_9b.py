"""gemma2-9b [dense] — local+global alternating attention, logit softcaps.

42L d_model=3584 16H (GQA kv=8) head_dim=256 d_ff=14336 vocab=256000
[arXiv:2408.00118; hf].
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="gemma2-9b",
    family="dense-lm",
    num_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    attention="gqa",
    sliding_window=4096,
    alt_local_global=True,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,
    ffn="geglu",
    norm="rms",
    tie_embeddings=True,
    rope_theta=10000.0,
    dtype="bfloat16",
    notes="(1+w) RMSNorm, sqrt(d) embedding scale, query_pre_attn_scalar=d/h.",
)
