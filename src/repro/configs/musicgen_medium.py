"""musicgen-medium [audio] — decoder-only over EnCodec tokens (backbone only).

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048 [arXiv:2306.05284; hf].
The EnCodec frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings; training consumes (embeds, labels), decode uses the codebook
embedding table.  Sinusoidal absolute positions, LayerNorm, GELU FFN.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="musicgen-medium",
    family="audio-lm",
    num_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv=24,
    head_dim=64,
    d_ff=6144,
    vocab=2048,
    attention="gqa",
    use_rope=False,
    ffn="gelu",
    norm="ln",
    codebooks=4,
    frontend="audio-frames",
    dtype="bfloat16",
    notes="Backbone only; 4-codebook delay pattern handled by the frontend stub.",
)
