"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8 experts, MTP.

61L d_model=7168 128H d_ff(dense)=18432 moe_ff=2048 vocab=129280
[arXiv:2412.19437; hf].  The assignment's ``d_ff=2048`` is the routed-expert
hidden dim; the first 3 layers are dense with d_ff=18432 per the paper.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="deepseek-v3-671b",
    family="moe-lm",
    num_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv=128,
    head_dim=128,
    d_ff=18432,
    vocab=129280,
    attention="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    ffn="swiglu",
    norm="rms",
    num_experts=256,
    top_k=8,
    shared_experts=1,
    moe_ff=2048,
    first_dense_layers=3,
    router_scale=True,
    mtp=True,
    rope_theta=10000.0,
    dtype="bfloat16",
    notes="MLA compressed KV cache; sigmoid router with selection bias; MTP aux head.",
)
