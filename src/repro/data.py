"""Deterministic synthetic data pipeline.

Stateless, counter-based generation: batch(step) is a pure function of
(seed, step, shape), so any host can regenerate any shard — restart after a
failure needs no data-loader state, and per-host sharding is just an index
slice.  This is the data substrate every train example/benchmark consumes;
the document distribution is Zipf-ish over the vocab with injected
structure (copy runs) so the loss actually goes down.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: float = 0.5       # fraction of positions forced into copy runs
    pack_docs: bool = True       # multiple documents per row + positions reset
    mean_doc_len: int = 512


class SyntheticPipeline:
    """``batch(step, host, num_hosts)`` -> per-host batch dict."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    # -- token generation ------------------------------------------------
    def _tokens(self, step: int, rows: int, row0: int) -> np.ndarray:
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, row0]))
        # Zipf-flavoured marginal over the vocab (bounded, vectorized)
        z = rng.zipf(1.3, size=(rows, c.seq_len)).astype(np.int64)
        toks = (z - 1) % c.vocab
        if c.structure > 0:
            # copy structure: tokens repeat with lag 8 on a random mask —
            # learnable signal for the end-to-end examples
            mask = rng.random((rows, c.seq_len)) < c.structure
            lag = 8
            toks[:, lag:] = np.where(mask[:, lag:], toks[:, :-lag],
                                     toks[:, lag:])
        return toks.astype(np.int32)

    def _positions(self, tokens: np.ndarray, step: int) -> np.ndarray:
        c = self.cfg
        if not c.pack_docs:
            return np.tile(np.arange(c.seq_len, dtype=np.int32),
                           (tokens.shape[0], 1))
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed + 1, step]))
        pos = np.zeros_like(tokens)
        for r in range(tokens.shape[0]):
            # document boundaries ~ geometric(1/mean_doc_len)
            p, start = 0, 0
            while start < c.seq_len:
                ln = int(rng.geometric(1.0 / c.mean_doc_len))
                ln = min(max(ln, 16), c.seq_len - start)
                pos[r, start:start + ln] = np.arange(ln)
                start += ln
        return pos.astype(np.int32)

    # -- batch assembly -----------------------------------------------------
    def batch(self, step: int, host: int = 0, num_hosts: int = 1,
              family: str = "dense-lm", d_model: int = 0,
              mrope: bool = False) -> Dict[str, np.ndarray]:
        c = self.cfg
        assert c.global_batch % num_hosts == 0, (c.global_batch, num_hosts)
        rows = c.global_batch // num_hosts
        row0 = host * rows
        toks = self._tokens(step, rows, row0)
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = 0
        pos = self._positions(toks, step)
        batch: Dict[str, np.ndarray] = {
            "tokens": toks, "labels": labels, "positions": pos,
            "mask": np.ones_like(toks, np.float32),
        }
        batch["mask"][:, -1] = 0.0
        if mrope:
            batch["positions"] = np.stack([pos, pos, pos])   # (3, b, s)
        if family == "audio-lm":
            rng = np.random.default_rng(
                np.random.SeedSequence([c.seed + 2, step, row0]))
            batch["embeds"] = rng.standard_normal(
                (rows, c.seq_len, d_model)).astype(np.float32) * 0.02
        if family == "vlm-lm":
            rng = np.random.default_rng(
                np.random.SeedSequence([c.seed + 3, step, row0]))
            n_patch = min(64, c.seq_len // 4)
            batch["vis_embeds"] = rng.standard_normal(
                (rows, n_patch, d_model)).astype(np.float32) * 0.02
        return batch

    def iterate(self, start_step: int = 0, host: int = 0,
                num_hosts: int = 1, **kw) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step, host, num_hosts, **kw)
            step += 1


def batch_for_arch(cfg, seq_len: int, global_batch: int, step: int = 0,
                   seed: int = 0, host: int = 0, num_hosts: int = 1):
    """One-call helper: arch-correct batch (frontend stubs included)."""
    pipe = SyntheticPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=seq_len, global_batch=global_batch,
        seed=seed))
    return pipe.batch(step, host, num_hosts, family=cfg.family,
                      d_model=cfg.d_model, mrope=bool(cfg.mrope_sections))
