"""Discrete-event transport: virtual-time link simulation + WAN faults.

The threaded fetch path models link bandwidth by *sleeping* each stripe
for ``bytes / bps`` — honest wall-clock, but a 200-node fleet deploy at
WAN bandwidths would sleep for hours.  This module replaces the sleeps
with an explicit discrete-event scheduler:

  * ``SimClock``    — a global virtual timeline.  Transfers *reserve* an
    interval on their link and advance the clock to the transfer's
    completion event; scheduled events (fault activations) fire exactly
    when the clock passes their timestamp.  No real time passes.
  * ``SimNetwork``  — binds a ``FleetTopology`` to a clock and a
    ``FaultPlan``: per-link FIFO serialization (a link is busy until its
    previous transfer's completion event), per-node transports for the
    fetch/peering layer, and node-loss hooks (e.g. ``PeerIndex.drop_node``).
  * ``FaultPlan``   — deterministic, seeded WAN fault schedules: node
    loss, link flap, and network partition, each a ``[t_start, t_end)``
    window in virtual time.  Faults gate transfer *admission*: a transfer
    overlapping an outage window raises ``LinkDownError`` (transient —
    the peering layer retries with virtual backoff) or ``NodeDownError``
    (the source or the puller died — retract-and-fallback, or build
    failure when the puller itself is gone).

Byte accounting is untouched by construction: the simulated transport
replaces only the *sleeps* of the threaded path — every
``service.fetch_chunks`` charge, singleflight claim and commit runs
through the exact same code — which is what the accounting-identity
tests in ``tests/test_simnet.py`` pin.

Determinism: same topology + same seed ⇒ identical ``FaultPlan``.  Byte
totals per node are deterministic regardless of concurrency (per-node
singleflight); virtual timestamps and the peer-vs-upstream split are
additionally deterministic when deploys are sequential
(``max_workers=1``) and fully so with ``fetch_workers=1`` — concurrent
transfers serialize their virtual intervals in arrival order.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import random
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

# Sentinel peer name for a node's upstream-registry link in fault specs
# and link keys ("flap the edge's WAN uplink" = link_flap(node, UPSTREAM)).
UPSTREAM = "@upstream"

FAULT_KINDS = ("node-loss", "link-flap", "partition")


class FaultError(RuntimeError):
    """Base class of injected-fault transfer failures."""


class LinkDownError(FaultError):
    """A link outage window overlaps the transfer — transient: the link
    heals at ``until``; the peering layer retries with (virtual) backoff
    or falls back to another source."""

    def __init__(self, a: str, b: str, until: float):
        self.a, self.b, self.until = a, b, until
        healed = "never heals" if math.isinf(until) \
            else f"heals at t={until:.3f}s"
        super().__init__(f"link {a}<->{b} is down ({healed})")


class NodeDownError(FaultError):
    """A node died before the transfer could complete — permanent for
    that node: a source is retracted and re-routed around, the puller's
    own build fails."""

    def __init__(self, node_id: str):
        self.node_id = node_id
        super().__init__(f"node {node_id!r} is down")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One fault window on the virtual timeline.

    ``node-loss``: every node in ``nodes`` is dead on [t_start, t_end)
    (default: forever).  ``link-flap``: every link in ``links`` is down
    for the window (``UPSTREAM`` as an endpoint flaps a WAN uplink).
    ``partition``: every peer link with exactly one endpoint in ``nodes``
    is down for the window — the group is isolated from the rest of the
    fleet, but upstream registry links still work (the fallback path the
    convergence tests pin).
    """
    kind: str
    t_start: float
    t_end: float = math.inf
    nodes: Tuple[str, ...] = ()
    links: Tuple[Tuple[str, str], ...] = ()

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {FAULT_KINDS})")
        if self.t_end <= self.t_start:
            raise ValueError("fault window must have t_end > t_start")

    def overlaps(self, t0: float, t1: float) -> bool:
        return self.t_start < t1 and self.t_end > t0

    def cuts_link(self, a: str, b: str) -> bool:
        """Is the (a, b) link down while this fault is active?"""
        if self.kind == "link-flap":
            return any({a, b} == {la, lb} for la, lb in self.links)
        if self.kind == "partition":
            # partitions cut peer links crossing the group boundary only;
            # upstream registry links are unaffected
            return b != UPSTREAM and a != UPSTREAM and \
                (a in self.nodes) != (b in self.nodes)
        return False


class FaultPlan:
    """A deterministic schedule of WAN faults in virtual time.

    Build one by hand (``node_loss`` / ``link_flap`` / ``partition``, each
    returns the added ``Fault``) or seeded via ``FaultPlan.random`` —
    same topology + same seed gives the identical plan.  Queried at
    transfer admission (``check_transfer``) and compiled into clock
    events by ``SimNetwork`` (node-loss fires ``drop_node`` hooks the
    moment virtual time passes it).
    """

    def __init__(self, faults: Sequence[Fault] = ()):
        self.faults: List[Fault] = list(faults)

    # -- construction ---------------------------------------------------
    def node_loss(self, node_id: str, at: float,
                  until: float = math.inf) -> Fault:
        f = Fault("node-loss", at, until, nodes=(node_id,))
        self.faults.append(f)
        return f

    def link_flap(self, a: str, b: str, at: float, until: float) -> Fault:
        f = Fault("link-flap", at, until, links=((a, b),))
        self.faults.append(f)
        return f

    def partition(self, nodes: Sequence[str], at: float,
                  until: float) -> Fault:
        f = Fault("partition", at, until, nodes=tuple(sorted(nodes)))
        self.faults.append(f)
        return f

    @classmethod
    def random(cls, topology: Any, seed: int, n_faults: int = 4,
               horizon_s: float = 30.0,
               kinds: Sequence[str] = FAULT_KINDS,
               protect: Sequence[str] = ()) -> "FaultPlan":
        """A seeded random plan over ``topology``'s nodes and peer links.

        ``protect`` names nodes never killed or isolated (conventionally
        the seed node).  Transient windows span 5–30% of the horizon;
        node losses are permanent.  Deterministic: the node/link pools
        are sorted before sampling.
        """
        rng = random.Random(seed)
        nodes = sorted(topology.node_ids())
        candidates = [n for n in nodes if n not in set(protect)]
        links = sorted({tuple(sorted((a, b))) for a in nodes
                        for b in topology.peers_of(a)})
        plan = cls()
        for _ in range(n_faults):
            kind = rng.choice(list(kinds))
            t0 = rng.uniform(0.0, horizon_s)
            dur = rng.uniform(0.05, 0.30) * horizon_s
            if kind == "node-loss" and candidates:
                plan.node_loss(rng.choice(candidates), at=t0)
            elif kind == "link-flap" and links:
                a, b = links[rng.randrange(len(links))]
                plan.link_flap(a, b, at=t0, until=t0 + dur)
            elif kind == "partition" and candidates:
                plan.partition([rng.choice(candidates)], at=t0,
                               until=t0 + dur)
        return plan

    # -- queries --------------------------------------------------------
    def node_alive(self, node_id: str, t: float) -> bool:
        return self.node_death_in(node_id, t, t + 1e-12) is None

    def node_death_in(self, node_id: str, t0: float,
                      t1: float) -> Optional[Fault]:
        """The first node-loss window of ``node_id`` overlapping
        [t0, t1), if any."""
        for f in self.faults:
            if f.kind == "node-loss" and node_id in f.nodes \
                    and f.overlaps(t0, t1):
                return f
        return None

    def link_outage_in(self, a: str, b: str, t0: float,
                       t1: float) -> Optional[Fault]:
        """The longest-lasting outage of the (a, b) link overlapping
        [t0, t1), if any (longest so the retry backoff hint is honest)."""
        hit: Optional[Fault] = None
        for f in self.faults:
            if f.cuts_link(a, b) and f.overlaps(t0, t1):
                if hit is None or f.t_end > hit.t_end:
                    hit = f
        return hit

    def check_transfer(self, dst: str, src: str, t0: float,
                       t1: float) -> None:
        """Admission gate for a transfer to ``dst`` from ``src``
        (``UPSTREAM`` for the registry) occupying [t0, t1) of virtual
        time.  Raises ``NodeDownError`` / ``LinkDownError`` if a fault
        interdicts it; a fault striking anywhere in the window kills the
        whole transfer (mid-stripe failure semantics)."""
        if self.node_death_in(dst, t0, t1) is not None:
            raise NodeDownError(dst)
        if src != UPSTREAM and self.node_death_in(src, t0, t1) is not None:
            raise NodeDownError(src)
        outage = self.link_outage_in(dst, src, t0, t1)
        if outage is not None:
            raise LinkDownError(dst, src, until=outage.t_end)

    def __len__(self) -> int:
        return len(self.faults)


class SimClock:
    """Global virtual timeline with scheduled events and per-key link
    reservations.

    ``reserve(key, duration, admission)`` is the discrete-event kernel:
    the transfer starts at ``max(now, busy_until[key])`` (per-link FIFO),
    its completion event is ``start + duration``; admission (fault
    checks) runs against that exact window *before* the link is reserved
    or time advances, so a rejected transfer occupies nothing.  On
    success the clock advances to the completion event and fires every
    scheduled event it passed, in timestamp order (sequence-number
    tie-break — deterministic).
    """

    def __init__(self) -> None:
        self._now = 0.0
        self._busy: Dict[Any, float] = {}
        self._events: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self._lock = threading.Lock()

    @property
    def now(self) -> float:
        with self._lock:
            return self._now

    def schedule(self, t: float, fn: Callable[[], None]) -> None:
        """Fire ``fn()`` when virtual time first passes ``t``."""
        with self._lock:
            heapq.heappush(self._events, (max(0.0, t), next(self._seq), fn))

    def _due_locked(self, t: float) -> List[Callable[[], None]]:
        due = []
        while self._events and self._events[0][0] <= t:
            due.append(heapq.heappop(self._events)[2])
        return due

    def _fire(self, due: Sequence[Callable[[], None]]) -> None:
        for fn in due:
            fn()                      # outside the lock: hooks take their own

    def advance_to(self, t: float) -> float:
        with self._lock:
            self._now = max(self._now, t)
            due = self._due_locked(self._now)
        self._fire(due)
        return self.now

    def sleep(self, duration: float) -> None:
        """Advance virtual time by ``duration`` (a virtual backoff)."""
        with self._lock:
            self._now += max(0.0, duration)
            due = self._due_locked(self._now)
        self._fire(due)

    def reserve(self, key: Any, duration: float,
                admission: Optional[Callable[[float, float], None]] = None
                ) -> Tuple[float, float]:
        """Reserve [start, start+duration) on link ``key``; see class doc.
        Returns the (start, end) window the transfer occupied."""
        with self._lock:
            start = max(self._now, self._busy.get(key, 0.0))
            end = start + duration
            if admission is not None:
                admission(start, end)     # may raise; nothing reserved yet
            self._busy[key] = end
            self._now = max(self._now, end)
            due = self._due_locked(self._now)
        self._fire(due)
        return start, end


class SimTransport:
    """One node's view of a ``SimNetwork`` — the object the fetch engine
    and ``NodePeering`` talk to.  All three methods are virtual-time:
    no real sleeping ever happens."""

    def __init__(self, net: "SimNetwork", node_id: str):
        self.net = net
        self.node_id = node_id

    def upstream_transfer(self, nbytes: int,
                          bps: Optional[float] = None) -> float:
        return self.net.transfer(self.node_id, UPSTREAM, nbytes, bps=bps)

    def peer_transfer(self, src: str, nbytes: int,
                      bps: Optional[float] = None) -> float:
        return self.net.transfer(self.node_id, src, nbytes, bps=bps)

    def backoff(self, seconds: float) -> None:
        self.net.clock.sleep(seconds)


class WallClockTransport:
    """The legacy real-sleep transport behind the same interface: each
    transfer sleeps ``bytes / bps`` of *wall* clock.  Never raises fault
    errors — faults are a simulated-transport feature."""

    def __init__(self, default_bps: Optional[float] = None):
        self.default_bps = default_bps

    def upstream_transfer(self, nbytes: int,
                          bps: Optional[float] = None) -> float:
        bps = bps if bps is not None else self.default_bps
        dt = nbytes / bps if bps else 0.0
        if dt:
            time.sleep(dt)
        return dt

    peer_transfer_bps = None

    def peer_transfer(self, src: str, nbytes: int,
                      bps: Optional[float] = None) -> float:
        del src
        return self.upstream_transfer(nbytes, bps=bps)

    def backoff(self, seconds: float) -> None:
        time.sleep(seconds)


class SimNetwork:
    """A topology's links on a shared virtual clock, with fault events.

    One instance per fleet: every node's transport shares the clock (so
    peer and upstream transfers interleave on one timeline) and the
    fault plan.  Node-loss faults are compiled into clock events at
    construction — when virtual time passes a death, the registered
    ``on_node_loss`` hooks fire (the fleet deployer retracts the node
    from the ``PeerIndex``); link flaps and partitions act purely at
    transfer admission.  ``inject_*`` adds faults after construction
    (e.g. "kill the seed at now + ε" mid-test).
    """

    def __init__(self, topology: Any,
                 faults: Optional[FaultPlan] = None,
                 latency_s: float = 0.0):
        self.topology = topology
        self.plan = faults if faults is not None else FaultPlan()
        self.latency_s = latency_s
        self.clock = SimClock()
        self.faults_fired = 0
        self.n_transfers = 0
        self.bytes_moved = 0
        self._node_loss_hooks: List[Callable[[str], None]] = []
        self._lock = threading.Lock()
        for f in self.plan.faults:
            self._schedule_fault(f)

    @property
    def now(self) -> float:
        """Current virtual time — the fleet's time base for anything that
        measures across transfers (planner ticks, migration downtime)."""
        return self.clock.now

    # -- fault events ---------------------------------------------------
    def on_node_loss(self, hook: Callable[[str], None]) -> None:
        """Register a hook fired (with the node id) when virtual time
        passes a node-loss fault."""
        self._node_loss_hooks.append(hook)

    def _schedule_fault(self, f: Fault) -> None:
        def fire() -> None:
            with self._lock:
                self.faults_fired += 1
            if f.kind == "node-loss":
                for node in f.nodes:
                    for hook in self._node_loss_hooks:
                        hook(node)
        self.clock.schedule(f.t_start, fire)

    def inject(self, f: Fault) -> Fault:
        """Add a fault to the plan after construction and schedule its
        activation event."""
        self.plan.faults.append(f)
        self._schedule_fault(f)
        return f

    def inject_node_loss(self, node_id: str, at: float,
                         until: float = math.inf) -> Fault:
        return self.inject(Fault("node-loss", at, until, nodes=(node_id,)))

    def inject_link_flap(self, a: str, b: str, at: float,
                         until: float) -> Fault:
        return self.inject(Fault("link-flap", at, until, links=((a, b),)))

    def inject_partition(self, nodes: Sequence[str], at: float,
                         until: float) -> Fault:
        return self.inject(Fault("partition", at, until,
                                 nodes=tuple(sorted(nodes))))

    # -- transfers ------------------------------------------------------
    def transport_for(self, node_id: str) -> SimTransport:
        if node_id not in self.topology.node_ids():
            raise KeyError(f"unknown node {node_id!r}")
        return SimTransport(self, node_id)

    def transfer(self, dst: str, src: str, nbytes: int,
                 bps: Optional[float] = None) -> float:
        """Run one transfer to ``dst`` from ``src`` (``UPSTREAM`` = the
        registry) in virtual time; returns the virtual duration.  Raises
        ``NodeDownError``/``LinkDownError`` when the fault plan
        interdicts the occupied window."""
        if bps is None:
            if src == UPSTREAM:
                bps = self.topology.node(dst).upstream_bps
            else:
                bps = self.topology.bandwidth(dst, src)
        if not bps:
            raise ValueError(f"no link between {dst!r} and {src!r}")
        key = (dst, UPSTREAM) if src == UPSTREAM \
            else tuple(sorted((dst, src)))
        duration = self.latency_s + nbytes / bps
        start, end = self.clock.reserve(
            key, duration,
            admission=lambda t0, t1: self.plan.check_transfer(
                dst, src, t0, t1))
        with self._lock:
            self.n_transfers += 1
            self.bytes_moved += nbytes
        return end - start
