"""Snapshot/restore of ASSEMBLED+COMPILED lifecycle state (scale-to-zero).

A serverless deployment that scales an instance to zero should not pay the
full cold build to come back: the node's content-addressed store still
holds the chunks, the lockfile still pins the exact components, and the
fleet compile cache still indexes the compiled executable.  A snapshot
captures exactly the control-plane state needed to reconstruct a READY
instance without re-resolving (the lock replays its pins), without
re-fetching (present chunks are hits; only evicted chunks move), and
without re-compiling (the compile stage restores the content-addressed
artifact via :mod:`repro.core.compilecache`).

The snapshot is a small JSON document — CIR bytes, lockfile, spec, compile
key — NOT a memory image: restore drives the ordinary locked-rebuild
pipeline, so every lifecycle gate, lease and accounting rule holds.
"""
from __future__ import annotations

import base64
import dataclasses
import hashlib
import json
from typing import Any, Optional, Tuple

from .cir import CIR
from .lazybuild import _STEP_ENTRIES, ContainerInstance, Lockfile
from .spec import SpecSheet

# Stages that may be snapshotted: the instance must have proven the
# ASSEMBLED+COMPILED state it claims to be restorable to.
SNAPSHOT_MIN_STAGE = "compiled"


@dataclasses.dataclass(frozen=True)
class InstanceSnapshot:
    """Restorable record of one ASSEMBLED+COMPILED (or later) instance."""
    cir_b64: str                       # gzip CIR wire bytes, base64
    lock_json: str                     # exact component pins to replay
    spec_json: str                     # the platform the lock is valid for
    stage: str                         # lifecycle stage at snapshot time
    entry_names: Tuple[str, ...]       # staged step entrypoints
    compile_key: Optional[str] = None  # fleet compile-cache key (if cached)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "InstanceSnapshot":
        d = json.loads(s)
        d["entry_names"] = tuple(d["entry_names"])
        return InstanceSnapshot(**d)

    def digest(self) -> str:
        return hashlib.sha256(self.to_json().encode()).hexdigest()

    @property
    def platform_id(self) -> str:
        """The platform this snapshot's lock is valid for — migration and
        restore tooling route on it without re-parsing the whole spec."""
        return json.loads(self.spec_json)["platform_id"]


def snapshot_instance(inst: ContainerInstance) -> InstanceSnapshot:
    """Capture a restorable snapshot of ``inst``.

    Requires the instance to have reached the COMPILED stage (the
    lifecycle state the snapshot claims to restore); a failed or
    still-fetching build has nothing consistent to capture.
    """
    life = inst.lifecycle
    if life.error is not None:
        raise ValueError(
            f"cannot snapshot a failed build (failed at "
            f"{life.failed_stage!r}: {life.error})")
    if not life.reached(SNAPSHOT_MIN_STAGE):
        raise ValueError(
            f"instance at stage {life.stage!r} — snapshot requires at "
            f"least {SNAPSHOT_MIN_STAGE!r}")
    return InstanceSnapshot(
        cir_b64=base64.b64encode(inst.cir.to_bytes()).decode("ascii"),
        lock_json=inst.lock.to_json(),
        spec_json=inst.spec.to_json(),
        stage=life.stage,
        entry_names=tuple(sorted(
            n for n in _STEP_ENTRIES if callable(inst.entry.get(n)))),
        compile_key=inst.compile_key,
    )


def restore_instance(snap: InstanceSnapshot, builder: Any,
                     mesh: Any = None,
                     overlap: bool = True,
                     block: bool = True) -> ContainerInstance:
    """Rebuild a scaled-to-zero instance from its snapshot.

    Drives the locked-rebuild pipeline: resolution is a pin replay (no
    version selection), the fetch is a pure chunk-delta against whatever
    the node's store still holds (typically all hits), and the compile
    stage restores the executable through the fleet compile cache — the
    snapshot's ``compile_key`` must match the key the rebuild derives, or
    the snapshot is stale for this builder's catalog and restore refuses
    rather than silently recompiling the wrong program.
    """
    cir = CIR.from_bytes(base64.b64decode(snap.cir_b64))
    lock = Lockfile.from_json(snap.lock_json)
    spec = SpecSheet.from_json(snap.spec_json)
    if snap.compile_key is not None:
        from .compilecache import compile_cache_key
        derived = compile_cache_key(lock, spec, snap.entry_names)
        if derived != snap.compile_key:
            raise ValueError(
                "snapshot compile key does not match this lock/spec — "
                "stale snapshot, re-deploy instead of restoring")
    inst = builder.build_from_lock(
        cir, lock, spec, mesh=mesh, assemble=True,
        compile_steps=bool(snap.entry_names), overlap=overlap, block=block)
    return inst
