"""Algorithm 1 — Uniform Component Selection, with the deployability metric.

    Input:  dependency item d = (M, n, specifier), building context (from the
            specSheet + resolution so far), local store (cache visibility).
    Output: uniform component c.

Version selection VS picks the best version matching the specifier; the
environment selection ES ranks environment variants by *deployability*:
"local caching, component size, download time, and execution performance"
(paper §3.2).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from .component import (DependencyItem, Specifier, UniformComponent, Version)
from .registry import UniformComponentService


class SelectionError(Exception):
    def __init__(self, d: DependencyItem, msg: str):
        super().__init__(f"no component satisfies {d}: {msg}")
        self.dep = d


# ---------------------------------------------------------------------------
# Deployability evaluator
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Deployability:
    score: float
    hard_ok: bool
    parts: Dict[str, float]


class DeployabilityEvaluator:
    """Scores a candidate environment-variant against a specSheet context.

    Hard gate: every ``Requirement`` must hold.  Soft score combines:
      + cache locality  (component already in the local store)
      + download time   (size / link bandwidth; smaller is better)
      + execution perf  (per-variant relative score, e.g. pallas > lax > naive
                         when on TPU; reversed weighting when interpreting)
      + specificity     (variants that *state* more satisfied requirements
                         outrank catch-all 'generic' variants)
    """

    def __init__(self, ctx: Mapping[str, Any],
                 cached_digests: Optional[set] = None,
                 link_bandwidth: float = 500e6 / 8):  # 500 Mbps default
        self.ctx = ctx
        self.cached = cached_digests or set()
        self.link_bandwidth = max(link_bandwidth, 1.0)

    def evaluate(self, c: UniformComponent) -> Deployability:
        if not c.env_satisfied(self.ctx):
            return Deployability(float("-inf"), False, {"hard": 0.0})
        parts: Dict[str, float] = {}
        # download time in seconds (1 GiB @500Mbps ≈ 17 s); a locally cached
        # component costs nothing — the cache bonus is exactly the download
        # it avoids (+ a small deterministic tie-break), so cache locality
        # dominates for GB-scale components and is negligible for KB ones.
        dl = min(c.size_bytes / self.link_bandwidth, 3600.0) / 10.0
        if c.digest() in self.cached:
            parts["cache"] = 0.05          # deterministic tie-break
            parts["download"] = 0.0        # nothing to pull
        else:
            parts["cache"] = 0.0
            parts["download"] = -dl
        # execution performance rank (catalog-assigned, per family)
        parts["perf"] = 3.0 * float(c.perf_score)
        # specificity: prefer variants that positively matched requirements
        parts["specificity"] = 0.25 * len(c.requires)
        return Deployability(sum(parts.values()), True, parts)


# ---------------------------------------------------------------------------
# VS / ES
# ---------------------------------------------------------------------------

def version_select(versions: Sequence[str], specifier: str) -> Optional[str]:
    """VS: highest version matching the specifier (or highest overall for
    'latest'/'any')."""
    spec = Specifier(specifier)
    ok = [v for v in versions if spec.matches(Version.parse(v))]
    if not ok:
        return None
    return max(ok, key=Version.parse)


def env_select(cands: Sequence[UniformComponent],
               evaluator: DeployabilityEvaluator
               ) -> Tuple[Optional[UniformComponent], Dict[str, float]]:
    """ES: highest-deployability variant; deterministic tie-break on env id."""
    best: Optional[UniformComponent] = None
    best_d: Optional[Deployability] = None
    scores: Dict[str, float] = {}
    for c in sorted(cands, key=lambda c: c.env):
        d = evaluator.evaluate(c)
        scores[c.env] = d.score
        if not d.hard_ok:
            continue
        if best_d is None or d.score > best_d.score:
            best, best_d = c, d
    return best, scores


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------

def uniform_component_selection(
        d: DependencyItem,
        service: UniformComponentService,
        evaluator: DeployabilityEvaluator,
        extra_constraint: Optional[str] = None,
) -> UniformComponent:
    """The paper's Algorithm 1, literally:

        V <- VQ(M, n)
        repeat:
            v <- VS(V, specifier);  error if empty
            E <- EQ(M, n, v)
            e <- ES(E, specSheet)
            if e empty: V <- V \\ {v}
        until e non-empty
        c <- CQ(M, n, v, e)
    """
    spec_text = d.specifier
    if extra_constraint:
        spec_text = Specifier(spec_text).intersect_text(Specifier(extra_constraint))
    versions = list(service.vq(d.manager, d.name))
    if not versions:
        raise SelectionError(d, "unknown component (no versions upstream)")
    remaining = list(versions)
    while True:
        v = version_select(remaining, spec_text)
        if v is None:
            raise SelectionError(
                d, f"no version in {versions} matches {spec_text!r} "
                   f"with a deployable environment variant")
        cands = service.candidates(d.manager, d.name, v)
        c, _scores = env_select(cands, evaluator)
        if c is None:
            # current v has no suitable environment variant: V <- V \ v
            remaining.remove(v)
            continue
        return service.cq(d.manager, d.name, v, c.env)
