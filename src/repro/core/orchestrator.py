"""Event-driven build orchestration: overlap fetch / assemble / compile.

The staged pipeline (resolve → fetch → assemble → compile) used to be four
strict barriers: assembly waited for the *entire* fetch — including the
multi-GB weight-asset tail — even though the fetch engine lands model /
runtime / kernel components first precisely so assembly could start early.
This module turns the stage boundaries into **per-component readiness
events**:

  * ``BuildGraph`` declares which component managers gate which downstream
    stages: model/runtime/kernel/parallel (and data, whose payloads the
    assembler calls) gate *assemble*; env gates *compile*; weight assets
    gate only *first-weight-use* — never deployment readiness.
  * ``ComponentReadiness`` tracks which components of one build have proven
    their content present (owned chunks committed, awaited chunks landed,
    orphans reclaimed) and fires each stage's gate the moment the last
    gating component is ready.  A sibling claimer dying past the
    singleflight wait backstop degrades gracefully: the component is
    still signalled (the build must not deadlock on a crashed peer), with
    ``fetch_wait_timeouts`` counted and its digest marked incomplete for
    the next build to re-verify.
  * ``Lifecycle`` is the container's explicit state machine
    (PLANNED → FETCHING → ASSEMBLED → COMPILED → READY → COMPLETE) behind
    ``ContainerInstance.wait(stage)``: deployment services wait for exactly
    the stage they need instead of blocking on ``build()`` returning.
  * ``BuildOrchestrator`` drives the stages off those gates, so assemble
    and jit-staging run concurrently with the asset tail, and records the
    per-stage wall offsets plus the measured critical path (build start →
    READY) into the ``BuildReport``.

READY means *deployable*: everything but the asset tail is local, the
entrypoints are assembled (and staged for the mesh when compilation was
requested).  COMPLETE means every byte — the weight tail included — has
landed and the fetch accounting is final; ``wait("weights")`` is the
first-weight-use gate and is an alias for COMPLETE.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import (Any, Callable, Dict, FrozenSet, List, Optional, Sequence,
                    Set)

from .component import UniformComponent

# Unique suffix for build pin-lease ids: concurrent builds of the same
# (CIR, platform) must hold distinct leases
_LEASE_SEQ = itertools.count(1)

# Lifecycle stages, in order.  "complete" (== "weights") is the only stage
# gated by the asset tail; "ready" is the deployable point.
STAGES = ("planned", "fetching", "assembled", "compiled", "ready", "complete")
_STAGE_ALIASES = {"weights": "complete", "fetched": "complete"}


class Lifecycle:
    """Explicit container state machine with waitable stage events.

    Monotonic: ``advance(stage)`` marks that stage and every earlier one
    complete.  ``fail(exc)`` releases every waiter; waiting on a stage the
    build never reached re-raises the build's error.
    """

    def __init__(self) -> None:
        self._events = {s: threading.Event() for s in STAGES}
        self._completed: Set[str] = set()
        self._error: Optional[BaseException] = None
        self._failed_stage: Optional[str] = None
        self._lock = threading.Lock()
        self.advance("planned")

    @staticmethod
    def _resolve(stage: str) -> str:
        s = _STAGE_ALIASES.get(stage, stage)
        if s not in STAGES:
            raise KeyError(f"unknown lifecycle stage {stage!r} "
                           f"(one of {STAGES} or {tuple(_STAGE_ALIASES)})")
        return s

    def _stage_locked(self) -> str:
        for s in reversed(STAGES):
            if s in self._completed:
                return s
        return "planned"

    @property
    def stage(self) -> str:
        with self._lock:
            return self._stage_locked()

    @property
    def error(self) -> Optional[BaseException]:
        return self._error

    @property
    def failed_stage(self) -> Optional[str]:
        """The last stage the build had reached when it failed (None for
        a healthy build) — under injected WAN faults this is where the
        fault struck the pipeline, e.g. ``"fetching"`` for a dead node
        mid-stripe, ``"ready"`` for a fault in the asset tail."""
        return self._failed_stage

    def advance(self, stage: str) -> None:
        stage = self._resolve(stage)
        with self._lock:
            for s in STAGES[:STAGES.index(stage) + 1]:
                self._completed.add(s)
                self._events[s].set()

    def fail(self, exc: BaseException) -> None:
        with self._lock:
            if self._error is None:
                self._error = exc
                self._failed_stage = self._stage_locked()
            for ev in self._events.values():
                ev.set()          # wake every waiter; wait() re-raises

    def reset_for_retry(self) -> None:
        """Re-arm the state machine before a rebuild of the same instance.

        A transient fault leaves ``error``/``failed_stage`` set and every
        stage event signalled (``fail`` wakes all waiters).  A retry that
        succeeds must not keep reporting the stale failure, and waiters on
        not-yet-reached stages must block again instead of observing the
        dead build's wakeup.  Stages actually completed stay completed.
        """
        with self._lock:
            self._error = None
            self._failed_stage = None
            for s, ev in self._events.items():
                if s in self._completed:
                    ev.set()
                else:
                    ev.clear()

    def reached(self, stage: str) -> bool:
        with self._lock:
            return self._resolve(stage) in self._completed

    def wait(self, stage: str, timeout: Optional[float] = None) -> str:
        """Block until ``stage`` is reached; returns the current stage.

        Raises the build's error if it failed before reaching ``stage``,
        or ``TimeoutError`` if ``timeout`` elapses first.
        """
        stage = self._resolve(stage)
        fired = self._events[stage].wait(timeout)
        with self._lock:
            done = stage in self._completed
        if not done and self._error is not None:
            raise self._error
        if not fired and not done:
            # done can flip between the event timing out and the re-check —
            # a stage that was reached is never reported as timed out
            raise TimeoutError(
                f"lifecycle stage {stage!r} not reached within {timeout}s")
        return self.stage


class BuildGraph:
    """Which component managers gate which downstream build stages.

    The defaults encode the assembler's real data dependencies: the model
    family + runtime/data payloads (and the kernel/parallel variants they
    pull from the bundle) must be local before assemble; the platform env
    must be proven before step compilation — as must the shared
    ``manager="ir"`` module when the §13 performance-portable split is
    on, since the per-platform tail is lowered *from* it (the compile
    stage fetches or derives the IR before any tail compile starts);
    weight assets gate only first-weight-use (the COMPLETE stage), so a
    deployment is READY while the tail still streams.  Managers named by
    no gate (e.g. ``opt``) gate READY — deployable means everything but
    the declared tail is local.
    """

    def __init__(self,
                 assemble_managers: Sequence[str] = ("model", "runtime",
                                                     "kernel", "parallel",
                                                     "data"),
                 compile_managers: Sequence[str] = ("env", "ir"),
                 tail_managers: Sequence[str] = ("asset",)):
        self.assemble_managers: FrozenSet[str] = frozenset(assemble_managers)
        self.compile_managers: FrozenSet[str] = frozenset(compile_managers)
        self.tail_managers: FrozenSet[str] = frozenset(tail_managers)

    def stage_of(self, manager: str) -> str:
        """The earliest stage a component of ``manager`` gates."""
        if manager in self.assemble_managers:
            return "assemble"
        if manager in self.compile_managers:
            return "compile"
        if manager in self.tail_managers:
            return "complete"
        return "ready"

    def gates_for(self, comps: Sequence[UniformComponent]
                  ) -> Dict[str, Set[str]]:
        """Concrete gate sets for one build: stage -> gating digests.

        ``ready`` includes every non-tail component (assemble/compile gates
        are subsets of it by construction); ``complete`` includes all.
        """
        gates: Dict[str, Set[str]] = {"assemble": set(), "compile": set(),
                                      "ready": set(), "complete": set()}
        for c in comps:
            dg = c.digest()
            stage = self.stage_of(c.manager)
            if stage == "assemble":
                gates["assemble"].add(dg)
            elif stage == "compile":
                gates["compile"].add(dg)
            if stage != "complete":
                gates["ready"].add(dg)
            gates["complete"].add(dg)
        return gates


class ComponentReadiness:
    """Per-build readiness tracker the fetch engine signals into.

    ``mark_ready(c)`` is called the moment a component's content is proven
    present — its owned chunks committed, awaited chunks landed (or
    reclaimed and re-fetched).  Each stage's event fires when its last
    gating component is ready; ``fail`` releases every gate so stage
    drivers observe the fetch error instead of hanging.

    ``listeners`` are per-component callbacks fired on every readiness
    event (after the stage gates update), e.g. a fleet node announcing the
    component's chunks to its peers.  Listeners are advisory: one raising
    is swallowed (and the rest still run) — a failing consumer must not
    fail the build it observes — but never silently: every swallowed raise
    is counted in ``listener_errors``, which the orchestrator surfaces
    through ``BuildReport.listener_errors``.
    """

    def __init__(self, comps: Sequence[UniformComponent],
                 graph: BuildGraph,
                 listeners: Optional[Sequence[
                     Callable[[UniformComponent], None]]] = None):
        self._lock = threading.Lock()
        self._pending = graph.gates_for(comps)
        self._events = {stage: threading.Event() for stage in self._pending}
        self._error: Optional[BaseException] = None
        self._listeners = list(listeners or ())
        self.listener_errors = 0      # advisory-callback raises, swallowed
        for stage, pend in self._pending.items():
            if not pend:
                self._events[stage].set()

    def mark_ready(self, c: UniformComponent) -> None:
        dg = c.digest()
        fire: List[threading.Event] = []
        with self._lock:
            for stage, pend in self._pending.items():
                pend.discard(dg)
                if not pend and not self._events[stage].is_set():
                    fire.append(self._events[stage])
        for ev in fire:
            ev.set()
        for listener in self._listeners:
            try:
                listener(c)
            except Exception:  # noqa: BLE001 — advisory consumers only
                with self._lock:
                    self.listener_errors += 1
                continue

    def fail(self, exc: BaseException) -> None:
        with self._lock:
            if self._error is None:
                self._error = exc
        for ev in self._events.values():
            ev.set()

    @property
    def error(self) -> Optional[BaseException]:
        return self._error

    def ready(self, stage: str) -> bool:
        with self._lock:
            return not self._pending[stage]

    def wait(self, stage: str, timeout: Optional[float] = None) -> None:
        """Block until every component gating ``stage`` is ready."""
        self._events[stage].wait(timeout)
        with self._lock:
            done = not self._pending[stage]
        if not done and self._error is not None:
            raise self._error
        if not done:
            raise TimeoutError(
                f"build stage gate {stage!r} not ready within {timeout}s")


class BuildOrchestrator:
    """Drives one build's stages off per-component readiness events.

    With ``overlap=True`` the fetch runs on a background thread and each
    downstream stage starts the moment its ``BuildGraph`` gate fires —
    assemble and jit-staging run under the asset tail, and READY does not
    wait for first-weight-use content.  With ``overlap=False`` the legacy
    barrier pipeline runs (fetch completes before assemble begins); both
    modes produce byte-identical fetch accounting and identical locks.
    """

    def __init__(self, builder: Any, graph: Optional[BuildGraph] = None):
        self.builder = builder
        self.graph = graph if graph is not None else BuildGraph()

    # ------------------------------------------------------------------
    def start(self, inst: Any, resolution: Any, *,
              mesh: Any = None,
              assemble: bool = True,
              compile_steps: bool = False,
              t0: Optional[float] = None,
              record_build: bool = True,
              overlap: bool = True,
              block: bool = True) -> None:
        """Run (``block=True``) or launch (``block=False``) the pipeline.

        Non-blocking callers get the stages driven on a daemon thread and
        observe progress/errors through ``inst.wait(stage)``.
        """
        t0 = time.perf_counter() if t0 is None else t0
        if block:
            self._drive(inst, resolution, mesh, assemble, compile_steps,
                        t0, record_build, overlap)
        else:
            def runner() -> None:
                try:
                    self._drive(inst, resolution, mesh, assemble,
                                compile_steps, t0, record_build, overlap)
                except BaseException:
                    pass      # delivered to waiters via Lifecycle.fail
            threading.Thread(target=runner, name="cir-build-driver",
                             daemon=True).start()

    # ------------------------------------------------------------------
    def _drive(self, inst: Any, resolution: Any, mesh: Any, assemble: bool,
               compile_steps: bool, t0: float, record_build: bool,
               overlap: bool) -> None:
        report, life = inst.report, inst.lifecycle
        if life.error is not None:
            # rebuilding after a transient fault: the previous attempt's
            # failure must not outlive it
            life.reset_for_retry()
        comps = resolution.components
        readiness = ComponentReadiness(
            comps, self.graph,
            listeners=getattr(self.builder, "readiness_listeners", None))
        report.orchestrated = overlap
        fetch_exc: List[BaseException] = []
        fetch_thread: Optional[threading.Thread] = None

        # pin lease: the build's resolved content is unevictable from plan
        # time until lifecycle COMPLETE (released in the finally below, so
        # error paths release too — a crashed build must not pin forever)
        store = getattr(self.builder, "store", None)
        lease_id = None
        if store is not None and hasattr(store, "acquire_build_lease"):
            lease_id = f"{inst.cir.name}@{inst.spec.platform_id}" \
                       f"#lease{next(_LEASE_SEQ)}"
            store.acquire_build_lease(lease_id, comps)

        def run_fetch() -> None:
            try:
                self.builder.fetch_engine.fetch(comps, report,
                                                readiness=readiness)
            except BaseException as e:  # noqa: BLE001 — relayed to waiters
                fetch_exc.append(e)
                readiness.fail(e)

        # report fields are always written BEFORE the stage event fires, so
        # a waiter woken by wait(stage) never reads stale zeros
        try:
            report.stage_s["fetching"] = time.perf_counter() - t0
            life.advance("fetching")
            if overlap:
                fetch_thread = threading.Thread(target=run_fetch,
                                                name="cir-fetch",
                                                daemon=True)
                fetch_thread.start()
            else:
                run_fetch()                    # barrier: fetch fully lands
                if fetch_exc:
                    raise fetch_exc[0]

            readiness.wait("assemble")
            model, entry = self.builder._stage_assemble(
                inst.cir, inst.spec, inst.bundle, mesh, report, assemble)
            inst.model, inst.entry = model, entry
            report.stage_s["assembled"] = time.perf_counter() - t0
            life.advance("assembled")

            if compile_steps and entry:
                readiness.wait("compile")
                inst.entry = self.builder._stage_compile(entry, report,
                                                         inst=inst)
            report.stage_s["compiled"] = time.perf_counter() - t0
            life.advance("compiled")

            readiness.wait("ready")
            report.critical_path_s = time.perf_counter() - t0
            report.stage_s["ready"] = report.critical_path_s
            life.advance("ready")

            if fetch_thread is not None:
                fetch_thread.join()            # asset tail / accounting
                if fetch_exc:
                    raise fetch_exc[0]
            if record_build:
                self.builder.store.record_build(
                    f"{inst.cir.name}@{inst.spec.platform_id}", comps)
            report.stage_s["complete"] = time.perf_counter() - t0
            barrier_sum = report.resolve_s + report.fetch_s \
                + report.assemble_s + report.compile_s
            report.overlap_s = max(0.0,
                                   barrier_sum - report.critical_path_s)
            report.listener_errors = readiness.listener_errors
            life.advance("complete")
        except BaseException as e:
            if fetch_thread is not None and fetch_thread.is_alive():
                fetch_thread.join()            # settle claims + accounting
            report.listener_errors = readiness.listener_errors
            life.fail(e)
            raise
        finally:
            # release after the fetch has settled on both paths (the tail
            # joined above), so nothing mid-transfer loses its pin
            if lease_id is not None:
                store.release_build(lease_id)
