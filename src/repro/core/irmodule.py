"""Platform-neutral IR modules + per-platform artifact tails (doc §13).

The compile cache (``repro.core.compilecache``) amortizes the XLA compile
*within* a platform class, but the cache key it derived until now was a
lock-digest proxy for the program — and the executable it shipped was a
monolithic blob, so a heterogeneous fleet (cpu-host + gpu + tpu deploying
one CIR) re-shipped bytes that are actually platform-neutral.  This
module makes the performance-portable split explicit:

* :func:`ir_module_digest` is the real program identity: a digest over
  the lock closure's assemble-gated pins plus the staged entry set —
  deliberately **platform-free** (no chip, mesh, backend or jax version),
  so semantically identical programs resolved from different catalogs or
  deployed to different platform classes share one IR module.
* :func:`ir_module_component` wraps that digest as a ``manager="ir"``
  component — the StableHLO-like module, chunk-distributed and
  peer-sourced like any component.  It is derived deterministically from
  the lock closure, so every node of *every* platform class constructs a
  byte-identical carrier: the shared IR is lowered once fleet-wide and
  only ever copied afterwards.
* :func:`autotune_component` wraps a compile key's Pallas autotune table
  as a small ``manager="autotune"`` component riding the same peer path.

With the split on, the per-platform bytes a node fetches or builds are
only the artifact *tail* (the platform-specific executable remainder,
``TAIL_BYTES_*``) plus the autotune table; the platform-neutral majority
of the old monolithic envelope (``IR_BYTES_*``) moves once per fleet
instead of once per platform class.  The size/cost model keeps the
monolithic envelope as the baseline: IR + tail == the §10 artifact
envelope, and IR lowering + tail compile == the §10 compile cost, so the
split changes *where* bytes and seconds land, never how many there are.
"""
from __future__ import annotations

import hashlib
import json
from typing import Sequence

from .component import UniformComponent

# Manager namespaces for the split.  Never resolved from a CIR dependency
# closure — IR modules are derived by the compile stage from the lock
# closure; autotune tables are produced next to the platform tail.
IR_MANAGER = "ir"
AUTOTUNE_MANAGER = "autotune"

# Folded into every IR digest: bump when the IR serialization (the
# modeled StableHLO bytecode format) changes so stale modules never
# false-hit across an incompatible lowering.
IR_VERSION_SALT = "cir-stablehlo-v1"

# The staged program is a pure function of the assemble-gated pins (model
# topology, runtime step closures, kernels, parallelism plan, data
# pipeline) — the same managers BuildGraph gates the assemble stage on.
PROGRAM_MANAGERS = ("model", "runtime", "kernel", "parallel", "data")

# The *platform-neutral* subset: what the exported StableHLO module is
# made of.  The ``parallel`` plan is deliberately excluded — partition
# plans are selected per platform class (``tp`` on a single host,
# ``fsdp-tp`` on a mesh), and like GSPMD partitioning they apply during
# the platform lowering, not in the exported module.  The plan instead
# feeds the *platform* side of the compile key
# (:func:`partition_plan_digest`), so dropping it here can never cause a
# cross-plan false hit on the compiled tail.
IR_PROGRAM_MANAGERS = ("model", "runtime", "kernel", "data")

# Size model (doc §13): the §10 monolithic envelope (24 MiB + 8 MiB per
# entry) splits into a platform-neutral IR majority and a per-platform
# tail; the two sum exactly to the monolithic sizes so the split is a
# re-labeling of the same bytes, never a free lunch.
IR_BYTES_BASE = 18 * 2 ** 20        # serialized StableHLO module envelope
IR_BYTES_PER_ENTRY = 6 * 2 ** 20    # per staged step function
TAIL_BYTES_BASE = 6 * 2 ** 20       # platform-specific executable remainder
TAIL_BYTES_PER_ENTRY = 2 * 2 ** 20

# Pallas autotune tables are small: block-size / pipeline choices per
# kernel, keyed by the compile key (platform class included via the key).
AUTOTUNE_BYTES_BASE = 128 * 2 ** 10
AUTOTUNE_BYTES_PER_ENTRY = 64 * 2 ** 10

# Cost model on the virtual clock: lowering to IR + compiling the tail
# (+ autotuning) sums to the §10 monolithic compile cost (8 s/entry), so
# a lone node pays the same either way — the fleet saves by sharing the
# lowering, not by pretending compiles got cheaper.
IR_LOWER_VIRTUAL_S_PER_ENTRY = 2.0
TAIL_COMPILE_VIRTUAL_S_PER_ENTRY = 5.5
AUTOTUNE_VIRTUAL_S_PER_ENTRY = 0.5


def ir_module_digest(lock, entry_names: Sequence[str]) -> str:
    """The real program identity: digest of the StableHLO-like module.

    Derived deterministically from the lock closure — sorted digests of
    the platform-neutral program pins (:data:`IR_PROGRAM_MANAGERS`) plus
    the sorted staged entry set and the IR format salt.  Deliberately
    excludes every platform input (chip, mesh, backend, jax version,
    ``platform_id``, and the platform-selected partition plan): the
    module is what the program *is*, before any platform lowers it.  Two
    locks that pin the same program content — even when resolved from
    different catalogs or for different platform classes — derive the
    same digest and therefore share IR and compiled artifacts.
    """
    program = sorted(
        d for (m, _n, _v, _e), d in zip(lock.pins, lock.digests)
        if m in IR_PROGRAM_MANAGERS)
    blob = json.dumps({
        "program": program,
        "entries": sorted(entry_names),
        "salt": IR_VERSION_SALT,
    }, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


def partition_plan_digest(lock) -> str:
    """Digest of the lock's partition-plan pins (the ``parallel``
    manager).  Excluded from the IR identity — the plan is a platform-
    class choice applied during lowering — and folded into the *platform*
    side of the compile key instead, so two platform classes that share
    an IR module but partition differently still compile distinct tails.
    """
    plan = sorted(
        d for (m, _n, _v, _e), d in zip(lock.pins, lock.digests)
        if m == "parallel")
    return hashlib.sha256(json.dumps(plan).encode()).hexdigest()


def ir_module_component(lock, entry_names: Sequence[str]) -> UniformComponent:
    """The content-addressed carrier for one shared IR module.

    The IR digest is the whole identity, so every node — of every
    platform class — constructs a byte-identical component with identical
    chunk ids; the module flows over the ordinary peer-to-peer chunk path
    and is fetched (or lowered) once fleet-wide.
    """
    digest = ir_module_digest(lock, entry_names)
    names = tuple(sorted(entry_names))
    return UniformComponent(
        manager=IR_MANAGER,
        name=f"stablehlo-{digest[:16]}",
        version="1.0",
        env="any",
        context={"ir_digest": digest, "entries": list(names)},
        payload="",
        size_bytes=IR_BYTES_BASE + IR_BYTES_PER_ENTRY * len(names),
    )


def autotune_component(key: str, spec,
                       entry_names: Sequence[str]) -> UniformComponent:
    """The Pallas autotune table for one compiled platform tail.

    Keyed by the compile key (which already folds in the platform class),
    so tables never cross platform-class boundaries but are shared — like
    the tail itself — between same-class nodes.
    """
    names = tuple(sorted(entry_names))
    return UniformComponent(
        manager=AUTOTUNE_MANAGER,
        name=f"autotune-{key[:16]}",
        version="1.0",
        env="any",
        context={"compile_key": key, "chip": spec.chip.name,
                 "entries": list(names)},
        payload="",
        size_bytes=AUTOTUNE_BYTES_BASE + AUTOTUNE_BYTES_PER_ENTRY * len(names),
    )
