"""Local Uniform Component Storage — content-addressed cache + sharing stats.

Implements the paper's component-level storage sharing (§5.7): components are
stored once by digest; builds reference them.  Weight assets carry *virtual*
bytes (accounted, not materialized) so multi-GB suites remain cheap offline.
The granularity study of Table 1 (layer/file/chunk/component × passive/active)
is reproduced by deterministic accounting transforms over the same builds.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Set, Tuple

from .component import UniformComponent


@dataclasses.dataclass
class StoreStats:
    puts: int = 0
    hits: int = 0
    misses: int = 0
    bytes_stored: int = 0          # unique bytes after dedup
    bytes_requested: int = 0       # bytes that would exist without sharing

    @property
    def sharing_rate(self) -> float:
        if self.bytes_requested == 0:
            return 0.0
        return 1.0 - self.bytes_stored / self.bytes_requested

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["sharing_rate"] = self.sharing_rate
        return d


class LocalComponentStore:
    """Content-addressed store: digest -> component metadata (+virtual bytes)."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._by_digest: Dict[str, UniformComponent] = {}
        self.stats = StoreStats()
        self._builds: Dict[str, List[str]] = {}   # build id -> digests
        self._lock = threading.Lock()
        if path:
            os.makedirs(path, exist_ok=True)
            self._load()

    # -- cache protocol -------------------------------------------------------
    def has(self, c: UniformComponent) -> bool:
        return c.digest() in self._by_digest

    def digests(self) -> Set[str]:
        return set(self._by_digest.keys())

    def get(self, digest: str) -> UniformComponent:
        return self._by_digest[digest]

    def put(self, c: UniformComponent) -> bool:
        """Returns True if the component was newly stored (a miss)."""
        dg = c.digest()
        with self._lock:
            self.stats.bytes_requested += c.size_bytes
            if dg in self._by_digest:
                self.stats.hits += 1
                return False
            self._by_digest[dg] = c
            self.stats.puts += 1
            self.stats.misses += 1
            self.stats.bytes_stored += c.size_bytes
            if self.path:
                fn = os.path.join(self.path, dg + ".json")
                with open(fn, "w") as f:
                    json.dump(c.to_json(), f)
            return True

    def record_build(self, build_id: str,
                     comps: Sequence[UniformComponent]) -> None:
        with self._lock:
            self._builds[build_id] = [c.digest() for c in comps]

    def _load(self) -> None:
        for fn in os.listdir(self.path):
            if fn.endswith(".json"):
                with open(os.path.join(self.path, fn)) as f:
                    c = UniformComponent.from_json(json.load(f))
                self._by_digest[c.digest()] = c
                self.stats.bytes_stored += c.size_bytes

    # -- sharing-granularity accounting (Table 1 analogue) ---------------------
    def sharing_report(self) -> Dict[str, Dict[str, float]]:
        """Before/after storage + object counts at four granularities.

        layer  : one object per (build, manager) group — coarse, like image
                 layers; identical only if the whole group matches.
        file   : each component contributes ~1 object per 256 KiB ("files").
        chunk  : fixed 64 KiB content chunks.
        component : our native granularity (digest-level dedup).
        """
        builds = list(self._builds.items())
        report: Dict[str, Dict[str, float]] = {}

        def digest_of(parts: Iterable[str]) -> str:
            h = hashlib.sha256()
            for p in parts:
                h.update(p.encode())
            return h.hexdigest()

        # --- component level
        before_b = before_o = 0
        uniq: Dict[str, int] = {}
        for _bid, dgs in builds:
            for dg in dgs:
                c = self._by_digest[dg]
                before_b += c.size_bytes
                before_o += 1
                uniq[dg] = c.size_bytes
        report["component"] = dict(
            before_bytes=before_b, after_bytes=sum(uniq.values()),
            before_objects=before_o, after_objects=len(uniq))

        # --- layer level: group per (build, manager); a layer dedups only if
        # the exact same component set appears in another build.
        before_b = before_o = 0
        layer_uniq: Dict[str, int] = {}
        for _bid, dgs in builds:
            groups: Dict[str, List[str]] = {}
            for dg in dgs:
                c = self._by_digest[dg]
                groups.setdefault(c.manager, []).append(dg)
            for mgr, group in sorted(groups.items()):
                size = sum(self._by_digest[d].size_bytes for d in group)
                ld = digest_of(sorted(group))
                before_b += size
                before_o += 1
                layer_uniq[ld] = size
        report["layer"] = dict(
            before_bytes=before_b, after_bytes=sum(layer_uniq.values()),
            before_objects=before_o, after_objects=len(layer_uniq))

        # --- file / chunk level: split each component deterministically; a
        # fraction of pieces is content-identical across *versions* of the
        # same (manager, name) — modelling partial file overlap.
        for gran, piece in (("file", 256 * 1024), ("chunk", 64 * 1024)):
            before_b = before_o = 0
            piece_uniq: Dict[str, int] = {}
            for _bid, dgs in builds:
                for dg in dgs:
                    c = self._by_digest[dg]
                    n = max(1, c.size_bytes // piece)
                    # stable share: pieces [0, shared) keyed by (M, n) only —
                    # identical across versions/envs; the rest keyed by digest.
                    shared = int(n * 0.3)
                    for i in range(n):
                        if i < shared:
                            pid = digest_of([c.manager, c.name, str(i), str(piece)])
                        else:
                            pid = digest_of([dg, str(i), str(piece)])
                        sz = min(piece, c.size_bytes - i * piece) if c.size_bytes else 0
                        sz = max(sz, 0)
                        before_b += sz
                        before_o += 1
                        piece_uniq[pid] = sz
            report[gran] = dict(
                before_bytes=before_b, after_bytes=sum(piece_uniq.values()),
                before_objects=before_o, after_objects=len(piece_uniq))

        for gran, row in report.items():
            bb, ab = row["before_bytes"], row["after_bytes"]
            row["bytes_saved_pct"] = 100.0 * (1 - ab / bb) if bb else 0.0
            bo, ao = row["before_objects"], row["after_objects"]
            row["objects_saved_pct"] = 100.0 * (1 - ao / bo) if bo else 0.0
        return report

    def pairwise_sharing(self) -> Dict[Tuple[str, str], float]:
        """Fig 10 analogue: pairwise component-sharing rate between builds."""
        out: Dict[Tuple[str, str], float] = {}
        items = list(self._builds.items())
        for i, (a, da) in enumerate(items):
            for b, db in items[i + 1:]:
                sa, sb = set(da), set(db)
                union_bytes = sum(self._by_digest[d].size_bytes for d in sa | sb)
                inter_bytes = sum(self._by_digest[d].size_bytes for d in sa & sb)
                out[(a, b)] = inter_bytes / union_bytes if union_bytes else 0.0
        return out
