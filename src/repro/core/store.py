"""Local Uniform Component Storage — content-addressed cache + sharing stats.

Implements the paper's component-level storage sharing (§5.7): components are
stored once by digest; builds reference them.  Weight assets carry *virtual*
bytes (accounted, not materialized) so multi-GB suites remain cheap offline.
The granularity study of Table 1 (layer/file/chunk/component × passive/active)
is reproduced by deterministic accounting transforms over the same builds.

The deterministic piece model (``component_pieces``) is shared with the *live*
chunk-addressed store (``repro.core.chunkstore``): a stable fraction of every
component's chunks is keyed by ``(manager, name, index)`` only — identical
across versions and environment variants of the same component — so a
version-bumped re-deploy pays only the unshared delta.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import json
import math
import os
import threading
from typing import (Dict, Iterable, List, Mapping, Optional,
                    Sequence, Set, Tuple)

from .component import UniformComponent

# Pluggable eviction policies a capacity-bounded store understands.
#   lru                 — evict the least-recently-used unpinned entry.
#   cheapest-to-restore — peer-aware: prefer evicting content a linked peer
#                         still holds (restoring it later costs a peer link,
#                         not the upstream registry), LRU within each tier.
#                         Without a peer probe it degrades to plain LRU.
EVICTION_POLICIES = ("lru", "cheapest-to-restore")

# Lease ids with this prefix are **speculative soft leases**: instead of
# pinning content they mark it as the FIRST eviction tier — pre-positioned
# bytes (demand-driven placement, migration pre-fetch) must always be
# evictable before pinned build content and before ordinary demand-fetched
# content.  Priority order under capacity pressure: spec < warm < build-pin
# (see docs/cir-format.md §11).  A real demand hit *promotes* the content
# out of the speculative tier.
SPEC_LEASE_PREFIX = "spec:"

# Fraction of a component's pieces whose identity is stable across versions
# and env variants of the same (manager, name) — the paper's Table 1 partial
# file-overlap model.  Pieces [0, int(n * SHARED_PIECE_FRACTION)) are shared.
SHARED_PIECE_FRACTION = 0.3


@dataclasses.dataclass(frozen=True)
class Chunk:
    """One deterministic content piece of a component."""
    id: str
    index: int
    size: int
    shared: bool      # keyed by (manager, name) — survives version bumps


def piece_digest(parts: Iterable[str]) -> str:
    h = hashlib.sha256()
    for p in parts:
        b = p.encode()
        # length-prefixed join: ('foo1', '2') must never collide with
        # ('foo', '12') — these ids are live chunk-presence keys
        h.update(len(b).to_bytes(4, "big"))
        h.update(b)
    return h.hexdigest()


def component_pieces(c: UniformComponent, piece_size: int,
                     shared_fraction: float = SHARED_PIECE_FRACTION
                     ) -> List[Chunk]:
    """Split a component into deterministic content chunks.

    Ceil partitioning: chunk sizes sum to exactly ``c.size_bytes``, so live
    byte accounting is exact.  Pieces ``[0, int(n * shared_fraction))`` are
    keyed by ``(manager, name, index, piece_size)`` — identical across
    versions/envs of the same component; the rest are keyed by the component
    digest.  ``int(n * f) < n`` for ``f < 1``, so the (possibly short) tail
    chunk is never shared and every shared chunk id maps to one size.
    """
    size = max(0, c.size_bytes)
    n = max(1, math.ceil(size / piece_size))
    shared_n = int(n * shared_fraction)
    dg = c.digest()
    out: List[Chunk] = []
    for i in range(n):
        if i < shared_n:
            cid = piece_digest([c.manager, c.name, str(i), str(piece_size)])
        else:
            cid = piece_digest([dg, str(i), str(piece_size)])
        sz = max(0, min(piece_size, size - i * piece_size))
        out.append(Chunk(id=cid, index=i, size=sz, shared=i < shared_n))
    return out


@dataclasses.dataclass
class StoreStats:
    puts: int = 0
    hits: int = 0
    misses: int = 0
    bytes_stored: int = 0          # unique bytes after dedup
    bytes_requested: int = 0       # bytes that would exist without sharing
    corrupt_skipped: int = 0       # torn/corrupt on-disk entries ignored

    @property
    def sharing_rate(self) -> float:
        if self.bytes_requested == 0:
            return 0.0
        return 1.0 - self.bytes_stored / self.bytes_requested

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["sharing_rate"] = self.sharing_rate
        return d


@dataclasses.dataclass
class LifecycleStats:
    """Capacity/eviction/lease accounting of a lifecycle-managed store."""
    evictions: int = 0              # entries (components or chunks) evicted
    evicted_bytes: int = 0          # bytes dropped by eviction, cumulative
    refetch_bytes: int = 0          # bytes re-fetched after being evicted
    pin_denied_evictions: int = 0   # passes pins/in-flight kept over budget
    components_gcd: int = 0         # components GC'd (every chunk evicted)
    leases_acquired: int = 0
    leases_released: int = 0
    # speculative-placement accounting (``spec:`` soft leases, §11):
    spec_bytes: int = 0             # bytes committed speculatively
    spec_hit_bytes: int = 0         # speculated bytes later hit by demand
    spec_wasted_bytes: int = 0      # speculated bytes evicted before demand

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class LocalComponentStore:
    """Content-addressed store: digest -> component metadata (+virtual bytes).

    Thread-safe: every read of ``_by_digest`` / ``_builds`` snapshots or
    checks under the lock, so concurrent ``FleetDeployer`` builds can freely
    interleave ``put()`` with ``digests()`` / ``get()`` / report calls.

    Lifecycle-managed: ``capacity_bytes`` bounds the resident bytes.  At
    component granularity (this class) the LRU unpinned component is evicted
    past the budget; ``ChunkedComponentStore`` refines this to chunk
    granularity.  A build **pin lease** (``acquire_build_lease`` at plan
    time, ``release_build`` at lifecycle COMPLETE — the ``BuildOrchestrator``
    drives both, error paths included) makes the build's resolved content
    unevictable while the build runs; the capacity budget is *soft* against
    pins — if everything resident is pinned or in flight the store stays
    over budget and counts a ``pin_denied_evictions`` instead of evicting.
    """

    def __init__(self, path: Optional[str] = None,
                 capacity_bytes: Optional[int] = None,
                 eviction_policy: str = "lru"):
        if capacity_bytes is not None and capacity_bytes <= 0:
            raise ValueError("capacity_bytes must be positive (or None)")
        if eviction_policy not in EVICTION_POLICIES:
            raise ValueError(f"unknown eviction policy {eviction_policy!r} "
                             f"(one of {EVICTION_POLICIES})")
        self.path = path
        self.capacity_bytes = capacity_bytes
        self.eviction_policy = eviction_policy
        # insertion/recency order IS the LRU order (get()/put()-hit refresh)
        self._by_digest: "collections.OrderedDict[str, UniformComponent]" = \
            collections.OrderedDict()
        self.stats = StoreStats()
        self.lifecycle_stats = LifecycleStats()
        self._builds: Dict[str, List[str]] = {}   # build id -> digests
        # build id -> (pinned digests, pinned chunk ids); chunk ids are
        # always empty at component granularity (see ChunkedComponentStore)
        self._leases: Dict[str, Tuple[List[str], List[str]]] = {}
        self._digest_pins: Dict[str, int] = {}    # digest -> lease refcount
        # digest -> spec-lease refcount: members of the speculative eviction
        # tier (first victims under pressure; never pinned by spec leases)
        self._spec_digests: Dict[str, int] = {}
        self._evicted_digests: Set[str] = set()   # for refetch accounting
        self._lock = threading.RLock()
        if path:
            os.makedirs(path, exist_ok=True)
            self._load()
            with self._lock:
                self._enforce_capacity_locked()

    # -- cache protocol -------------------------------------------------------
    def has(self, c: UniformComponent) -> bool:
        dg = c.digest()
        with self._lock:
            return dg in self._by_digest

    def digests(self) -> Set[str]:
        with self._lock:
            return set(self._by_digest.keys())

    def get(self, digest: str) -> UniformComponent:
        with self._lock:
            c = self._by_digest[digest]
            self._by_digest.move_to_end(digest)      # LRU refresh
            return c

    def put(self, c: UniformComponent) -> bool:
        """Returns True if the component was newly stored (a miss)."""
        with self._lock:
            return self._put_locked(c)

    def _put_locked(self, c: UniformComponent) -> bool:
        """Registration body; callers hold ``self._lock`` (it is an RLock, so
        subclasses may compose this with their own locked bookkeeping)."""
        dg = c.digest()
        self.stats.bytes_requested += c.size_bytes
        if dg in self._by_digest:
            self.stats.hits += 1
            self._by_digest.move_to_end(dg)          # LRU refresh
            # a real demand hit promotes content out of the speculative
            # eviction tier, even while its spec: lease is still active
            self._spec_digests.pop(dg, None)
            return False
        self._by_digest[dg] = c
        self.stats.puts += 1
        self.stats.misses += 1
        self.stats.bytes_stored += c.size_bytes
        if dg in self._evicted_digests:
            self._evicted_digests.discard(dg)
            self._count_refetch_locked(c)
        if self.path:
            self._persist(c)
        self._enforce_capacity_locked(exempt=dg)
        return True

    def _count_refetch_locked(self, c: UniformComponent) -> None:
        """A previously evicted entry came back; holds ``_lock``.  At
        component granularity the whole size is the re-fetch; the chunk
        store refines this to the actually re-claimed chunk bytes."""
        self.lifecycle_stats.refetch_bytes += c.size_bytes

    def _persist(self, c: UniformComponent) -> None:
        """Write one component's JSON; subclasses may defer (the chunk
        store persists only once the content has fully landed)."""
        fn = os.path.join(self.path, c.digest() + ".json")
        with open(fn, "w") as f:
            json.dump(c.to_json(), f)

    def record_build(self, build_id: str,
                     comps: Sequence[UniformComponent]) -> None:
        with self._lock:
            self._builds[build_id] = [c.digest() for c in comps]

    # -- pin leases (build lifecycle) ----------------------------------------
    def acquire_build_lease(self, build_id: str,
                            comps: Sequence[UniformComponent]) -> None:
        """Pin ``comps`` for ``build_id``: from plan time until
        ``release_build``, none of this content is evictable.  One lease per
        build id — re-acquiring an active id is a caller bug.

        Ids starting with ``SPEC_LEASE_PREFIX`` are **soft** leases: they do
        not pin anything — they mark the content as the speculative eviction
        tier (first victims under capacity pressure), so pre-positioned bytes
        can never crowd out pinned or demand-fetched content."""
        digests = [c.digest() for c in comps]
        chunk_ids = self._lease_chunk_ids(comps)
        spec = build_id.startswith(SPEC_LEASE_PREFIX)
        with self._lock:
            if build_id in self._leases:
                raise ValueError(f"build lease {build_id!r} already active")
            if spec:
                for dg in digests:
                    self._spec_digests[dg] = self._spec_digests.get(dg, 0) + 1
                self._spec_chunks_locked(chunk_ids, +1)
            else:
                for dg in digests:
                    self._digest_pins[dg] = self._digest_pins.get(dg, 0) + 1
                self._pin_chunks_locked(chunk_ids)
            self._leases[build_id] = (digests, chunk_ids)
            self.lifecycle_stats.leases_acquired += 1

    def release_build(self, build_id: str) -> bool:
        """Release ``build_id``'s pin lease (idempotent; the ``_builds``
        history written by ``record_build`` is kept — it is accounting, the
        lease is lifecycle).  Newly unpinned content becomes evictable, so a
        store held over budget by pins shrinks back here."""
        with self._lock:
            rec = self._leases.pop(build_id, None)
            if rec is None:
                return False
            digests, chunk_ids = rec
            if build_id.startswith(SPEC_LEASE_PREFIX):
                # a demand hit may already have promoted some content out of
                # the spec tier (refcount gone) — tolerate the decrement
                for dg in digests:
                    n = self._spec_digests.get(dg, 0) - 1
                    if n > 0:
                        self._spec_digests[dg] = n
                    else:
                        self._spec_digests.pop(dg, None)
                self._spec_chunks_locked(chunk_ids, -1)
            else:
                for dg in digests:
                    n = self._digest_pins.get(dg, 0) - 1
                    if n > 0:
                        self._digest_pins[dg] = n
                    else:
                        self._digest_pins.pop(dg, None)
                self._unpin_chunks_locked(chunk_ids)
            self.lifecycle_stats.leases_released += 1
            self._enforce_capacity_locked()
            return True

    def lease_active(self, build_id: str) -> bool:
        with self._lock:
            return build_id in self._leases

    def pinned_digests(self) -> Set[str]:
        with self._lock:
            return set(self._digest_pins)

    # chunk-granularity hooks the ChunkedComponentStore overrides
    def _lease_chunk_ids(self, comps: Sequence[UniformComponent]
                         ) -> List[str]:
        return []

    def _pin_chunks_locked(self, chunk_ids: Sequence[str]) -> None:
        pass

    def _unpin_chunks_locked(self, chunk_ids: Sequence[str]) -> None:
        pass

    def _spec_chunks_locked(self, chunk_ids: Sequence[str],
                            delta: int) -> None:
        """Adjust chunk-level speculative-tier membership; no-op at
        component granularity (``ChunkedComponentStore`` overrides)."""
        pass

    # -- capacity enforcement (component granularity) -------------------------
    def _enforce_capacity_locked(self, exempt: Optional[str] = None) -> None:
        """Evict LRU unpinned components past ``capacity_bytes``; holds
        ``_lock``.  ``ChunkedComponentStore`` replaces this with chunk-level
        eviction.  The budget is soft against pins (and against the entry
        just being stored, ``exempt`` — inserting must not thrash itself
        out): when nothing else is evictable the store stays over budget,
        counted in ``pin_denied_evictions``."""
        if self.capacity_bytes is None:
            return
        while self.stats.bytes_stored > self.capacity_bytes:
            # speculative-tier content (spec: soft leases) goes first —
            # pre-positioned bytes must never displace demand content
            victim = next((dg for dg in self._by_digest
                           if dg != exempt and not self._digest_pins.get(dg)
                           and self._spec_digests.get(dg)),
                          None)
            if victim is None:
                victim = next((dg for dg in self._by_digest
                               if dg != exempt
                               and not self._digest_pins.get(dg)),
                              None)
            if victim is None:
                self.lifecycle_stats.pin_denied_evictions += 1
                return
            self._evict_component_locked(victim)

    def _evict_component_locked(self, dg: str) -> None:
        c = self._by_digest.pop(dg)
        self.stats.bytes_stored -= c.size_bytes
        # content fetched again after eviction arrives on demand — it must
        # not inherit the old speculative-tier marking
        self._spec_digests.pop(dg, None)
        self._evicted_digests.add(dg)
        self.lifecycle_stats.evictions += 1
        self.lifecycle_stats.evicted_bytes += c.size_bytes
        if self.path:
            try:
                os.remove(os.path.join(self.path, dg + ".json"))
            except OSError:
                pass

    def _load(self) -> None:
        for fn in sorted(os.listdir(self.path)):
            if not fn.endswith(".json"):
                continue
            try:
                with open(os.path.join(self.path, fn)) as f:
                    c = UniformComponent.from_json(json.load(f))
            except (OSError, ValueError, KeyError, TypeError):
                # a torn/corrupt entry is skipped (and counted), not fatal —
                # the component will simply be re-fetched and re-written
                self.stats.corrupt_skipped += 1
                continue
            self._by_digest[c.digest()] = c
            self.stats.bytes_stored += c.size_bytes

    # -- sharing-granularity accounting (Table 1 analogue) ---------------------
    def _snapshot(self) -> Tuple[Dict[str, UniformComponent],
                                 List[Tuple[str, List[str]]]]:
        with self._lock:
            return dict(self._by_digest), list(self._builds.items())

    def sharing_report(self) -> Dict[str, Dict[str, float]]:
        """Before/after storage + object counts at four granularities.

        layer  : one object per (build, manager) group — coarse, like image
                 layers; identical only if the whole group matches.
        file   : each component contributes ~1 object per 256 KiB ("files").
        chunk  : fixed 64 KiB content chunks.
        component : our native granularity (digest-level dedup).
        """
        by_digest, builds = self._snapshot()
        report: Dict[str, Dict[str, float]] = {}

        # --- component level  (digests evicted/GC'd since their build was
        # recorded are skipped — the history outlives bounded-store content)
        before_b = before_o = 0
        uniq: Dict[str, int] = {}
        for _bid, dgs in builds:
            for dg in dgs:
                c = by_digest.get(dg)
                if c is None:
                    continue
                before_b += c.size_bytes
                before_o += 1
                uniq[dg] = c.size_bytes
        report["component"] = dict(
            before_bytes=before_b, after_bytes=sum(uniq.values()),
            before_objects=before_o, after_objects=len(uniq))

        # --- layer level: group per (build, manager); a layer dedups only if
        # the exact same component set appears in another build.
        before_b = before_o = 0
        layer_uniq: Dict[str, int] = {}
        for _bid, dgs in builds:
            groups: Dict[str, List[str]] = {}
            for dg in dgs:
                c = by_digest.get(dg)
                if c is None:
                    continue
                groups.setdefault(c.manager, []).append(dg)
            for mgr, group in sorted(groups.items()):
                size = sum(by_digest[d].size_bytes for d in group)
                ld = piece_digest(sorted(group))
                before_b += size
                before_o += 1
                layer_uniq[ld] = size
        report["layer"] = dict(
            before_bytes=before_b, after_bytes=sum(layer_uniq.values()),
            before_objects=before_o, after_objects=len(layer_uniq))

        # --- file / chunk level: the same deterministic piece model the live
        # chunk store uses (component_pieces) at two study granularities.
        for gran, piece in (("file", 256 * 1024), ("chunk", 64 * 1024)):
            before_b = before_o = 0
            piece_uniq: Dict[str, int] = {}
            for _bid, dgs in builds:
                for dg in dgs:
                    if dg not in by_digest:
                        continue
                    for ch in component_pieces(by_digest[dg], piece):
                        before_b += ch.size
                        before_o += 1
                        piece_uniq[ch.id] = ch.size
            report[gran] = dict(
                before_bytes=before_b, after_bytes=sum(piece_uniq.values()),
                before_objects=before_o, after_objects=len(piece_uniq))

        for gran, row in report.items():
            bb, ab = row["before_bytes"], row["after_bytes"]
            row["bytes_saved_pct"] = 100.0 * (1 - ab / bb) if bb else 0.0
            bo, ao = row["before_objects"], row["after_objects"]
            row["objects_saved_pct"] = 100.0 * (1 - ao / bo) if bo else 0.0
        return report

    def pairwise_sharing(self) -> Dict[Tuple[str, str], float]:
        """Fig 10 analogue: pairwise component-sharing rate between builds."""
        by_digest, builds = self._snapshot()
        out: Dict[Tuple[str, str], float] = {}
        for i, (a, da) in enumerate(builds):
            for b, db in builds[i + 1:]:
                sa, sb = set(da) & set(by_digest), set(db) & set(by_digest)
                union_bytes = sum(by_digest[d].size_bytes for d in sa | sb)
                inter_bytes = sum(by_digest[d].size_bytes for d in sa & sb)
                out[(a, b)] = inter_bytes / union_bytes if union_bytes else 0.0
        return out
