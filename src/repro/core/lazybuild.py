"""Lazy-builder: the staged deployment pipeline (paper §4.2).

The lazy-build is an explicit four-stage pipeline:

    resolve  → pick concrete uniform components for the target platform
               (Algorithms 1+2), or REPLAY a cached build plan;
    fetch    → pull missing content against the local store.  With the
               default ``ChunkedComponentStore`` this is a *delta* fetch:
               a missing-chunk plan per component, executed by a bounded
               thread-pool ``FetchEngine`` with singleflight dedup and
               priority ordering (model/runtime first, weight tail last);
    assemble → overlay components into the model + entrypoint callables
               (the OverlayFS-mount analogue);
    compile  → stage the step entrypoints for the target mesh (jit).

Stage 1 consults a persistent, content-addressed **build-plan cache** keyed
by ``(CIR digest, SpecSheet digest, catalog epoch, overrides)``: a hit skips
resolution/selection entirely and replays the stored version-lock manifest
against the component service + ``LocalComponentStore``.  This is what makes
re-deploying the same CIR to the same platform class — the hot path of a
deployment service — cheap, and what ``FleetDeployer`` (repro.deploy) builds
on to amortize one CIR across N heterogeneous platforms.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import json
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from .cir import CIR
from .chunkstore import CLAIM_WAIT_TIMEOUT_S, ChunkedComponentStore, FetchPlan
from .compilecache import (COMPILE_VIRTUAL_S_PER_ENTRY, CompileCache,
                           CompiledArtifact, artifact_component,
                           compile_cache_key)
from .component import UniformComponent
from .integrity import (Attestation, AttestationError, Signer, make_sbom,
                        attest as _sign_manifest, verify_attestation)
from .irmodule import (AUTOTUNE_VIRTUAL_S_PER_ENTRY,
                       IR_LOWER_VIRTUAL_S_PER_ENTRY,
                       TAIL_COMPILE_VIRTUAL_S_PER_ENTRY,
                       autotune_component, ir_module_component)
from .orchestrator import (BuildGraph, BuildOrchestrator, ComponentReadiness,
                           Lifecycle)
from .registry import RegistryError, UniformComponentService
from .resolution import (Resolution, ResolutionError, resolution_from_pins,
                         uniform_dependency_resolution)
from .simnet import SimTransport, WallClockTransport
from .spec import SpecSheet
from .store import LocalComponentStore

# Payload catalog: payload-reference -> python factory.  Populated by
# repro.core.catalog at import time (the 'converted component' bodies).
PAYLOADS: Dict[str, Callable] = {}


def register_payload(name: str):
    def deco(fn):
        if name in PAYLOADS and PAYLOADS[name] is not fn:
            raise ValueError(f"payload {name!r} already registered")
        PAYLOADS[name] = fn
        return fn
    return deco


class ComponentBundle:
    """The selected components of one build, addressable by (manager, name).

    Assembly code pulls concrete variants from here — this is how the model
    family finds *which* attention/kernel/plan variant Algorithm 1 picked.
    """

    def __init__(self, resolution: Resolution):
        self.resolution = resolution
        self._by_key = dict(resolution.selected_by_key)

    def component(self, manager: str, name: str) -> UniformComponent:
        return self._by_key[(manager, name)]

    def has(self, manager: str, name: str) -> bool:
        return (manager, name) in self._by_key

    def payload(self, manager: str, name: str) -> Callable:
        c = self.component(manager, name)
        try:
            return PAYLOADS[c.payload]
        except KeyError:
            raise KeyError(
                f"component {c.ident_str()} references unknown payload "
                f"{c.payload!r} — is repro.core.catalog imported?") from None

    def payload_of(self, c: UniformComponent) -> Callable:
        return PAYLOADS[c.payload]

    @property
    def context(self) -> Dict[str, Any]:
        return self.resolution.context

    def components(self) -> List[UniformComponent]:
        return list(self.resolution.components)


# ---------------------------------------------------------------------------
# Lockfile (paper §4.2: "a dedicated version locking file for each platform")
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Lockfile:
    cir_digest: str
    platform_id: str
    seed: int
    pins: Tuple[Tuple[str, str, str, str], ...]   # (M, n, v, e)
    digests: Tuple[str, ...]

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "Lockfile":
        d = json.loads(s)
        d["pins"] = tuple(tuple(p) for p in d["pins"])
        d["digests"] = tuple(d["digests"])
        return Lockfile(**d)

    def digest(self) -> str:
        return hashlib.sha256(self.to_json().encode()).hexdigest()


# ---------------------------------------------------------------------------
# Build-plan cache (deployment-service hot path)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BuildPlan:
    """The replayable outcome of one resolution: a version-lock manifest.

    Content-addressed by ``(cir_digest, spec_digest, catalog_epoch,
    overrides)`` — any of these changing means resolution could pick
    different components, so the plan only ever replays for the exact
    deployment it was computed for.
    """
    cir_digest: str
    spec_digest: str
    catalog_epoch: str            # registry content fingerprint (hex)
    pins: Tuple[Tuple[str, str, str, str], ...]
    digests: Tuple[str, ...]

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "BuildPlan":
        d = json.loads(s)
        d["pins"] = tuple(tuple(p) for p in d["pins"])
        d["digests"] = tuple(d["digests"])
        return BuildPlan(**d)


@dataclasses.dataclass
class PlanCacheStats:
    hits: int = 0
    misses: int = 0
    puts: int = 0
    stale_drops: int = 0      # replays that failed (catalog changed underfoot)
    evictions: int = 0        # LRU drops past max_entries


class BuildPlanCache:
    """Persistent, content-addressed store of build plans.

    In-memory by default; give it a directory ``path`` and plans survive
    process restarts (one JSON file per cache key, written atomically).
    Epoch-based invalidation is structural: the catalog epoch — a
    restart-stable content fingerprint — is part of the key, so a registry
    content change simply never matches old entries.

    One consequence: plans are stored under the *post-resolution* epoch.
    A build whose resolution itself pulls new components from upstream
    (on-demand conversion) therefore looks up at the pre-pull epoch and
    misses once per fresh process; builds against an already-converted
    catalog replay across restarts.

    ``max_entries`` bounds the cache LRU-wise (a long-lived deployment
    service accumulates one entry per (CIR, platform, epoch, overrides)
    forever otherwise): the least-recently-used plan — in memory *and* its
    on-disk file — is evicted past the cap, counted in ``stats.evictions``.
    """

    def __init__(self, path: Optional[str] = None,
                 max_entries: Optional[int] = None):
        if max_entries is not None and max_entries < 1:
            raise ValueError("max_entries must be >= 1 (or None)")
        self.path = path
        self.max_entries = max_entries
        self._plans: "collections.OrderedDict[str, BuildPlan]" = \
            collections.OrderedDict()
        self.stats = PlanCacheStats()
        self._lock = threading.Lock()
        if path:
            os.makedirs(path, exist_ok=True)
            self._load()
            with self._lock:
                self._evict_locked()

    @staticmethod
    def key(cir: CIR, spec: SpecSheet, catalog_epoch: str,
            overrides: Optional[Mapping[str, Any]] = None) -> str:
        blob = json.dumps({
            "cir": cir.digest(),
            "spec": spec.digest(),
            "epoch": catalog_epoch,
            "overrides": dict(overrides or {}),
        }, sort_keys=True, default=str).encode()
        return hashlib.sha256(blob).hexdigest()

    def get(self, key: str) -> Optional[BuildPlan]:
        with self._lock:
            plan = self._plans.get(key)
            if plan is None:
                self.stats.misses += 1
            else:
                self.stats.hits += 1
                self._plans.move_to_end(key)     # LRU refresh
            return plan

    def put(self, key: str, plan: BuildPlan) -> None:
        with self._lock:
            self._plans[key] = plan
            self._plans.move_to_end(key)
            self.stats.puts += 1
            if self.path:
                fn = os.path.join(self.path, key + ".json")
                tmp = fn + ".tmp"
                with open(tmp, "w") as f:
                    f.write(plan.to_json())
                os.replace(tmp, fn)
            self._evict_locked()

    def _evict_locked(self) -> None:
        """Drop least-recently-used plans past ``max_entries``; holds _lock."""
        if self.max_entries is None:
            return
        while len(self._plans) > self.max_entries:
            old, _plan = self._plans.popitem(last=False)
            self.stats.evictions += 1
            if self.path:
                try:
                    os.remove(os.path.join(self.path, old + ".json"))
                except OSError:
                    pass

    def drop(self, key: str) -> None:
        with self._lock:
            self._plans.pop(key, None)
            self.stats.stale_drops += 1
            if self.path:
                try:
                    os.remove(os.path.join(self.path, key + ".json"))
                except OSError:
                    pass

    def _load(self) -> None:
        def mtime(fn: str) -> float:
            try:
                return os.path.getmtime(os.path.join(self.path, fn))
            except OSError:
                return 0.0
        # oldest first, so insertion order approximates on-disk recency and
        # the LRU cap evicts the stalest entries after a restart
        for fn in sorted(os.listdir(self.path), key=mtime):
            if not fn.endswith(".json"):
                continue
            try:
                with open(os.path.join(self.path, fn)) as f:
                    self._plans[fn[:-len(".json")]] = BuildPlan.from_json(
                        f.read())
            except (OSError, ValueError, KeyError, TypeError):
                # a torn/corrupt entry is a miss, not a fatal error — the
                # plan will be recomputed and rewritten atomically
                continue

    def __len__(self) -> int:
        return len(self._plans)


# ---------------------------------------------------------------------------
# Build report (feeds every benchmark)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BuildReport:
    cir_name: str
    platform_id: str
    resolve_s: float = 0.0
    fetch_s: float = 0.0            # wall time of the (pipelined) fetch stage
    assemble_s: float = 0.0
    bytes_cir: int = 0
    bytes_fetched: int = 0          # component-level bytes of missed components
    bytes_total_components: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    n_components: int = 0
    restarts: int = 0
    locked: bool = False
    plan_cache_hit: bool = False    # resolution skipped via build-plan cache
    compile_s: float = 0.0
    n_compiled: int = 0
    # -- chunk-level delta-fetch columns (ChunkedComponentStore path) -------
    chunked_fetch: bool = False     # fetch ran through the chunk planner
    bytes_delta_fetched: int = 0    # wire bytes: missing chunks only
    chunks_hit: int = 0             # chunks already present locally
    chunks_missed: int = 0          # chunks this build fetched (and paid for)
    chunks_waited: int = 0          # chunks in flight under another build
    fetch_concurrency: int = 1      # thread-pool width the engine used
    fetch_serial_s: float = 0.0     # sum of per-task fetch times (no overlap)
    fetch_wait_timeouts: int = 0    # in-flight waits that hit the backstop
    # -- event-driven orchestration columns (BuildOrchestrator) -------------
    orchestrated: bool = False      # stages overlapped via readiness events
    critical_path_s: float = 0.0    # measured wall: build start -> READY
    overlap_s: float = 0.0          # barrier-stage sum minus critical path
    stage_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    #                               ^ per-lifecycle-stage wall offsets
    listener_errors: int = 0        # advisory readiness-callback raises
    # -- fleet compile-cache columns (compiled-artifact components) ---------
    # Artifact bytes are accounted separately from the resolved-content
    # columns above: cache-hit and cache-miss builds of the same content
    # keep identical bytes_fetched / bytes_delta_fetched / chunk counts,
    # and NodeTraffic.bytes_total still equals bytes_delta_fetched.
    compile_cache_hit: bool = False  # executable restored from fleet cache
    compile_skips: int = 0           # step compiles skipped via the cache
    artifact_bytes_fetched: int = 0  # compiled-artifact wire bytes (peers)
    artifact_chunks_fetched: int = 0
    artifact_bytes_published: int = 0  # locally-compiled bytes stored
    # -- performance-portable IR columns (core/irmodule.py, docs §13) --------
    # Accounted exactly like artifacts: never in the resolved-content
    # columns, so with the split disabled every column below is zero and
    # the whole report is byte-identical to a pre-§13 build.
    ir_enabled: bool = False         # builder ran with the IR split on
    ir_shared_bytes: int = 0         # shared-IR bytes sourced (store/peers)
    ir_bytes_published: int = 0      # IR lowered locally + published
    platform_tail_bytes: int = 0     # per-platform bytes (tail + autotune)
    autotune_bytes_fetched: int = 0  # autotune-table wire bytes (peers)
    autotune_bytes_published: int = 0
    # -- trust & integrity columns (core/integrity.py, docs §12) -------------
    attestation_verified: bool = False  # signed manifest checked at plan time

    @property
    def bytes_wire_fetched(self) -> int:
        """Bytes that actually cross the link: the chunk delta when chunk
        accounting ran, the full missed-component bytes otherwise."""
        return self.bytes_delta_fetched if self.chunked_fetch \
            else self.bytes_fetched

    def network_time(self, bandwidth_bps: float) -> float:
        """Simulated link time: CIR pull + parallel delta fetch."""
        return (self.bytes_cir + self.bytes_wire_fetched) * 8.0 / bandwidth_bps

    def lazy_build_time(self, bandwidth_bps: float) -> float:
        """Deploy wall time at a simulated link — the orchestrator's actual
        critical path, not an analytic stage sum.

        ``overlap_s`` is the *measured* time the event-driven pipeline ran
        stages concurrently (assemble/jit under the asset tail, READY not
        gated on first-weight-use content), so the stage sum is credited by
        exactly what the orchestrator achieved; barrier builds have
        ``overlap_s == 0`` and reduce to the legacy analytic form.
        Resolution still overlaps the CIR pull + delta fetch on the link
        (paper §4.3: converters split metadata from payload).
        """
        stage_sum = self.fetch_s + self.assemble_s + self.compile_s
        return max(self.resolve_s, self.network_time(bandwidth_bps)) \
            + stage_sum - min(self.overlap_s, stage_sum)

    def as_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["bytes_wire_fetched"] = self.bytes_wire_fetched
        return d


# ---------------------------------------------------------------------------
# Fetch engine (stage 2): planner + bounded-concurrency executor
# ---------------------------------------------------------------------------

# Assembly needs the model family and runtime step builders first; kernels
# and plans next; the platform env is usually host-seeded; the weight tail
# (assets) lands last so assemble can start before it finishes.
_FETCH_PRIORITY = {"model": 0, "runtime": 0, "kernel": 1, "parallel": 1,
                   "opt": 2, "data": 2, "env": 3, "asset": 4}


def _partition(items: Sequence, n: int) -> List[List]:
    """Split ``items`` into at most ``n`` contiguous, near-equal groups."""
    n = max(1, min(n, len(items)))
    k, m = divmod(len(items), n)
    out, i = [], 0
    for j in range(n):
        step = k + (1 if j < m else 0)
        if step:
            out.append(list(items[i:i + step]))
            i += step
    return out


class FetchEngine:
    """Concurrent, pipelined, *streaming* fetch executor for the builder.

    Against a ``ChunkedComponentStore`` it plans a missing-chunk delta per
    component (priority order), stripes each component's claimed chunks
    across a bounded thread pool (range-parallel blob pulls), charges only
    delta bytes through ``service.fetch_chunks``, and waits on chunks other
    builds have in flight — the singleflight guarantee that a fleet never
    fetches the same chunk twice, even mid-transfer.

    The fetch is a streaming stage: given a ``ComponentReadiness`` tracker
    it signals each component ``ready`` the moment its content is *proven*
    present — owned stripes committed, awaited chunks landed (orphans of an
    aborted claimer reclaimed and re-pulled) — in priority order, so the
    ``BuildOrchestrator`` starts assembly while the weight-asset tail is
    still on the wire.  Accounting is independent of the overlap: byte and
    chunk columns are identical with or without a readiness consumer.

    Link time is modelled behind a **transport** (``upstream_transfer`` /
    ``peer_transfer`` / ``backoff``): ``simulate_bps`` installs the
    legacy real-sleep ``WallClockTransport`` (each stripe sleeps
    ``bytes / bps`` so benchmarks can observe real wall-clock overlap);
    a ``repro.core.simnet.SimTransport`` advances a *virtual* clock
    instead — milliseconds of wall time for a WAN-sized fleet — and may
    raise fault errors.  Accounting is identical under any transport (or
    none): the transport replaces only the sleeps, never the
    ``service.fetch_chunks`` charges or the claim/commit protocol.
    Plain ``LocalComponentStore``s keep the legacy serial
    whole-component path.

    ``peering`` is the optional chunk-source router of a fleet-topology
    node (``repro.deploy.topology.NodePeering``): when set, every claimed
    stripe is transferred through ``peering.fetch_stripe`` — which may pull
    chunks from peer nodes instead of the upstream registry and does its
    own per-link simulated sleeps — and every committed stripe is announced
    through ``peering.announce_chunks`` so other nodes can source from this
    one.  Chunk/byte accounting in the ``BuildReport`` is identical with or
    without a router; only the upstream-vs-peer split (tracked by the
    router) changes.
    """

    def __init__(self, store: LocalComponentStore,
                 service: UniformComponentService,
                 max_workers: int = 8,
                 simulate_bps: Optional[float] = None,
                 peering: Optional[Any] = None,
                 transport: Optional[Any] = None):
        self.store = store
        self.service = service
        self.max_workers = max(1, max_workers)
        self.simulate_bps = simulate_bps
        self.peering = peering
        if transport is None and simulate_bps:
            transport = WallClockTransport(default_bps=simulate_bps)
        self.transport = transport

    def fetch(self, comps: Sequence[UniformComponent],
              report: BuildReport,
              readiness: Optional[ComponentReadiness] = None) -> None:
        t0 = time.perf_counter()
        order = sorted(range(len(comps)),
                       key=lambda i: (_FETCH_PRIORITY.get(comps[i].manager, 3),
                                      i))
        ordered = [comps[i] for i in order]
        try:
            if isinstance(self.store, ChunkedComponentStore):
                self._fetch_chunked(ordered, report, readiness)
            else:
                self._fetch_serial(ordered, report, readiness)
        finally:
            report.fetch_s = time.perf_counter() - t0

    # -- legacy component-granularity path --------------------------------
    def _fetch_serial(self, comps: Sequence[UniformComponent],
                      report: BuildReport,
                      readiness: Optional[ComponentReadiness] = None) -> None:
        for c in comps:
            report.bytes_total_components += c.size_bytes
            t = time.perf_counter()
            # put() decides hit-vs-miss under the store lock, so concurrent
            # builds charge each component's bytes exactly once.
            if self.store.put(c):
                self.service.fetch(c)
                report.bytes_fetched += c.size_bytes
                report.cache_misses += 1
            else:
                report.cache_hits += 1
            report.fetch_serial_s += time.perf_counter() - t
            if readiness is not None:
                readiness.mark_ready(c)

    # -- chunk-delta path -------------------------------------------------
    def _fetch_chunked(self, comps: Sequence[UniformComponent],
                       report: BuildReport,
                       readiness: Optional[ComponentReadiness] = None) -> None:
        report.chunked_fetch = True
        plans: List[FetchPlan] = []
        for c in comps:
            report.bytes_total_components += c.size_bytes
            plan = self.store.plan_fetch(c)
            if plan.component_new or plan.rescan:
                # a rescan repairs content an aborted build left behind:
                # it does real transfer work, so it counts as a miss (and
                # keeps bytes_delta_fetched <= bytes_fetched)
                report.cache_misses += 1
                report.bytes_fetched += c.size_bytes
            else:
                report.cache_hits += 1
            report.chunks_hit += len(plan.hits)
            report.chunks_waited += len(plan.waits)
            plans.append(plan)

        width = max(1, min(self.max_workers,
                           sum(len(p.claimed) for p in plans)))
        report.fetch_concurrency = width
        # stripe each component's claim across the pool, in priority order
        stripes_of: Dict[int, List[List]] = {id(p): [] for p in plans}
        for plan in plans:
            for stripe in _partition(plan.claimed, width):
                stripes_of[id(plan)].append(stripe)

        def pull(c: UniformComponent, stripe: List) -> Tuple[int, int, float]:
            t = time.perf_counter()
            nbytes = sum(ch.size for ch, _ev in stripe)
            try:
                if self.peering is not None:
                    # fleet-topology node: the router picks the source per
                    # chunk (peer vs upstream) and does its own link sleeps
                    self.peering.fetch_stripe(c, stripe)
                else:
                    if self.transport is not None:
                        self.transport.upstream_transfer(
                            nbytes, bps=self.simulate_bps)
                    self.service.fetch_chunks(c, nbytes, len(stripe))
                self.store.commit_chunks(stripe, component=c)
            except BaseException:
                self.store.abort_chunks(stripe, component=c)
                raise
            if self.peering is not None:
                self.peering.announce_chunks([ch for ch, _ev in stripe])
            return nbytes, len(stripe), time.perf_counter() - t

        # shared wait budget for content another build is pulling — both
        # chunk-level waits and same-digest component barriers.  Scaled to
        # the awaited PLUS owned bytes when transfers are simulated: the
        # deadline starts before this build's own stripe pulls run (each
        # component finishes as its stripes land, streaming), so our own
        # simulated transfer time must not eat the waiters' budget, and a
        # legitimate slow-link stripe must not be declared dead.  The fixed
        # floor only guards against a claimer that died without
        # commit/abort.
        awaited_bytes = sum(ch.size for p in plans for ch, _ev in p.waits) \
            + sum(p.component.size_bytes for p in plans if p.barriers)
        owned_bytes = sum(ch.size for p in plans for ch, _ev in p.claimed)
        budget = CLAIM_WAIT_TIMEOUT_S
        if self.simulate_bps:
            budget += 2.0 * (awaited_bytes + owned_bytes) / self.simulate_bps
        deadline = time.monotonic() + budget

        def finish(plan: FetchPlan) -> None:
            """Prove one component's content present, then signal ready.

            Waits out transfers other builds own; if content we waited on
            was aborted by its claimer — a chunk-level wait or a whole
            component barrier — we re-claim and fetch it ourselves: a
            waiter must never finish with a hole another build's failure
            left behind.  Anything we cannot prove complete (still in
            flight under a third build, or a timed-out barrier) marks OUR
            digest incomplete, so the next build of it re-verifies — no
            permanent present-with-holes state.
            """
            timed_out = False
            for ev in [ev for _ch, ev in plan.waits] + plan.barriers:
                if not ev.wait(max(0.0, deadline - time.monotonic())):
                    report.fetch_wait_timeouts += 1
                    timed_out = True
            if plan.waits:
                orphans = self.store.reclaim_chunks([ch for ch, _ev
                                                     in plan.waits])
            elif plan.barriers:
                orphans = self.store.reclaim_component(plan.component)
            else:
                orphans = []
            if orphans:
                report.bytes_delta_fetched += \
                    sum(ch.size for ch, _ev in orphans)
                report.chunks_missed += len(orphans)
                pull(plan.component, orphans)
            holey = any(not self.store.has_chunk(ch.id)
                        for ch, _ev in plan.waits) or \
                (plan.barriers and timed_out)
            if holey:
                self.store.mark_incomplete(plan.component)
            if readiness is not None:
                readiness.mark_ready(plan.component)

        def account(res: Tuple[int, int, float]) -> None:
            nbytes, nchunks, dt = res
            report.bytes_delta_fetched += nbytes
            report.chunks_missed += nchunks
            report.fetch_serial_s += dt

        def release_from(pi: int, si: int) -> None:
            """Failure cleanup from plan ``pi``, stripe ``si`` on: abort the
            never-executed stripes' claims (or sibling builds block on
            events that can't fire) and mark every plan whose awaited
            content was never verified incomplete, so the next build of
            those digests re-scans instead of trusting a component hit."""
            for s2 in stripes_of[id(plans[pi])][si:]:
                self.store.abort_chunks(s2, component=plans[pi].component)
            for p2 in plans[pi + 1:]:
                for s2 in stripes_of[id(p2)]:
                    self.store.abort_chunks(s2, component=p2.component)
            for p2 in plans[pi:]:
                if p2.waits or p2.barriers:
                    self.store.mark_incomplete(p2.component)

        n_stripes = sum(len(s) for s in stripes_of.values())
        if width == 1 or n_stripes <= 1:
            for pi, plan in enumerate(plans):
                stripes = stripes_of[id(plan)]
                for si, stripe in enumerate(stripes):
                    try:
                        account(pull(plan.component, stripe))
                    except BaseException:
                        release_from(pi, si + 1)
                        raise
                try:
                    finish(plan)
                except BaseException:
                    # the orphan-repair re-pull can fail too: its own claim
                    # aborts inside pull(), the rest is released here
                    release_from(pi, len(stripes))
                    raise
        else:
            # every stripe is submitted eagerly (priority order == queue
            # order), so each runs pull() and aborts its own claim on
            # failure; components complete — and signal readiness — in
            # priority order as their last stripe lands
            with ThreadPoolExecutor(max_workers=width) as pool:
                futs = {id(p): [pool.submit(pull, p.component, s)
                                for s in stripes_of[id(p)]]
                        for p in plans}
                first_err: Optional[BaseException] = None
                for plan in plans:
                    results, failed = [], False
                    for f in futs[id(plan)]:
                        try:
                            results.append(f.result())
                        except BaseException as e:  # noqa: BLE001
                            failed = True
                            if first_err is None:
                                first_err = e
                    # every committed-and-charged stripe is accounted, even
                    # on a failing build — the partial report feeds fleet
                    # byte totals, which must not understate real transfers
                    for res in results:
                        account(res)
                    if first_err is None and not failed:
                        try:
                            finish(plan)
                        except BaseException as e:  # noqa: BLE001
                            # keep draining later plans' futures so their
                            # committed stripes are still accounted
                            first_err = e
                            if plan.waits or plan.barriers:
                                self.store.mark_incomplete(plan.component)
                    elif plan.waits or plan.barriers:
                        # never verified this plan's awaited content
                        self.store.mark_incomplete(plan.component)
                if first_err is not None:
                    raise first_err


# ---------------------------------------------------------------------------
# Container instance
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ContainerInstance:
    """The assembled, runnable unit, with an explicit lifecycle.

    ``model`` is the family-assembled Model object (init/apply + sharding
    rules); ``entry`` holds the built entrypoint callables (train_step or
    prefill/decode) produced by the runtime components.  The launcher gives
    it a mesh to produce shardings, lower and compile.

    The instance exists from the moment resolution pins its components
    (stage PLANNED); the orchestrator advances it through FETCHING →
    ASSEMBLED → COMPILED → READY → COMPLETE as per-component readiness
    gates fire.  ``wait(stage)`` blocks until a stage is reached (READY =
    deployable, the asset tail may still stream; ``wait("weights")`` is
    the first-weight-use gate) and re-raises the build's error if it
    failed first.  ``model``/``entry`` are populated at ASSEMBLED; the
    fetch accounting in ``report`` is final at COMPLETE.
    """
    cir: CIR
    spec: SpecSheet
    bundle: ComponentBundle
    model: Any
    entry: Dict[str, Callable]
    lock: Lockfile
    report: BuildReport
    lifecycle: Lifecycle = dataclasses.field(default_factory=Lifecycle,
                                             repr=False, compare=False)
    # fleet compile-cache key of the staged executable (set by the compile
    # stage when a CompileCache is wired; snapshot/restore round-trips it)
    compile_key: Optional[str] = dataclasses.field(default=None,
                                                   compare=False)

    @property
    def arch_id(self) -> str:
        return self.cir.name

    @property
    def stage(self) -> str:
        return self.lifecycle.stage

    def wait(self, stage: str = "complete",
             timeout: Optional[float] = None) -> "ContainerInstance":
        """Block until ``stage`` is reached; returns self for chaining."""
        self.lifecycle.wait(stage, timeout)
        return self


# Entry keys the compile stage treats as per-mesh step functions.
_STEP_ENTRIES = ("train_step", "prefill", "decode_step")


class LazyBuilder:
    """The staged deployment pipeline: resolve → fetch → assemble → compile.

    The stages are no longer strict barriers: after resolution, a
    ``BuildOrchestrator`` drives fetch / assemble / compile off
    per-component readiness events (``BuildGraph`` gates), so assembly and
    jit-staging overlap the weight-asset tail and the instance is READY —
    deployable — before first-weight-use content has landed.  Every stage
    is still an explicit method so deployment services (FleetDeployer,
    launchers) can run, time and skip stages individually; a shared
    ``BuildPlanCache`` (created per-builder when not given) short-circuits
    the resolve stage for repeat deployments.
    """

    def __init__(self, service: UniformComponentService,
                 store: Optional[LocalComponentStore] = None,
                 link_bandwidth_bps: float = 500e6,
                 plan_cache: Optional[BuildPlanCache] = None,
                 fetch_workers: int = 8,
                 fetch_simulate_bps: Optional[float] = None,
                 build_graph: Optional[BuildGraph] = None,
                 peering: Optional[Any] = None,
                 fetch_transport: Optional[Any] = None,
                 compile_cache: Optional[CompileCache] = None,
                 signer: Optional[Signer] = None,
                 require_attestation: bool = False,
                 ir_components: bool = False):
        self.service = service
        # manifest-attestation policy (docs §12): a signer makes this
        # builder able to verify (and mint) attestations; require_attestation
        # hard-fails any build that arrives without one — verified at plan
        # time, before a single fetch is scheduled.
        self.signer = signer
        self.require_attestation = require_attestation
        if require_attestation and signer is None:
            raise ValueError("require_attestation=True needs a signer")
        self.store = store if store is not None else ChunkedComponentStore()
        self.link_bandwidth_bps = link_bandwidth_bps
        self.plan_cache = BuildPlanCache() if plan_cache is None else plan_cache
        # fleet-wide compiled-executable index (opt-in: None disables the
        # cache and the compile stage behaves exactly as before)
        self.compile_cache = compile_cache
        # performance-portable split (docs §13, opt-in): compile as a
        # shared platform-neutral IR module plus a per-platform artifact
        # tail + autotune table, instead of one monolithic executable.
        # Off by default so every pre-§13 accounting identity holds.
        self.ir_components = ir_components
        self.build_graph = build_graph if build_graph is not None \
            else BuildGraph()
        self.fetch_engine = FetchEngine(self.store, service,
                                        max_workers=fetch_workers,
                                        simulate_bps=fetch_simulate_bps,
                                        peering=peering,
                                        transport=fetch_transport)
        # per-component readiness listeners the orchestrator wires into
        # every build's ComponentReadiness (e.g. a fleet node announcing
        # proven-present content to the PeerIndex)
        self.readiness_listeners: List[Callable[[UniformComponent], None]] = []

    # -- stage 1: resolve (or replay a cached plan) ---------------------
    def _stage_resolve(self, cir: CIR, spec: SpecSheet,
                       ctx0: Dict[str, Any],
                       overrides: Optional[Mapping[str, Any]],
                       report: BuildReport,
                       use_plan_cache: bool) -> Tuple[Resolution, BuildPlan]:
        t0 = time.perf_counter()
        resolution: Optional[Resolution] = None
        plan: Optional[BuildPlan] = None
        cache = self.plan_cache if use_plan_cache else None

        if cache is not None:
            key = cache.key(cir, spec, self.service.catalog_epoch, overrides)
            plan = cache.get(key)
            if plan is not None:
                try:
                    resolution = resolution_from_pins(
                        plan.pins, self.service, ctx0, plan.digests)
                    report.plan_cache_hit = True
                except (ResolutionError, RegistryError):
                    # catalog changed under an epoch collision — drop + redo
                    cache.drop(key)
                    plan = None

        if resolution is None:
            resolution = uniform_dependency_resolution(
                cir.deps, self.service, ctx0,
                cached_digests=self.store.digests(),
                link_bandwidth=self.link_bandwidth_bps / 8.0)
            report.restarts = resolution.restarts
            plan = BuildPlan(
                cir_digest=cir.digest(), spec_digest=spec.digest(),
                catalog_epoch=self.service.catalog_epoch,
                pins=resolution.pins(), digests=resolution.pin_digests())
            if cache is not None:
                # key at the *post-resolution* epoch: upstream pulls during
                # resolution register components and bump the epoch
                cache.put(cache.key(cir, spec, plan.catalog_epoch, overrides),
                          plan)

        report.resolve_s = time.perf_counter() - t0
        report.n_components = len(resolution.components)
        return resolution, plan

    # -- stage 2: fetch runs through self.fetch_engine, driven by the
    # BuildOrchestrator so readiness events stream into the stage gates --

    # -- stage 3: assemble ----------------------------------------------
    def _stage_assemble(self, cir: CIR, spec: SpecSheet,
                        bundle: ComponentBundle, mesh: Any,
                        report: BuildReport, assemble: bool
                        ) -> Tuple[Any, Dict[str, Callable]]:
        t0 = time.perf_counter()
        model, entry = (None, {})
        if assemble:
            model, entry = self._assemble(cir, spec, bundle, mesh)
        report.assemble_s = time.perf_counter() - t0
        return model, entry

    # -- stage 4: compile (stage step entrypoints for the mesh) ---------
    def _stage_compile(self, entry: Dict[str, Callable],
                       report: BuildReport,
                       inst: Optional[ContainerInstance] = None
                       ) -> Dict[str, Callable]:
        """Wrap the step entrypoints in ``jax.jit``, consulting the fleet
        compile cache.

        Compilation itself stays lazy (first call traces + compiles for the
        actual argument shapes — AOT lowering needs them), but the staged
        callables are what launchers hand straight to the mesh.

        When a ``CompileCache`` is wired and the build exposes its lockfile
        (``inst``), the stage derives the fleet-wide cache key and either
        restores the compiled executable — landing its content-addressed
        artifact component from peers through the ordinary chunk path, and
        counting the skipped compiles in ``report.compile_skips`` — or pays
        the (virtual) compile cost and publishes the artifact for every
        peer of the platform class.  Both paths satisfy the COMPILED
        lifecycle stage; the resolved-content byte accounting is identical
        hit-vs-miss (artifact bytes live in their own report columns).
        """
        t0 = time.perf_counter()
        import jax
        out = dict(entry)
        names = tuple(n for n in _STEP_ENTRIES if callable(out.get(n)))

        cache = self.compile_cache
        if cache is not None and inst is not None and names:
            key = compile_cache_key(inst.lock, inst.spec, names)
            inst.compile_key = key
            art = cache.get(key)
            if art is not None and self._ingest_artifact(art, report):
                report.compile_cache_hit = True
                report.compile_skips += len(names)
                cache.stats.compile_skips += len(names)
                if self.ir_components:
                    self._ingest_autotune(art, report)
            elif self.ir_components:
                # §13 split: the per-platform tail can only be lowered
                # from the shared IR module, so the compile is gated on
                # IR-readiness — fetch the module from the fleet or
                # derive it locally before the tail compile may start
                self._ensure_ir(inst.lock, names, report)
                self._model_compile_cost(
                    len(names), TAIL_COMPILE_VIRTUAL_S_PER_ENTRY)
                auto = autotune_component(key, inst.spec, names)
                art = CompiledArtifact(
                    key=key,
                    component=artifact_component(key, names, tail=True),
                    entry_names=names,
                    compile_s=TAIL_COMPILE_VIRTUAL_S_PER_ENTRY * len(names),
                    autotune=auto)
                self._publish_artifact(art, report)
                self._model_compile_cost(
                    len(names), AUTOTUNE_VIRTUAL_S_PER_ENTRY)
                report.autotune_bytes_published += self._commit_local(auto)
                cache.put(art)
            else:
                # miss (or no reachable copy of the bytes): pay the
                # platform compile, then publish the executable fleet-wide
                self._model_compile_cost(len(names))
                art = CompiledArtifact(
                    key=key, component=artifact_component(key, names),
                    entry_names=names,
                    compile_s=COMPILE_VIRTUAL_S_PER_ENTRY * len(names))
                self._publish_artifact(art, report)
                cache.put(art)
            if self.ir_components:
                report.ir_enabled = True
                # every platform-specific byte this build moved or made:
                # the tail executable plus its autotune table
                report.platform_tail_bytes = (
                    report.artifact_bytes_fetched
                    + report.artifact_bytes_published
                    + report.autotune_bytes_fetched
                    + report.autotune_bytes_published)

        for name in names:
            out[name] = jax.jit(out[name])
            report.n_compiled += 1
        report.compile_s = time.perf_counter() - t0
        return out

    def _model_compile_cost(self, n_entries: int,
                            s_per_entry: float =
                            COMPILE_VIRTUAL_S_PER_ENTRY) -> None:
        """Advance the virtual clock by the modeled XLA compile cost.

        Only the discrete-event transport observes it (wall-clock builds
        measure the real jit wall instead), so real deployments and legacy
        benchmarks are unaffected.
        """
        tr = self.fetch_engine.transport
        if isinstance(tr, SimTransport):
            tr.backoff(s_per_entry * n_entries)

    def _ingest_peer_component(self, comp: UniformComponent,
                               stripe_method: str = "fetch_artifact_stripe"
                               ) -> Optional[Tuple[int, int]]:
        """Land a derived component's bytes locally, *peers only*.

        The shared body of every derived-component ingest (compiled
        executables, §13 platform tails, IR modules, autotune tables):
        resident content is a free hit; missing chunks are sourced from
        linked peers only — derived components are born on fleet nodes,
        the upstream registry never stores them — through the same claim /
        commit / abort singleflight protocol as every other component.
        ``stripe_method`` names the ``NodePeering`` transfer so each kind
        lands in its own ``NodeTraffic`` columns.  Returns
        ``(wire_bytes, chunks)`` — ``(0, 0)`` for resident content — or
        ``None`` when no reachable copy exists.
        """
        store = self.store
        if not isinstance(store, ChunkedComponentStore):
            return (0, 0) if store.has(comp) else None
        if store.has(comp) and not store.missing_chunks(comp):
            return (0, 0)
        peering = self.fetch_engine.peering
        fetch = getattr(peering, stripe_method, None)
        plan = store.plan_fetch(comp)
        fetched = (0, 0)
        try:
            if plan.claimed:
                if fetch is None or not fetch(comp, plan.claimed):
                    store.abort_chunks(plan.claimed, component=comp)
                    store.mark_incomplete(comp)
                    return None
                store.commit_chunks(plan.claimed, component=comp)
                fetched = (sum(ch.size for ch, _ev in plan.claimed),
                           len(plan.claimed))
        except BaseException:
            store.abort_chunks(plan.claimed, component=comp)
            raise
        for ev in [ev for _ch, ev in plan.waits] + list(plan.barriers):
            ev.wait(CLAIM_WAIT_TIMEOUT_S)
        if store.missing_chunks(comp):
            store.mark_incomplete(comp)
            return None
        if peering is not None:
            peering.announce_chunks(store.chunks_of(comp))
        return fetched

    def _ingest_artifact(self, art: CompiledArtifact,
                         report: BuildReport) -> bool:
        """Land a cached executable's bytes locally; False means recompile.

        Artifact wire bytes land in ``report.artifact_bytes_fetched``,
        never in the resolved-content columns.  A §13 platform tail
        (``context["tail"]``) rides the tail stripe so ``NodeTraffic``
        can additionally prove the bytes were platform-specific.
        """
        comp = art.component
        method = "fetch_tail_stripe" if comp.context.get("tail") \
            else "fetch_artifact_stripe"
        res = self._ingest_peer_component(comp, method)
        if res is None:
            return False
        report.artifact_bytes_fetched += res[0]
        report.artifact_chunks_fetched += res[1]
        return True

    def _commit_local(self, comp: UniformComponent) -> int:
        """Store a locally-produced component (a local ingest: no wire
        bytes) and announce its chunks so peers can source it.  Returns
        the bytes committed."""
        store = self.store
        if not isinstance(store, ChunkedComponentStore):
            return comp.size_bytes if store.put(comp) else 0
        plan = store.plan_fetch(comp)
        nbytes = 0
        try:
            if plan.claimed:
                store.commit_chunks(plan.claimed, component=comp)
                nbytes = sum(ch.size for ch, _ev in plan.claimed)
        except BaseException:
            store.abort_chunks(plan.claimed, component=comp)
            raise
        peering = self.fetch_engine.peering
        if peering is not None:
            peering.announce_chunks(store.chunks_of(comp))
        return nbytes

    def _publish_artifact(self, art: CompiledArtifact,
                          report: BuildReport) -> None:
        """Store the locally-compiled executable and announce its chunks
        so peers can source it."""
        report.artifact_bytes_published += self._commit_local(art.component)

    def _ensure_ir(self, lock: Lockfile, entry_names: Sequence[str],
                   report: BuildReport) -> UniformComponent:
        """The §13 IR-readiness gate: land the shared IR module locally.

        Resident IR is a free hit; otherwise linked peers are tried first
        (the module is lowered once fleet-wide and only ever copied
        afterwards, riding ``NodePeering.fetch_ir_stripe``); only when no
        reachable copy exists does this node pay the lowering cost and
        publish the module for the rest of the fleet.  Shared-IR bytes
        land in ``report.ir_shared_bytes`` / ``ir_bytes_published``,
        never in the resolved-content columns.
        """
        comp = ir_module_component(lock, entry_names)
        res = self._ingest_peer_component(comp, "fetch_ir_stripe")
        if res is not None:
            report.ir_shared_bytes += comp.size_bytes
            return comp
        self._model_compile_cost(len(entry_names),
                                 IR_LOWER_VIRTUAL_S_PER_ENTRY)
        report.ir_bytes_published += self._commit_local(comp)
        return comp

    def _ingest_autotune(self, art: CompiledArtifact,
                         report: BuildReport) -> None:
        """Land the restored tail's Pallas autotune table (§13).

        Peer-first like the tail itself; when no peer still holds the
        table the node re-tunes locally (a small virtual cost — tables
        are cheap to regenerate, unlike compiles) and re-publishes.
        """
        auto = art.autotune
        if auto is None:
            return
        res = self._ingest_peer_component(auto, "fetch_tail_stripe")
        if res is not None:
            report.autotune_bytes_fetched += res[0]
            return
        self._model_compile_cost(len(art.entry_names),
                                 AUTOTUNE_VIRTUAL_S_PER_ENTRY)
        report.autotune_bytes_published += self._commit_local(auto)

    # -- trust & integrity (core/integrity.py, docs §12) ----------------
    def _check_attestation(self, cir: CIR, lock: Lockfile,
                           attestation: Optional[Attestation],
                           report: BuildReport) -> None:
        """The plan-time attestation gate: runs after the lock is known and
        BEFORE the orchestrator schedules any fetch.  Hard-fails
        (``AttestationError``) on a missing-but-required or invalid
        envelope; sets ``report.attestation_verified`` on success."""
        if attestation is None:
            if self.require_attestation:
                raise AttestationError(
                    f"builder requires a signed manifest but none was "
                    f"supplied for {cir.name}@{lock.platform_id} — "
                    f"refusing to schedule fetch")
            return
        if self.signer is None:
            raise AttestationError(
                "an attestation was supplied but this builder has no "
                "signer to verify it with")
        verify_attestation(cir, lock, attestation, self.signer)
        report.attestation_verified = True

    def attest(self, inst: ContainerInstance) -> Attestation:
        """Sign an instance's manifest (its CIR + per-platform lock) with
        this builder's signer — the pre-build side of the §12 handshake."""
        if self.signer is None:
            raise AttestationError("builder has no signer configured")
        return _sign_manifest(inst.cir, inst.lock, self.signer)

    def sbom(self, inst: ContainerInstance) -> Dict[str, Any]:
        """CycloneDX-shaped SBOM of the instance's resolved dependency
        closure (R-096), with chunk counts from this builder's store when
        it is chunk-addressed."""
        counts: Dict[str, int] = {}
        if isinstance(self.store, ChunkedComponentStore):
            for c in inst.bundle.components():
                counts[c.digest()] = len(self.store.chunks_of(c))
        return make_sbom(inst.cir, inst.lock, inst.bundle.resolution,
                         chunk_counts=counts)

    # ------------------------------------------------------------------
    def build(self, cir: CIR, spec: SpecSheet,
              mesh: Any = None,
              overrides: Optional[Mapping[str, Any]] = None,
              assemble: bool = True,
              compile_steps: bool = False,
              use_plan_cache: bool = True,
              overlap: bool = True,
              block: bool = True,
              attestation: Optional[Attestation] = None
              ) -> ContainerInstance:
        """Run the full pipeline: resolve, then orchestrated
        fetch / assemble / compile off per-component readiness.

        ``overlap=False`` runs the legacy barrier pipeline (each stage
        waits for the previous to fully finish) — accounting is identical,
        only wall-clock differs.  ``block=False`` returns the instance as
        soon as its components are pinned (stage PLANNED/FETCHING); callers
        observe progress through ``instance.wait(stage)``, which also
        re-raises any build error.
        """
        t0 = time.perf_counter()
        report = BuildReport(cir_name=cir.name, platform_id=spec.platform_id,
                             bytes_cir=cir.size_bytes())

        # inspect platform → building context
        ctx0 = spec.context()
        ctx0["entrypoint"] = cir.entrypoint
        if overrides:
            ctx0.update(overrides)

        resolution, plan = self._stage_resolve(cir, spec, ctx0, overrides,
                                               report, use_plan_cache)
        lock = Lockfile(
            cir_digest=cir.digest(), platform_id=spec.platform_id,
            seed=cir.seed, pins=plan.pins, digests=plan.digests)
        # plan-time gate: the attested manifest must match what resolution
        # just produced — a hard fail here means nothing was fetched
        self._check_attestation(cir, lock, attestation, report)
        bundle = ComponentBundle(resolution)
        inst = ContainerInstance(cir=cir, spec=spec, bundle=bundle,
                                 model=None, entry={}, lock=lock,
                                 report=report)
        BuildOrchestrator(self, self.build_graph).start(
            inst, resolution, mesh=mesh, assemble=assemble,
            compile_steps=compile_steps, t0=t0, record_build=True,
            overlap=overlap, block=block)
        return inst

    # ------------------------------------------------------------------
    def build_from_lock(self, cir: CIR, lock: Lockfile, spec: SpecSheet,
                        mesh: Any = None,
                        assemble: bool = True,
                        compile_steps: bool = False,
                        overlap: bool = True,
                        block: bool = True,
                        attestation: Optional[Attestation] = None
                        ) -> ContainerInstance:
        """CIR-locked rebuild: CQ-only (no VS/ES), deterministic and
        bit-identical (paper §3.3, §5.4 CIR-locked)."""
        if lock.cir_digest != cir.digest():
            raise ValueError("lockfile does not match this CIR")
        if lock.platform_id != spec.platform_id:
            # locks are per-platform (paper §4.2): replaying one platform's
            # pins under another's host context would silently merge
            # incompatible context contributions the resolver would reject
            raise ValueError(
                f"lockfile is for platform {lock.platform_id!r}, "
                f"not {spec.platform_id!r} — re-run a full lazy-build")
        report = BuildReport(cir_name=cir.name, platform_id=spec.platform_id,
                             bytes_cir=cir.size_bytes(), locked=True)
        # locked rebuilds verify the attested lock verbatim — still before
        # any fetch is scheduled
        self._check_attestation(cir, lock, attestation, report)
        t0 = time.perf_counter()
        try:
            res = resolution_from_pins(
                lock.pins, self.service,
                {**spec.context(), "entrypoint": cir.entrypoint},
                lock.digests)
        except ResolutionError as e:
            raise ValueError(str(e)) from e
        report.resolve_s = time.perf_counter() - t0
        report.n_components = len(res.components)

        bundle = ComponentBundle(res)
        inst = ContainerInstance(cir=cir, spec=spec, bundle=bundle,
                                 model=None, entry={}, lock=lock,
                                 report=report)
        # locked rebuilds never record a new build id (they replay one)
        BuildOrchestrator(self, self.build_graph).start(
            inst, res, mesh=mesh, assemble=assemble,
            compile_steps=compile_steps, t0=t0, record_build=False,
            overlap=overlap, block=block)
        return inst

    # ------------------------------------------------------------------
    def retry(self, inst: ContainerInstance,
              mesh: Any = None,
              assemble: bool = True,
              compile_steps: bool = False,
              overlap: bool = True,
              block: bool = True) -> ContainerInstance:
        """Re-drive a failed instance's build after a transient fault.

        The instance keeps its resolution, lockfile and report; the
        lifecycle is re-armed (``Lifecycle.reset_for_retry``) so a retry
        that succeeds no longer reports the stale ``failed_stage`` from the
        faulted attempt.  Chunks the first attempt landed are ordinary
        local hits for the retry.
        """
        if inst.lifecycle.error is None and inst.lifecycle.reached("complete"):
            return inst
        BuildOrchestrator(self, self.build_graph).start(
            inst, inst.bundle.resolution, mesh=mesh, assemble=assemble,
            compile_steps=compile_steps, record_build=not inst.report.locked,
            overlap=overlap, block=block)
        return inst

    # ------------------------------------------------------------------
    def _assemble(self, cir: CIR, spec: SpecSheet, bundle: ComponentBundle,
                  mesh: Any) -> Tuple[Any, Dict[str, Callable]]:
        """Uniform Component Assembler: the OverlayFS-mount analogue.

        The model-family component's payload composes the layer/kernel
        components; runtime components wrap the model into step functions.
        """
        cfg = cir.arch_config()
        # the model family is whichever 'model' manager component was selected
        model_comps = [c for c in bundle.components() if c.manager == "model"]
        if not model_comps:
            raise ValueError("no model family component resolved")
        family = model_comps[0]
        model = bundle.payload_of(family)(cfg, bundle.context, bundle)

        entry: Dict[str, Callable] = {}
        for c in bundle.components():
            if c.manager not in ("runtime", "data"):
                continue
            builder = bundle.payload_of(c)
            built = builder(model, cfg, bundle.context, bundle, mesh=mesh)
            if isinstance(built, Mapping):
                entry.update(built)
        return model, entry
