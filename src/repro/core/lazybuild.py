"""Lazy-builder: deployment-time resolution → fetch → assembly (paper §4.2).

The lazy-builder (1) inspects the target platform (specSheet), (2) resolves
the CIR's declarative direct dependencies to concrete uniform components
(Algorithms 1+2), (3) fetches missing components against the local store
(component-level *active sharing*), and (4) assembles them into a runnable
container instance — here, the composed model + step functions ready to be
``jit(...).lower(...).compile()``d for the target mesh, plus a version-lock
manifest for bit-identical rebuilds.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from .cir import CIR
from .component import DependencyItem, UniformComponent
from .registry import UniformComponentService
from .resolution import Resolution, uniform_dependency_resolution
from .spec import SpecSheet
from .store import LocalComponentStore

# Payload catalog: payload-reference -> python factory.  Populated by
# repro.core.catalog at import time (the 'converted component' bodies).
PAYLOADS: Dict[str, Callable] = {}


def register_payload(name: str):
    def deco(fn):
        if name in PAYLOADS and PAYLOADS[name] is not fn:
            raise ValueError(f"payload {name!r} already registered")
        PAYLOADS[name] = fn
        return fn
    return deco


class ComponentBundle:
    """The selected components of one build, addressable by (manager, name).

    Assembly code pulls concrete variants from here — this is how the model
    family finds *which* attention/kernel/plan variant Algorithm 1 picked.
    """

    def __init__(self, resolution: Resolution):
        self.resolution = resolution
        self._by_key = dict(resolution.selected_by_key)

    def component(self, manager: str, name: str) -> UniformComponent:
        return self._by_key[(manager, name)]

    def has(self, manager: str, name: str) -> bool:
        return (manager, name) in self._by_key

    def payload(self, manager: str, name: str) -> Callable:
        c = self.component(manager, name)
        try:
            return PAYLOADS[c.payload]
        except KeyError:
            raise KeyError(
                f"component {c.ident_str()} references unknown payload "
                f"{c.payload!r} — is repro.core.catalog imported?") from None

    def payload_of(self, c: UniformComponent) -> Callable:
        return PAYLOADS[c.payload]

    @property
    def context(self) -> Dict[str, Any]:
        return self.resolution.context

    def components(self) -> List[UniformComponent]:
        return list(self.resolution.components)


# ---------------------------------------------------------------------------
# Lockfile (paper §4.2: "a dedicated version locking file for each platform")
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Lockfile:
    cir_digest: str
    platform_id: str
    seed: int
    pins: Tuple[Tuple[str, str, str, str], ...]   # (M, n, v, e)
    digests: Tuple[str, ...]

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "Lockfile":
        d = json.loads(s)
        d["pins"] = tuple(tuple(p) for p in d["pins"])
        d["digests"] = tuple(d["digests"])
        return Lockfile(**d)

    def digest(self) -> str:
        return hashlib.sha256(self.to_json().encode()).hexdigest()


# ---------------------------------------------------------------------------
# Build report (feeds every benchmark)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BuildReport:
    cir_name: str
    platform_id: str
    resolve_s: float = 0.0
    fetch_s: float = 0.0            # compute time spent in fetch bookkeeping
    assemble_s: float = 0.0
    bytes_cir: int = 0
    bytes_fetched: int = 0          # network bytes for missing components
    bytes_total_components: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    n_components: int = 0
    restarts: int = 0
    locked: bool = False

    def network_time(self, bandwidth_bps: float) -> float:
        """Simulated link time: CIR pull + parallel component fetch."""
        return (self.bytes_cir + self.bytes_fetched) * 8.0 / bandwidth_bps

    def lazy_build_time(self, bandwidth_bps: float) -> float:
        # resolution overlaps fetch in the real system (paper §4.3 converters
        # split metadata from payload); assembly is strictly after.
        return max(self.resolve_s, self.network_time(bandwidth_bps)) \
            + self.fetch_s + self.assemble_s

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Container instance
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ContainerInstance:
    """The assembled, runnable unit.

    ``model`` is the family-assembled Model object (init/apply + sharding
    rules); ``entry`` holds the built entrypoint callables (train_step or
    prefill/decode) produced by the runtime components.  The launcher gives
    it a mesh to produce shardings, lower and compile.
    """
    cir: CIR
    spec: SpecSheet
    bundle: ComponentBundle
    model: Any
    entry: Dict[str, Callable]
    lock: Lockfile
    report: BuildReport

    @property
    def arch_id(self) -> str:
        return self.cir.name


class LazyBuilder:
    def __init__(self, service: UniformComponentService,
                 store: Optional[LocalComponentStore] = None,
                 link_bandwidth_bps: float = 500e6):
        self.service = service
        self.store = store or LocalComponentStore()
        self.link_bandwidth_bps = link_bandwidth_bps

    # ------------------------------------------------------------------
    def build(self, cir: CIR, spec: SpecSheet,
              mesh: Any = None,
              overrides: Optional[Mapping[str, Any]] = None,
              assemble: bool = True) -> ContainerInstance:
        """The lazy-build: resolve → fetch → assemble → lock."""
        report = BuildReport(cir_name=cir.name, platform_id=spec.platform_id,
                             bytes_cir=cir.size_bytes())

        # (1) inspect platform → building context
        ctx0 = spec.context()
        ctx0["entrypoint"] = cir.entrypoint
        if overrides:
            ctx0.update(overrides)

        # (2) resolve (Algorithms 1 + 2); cached digests feed deployability
        t0 = time.perf_counter()
        resolution = uniform_dependency_resolution(
            cir.deps, self.service, ctx0,
            cached_digests=self.store.digests(),
            link_bandwidth=self.link_bandwidth_bps / 8.0)
        report.resolve_s = time.perf_counter() - t0
        report.restarts = resolution.restarts
        report.n_components = len(resolution.components)

        # (3) fetch missing components — component-level active sharing
        t0 = time.perf_counter()
        for c in resolution.components:
            report.bytes_total_components += c.size_bytes
            if self.store.has(c):
                report.cache_hits += 1
                self.store.put(c)   # count the hit in store stats
            else:
                self.service.fetch(c)
                report.bytes_fetched += c.size_bytes
                report.cache_misses += 1
                self.store.put(c)
        self.store.record_build(f"{cir.name}@{spec.platform_id}",
                                resolution.components)
        report.fetch_s = time.perf_counter() - t0

        # (4) assemble: overlay components into model + entry steps
        bundle = ComponentBundle(resolution)
        t0 = time.perf_counter()
        model, entry = (None, {})
        if assemble:
            model, entry = self._assemble(cir, spec, bundle, mesh)
        report.assemble_s = time.perf_counter() - t0

        lock = Lockfile(
            cir_digest=cir.digest(), platform_id=spec.platform_id,
            seed=cir.seed,
            pins=tuple(c.ident() for c in resolution.components),
            digests=tuple(c.digest() for c in resolution.components))

        return ContainerInstance(cir=cir, spec=spec, bundle=bundle,
                                 model=model, entry=entry, lock=lock,
                                 report=report)

    # ------------------------------------------------------------------
    def build_from_lock(self, cir: CIR, lock: Lockfile, spec: SpecSheet,
                        mesh: Any = None,
                        assemble: bool = True) -> ContainerInstance:
        """CIR-locked rebuild: CQ-only (no VS/ES), deterministic and
        bit-identical (paper §3.3, §5.4 CIR-locked)."""
        if lock.cir_digest != cir.digest():
            raise ValueError("lockfile does not match this CIR")
        report = BuildReport(cir_name=cir.name, platform_id=spec.platform_id,
                             bytes_cir=cir.size_bytes(), locked=True)
        t0 = time.perf_counter()
        comps = [self.service.cq(*pin) for pin in lock.pins]
        for c, dg in zip(comps, lock.digests):
            if c.digest() != dg:
                raise ValueError(f"immutability violation for {c.ident_str()}")
        report.resolve_s = time.perf_counter() - t0
        report.n_components = len(comps)

        t0 = time.perf_counter()
        for c in comps:
            report.bytes_total_components += c.size_bytes
            if self.store.has(c):
                report.cache_hits += 1
            else:
                self.service.fetch(c)
                report.bytes_fetched += c.size_bytes
                report.cache_misses += 1
            self.store.put(c)
        report.fetch_s = time.perf_counter() - t0

        # Rebuild a Resolution facade for assembly
        res = Resolution(components=comps, context={**spec.context(),
                                                    "entrypoint": cir.entrypoint},
                         tree=None, restarts=0, learned={},
                         selected_by_key={(c.manager, c.name): c for c in comps})
        bundle = ComponentBundle(res)
        t0 = time.perf_counter()
        model, entry = (None, {})
        if assemble:
            model, entry = self._assemble(cir, spec, bundle, mesh)
        report.assemble_s = time.perf_counter() - t0
        return ContainerInstance(cir=cir, spec=spec, bundle=bundle,
                                 model=model, entry=entry, lock=lock,
                                 report=report)

    # ------------------------------------------------------------------
    def _assemble(self, cir: CIR, spec: SpecSheet, bundle: ComponentBundle,
                  mesh: Any) -> Tuple[Any, Dict[str, Callable]]:
        """Uniform Component Assembler: the OverlayFS-mount analogue.

        The model-family component's payload composes the layer/kernel
        components; runtime components wrap the model into step functions.
        """
        cfg = cir.arch_config()
        # the model family is whichever 'model' manager component was selected
        model_comps = [c for c in bundle.components() if c.manager == "model"]
        if not model_comps:
            raise ValueError("no model family component resolved")
        family = model_comps[0]
        model = bundle.payload_of(family)(cfg, bundle.context, bundle)

        entry: Dict[str, Callable] = {}
        for c in bundle.components():
            if c.manager not in ("runtime", "data"):
                continue
            builder = bundle.payload_of(c)
            built = builder(model, cfg, bundle.context, bundle, mesh=mesh)
            if isinstance(built, Mapping):
                entry.update(built)
        return model, entry
