"""Trust & integrity: signed manifest attestation + SBOM emission (§12).

Chunks are already content-addressed (their ids are length-prefixed sha256
piece digests, docs §5), but nothing attested the *manifest* that names
them: a tampered lockfile would happily drive a build of the wrong
content.  This module closes that gap:

  * **Canonical serialization** — ``canonical_manifest`` renders the
    ``(Lockfile, CIR digest)`` pair as deterministic bytes (sorted keys,
    no whitespace), so the same lock always signs to the same payload on
    every platform and Python version.
  * **Attestation envelope** — ``Attestation`` carries the payload digest,
    the signing algorithm + key id, and the signature.  ``attest`` signs
    at pre-build time (the control plane that resolved and locked the
    CIR); ``verify_attestation`` re-derives the canonical payload from the
    *local* lock and CIR and checks both digest and signature, so any
    tampering — pins, digests, seed, platform, CIR app — fails closed with
    ``AttestationError`` before a single fetch is scheduled
    (``LazyBuilder`` wires the check ahead of the orchestrator).
  * **Pluggable signers** — ``HMACSigner`` is the stdlib reference
    implementation (shared-secret fleets); ``Ed25519Signer`` provides
    asymmetric signatures when the optional ``cryptography`` package is
    present (``ED25519_AVAILABLE`` gates it — never a hard dependency).
  * **SBOM emission** — ``make_sbom`` renders the resolved dependency
    closure as CycloneDX-shaped JSON (one component record per resolved
    uniform component: manager/name/version/digest/chunk count), the
    R-096 acceptance bar for production container distribution.

Verify-on-receipt for peer transfers — the *transport* half of the trust
story — lives with the peering layer (``repro.deploy.topology``); this
module is pure control-plane: no store, no network, no threads.
"""
from __future__ import annotations

import dataclasses
import hashlib
import hmac
import json
from typing import Any, Dict, List, Optional, Protocol, TYPE_CHECKING

if TYPE_CHECKING:                                    # pragma: no cover
    from .cir import CIR
    from .lazybuild import Lockfile
    from .resolution import Resolution

# Envelope format version: bumped if the canonical payload layout changes
# (a verifier must never accept a payload it would canonicalize differently
# than the signer did).
ATTESTATION_VERSION = 1

# Optional asymmetric backend.  The container does not bake `cryptography`
# in, so ed25519 is strictly additive: available where the host provides
# it, cleanly reported absent everywhere else.
try:                                                  # pragma: no cover
    from cryptography.hazmat.primitives.asymmetric.ed25519 import (
        Ed25519PrivateKey)
    ED25519_AVAILABLE = True
except Exception:                                     # pragma: no cover
    Ed25519PrivateKey = None
    ED25519_AVAILABLE = False


class AttestationError(RuntimeError):
    """Attestation missing, malformed, or failing verification — the hard
    failure of the plan-time gate: the build must not schedule a fetch."""


# ---------------------------------------------------------------------------
# Canonical serialization
# ---------------------------------------------------------------------------

def canonical_manifest(cir: "CIR", lock: "Lockfile") -> bytes:
    """Deterministic signing payload for ``(lock, CIR digest)``.

    Sorted keys, compact separators, explicit version tag: byte-identical
    across processes and platforms for the same logical content.  The CIR
    digest is carried twice on purpose — inside the lock (as recorded at
    resolution time) and alongside it (re-derived here from the actual CIR
    object) — so a lock grafted onto a different CIR canonicalizes
    differently and fails the digest check.
    """
    return json.dumps({
        "version": ATTESTATION_VERSION,
        "cir_digest": cir.digest(),
        "lockfile": json.loads(lock.to_json()),
    }, sort_keys=True, separators=(",", ":")).encode()


def manifest_digest(cir: "CIR", lock: "Lockfile") -> str:
    """sha256 of the canonical manifest payload (hex)."""
    return hashlib.sha256(canonical_manifest(cir, lock)).hexdigest()


# ---------------------------------------------------------------------------
# Signers
# ---------------------------------------------------------------------------

class Signer(Protocol):
    """Pluggable signature backend: anything with an algorithm name, a key
    id, ``sign(payload) -> hex`` and ``verify(payload, hex) -> bool``."""
    algorithm: str
    key_id: str

    def sign(self, payload: bytes) -> str: ...      # pragma: no cover

    def verify(self, payload: bytes, signature: str
               ) -> bool: ...                        # pragma: no cover


class HMACSigner:
    """Reference signer: HMAC-SHA256 over a fleet shared secret (stdlib
    only).  Symmetric — every verifier can also sign — which is the right
    trust model for a single-operator fleet; use ``Ed25519Signer`` when
    verifiers must not be able to mint attestations."""

    algorithm = "hmac-sha256"

    def __init__(self, secret: bytes, key_id: str = "fleet-hmac"):
        if not secret:
            raise ValueError("HMACSigner needs a non-empty secret")
        self._secret = bytes(secret)
        self.key_id = key_id

    def sign(self, payload: bytes) -> str:
        return hmac.new(self._secret, payload, hashlib.sha256).hexdigest()

    def verify(self, payload: bytes, signature: str) -> bool:
        try:
            return hmac.compare_digest(self.sign(payload), signature)
        except (TypeError, ValueError):
            return False


class Ed25519Signer:
    """Asymmetric signer over the optional ``cryptography`` backend.

    Constructing one when the backend is absent raises ``RuntimeError`` —
    callers gate on ``ED25519_AVAILABLE`` (the repo never hard-depends on
    the package; ``HMACSigner`` is always available).
    """

    algorithm = "ed25519"

    def __init__(self, private_key: Any = None, key_id: str = "fleet-ed25519"):
        if not ED25519_AVAILABLE:
            raise RuntimeError(
                "ed25519 signing needs the optional 'cryptography' package "
                "(not installed) — use HMACSigner, the stdlib reference "
                "implementation")
        self._key = private_key if private_key is not None \
            else Ed25519PrivateKey.generate()
        self._pub = self._key.public_key()
        self.key_id = key_id

    def sign(self, payload: bytes) -> str:
        return self._key.sign(payload).hex()

    def verify(self, payload: bytes, signature: str) -> bool:
        try:
            self._pub.verify(bytes.fromhex(signature), payload)
            return True
        except Exception:  # noqa: BLE001 — any backend error == invalid
            return False


# ---------------------------------------------------------------------------
# Attestation envelope
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Attestation:
    """The signature envelope shipped alongside a lockfile (docs §12).

    ``payload_digest`` is the sha256 of the canonical manifest bytes —
    recorded so a verifier can tell *tampered content* (digest mismatch)
    apart from *forged signature* (digest ok, signature bad) in its error.
    """
    payload_digest: str
    algorithm: str
    key_id: str
    signature: str
    version: int = ATTESTATION_VERSION

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "Attestation":
        try:
            return Attestation(**json.loads(s))
        except (ValueError, TypeError, KeyError) as e:
            raise AttestationError(f"malformed attestation envelope: {e}") \
                from e


def attest(cir: "CIR", lock: "Lockfile", signer: Signer) -> Attestation:
    """Sign the canonical ``(lock, CIR digest)`` payload — the pre-build
    side: whoever resolved and locked the CIR mints the envelope."""
    payload = canonical_manifest(cir, lock)
    return Attestation(
        payload_digest=hashlib.sha256(payload).hexdigest(),
        algorithm=signer.algorithm,
        key_id=signer.key_id,
        signature=signer.sign(payload),
    )


def verify_attestation(cir: "CIR", lock: "Lockfile",
                       attestation: Attestation, signer: Signer) -> None:
    """Plan-time verification: re-derive the canonical payload from the
    *local* CIR + lock and check it against the envelope.  Raises
    ``AttestationError`` on any mismatch; returning means the lock the
    build is about to fetch against is exactly the one that was signed."""
    if attestation.version != ATTESTATION_VERSION:
        raise AttestationError(
            f"attestation version {attestation.version} != "
            f"{ATTESTATION_VERSION} — refusing to canonicalize differently "
            f"than the signer did")
    if attestation.algorithm != signer.algorithm:
        raise AttestationError(
            f"attestation algorithm {attestation.algorithm!r} does not "
            f"match the verifier's {signer.algorithm!r}")
    payload = canonical_manifest(cir, lock)
    digest = hashlib.sha256(payload).hexdigest()
    if digest != attestation.payload_digest:
        raise AttestationError(
            f"manifest digest mismatch: the lockfile/CIR differ from what "
            f"was signed (got {digest[:16]}…, attested "
            f"{attestation.payload_digest[:16]}…)")
    if not signer.verify(payload, attestation.signature):
        raise AttestationError(
            f"signature verification failed for key {attestation.key_id!r} "
            f"({attestation.algorithm})")


# ---------------------------------------------------------------------------
# SBOM (CycloneDX-shaped, R-096)
# ---------------------------------------------------------------------------

SBOM_FORMAT = "CycloneDX"
SBOM_SPEC_VERSION = "1.5"


def make_sbom(cir: "CIR", lock: "Lockfile", resolution: "Resolution",
              chunk_counts: Optional[Dict[str, int]] = None
              ) -> Dict[str, Any]:
    """Render the resolved dependency closure as a CycloneDX-shaped SBOM.

    One component record per resolved uniform component — manager as the
    group, content digest as both ``bom-ref`` and SHA-256 hash, chunk
    count and wire size as ``cir:`` properties — plus the application
    itself (the CIR) as the metadata component.  ``chunk_counts`` maps
    component digest -> chunk count (the builder supplies it from its
    chunk store); absent entries fall back to 0 chunks (component-
    granularity stores have no chunk layer).

    Deterministic: records are canonically sorted and carry no wall-clock
    timestamp, so the same lock always emits byte-identical JSON — an SBOM
    diff is a content diff.
    """
    counts = chunk_counts or {}
    components: List[Dict[str, Any]] = []
    for rec in resolution.component_records():
        components.append({
            "type": "library",
            "group": rec["manager"],
            "name": rec["name"],
            "version": rec["version"],
            "bom-ref": rec["digest"],
            "purl": f"pkg:cir/{rec['manager']}/{rec['name']}"
                    f"@{rec['version']}",
            "hashes": [{"alg": "SHA-256", "content": rec["digest"]}],
            "properties": [
                {"name": "cir:env", "value": rec["env"]},
                {"name": "cir:sizeBytes", "value": str(rec["size_bytes"])},
                {"name": "cir:chunkCount",
                 "value": str(counts.get(rec["digest"], 0))},
            ],
        })
    return {
        "bomFormat": SBOM_FORMAT,
        "specVersion": SBOM_SPEC_VERSION,
        "version": 1,
        "serialNumber": f"urn:cir:lock:{lock.digest()}",
        "metadata": {
            "component": {
                "type": "application",
                "name": cir.name,
                "version": cir.version,
                "bom-ref": cir.digest(),
                "purl": f"pkg:cir/{cir.name}@{cir.version}",
                "hashes": [{"alg": "SHA-256", "content": cir.digest()}],
            },
            "properties": [
                {"name": "cir:platform", "value": lock.platform_id},
                {"name": "cir:lockDigest", "value": lock.digest()},
                {"name": "cir:seed", "value": str(lock.seed)},
            ],
        },
        "components": components,
    }


def write_sbom(path: str, sbom: Dict[str, Any]) -> str:
    """Write an SBOM document as indented JSON; returns ``path``."""
    with open(path, "w") as f:
        json.dump(sbom, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
