"""CIR — the Container Intermediate Representation — and its pre-builder.

A CIR stores the *application* (the architecture config + entrypoint) and the
*identifiers of its direct dependencies* only (paper §4.1).  Everything
platform-specific (kernels, sharding plans, compiled steps, materialized
weights) is resolved by the lazy-builder at deployment time.
"""
from __future__ import annotations

import dataclasses
import gzip
import hashlib
import io
import json
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence, Set, Tuple

from ..configs.base import ArchConfig, FAMILY_MODEL_COMPONENT
from .component import DependencyItem
from .registry import UniformComponentService


@dataclasses.dataclass
class CIR:
    name: str
    version: str
    deps: Tuple[DependencyItem, ...]
    app: Dict[str, Any]                    # the cross-platform application
    entrypoint: str = "train"              # train | serve
    workdir: str = "/app"
    locals: Tuple[Tuple[str, str], ...] = ()   # (path, asset-name) pairs
    seed: int = 0                          # init RNG seed (weights are lazy!)
    created: float = 0.0

    # -- serialization: the on-wire image -----------------------------------
    def to_text(self) -> str:
        """Human-readable manifest in the paper's §4.1 style."""
        lines = [f"[NAME] {self.name}", f"[VERSION] {self.version}",
                 "[DEPENDENCY]"]
        for d in self.deps:
            lines.append(f"- [{d.manager}] {d.name} [{d.specifier}]")
        for path, asset in self.locals:
            lines.append(f"- [LOCAL] {path} [{asset}]")
        lines.append(f"[ENTRYPOINT] {self.entrypoint}")
        lines.append(f"[WORKDIR] {self.workdir}")
        lines.append(f"[SEED] {self.seed}")
        return "\n".join(lines)

    def to_bytes(self) -> bytes:
        """The actual image bytes: gz(manifest + app payload)."""
        payload = json.dumps({
            "manifest": self.to_text(),
            "app": self.app,
            "created": self.created,
        }, sort_keys=True).encode()
        buf = io.BytesIO()
        # mtime=0 → deterministic bytes (immutability / digest stability)
        with gzip.GzipFile(fileobj=buf, mode="wb", mtime=0) as f:
            f.write(payload)
        return buf.getvalue()

    @staticmethod
    def from_bytes(b: bytes) -> "CIR":
        payload = json.loads(gzip.decompress(b).decode())
        return _parse(payload)

    def size_bytes(self) -> int:
        return len(self.to_bytes())

    def signing_payload(self) -> bytes:
        """Canonical identity bytes: manifest text + app payload, sorted
        keys, ``created`` deliberately excluded so two pre-builds of the
        same application produce the same bytes (digest stability rule,
        see docs/cir-format.md §12).  This is both what ``digest()``
        hashes and what manifest attestation ultimately covers."""
        return json.dumps({"manifest": self.to_text(), "app": self.app},
                          sort_keys=True).encode()

    def digest(self) -> str:
        """Content digest — the identity cache keys are built from.

        Hashes ``signing_payload()`` only; the on-wire bytes remain
        deterministic too (mtime=0 gzip), but they carry ``created`` and
        so are not the identity.
        """
        return hashlib.sha256(self.signing_payload()).hexdigest()

    def arch_config(self) -> ArchConfig:
        return ArchConfig.from_json(self.app["config"])


def _parse(payload: Mapping[str, Any]) -> CIR:
    deps: List[DependencyItem] = []
    locals_: List[Tuple[str, str]] = []
    name = version = entry = workdir = ""
    seed = 0
    for line in payload["manifest"].splitlines():
        line = line.strip()
        if line.startswith("[NAME]"):
            name = line.split("]", 1)[1].strip()
        elif line.startswith("[VERSION]"):
            version = line.split("]", 1)[1].strip()
        elif line.startswith("[ENTRYPOINT]"):
            entry = line.split("]", 1)[1].strip()
        elif line.startswith("[WORKDIR]"):
            workdir = line.split("]", 1)[1].strip()
        elif line.startswith("[SEED]"):
            seed = int(line.split("]", 1)[1].strip())
        elif line.startswith("- [LOCAL]"):
            body = line[len("- [LOCAL]"):].strip()
            path, asset = body.rsplit(" [", 1)
            locals_.append((path.strip(), asset.rstrip("]")))
        elif line.startswith("- ["):
            mgr = line[3:line.index("]")]
            rest = line[line.index("]") + 1:].strip()
            n, spec = rest.rsplit(" [", 1)
            deps.append(DependencyItem(mgr, n.strip(), spec.rstrip("]")))
    return CIR(name=name, version=version, deps=tuple(deps),
               app=dict(payload["app"]), entrypoint=entry, workdir=workdir,
               locals=tuple(locals_), seed=seed,
               created=payload.get("created", 0.0))


# ---------------------------------------------------------------------------
# Pre-builder
# ---------------------------------------------------------------------------

class PreBuilder:
    """Development-platform side (paper §4.1).

    Dependency analysis = the arch config's family implies a model component;
    the entrypoint implies a runtime component; declared extra deps are taken
    as-is.  The pre-builder then *filters indirect dependencies*: any declared
    dep that is reachable from another declared dep's transitive closure in
    the registry is dropped (the lazy-builder will re-derive it for the
    actual target platform, possibly differently).
    """

    def __init__(self, service: Optional[UniformComponentService] = None):
        self.service = service

    def analyze(self, cfg: ArchConfig, entrypoint: str = "train",
                with_weights: bool = False) -> List[DependencyItem]:
        deps: List[DependencyItem] = [
            DependencyItem("model", FAMILY_MODEL_COMPONENT[cfg.family], "~=1.0"),
            DependencyItem("runtime",
                           "train-step" if entrypoint == "train" else "serve-step",
                           "any"),
            DependencyItem("data", "pipeline-synthetic", "any")
            if entrypoint == "train" else
            DependencyItem("runtime", "request-batcher", "any"),
        ]
        if with_weights:
            deps.append(DependencyItem("asset", f"weights-{cfg.arch_id}", "latest"))
        if cfg.frontend:
            deps.append(DependencyItem("asset", f"frontend-{cfg.frontend}", "any"))
        for m, n, s in cfg.extra_deps:
            deps.append(DependencyItem(m, n, s))
        return deps

    def filter_indirect(self, deps: Sequence[DependencyItem]
                        ) -> List[DependencyItem]:
        if self.service is None:
            return list(deps)
        # transitive closure of each dep's *metadata* dependencies
        reach: Set[Tuple[str, str]] = set()
        for d in deps:
            reach |= self._closure_of(d, depth=0)
        out: List[DependencyItem] = []
        for d in deps:
            if d.key() in reach:
                continue  # indirect: some other declared dep already implies it
            out.append(d)
        return out

    def _closure_of(self, d: DependencyItem, depth: int,
                    max_depth: int = 12) -> Set[Tuple[str, str]]:
        if depth > max_depth:
            return set()
        out: Set[Tuple[str, str]] = set()
        try:
            versions = self.service.vq(d.manager, d.name)
        except Exception:
            return out
        for v in versions[-1:]:   # newest version's metadata is representative
            for c in self.service.candidates(d.manager, d.name, v):
                for sub in c.deps:
                    if sub.key() not in out:
                        out.add(sub.key())
                        out |= self._closure_of(sub, depth + 1, max_depth)
        return out

    def prebuild(self, cfg: ArchConfig, entrypoint: str = "train",
                 version: str = "1.0", seed: int = 0,
                 with_weights: Optional[bool] = None) -> CIR:
        if with_weights is None:
            with_weights = (entrypoint == "serve")
        deps = self.analyze(cfg, entrypoint, with_weights)
        deps = self.filter_indirect(deps)
        locals_: Tuple[Tuple[str, str], ...] = ()
        if with_weights:
            locals_ = ((f"/{cfg.arch_id}", f"weights-{cfg.arch_id}"),)
        return CIR(
            name=cfg.arch_id, version=version, deps=tuple(deps),
            app={"config": cfg.to_json(), "kind": "arch-config"},
            entrypoint=entrypoint, workdir=f"/{cfg.arch_id}",
            locals=locals_, seed=seed, created=time.time(),
        )
